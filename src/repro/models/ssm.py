"""Mamba2 (SSD — state-space duality) blocks, TPU-adapted.

Chunked SSD algorithm (arXiv:2405.21060 §6): the sequence is split into
Q-length chunks; intra-chunk interactions use the quadratic (attention-
like) form, inter-chunk information flows through the [N x hd] state via a
short lax.scan over chunks.  Everything is batched over heads.

Sharding: d_inner (and so SSD heads) over the model axis; B/C projections
are per-group (G small) and replicated; the scan itself is local per head
— there is no cross-rank weight block, which is why the paper's phantom
factorization applies only to the in/out projections here
(DESIGN.md §Arch-applicability).

Simplification noted in DESIGN.md: the short causal conv is applied to x
only (not the BC streams).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import PHANTOM_KINDS
from repro.models.layers import from_partial, to_full
from repro.parallel.axes import MeshAxes
from repro.parallel.params import ParamDecl
from repro.parallel.strategies import site_strategy


def ssm_dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    return d_inner, H, s.d_state, s.head_dim


def ssm_site_strategies(cfg, axes: MeshAxes):
    """Strategies for the in (z/x) and out projections.  Phantom only
    applies when the sharded dims divide the model axis (the legacy
    ``apply_attn_proj`` guard, now per site)."""
    d = cfg.d_model
    d_inner = cfg.ssm.expand * d
    p = axes.tp
    ok = d_inner % p == 0 and d % p == 0
    mk = lambda site, ni, no: site_strategy(
        cfg, site, ni, no, p, dp=axes.dp, bias=False, fsdp=cfg.fsdp,
        allow_phantom=ok)
    return {"in": mk("ssm_in", d, d_inner),
            "out": mk("ssm_out", d_inner, d)}


# ---------------------------------------------------------------------------
# declarations
# ---------------------------------------------------------------------------

def ssm_decls(cfg, axes: MeshAxes):
    d = cfg.d_model
    d_inner, H, N, hd = ssm_dims(cfg)
    p = axes.tp
    s = cfg.ssm
    sts = ssm_site_strategies(cfg, axes)
    assert H % p == 0, (H, p)
    return {
        "wz": sts["in"].decls(),
        "wx": sts["in"].decls(),
        "wbc": {"w": ParamDecl((d, 2 * s.ngroups * N), P(),
                               scale=d ** -0.5)},           # replicated
        "wdt": {"w": ParamDecl((d, H), P(None, "tp"), scale=d ** -0.5),
                "b": ParamDecl((H,), P("tp"), init="zeros")},
        "out": sts["out"].decls(),
        "A_log": ParamDecl((H,), P("tp"), init="zeros"),
        "Dskip": ParamDecl((H,), P("tp"), init="ones"),
        "conv_w": ParamDecl((s.conv_width, d_inner), P(None, "tp"),
                            scale=s.conv_width ** -0.5),
        "norm_scale": ParamDecl((d_inner,), P("tp"), init="ones"),
    }


def ssm_cache_shape(cfg, axes: MeshAxes, batch: int):
    """Decode state: conv rolling buffer + SSD state (local shapes have
    tp-sharded dims; global shapes given here)."""
    d_inner, H, N, hd = ssm_dims(cfg)
    return {
        "conv": ((batch, cfg.ssm.conv_width - 1, d_inner),
                 P("dp", None, "tp")),
        "ssm": ((batch, H, hd, N), P("dp", "tp", None, None)),
    }


# ---------------------------------------------------------------------------
# chunked SSD (train / prefill)
# ---------------------------------------------------------------------------

def _pick_chunk(S: int, chunk: int) -> int:
    """Largest divisor of S that is <= chunk (ragged prompt lengths)."""
    q = min(chunk, S)
    while S % q:
        q -= 1
    return q


def _ssd_chunked(x, dt, A, Bm, Cm, chunk: int, initial_state=None):
    """x [B,S,H,hd]; dt [B,S,H] (>0); A [H] (<0); Bm/Cm [B,S,N].
    Returns (y [B,S,H,hd], final_state [B,H,hd,N])."""
    Bsz, S, H, hd = x.shape
    N = Bm.shape[-1]
    Q = _pick_chunk(S, chunk)
    nc = S // Q

    xr = x.reshape(Bsz, nc, Q, H, hd)
    dtr = dt.reshape(Bsz, nc, Q, H)
    Br = Bm.reshape(Bsz, nc, Q, N)
    Cr = Cm.reshape(Bsz, nc, Q, N)

    dA = dtr * A[None, None, None, :]                     # [B,nc,Q,H] (<0)
    cum = jnp.cumsum(dA, axis=2)                          # inclusive
    # intra-chunk: scores[i,j] = C_i.B_j * exp(cum_i - cum_j) * dt_j, i>=j
    CB = jnp.einsum("bnim,bnjm->bnij", Cr, Br)            # [B,nc,Q,Q]
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,Q,Q,H]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = CB[..., None] * decay * dtr[:, :, None, :, :]  # [B,nc,i,j,H]
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", scores, xr)

    # chunk-local end states: sum_j exp(cum_Q - cum_j) dt_j  B_j (x) x_j
    w_end = jnp.exp(cum[:, :, -1:, :] - cum) * dtr        # [B,nc,Q,H]
    states = jnp.einsum("bnjh,bnjm,bnjhp->bnhpm", w_end, Br, xr)

    # inter-chunk recurrence over nc chunks
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))            # [B,nc,H]
    s0 = (initial_state if initial_state is not None
          else jnp.zeros((Bsz, H, hd, N), jnp.float32))

    def step(s_prev, inp):
        dec, st = inp                                      # [B,H], [B,H,hd,N]
        s_new = s_prev * dec[:, :, None, None] + st
        return s_new, s_prev                               # emit state BEFORE

    sc = jnp.moveaxis(chunk_decay, 1, 0)                   # [nc,B,H]
    st = jnp.moveaxis(states, 1, 0)                        # [nc,B,H,hd,N]
    final_state, prev_states = lax.scan(step, s0, (sc, st))
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # [B,nc,H,hd,N]

    # y_inter[i] = exp(cum_i) * C_i . S_prev
    y_inter = jnp.einsum("bnim,bnhpm,bnih->bnihp",
                         Cr, prev_states, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(Bsz, S, H, hd)
    return y, final_state


def _ssd_decode_step(state, x, dt, A, Bm, Cm):
    """One-token SSD update.  state [B,H,hd,N]; x [B,H,hd]; dt [B,H];
    Bm/Cm [B,N] -> (y [B,H,hd], new_state)."""
    dA = jnp.exp(dt * A[None, :])                          # [B,H]
    dBx = jnp.einsum("bh,bm,bhp->bhpm", dt, Bm, x)
    s_new = state * dA[:, :, None, None] + dBx
    y = jnp.einsum("bm,bhpm->bhp", Cm, s_new)
    return y, s_new


# ---------------------------------------------------------------------------
# full block apply
# ---------------------------------------------------------------------------

def _in_projs(cfg, params, xin, axes, dtype, st_in):
    if st_in.kind in PHANTOM_KINDS:
        z = st_in.apply(params["wz"], xin, axes=axes, compute_dtype=dtype)
        xs = st_in.apply(params["wx"], xin, axes=axes, compute_dtype=dtype)
    else:
        z = st_in.apply(params["wz"], xin, compute_dtype=dtype)
        xs = st_in.apply(params["wx"], xin, compute_dtype=dtype)
    return z, xs


def ssm_apply(cfg, layout: str, params, x, axes: MeshAxes, decls=None, *,
              kind: str = "train", cache=None):
    """x: residual shard -> (residual shard, new_cache|None)."""
    d_inner, H, N, hd = ssm_dims(cfg)
    p = axes.tp
    dtype = jnp.dtype(cfg.dtype)
    H_loc, dinner_loc = H // p, d_inner // p
    sts = ssm_site_strategies(cfg, axes)
    phantom_in = sts["in"].kind in PHANTOM_KINDS
    s = cfg.ssm

    from repro.models.layers import gather_tree_fsdp
    if cfg.fsdp:
        params = gather_tree_fsdp(params, decls, axes,
                                  quant=cfg.fsdp_gather_quant)
    if kind == "decode":
        return _ssm_decode(cfg, layout, params, x, axes, cache=cache)

    # --- input projections -------------------------------------------------
    if phantom_in:
        xin = x                                            # fp shard
        full_for_small = to_full(x, layout, axes)          # [B,S,d] for bc/dt
    else:
        xin = to_full(x, layout, axes)
        full_for_small = xin
    z, xs = _in_projs(cfg, params, xin, axes, dtype, sts["in"])
    Bsz, S = full_for_small.shape[0], full_for_small.shape[1]
    xs = xs.reshape(Bsz, S, dinner_loc)
    z = z.reshape(Bsz, S, dinner_loc)

    bc = jnp.einsum("bsd,dn->bsn", full_for_small.astype(dtype),
                    params["wbc"]["w"].astype(dtype))
    Bm, Cm = jnp.split(bc.astype(jnp.float32), 2, axis=-1)  # [B,S,N] (G=1)
    dt_raw = jnp.einsum("bsd,dh->bsh", full_for_small.astype(dtype),
                        params["wdt"]["w"].astype(dtype))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["wdt"]["b"].astype(jnp.float32))

    # --- short causal conv on x (local channels) ----------------------------
    conv_w = params["conv_w"]                               # [cw, din_loc]
    xpad = jnp.pad(xs, ((0, 0), (s.conv_width - 1, 0), (0, 0)))
    xc = sum(xpad[:, i:i + S] * conv_w[i][None, None, :]
             for i in range(s.conv_width))
    xc = jax.nn.silu(xc.astype(jnp.float32))

    # --- SSD ---------------------------------------------------------------
    A = -jnp.exp(params["A_log"].astype(jnp.float32))       # [H_loc]
    xh = xc.reshape(Bsz, S, H_loc, hd)
    y, final_state = _ssd_chunked(xh, dt, A, Bm, Cm, s.chunk)
    y = y + params["Dskip"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(Bsz, S, dinner_loc)

    # --- gate + (local-channel) RMSNorm + out projection --------------------
    y = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(y), -1, keepdims=True)
    ms = lax.psum(ms, axes.tp_name) / p
    y = (y * lax.rsqrt(ms + cfg.norm_eps)
         * params["norm_scale"].astype(jnp.float32)).astype(dtype)

    if sts["out"].kind in PHANTOM_KINDS:
        res = sts["out"].apply(params["out"], y, axes=axes,
                               compute_dtype=dtype)
    else:
        zp = sts["out"].apply(params["out"], y, compute_dtype=dtype)
        res = from_partial(zp, layout, axes)

    new_cache = None
    if kind == "prefill":
        conv_state = xs[:, S - (s.conv_width - 1):, :]     # raw pre-conv x
        new_cache = {"conv": conv_state.astype(dtype),
                     "ssm": final_state.astype(jnp.float32)}
    return res, new_cache


def _ssm_decode(cfg, layout, params, x, axes, *, cache):
    d_inner, H, N, hd = ssm_dims(cfg)
    p = axes.tp
    dtype = jnp.dtype(cfg.dtype)
    H_loc, dinner_loc = H // p, d_inner // p
    sts = ssm_site_strategies(cfg, axes)
    phantom_in = sts["in"].kind in PHANTOM_KINDS
    s = cfg.ssm

    x_full = to_full(x, layout, axes)                      # [B,1,d]
    xin = x if phantom_in else x_full
    z, xs = _in_projs(cfg, params, xin, axes, dtype, sts["in"])
    Bsz = x_full.shape[0]
    xs = xs.reshape(Bsz, dinner_loc)
    z = z.reshape(Bsz, dinner_loc)

    bc = jnp.einsum("bd,dn->bn", x_full[:, 0].astype(dtype),
                    params["wbc"]["w"].astype(dtype))
    Bm, Cm = jnp.split(bc.astype(jnp.float32), 2, axis=-1)
    dt_raw = jnp.einsum("bd,dh->bh", x_full[:, 0].astype(dtype),
                        params["wdt"]["w"].astype(dtype))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["wdt"]["b"].astype(jnp.float32))

    # conv with rolling state
    conv_hist = jnp.concatenate([cache["conv"].astype(dtype),
                                 xs[:, None, :]], axis=1)  # [B,cw,din]
    conv_w = params["conv_w"]
    xc = jnp.sum(conv_hist * conv_w[None, :, :], axis=1)
    xc = jax.nn.silu(xc.astype(jnp.float32))
    new_conv = conv_hist[:, 1:, :]

    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xc.reshape(Bsz, H_loc, hd)
    y, new_state = _ssd_decode_step(cache["ssm"], xh, dt, A, Bm, Cm)
    y = y + params["Dskip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(Bsz, dinner_loc)

    y = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(y), -1, keepdims=True)
    ms = lax.psum(ms, axes.tp_name) / p
    y = (y * lax.rsqrt(ms + cfg.norm_eps)
         * params["norm_scale"].astype(jnp.float32)).astype(dtype)
    y = y[:, None, :]                                      # [B,1,din_loc]

    if sts["out"].kind in PHANTOM_KINDS:
        res = sts["out"].apply(params["out"], y, axes=axes,
                               compute_dtype=dtype)
    else:
        zp = sts["out"].apply(params["out"], y, compute_dtype=dtype)
        res = from_partial(zp, layout, axes)
    return res, {"conv": new_conv.astype(dtype),
                 "ssm": new_state.astype(cache["ssm"].dtype)}
