"""Shared layers: norms (layout-aware), embeddings, MLPs (dense-TP and
phantom), logit head with sharded+chunked cross-entropy.

Residual-stream layouts (DESIGN.md §6) — all code here runs inside
``shard_map`` and sees local shards:

  * ``sp``  — sequence-parallel  [B_loc, S/p, d]   (dense TP baseline)
  * ``fp``  — feature-parallel   [B_loc, S, d/p]   (phantom: activations
              stay feature-sharded end-to-end, the paper's layout)
  * ``rep`` — replicated         [B_loc, S, d]     (dense decode)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import PHANTOM_KINDS
from repro.core import tp as tpmod
from repro.parallel.axes import MeshAxes
from repro.parallel.params import ParamDecl
from repro.parallel.strategies import site_strategy


# ---------------------------------------------------------------------------
# layout helpers
# ---------------------------------------------------------------------------

def residual_layout(cfg, kind: str) -> str:
    """Which layout the residual stream uses for this config/step kind.

    Any projection site resolving to a phantom-family strategy keeps the
    residual feature-sharded end-to-end (the paper's layout)."""
    if cfg.uses_phantom_sites():
        return "fp"
    if kind == "decode":
        return "rep"
    return "sp"


def to_full(x, layout: str, axes: MeshAxes):
    """local residual shard -> full [B, S, d] (fwd AG, bwd RS)."""
    if layout == "sp":
        return tpmod.gather_seq(x, axes, axis=1)
    if layout == "fp":
        return tpmod.gather_features(x, axes)
    return x


def from_partial(z, layout: str, axes: MeshAxes):
    """partial-sum full [B, S, d] -> reduced local shard (fwd RS, bwd AG)."""
    if layout == "sp":
        return tpmod.scatter_seq(z, axes, axis=1)
    if layout == "fp":
        return tpmod.scatter_features(z, axes)
    return lax.psum(z, axes.tp_name)


def seq_to_feature(x, axes: MeshAxes):
    """[B, S/p, d] -> [B, S, d/p] (single all-to-all)."""
    return lax.all_to_all(x, axes.tp_name, split_axis=2, concat_axis=1,
                          tiled=True)


def feature_to_seq(x, axes: MeshAxes):
    """[B, S, d/p] -> [B, S/p, d] (single all-to-all)."""
    return lax.all_to_all(x, axes.tp_name, split_axis=1, concat_axis=2,
                          tiled=True)


def gather_on_use(w, axes: MeshAxes, dim: int = 0):
    """'Weight-sharded, gather-on-use' params (ring-attention projections,
    FSDP dims): fwd all-gather, bwd reduce-scatter of the grads."""
    return lax.all_gather(w, axes.tp_name, axis=dim, tiled=True)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_decls(cfg, layout: str, d: int):
    spec = P("tp") if layout == "fp" else P()
    decl = {"scale": ParamDecl((d,), spec, init="ones")}
    if cfg.norm == "layernorm":
        decl["bias"] = ParamDecl((d,), spec, init="zeros")
    return decl


def norm_apply(cfg, layout: str, params, x, axes: MeshAxes):
    """RMSNorm/LayerNorm over the feature dim; psums partial moments when
    the features are sharded (fp layout)."""
    xf = x.astype(jnp.float32)
    d_local = x.shape[-1]
    if layout == "fp":
        d_global = d_local * axes.tp
        if cfg.norm == "layernorm":
            mean = lax.psum(jnp.sum(xf, -1, keepdims=True), axes.tp_name)
            mean = mean / d_global
            xc = xf - mean
            var = lax.psum(jnp.sum(xc * xc, -1, keepdims=True),
                           axes.tp_name) / d_global
            y = xc * lax.rsqrt(var + cfg.norm_eps)
            y = y * params["scale"] + params["bias"]
        else:
            ms = lax.psum(jnp.sum(xf * xf, -1, keepdims=True),
                          axes.tp_name) / d_global
            y = xf * lax.rsqrt(ms + cfg.norm_eps) * params["scale"]
    else:
        if cfg.norm == "layernorm":
            mean = jnp.mean(xf, -1, keepdims=True)
            xc = xf - mean
            var = jnp.mean(xc * xc, -1, keepdims=True)
            y = xc * lax.rsqrt(var + cfg.norm_eps)
            y = y * params["scale"] + params["bias"]
        else:
            ms = jnp.mean(xf * xf, -1, keepdims=True)
            y = xf * lax.rsqrt(ms + cfg.norm_eps) * params["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding
# ---------------------------------------------------------------------------

def padded_vocab(cfg) -> int:
    """Vocab rounded up to a multiple of 128 so vocab-sharding divides any
    tp <= 128 and logit GEMMs stay MXU-aligned.  Padded columns are masked
    to -inf in the softmax (see xent_loss)."""
    v = cfg.vocab_size
    return -(-v // 128) * 128


def embed_decls(cfg):
    fs = "dp" if cfg.fsdp else None
    return {"table": ParamDecl((padded_vocab(cfg), cfg.d_model),
                               P("tp", fs), init="embed")}


def embed_apply(cfg, layout: str, params, tokens, axes: MeshAxes,
                decls=None):
    """tokens [B_loc, S] -> residual shard in `layout`.

    Vocab-sharded lookup: local take + masked, then a single fused
    psum-scatter into the residual layout (psum for rep).
    """
    table = params["table"]
    if cfg.fsdp:
        table = gather_fsdp(table, P("tp", "dp"), axes,
                            quant=cfg.fsdp_gather_quant)
    vshard = table.shape[0]
    j = lax.axis_index(axes.tp_name)
    start = j * vshard
    local = tokens - start
    ok = (local >= 0) & (local < vshard)
    local = jnp.clip(local, 0, vshard - 1)
    h = jnp.take(table, local, axis=0)                    # [B, S, d]
    h = jnp.where(ok[..., None], h, 0).astype(cfg.dtype)
    if layout == "sp":
        return lax.psum_scatter(h, axes.tp_name, scatter_dimension=1,
                                tiled=True)
    if layout == "fp":
        return lax.psum_scatter(h, axes.tp_name,
                                scatter_dimension=h.ndim - 1, tiled=True)
    return lax.psum(h, axes.tp_name)


def gather_fsdp(w, spec: P, axes: MeshAxes, quant: bool = False):
    """All-gather any 'dp'-sharded dims of a param (FSDP gather-on-use).

    quant=True (serving, §Perf): symmetric-int8-quantize the local shard
    per output column before the gather and dequantize after — halves the
    wire bytes of the dominant decode collective at ~1e-2 relative error
    (w8a16, standard serving practice)."""
    for dim, entry in enumerate(spec):
        if entry == "dp":
            if quant and jnp.issubdtype(w.dtype, jnp.floating):
                scale = jnp.max(jnp.abs(w), axis=dim, keepdims=True) / 127.0
                scale = jnp.maximum(scale, 1e-12)
                wq = jnp.round(w / scale).astype(jnp.int8)
                wq = lax.all_gather(wq, axes.dp_names, axis=dim,
                                    tiled=True)
                sc = lax.all_gather(scale, axes.dp_names, axis=dim,
                                    tiled=True)
                # scales along the gathered dim are per-shard: broadcast
                w = (wq.astype(jnp.bfloat16)
                     * _expand_scales(sc, wq.shape, dim).astype(jnp.bfloat16))
            else:
                w = lax.all_gather(w, axes.dp_names, axis=dim, tiled=True)
    return w


def _expand_scales(sc, target_shape, dim):
    """Per-shard scales gathered along `dim` -> broadcast to target."""
    reps = target_shape[dim] // sc.shape[dim]
    return jnp.repeat(sc, reps, axis=dim)[
        tuple(slice(0, s) for s in target_shape)]


def gather_tree_fsdp(params, decls, axes: MeshAxes, quant: bool = False):
    """FSDP gather-on-use for a whole param subtree (VJP: reduce-scatter)."""
    if decls is None:
        return params
    from repro.parallel.params import ParamDecl
    return jax.tree.map(
        lambda w, d: gather_fsdp(w, d.spec, axes, quant=quant), params,
        decls, is_leaf=lambda v: isinstance(v, ParamDecl))


# ---------------------------------------------------------------------------
# MLP (dense TP and phantom)
# ---------------------------------------------------------------------------

def mlp_strategies(cfg, axes: MeshAxes, d: int, ff: int):
    """One ProjectionStrategy per MLP site (gate/up/down), per-site
    selectable via cfg.projections (ffn_gate / ffn_up / ffn_down)."""
    names = ("gate", "up", "down") if cfg.mlp == "swiglu" else ("up", "down")
    out = {}
    for name in names:
        n_in, n_out = (ff, d) if name == "down" else (d, ff)
        bias = name == "up" and cfg.mlp != "swiglu"
        out[name] = site_strategy(cfg, f"ffn_{name}", n_in, n_out, axes.tp,
                                  dp=axes.dp, bias=bias, fsdp=cfg.fsdp)
    return out


def mlp_decls(cfg, axes: MeshAxes, d: int, ff: int):
    return {name: st.decls()
            for name, st in mlp_strategies(cfg, axes, d, ff).items()}


def _mlp_act(cfg):
    return {"swiglu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[cfg.mlp]


def mlp_apply(cfg, layout: str, params, x, axes: MeshAxes, decls=None):
    """x: residual shard -> residual shard (same layout).

    all-phantom: stays feature-sharded; communicates only k-wide ghosts.
    all-tensor:  gather -> col -> act -> row -> reduce-scatter
                 (Megatron-SP; one gather shared by gate and up).
    mixed:       per-site shard->shard composition in the fp layout.
    """
    act = _mlp_act(cfg)
    dt = jnp.dtype(cfg.dtype)
    d_in = x.shape[-1] * (axes.tp if layout == "fp" else 1)
    ff = cfg.d_ff
    sts = mlp_strategies(cfg, axes, d_in, ff)
    kinds = {st.kind for st in sts.values()}

    def p_(name):
        return _fs(params[name], decls, name, axes, cfg.fsdp_gather_quant)

    if kinds <= {"phantom", "lowrank_distill"}:
        if cfg.mlp == "swiglu":
            g = sts["gate"].apply(p_("gate"), x, axes=axes, compute_dtype=dt)
            u = sts["up"].apply(p_("up"), x, axes=axes, compute_dtype=dt)
            h = act(g) * u
        else:
            h = act(sts["up"].apply(p_("up"), x, axes=axes,
                                    compute_dtype=dt))
        return sts["down"].apply(p_("down"), h, axes=axes, compute_dtype=dt)

    if kinds <= {"tensor_col", "tensor_row"}:
        x_full = to_full(x, layout, axes)
        if cfg.mlp == "swiglu":
            g = sts["gate"].apply(p_("gate"), x_full, compute_dtype=dt)
            u = sts["up"].apply(p_("up"), x_full, compute_dtype=dt)
            h = act(g) * u
        else:
            h = act(sts["up"].apply(p_("up"), x_full, compute_dtype=dt))
        pd = p_("down")
        z = sts["down"].apply(pd, h, compute_dtype=dt)
        z = from_partial(z, layout, axes)
        return sts["down"].add_bias(z, pd, axes, sharded=(layout == "fp"))

    # mixed strategies: uniform feature-shard composition (fp layout only —
    # residual_layout guarantees fp whenever any site is phantom-family)
    assert layout == "fp", (layout, kinds)
    if cfg.mlp == "swiglu":
        g = sts["gate"].apply_shard(p_("gate"), x, axes, compute_dtype=dt)
        u = sts["up"].apply_shard(p_("up"), x, axes, compute_dtype=dt)
        h = act(g) * u
    else:
        h = act(sts["up"].apply_shard(p_("up"), x, axes, compute_dtype=dt))
    return sts["down"].apply_shard(p_("down"), h, axes, compute_dtype=dt)


def _fs(params, decls, key, axes, quant: bool = False):
    """Gather FSDP-sharded dims of a param subtree on use."""
    if decls is None:
        return params
    sub = decls[key]
    return jax.tree.map(
        lambda w, d: gather_fsdp(w, d.spec, axes, quant=quant), params,
        sub, is_leaf=lambda v: isinstance(v, ParamDecl))


# ---------------------------------------------------------------------------
# logit head + sharded, seq-chunked cross entropy
# ---------------------------------------------------------------------------

def head_decls(cfg):
    fs = "dp" if cfg.fsdp else None
    return {"w": ParamDecl((cfg.d_model, padded_vocab(cfg)), P(fs, "tp"),
                           scale=cfg.d_model ** -0.5)}


def xent_loss(cfg, layout: str, params, h, labels, axes: MeshAxes,
              valid=None):
    """h: residual shard; labels [B_loc, S] -> (sum_loss, n_valid) local
    contributions (caller psums over dp; tp already reduced here).

    Never materializes [B, S, V]: scans seq chunks of `cfg.loss_chunk`,
    each chunk computing local-vocab logits + global logsumexp via psums.
    """
    w = params["w"]
    if cfg.fsdp:
        w = gather_fsdp(w, P("dp", "tp"), axes,
                        quant=cfg.fsdp_gather_quant)
    h_full = to_full(h, layout, axes)                 # [B, S, d]
    B, S, d = h_full.shape
    vshard = w.shape[1]
    j = lax.axis_index(axes.tp_name)
    vstart = j * vshard

    chunk = min(cfg.loss_chunk, S)
    n_chunks = S // chunk
    assert S % chunk == 0, (S, chunk)

    hc = h_full.reshape(B, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
    if valid is None:
        vc = jnp.ones((n_chunks, B, chunk), bool)
    else:
        vc = valid.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    # mask padded vocab columns (global col id >= true vocab)
    col_ok = (vstart + jnp.arange(vshard)) < cfg.vocab_size

    def body(carry, xs):
        hch, lch, vch = xs
        logits = jnp.einsum("bcd,dv->bcv", hch.astype(jnp.float32),
                            w.astype(jnp.float32))
        logits = jnp.where(col_ok, logits, -1e30)
        # the max shift is a mathematical constant: stop_gradient is exact
        # (placed BEFORE pmax — pmax has no differentiation rule)
        m = lax.pmax(jnp.max(lax.stop_gradient(logits), -1), axes.tp_name)
        se = jnp.sum(jnp.exp(logits - m[..., None]), -1)
        lse = jnp.log(lax.psum(se, axes.tp_name)) + m
        loc = lch - vstart
        ok = (loc >= 0) & (loc < vshard)
        loc = jnp.clip(loc, 0, vshard - 1)
        true_logit = jnp.take_along_axis(logits, loc[..., None],
                                         axis=-1)[..., 0]
        true_logit = lax.psum(jnp.where(ok, true_logit, 0.0), axes.tp_name)
        tok_loss = jnp.where(vch, lse - true_logit, 0.0)
        sl, nv = carry
        return (sl + jnp.sum(tok_loss), nv + jnp.sum(vch)), None

    (sum_loss, n_valid), _ = lax.scan(body, (jnp.float32(0), jnp.int32(0)),
                                      (hc, lc, vc))
    return sum_loss, n_valid


def head_logits(cfg, layout: str, params, h_last, axes: MeshAxes):
    """Logits for the last position only (decode): h_last [B, 1, d-shard]
    -> full-vocab logits [B, 1, V] (gathered; decode batch is small)."""
    w = params["w"]
    if cfg.fsdp:
        w = gather_fsdp(w, P("dp", "tp"), axes,
                        quant=cfg.fsdp_gather_quant)
    h_full = to_full(h_last, layout, axes) if layout == "fp" else h_last
    logits_loc = jnp.einsum("btd,dv->btv", h_full.astype(jnp.float32),
                            w.astype(jnp.float32))
    vshard = w.shape[1]
    j = lax.axis_index(axes.tp_name)
    col_ok = (j * vshard + jnp.arange(vshard)) < cfg.vocab_size
    logits_loc = jnp.where(col_ok, logits_loc, -1e30)
    return lax.all_gather(logits_loc, axes.tp_name, axis=-1, tiled=True)
