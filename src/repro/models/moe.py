"""Mixture-of-Experts with top-k routing and capacity-based, index-driven
dispatch (take/scatter-add, NOT the GShard one-hot einsum — the einsum
dispatch costs O(T^2) FLOPs at these token counts and would wreck the
roofline; DESIGN.md §6).

Two expert partitioning strategies over the model axis:

* ``expert`` (olmoe 64e, jamba 16e): experts sharded over the model axis;
  one all-to-all routes capacity slots to expert owners and (in fp layout)
  simultaneously un-shards features, its inverse routes outputs back.
* ``tensor`` (granite 40e, E % tp != 0): every expert's d_ff is sharded
  over the model axis; tokens are gathered once (the standard Megatron AG)
  and expert outputs reduce-scatter back.

Routing is computed identically on every rank (router weights replicated
or psum'd logits), so dispatch indices agree across the mesh without
communication.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import PHANTOM_KINDS, PhantomConfig
from repro.models.layers import (_mlp_act, from_partial, gather_fsdp,
                                 to_full)
from repro.parallel.axes import MeshAxes
from repro.parallel.params import ParamDecl, stack


# ---------------------------------------------------------------------------
# declarations
# ---------------------------------------------------------------------------

def moe_expert_spec(cfg, axes: MeshAxes):
    """Resolved ProjectionSpec for the expert FFNs, or None for the dense
    layout.  Phantom-factorized experts require the tensor partition
    (each expert's d_ff sharded over the model axis), divisible dims, and
    no FSDP (the batched phantom decls don't carry dp-sharded dims)."""
    m = cfg.moe
    spec = cfg.projection_spec("moe_experts")
    if (spec.kind in PHANTOM_KINDS and m.partition == "tensor"
            and cfg.d_model % axes.tp == 0
            and m.d_ff_expert % axes.tp == 0 and not cfg.fsdp):
        return spec
    return None


def moe_decls(cfg, axes: MeshAxes):
    m = cfg.moe
    d, E, ff = cfg.d_model, m.num_experts, m.d_ff_expert
    fs = "dp" if cfg.fsdp else None
    swiglu = cfg.mlp == "swiglu"
    pspec = moe_expert_spec(cfg, axes)
    if pspec is not None:
        # per-expert phantom factorization (E-stacked phantom decls)
        from repro.core.phantom import phantom_decls
        mk = lambda ni, no: stack(
            phantom_decls(ni, no, pspec.k, axes.tp, bias=False), E)
        dec = {
            "router": {"w": ParamDecl((d, E), P(), scale=d ** -0.5)},
            "w_up": mk(d, ff),
            "w_down": mk(ff, d),
        }
        if swiglu:
            dec["w_gate"] = mk(d, ff)
        return dec
    if m.partition == "expert":
        assert E % axes.tp == 0, (E, axes.tp)
        from repro.models.layers import residual_layout
        layout = residual_layout(cfg, "train")
        # fp layout: router input is a feature shard -> row-sharded router
        # (partial logits psum'd); sp/rep layouts see full features ->
        # replicated router.
        rspec = P("tp", None) if layout == "fp" else P()
        espec_in = P("tp", fs, None)
        espec_out = P("tp", None, fs)
        dec = {
            "router": {"w": ParamDecl((d, E), rspec, scale=d ** -0.5)},
            "w_up": {"w": ParamDecl((E, d, ff), espec_in)},
            "w_down": {"w": ParamDecl((E, ff, d), espec_out)},
        }
        if swiglu:
            dec["w_gate"] = {"w": ParamDecl((E, d, ff), espec_in)}
    else:  # tensor partition (works for any E)
        dec = {
            "router": {"w": ParamDecl((d, E), P(), scale=d ** -0.5)},
            "w_up": {"w": ParamDecl((E, d, ff), P(None, fs, "tp"))},
            "w_down": {"w": ParamDecl((E, ff, d), P(None, "tp", fs))},
        }
        if swiglu:
            dec["w_gate"] = {"w": ParamDecl((E, d, ff), P(None, fs, "tp"))}
    return dec


# ---------------------------------------------------------------------------
# routing: top-k + capacity assignment (index-based)
# ---------------------------------------------------------------------------

def route(logits, top_k: int, capacity: int):
    """logits [T, E] -> (disp_tok [E, C], disp_ok [E, C], combine [T, K]
    gate weights, combine_slot [T, K] flat slot ids or -1 if dropped).

    Position-in-expert via cumsum over token order (deterministic,
    mesh-replicated).
    """
    T, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, exp_idx = lax.top_k(probs, top_k)           # [T, K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # one-hot per (t, k) slot over experts; rank within expert = cumsum
    oh = jax.nn.one_hot(exp_idx, E, dtype=jnp.int32)       # [T, K, E]
    ohf = oh.reshape(T * top_k, E)
    pos = jnp.cumsum(ohf, axis=0) - ohf                    # rank in expert
    pos = jnp.sum(pos * ohf, axis=-1)                      # [T*K]
    e_flat = exp_idx.reshape(-1)
    keep = pos < capacity

    # dispatch tables; dropped entries route to the sentinel row E*C
    # (NOT e*C+capacity, which would collide with expert e+1's slot 0)
    slot = jnp.where(keep, e_flat * capacity + pos, E * capacity)
    disp_tok = jnp.zeros((E * capacity + 1,), jnp.int32)
    tok_ids = jnp.repeat(jnp.arange(T), top_k)
    disp_tok = disp_tok.at[slot].set(tok_ids, mode="drop")
    disp_ok = jnp.zeros((E * capacity + 1,), bool).at[slot].set(
        keep, mode="drop")
    combine_slot = jnp.where(keep, slot, -1).reshape(T, top_k)
    return (disp_tok[:-1].reshape(E, capacity),
            disp_ok[:-1].reshape(E, capacity),
            gate_vals, combine_slot)


def moe_capacity(tokens: int, E: int, top_k: int, cf: float) -> int:
    c = int(tokens * top_k * cf / E)
    return max(8, c + (-c) % 8)   # pad to a multiple of 8 lanes


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def moe_apply(cfg, layout: str, params, x, axes: MeshAxes, decls=None):
    m = cfg.moe
    if m.partition == "expert":
        return _moe_expert_partition(cfg, layout, params, x, axes, decls)
    return _moe_tensor_partition(cfg, layout, params, x, axes, decls)


def _expert_ffn(cfg, params, decls, xin, axes, dtype):
    """xin [E_loc, C', d] -> [E_loc, C', d] batched expert GEMMs."""
    act = _mlp_act(cfg)
    w_up = _w(params, decls, "w_up", axes,
              cfg.fsdp_gather_quant).astype(dtype)
    w_down = _w(params, decls, "w_down", axes,
                cfg.fsdp_gather_quant).astype(dtype)
    if cfg.mlp == "swiglu":
        w_gate = _w(params, decls, "w_gate", axes,
                    cfg.fsdp_gather_quant).astype(dtype)
        h = act(jnp.einsum("ecd,edf->ecf", xin, w_gate)) \
            * jnp.einsum("ecd,edf->ecf", xin, w_up)
    else:
        h = act(jnp.einsum("ecd,edf->ecf", xin, w_up))
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _moe_expert_partition(cfg, layout, params, x, axes, decls):
    """Experts sharded over the model axis.  Three residual layouts:

    fp  — x [B, S, d/p]: all tokens, feature shard.  One all-to-all moves
          capacity slots to expert owners AND un-shards features.
    sp  — x [B, S/p, d]: this rank's tokens, full features.  Classic EP:
          all-to-all swaps (expert -> owner) against (source rank).
    rep — x [B, 1, d] replicated (dense decode): each rank computes its
          own experts' contributions, psum combines.
    """
    m = cfg.moe
    dtype = jnp.dtype(cfg.dtype)
    p = axes.tp
    E = m.num_experts
    B, S = x.shape[0], x.shape[1]
    T = B * S
    xf = x.reshape(T, -1)

    if layout == "fp":
        # routing (replicated decisions): partial logits + psum
        rl = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"]["w"].astype(jnp.float32))
        logits = lax.psum(rl, axes.tp_name)                 # [T, E]
    else:
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                            params["router"]["w"].astype(jnp.float32))
    C = moe_capacity(T, E, m.top_k, m.capacity_factor)
    disp_tok, disp_ok, gates, combine_slot = route(logits, m.top_k, C)

    # dispatch: [E, C, d_local_or_full]
    xin = jnp.take(xf, disp_tok.reshape(-1), axis=0)
    xin = jnp.where(disp_ok.reshape(-1, 1), xin, 0)
    xin = xin.reshape(E, C, -1).astype(dtype)

    if layout == "fp":
        # split experts -> concat features: [E/p, C, d]
        xin = lax.all_to_all(xin, axes.tp_name, split_axis=0,
                             concat_axis=2, tiled=True)
        yout = _expert_ffn(cfg, params, decls, xin, axes, dtype)
        yout = lax.all_to_all(yout, axes.tp_name, split_axis=2,
                              concat_axis=0, tiled=True)
    elif layout == "sp":
        # split experts -> concat capacity (tokens from all source ranks):
        # [E/p, p*C, d]
        xin = lax.all_to_all(xin, axes.tp_name, split_axis=0,
                             concat_axis=1, tiled=True)
        yout = _expert_ffn(cfg, params, decls, xin, axes, dtype)
        yout = lax.all_to_all(yout, axes.tp_name, split_axis=1,
                              concat_axis=0, tiled=True)
    else:  # rep: tokens replicated; each rank serves its expert slice
        j = lax.axis_index(axes.tp_name)
        E_loc = E // p
        xin_loc = lax.dynamic_slice_in_dim(xin, j * E_loc, E_loc, 0)
        yout_loc = _expert_ffn(cfg, params, decls, xin_loc, axes, dtype)
        yout = jnp.zeros((E, C, xf.shape[-1]), yout_loc.dtype)
        yout = lax.dynamic_update_slice_in_dim(yout, yout_loc, j * E_loc,
                                               0)
        yout = lax.psum(yout, axes.tp_name)

    # combine: weighted scatter back to tokens
    yflat = yout.reshape(E * C, -1)
    ok = combine_slot >= 0                                  # [T, K]
    slots = jnp.where(ok, combine_slot, 0)
    picked = jnp.take(yflat, slots.reshape(-1), axis=0)
    picked = picked.reshape(T, m.top_k, -1)
    w = jnp.where(ok, gates, 0.0)[..., None].astype(picked.dtype)
    y = jnp.sum(picked * w, axis=1)
    return y.reshape(x.shape), _aux_loss(logits, E)


def _expert_ffn_phantom(cfg, pspec, params, xin, axes, dtype):
    """Phantom-factorized experts (tensor partition): xin [E, C, d] full
    features -> feature-shard output [E, C, d/p].

    Each expert's projections are phantom matmuls vmapped over the expert
    dim; the ghost all-gathers batch across experts."""
    from repro.core.phantom import phantom_apply
    act = _mlp_act(cfg)
    pp = PhantomConfig(k=pspec.k, variant=pspec.variant,
                       include_self_term=pspec.include_self_term)
    p = axes.tp
    j = lax.axis_index(axes.tp_name)
    dloc = xin.shape[-1] // p
    xloc = lax.dynamic_slice_in_dim(xin, j * dloc, dloc, axis=2)

    def pa(pe, xe):
        return jax.vmap(
            lambda pee, xee: phantom_apply(pp, pee, xee, axes,
                                           compute_dtype=dtype))(pe, xe)

    if cfg.mlp == "swiglu":
        h = act(pa(params["w_gate"], xloc)) * pa(params["w_up"], xloc)
    else:
        h = act(pa(params["w_up"], xloc))
    return pa(params["w_down"], h)                          # [E, C, d/p]


def _moe_tensor_partition(cfg, layout, params, x, axes, decls):
    """sp layout: x [B, S/p, d].  Tokens gathered once (Megatron AG), every
    expert's d_ff sharded; outputs reduce-scatter back.  With phantom
    experts (fp layout) the expert outputs come back feature-sharded and
    ARE the residual shard — only k-wide ghosts cross the mesh."""
    m = cfg.moe
    dtype = jnp.dtype(cfg.dtype)
    E = m.num_experts
    x_full = to_full(x, layout, axes)                       # [B, S, d]
    B, S, d = x_full.shape
    T = B * S
    xf = x_full.reshape(T, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"]["w"].astype(jnp.float32))
    C = moe_capacity(T, E, m.top_k, m.capacity_factor)
    disp_tok, disp_ok, gates, combine_slot = route(logits, m.top_k, C)

    xin = jnp.take(xf, disp_tok.reshape(-1), axis=0)
    xin = jnp.where(disp_ok.reshape(-1, 1), xin, 0)
    xin = xin.reshape(E, C, d).astype(dtype)

    pspec = moe_expert_spec(cfg, axes)
    if pspec is not None:
        yout = _expert_ffn_phantom(cfg, pspec, params, xin, axes, dtype)
        d_out = d // axes.tp                                # feature shard
    else:
        yout = _expert_ffn(cfg, params, decls, xin, axes, dtype)
        d_out = d                    # PARTIAL sum over the sharded d_ff dim
    yflat = yout.reshape(E * C, d_out)
    ok = combine_slot >= 0
    slots = jnp.where(ok, combine_slot, 0)
    picked = jnp.take(yflat, slots.reshape(-1), axis=0) \
        .reshape(T, m.top_k, d_out)
    w = jnp.where(ok, gates, 0.0)[..., None].astype(picked.dtype)
    y = jnp.sum(picked * w, axis=1).reshape(B, S, d_out)
    if pspec is not None:
        assert layout == "fp", layout   # phantom keeps features sharded
        return y, _aux_loss(logits, E)
    y = from_partial(y, layout, axes)                       # RS the partials
    return y, _aux_loss(logits, E)


def _aux_loss(logits, E):
    """Load-balancing auxiliary loss (Switch-style): E * sum(f_e * P_e)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    top1 = jnp.argmax(probs, -1)
    f = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=0)
    P_ = jnp.mean(probs, axis=0)
    return E * jnp.sum(f * P_)


def _w(params, decls, key, axes, quant: bool = False):
    if decls is None:
        return params[key]["w"]
    return gather_fsdp(params[key]["w"], decls[key]["w"].spec, axes,
                       quant=quant)
