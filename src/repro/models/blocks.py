"""Composable blocks: (mixer + ffn) residual layers.

mixer: "attn" (GQA, any sharding mode) or "mamba" (SSD).
ffn:   "mlp" (dense-TP or phantom), "moe", or None (mamba2 has no FFN).

A layer plan (list of (mixer, ffn) pairs) describes any assigned arch;
hybrid archs scan over superblocks of `period` layers (jamba: 8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moemod
from repro.models import ssm as ssmmod
from repro.models.layers import mlp_decls, mlp_apply, norm_decls, norm_apply
from repro.parallel.axes import MeshAxes


def layer_plan(cfg):
    """[(mixer, ffn)] for each layer."""
    plan = []
    for l in range(cfg.num_layers):
        if cfg.attn_period == -1:
            mixer = "mamba"
        elif cfg.attn_period and cfg.attn_period > 0:
            mixer = "attn" if l % cfg.attn_period == 0 else "mamba"
        else:
            mixer = "attn"
        if cfg.family == "ssm":
            ffn = None
        elif cfg.moe is not None and l % cfg.moe.every_n == cfg.moe.offset:
            ffn = "moe"
        elif cfg.d_ff > 0:
            ffn = "mlp"
        else:
            ffn = None
        plan.append((mixer, ffn))
    return plan


def plan_period(cfg) -> int:
    """Smallest repeating period of the layer plan (scan superblock size)."""
    plan = layer_plan(cfg)
    for per in range(1, len(plan) + 1):
        if len(plan) % per == 0 and plan == plan[:per] * (len(plan) // per):
            return per
    return len(plan)


# ---------------------------------------------------------------------------

def block_decls(cfg, axes: MeshAxes, mixer: str, ffn, layout: str,
                cross: bool = False):
    d = {"norm1": norm_decls(cfg, layout, cfg.d_model)}
    if mixer == "attn":
        d["mixer"] = attn.attn_decls(cfg, axes)
    else:
        d["mixer"] = ssmmod.ssm_decls(cfg, axes)
    if cross:
        d["norm_x"] = norm_decls(cfg, layout, cfg.d_model)
        d["cross"] = attn.attn_decls(cfg, axes, cross=True)
    if ffn == "mlp":
        d["norm2"] = norm_decls(cfg, layout, cfg.d_model)
        d["ffn"] = mlp_decls(cfg, axes, cfg.d_model, cfg.d_ff)
    elif ffn == "moe":
        d["norm2"] = norm_decls(cfg, layout, cfg.d_model)
        d["ffn"] = moemod.moe_decls(cfg, axes)
    return d


def block_apply(cfg, layout: str, params, decls, x, positions,
                axes: MeshAxes, *, mixer: str, ffn, kind: str,
                causal: bool = True, cache=None, pos=None, memory=None,
                return_kv: bool = False):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.float32(0)
    has_cross = "cross" in params
    # train-mode scan passes a dummy (non-dict) placeholder for cache
    cache = cache if isinstance(cache, dict) else None
    self_cache = (cache.get("self") if (has_cross and cache is not None)
                  else cache)
    h = norm_apply(cfg, layout, params["norm1"], x, axes)
    if mixer == "attn":
        out, new_kv = attn.attention(
            cfg, layout, params["mixer"], h, positions, axes,
            decls["mixer"], kind=kind, causal=causal, cache=self_cache,
            pos=pos, return_kv=return_kv)
    else:
        out, new_kv = ssmmod.ssm_apply(
            cfg, layout, params["mixer"], h, axes, decls["mixer"],
            kind=kind, cache=self_cache)
    x = x + out.astype(x.dtype)

    if has_cross:
        hx = norm_apply(cfg, layout, params["norm_x"], x, axes)
        cross_cache = (cache.get("cross")
                       if (cache is not None and kind == "decode") else None)
        cout, cross_kv = attn.attention(
            cfg, layout, params["cross"], hx, positions, axes,
            decls["cross"], kind=kind, causal=False, memory=memory,
            cross=True, cache=cross_cache, pos=pos,
            return_kv=return_kv and kind == "prefill")
        x = x + cout.astype(x.dtype)
        if kind == "prefill" and return_kv:
            new_kv = {"self": new_kv, "cross": cross_kv}
        elif kind == "decode":
            new_kv = {"self": new_kv, "cross": cross_kv}

    if ffn is not None:
        h2 = norm_apply(cfg, layout, params["norm2"], x, axes)
        if ffn == "moe":
            f, aux = moemod.moe_apply(cfg, layout, params["ffn"], h2, axes,
                                      decls["ffn"])
        else:
            f = mlp_apply(cfg, layout, params["ffn"], h2, axes,
                          decls["ffn"])
        x = x + f.astype(x.dtype)
    return x, new_kv, aux
