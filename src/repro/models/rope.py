"""Rotary position embeddings: full, partial (chatglm3 "2d"/stablelm),
and M-RoPE (qwen2-vl 3-axis multimodal rope).
"""
from __future__ import annotations

import jax.numpy as jnp


def _rotate_half(x):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([-x2, x1], axis=-1)


def _angles(positions, rot_dim: int, theta: float):
    """positions [...,] -> cos/sin [..., rot_dim]."""
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32)
                           / rot_dim))
    ang = positions[..., None].astype(jnp.float32) * inv     # [..., rot/2]
    ang = jnp.concatenate([ang, ang], axis=-1)               # [..., rot]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, *, fraction: float = 1.0,
               theta: float = 10000.0):
    """x: [B, S, H, hd]; positions: [B, S] (or [S]).  Rotates the first
    fraction*hd dims (chatglm3's 2d rope == fraction 0.5; stablelm 0.25).
    """
    hd = x.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    if positions.ndim == 1:
        positions = positions[None, :]
    cos, sin = _angles(positions, rot, theta)                # [B, S, rot]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    xr = xr * cos.astype(x.dtype) + _rotate_half(xr) * sin.astype(x.dtype)
    return jnp.concatenate([xr, xp], axis=-1)


# M-RoPE (qwen2-vl): head dim split in 3 sections rotated by (t, h, w)
# position components.  Section split follows the 1/4-3/8-3/8 convention.
def mrope_sections(hd: int):
    half = hd // 2
    s0 = half // 4
    s1 = (half - s0) // 2
    s2 = half - s0 - s1
    return (2 * s0, 2 * s1, 2 * s2)


def apply_mrope(x, positions3, *, theta: float = 10000.0):
    """x: [B, S, H, hd]; positions3: [3, B, S] (t/h/w position ids)."""
    hd = x.shape[-1]
    secs = mrope_sections(hd)
    outs = []
    off = 0
    for i, sec in enumerate(secs):
        outs.append(apply_rope(x[..., off:off + sec], positions3[i],
                               fraction=1.0, theta=theta))
        off += sec
    if off < hd:
        outs.append(x[..., off:])
    return jnp.concatenate(outs, axis=-1)


def rope_for(cfg, x, positions):
    """Dispatch on cfg.rope. positions: [B,S] or [3,B,S] for mrope."""
    if cfg.rope == "none":
        return x
    if cfg.rope == "mrope":
        return apply_mrope(x, positions, theta=cfg.rope_theta)
    frac = cfg.rope_fraction if cfg.rope == "partial" else 1.0
    return apply_rope(x, positions, fraction=frac, theta=cfg.rope_theta)
