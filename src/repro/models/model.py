"""Model assembly: decls + forward passes (train / prefill / decode) for
every assigned architecture family.  All forward code runs inside
``shard_map``; layers scan over stacked params (HLO size independent of
depth), hybrid archs scan over superblocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.blocks import (block_apply, block_decls, layer_plan,
                                 plan_period)
from repro.models.layers import (embed_apply, embed_decls, head_decls,
                                 head_logits, norm_apply, norm_decls,
                                 residual_layout, xent_loss)
from repro.models.ssm import ssm_dims
from repro.parallel.axes import MeshAxes
from repro.parallel.params import ParamDecl, is_decl, param_count, stack

VISION_TOKENS = 256
AUX_LOSS_WEIGHT = 0.01


def n_vision_tokens(cfg, seq_len: int) -> int:
    return min(VISION_TOKENS, seq_len // 4)


# ---------------------------------------------------------------------------
# declarations
# ---------------------------------------------------------------------------

def _cast_decls(tree, dtype_str: str):
    """Store params in cfg.param_dtype (bf16 for the largest archs)."""
    import dataclasses
    dt = jnp.dtype(dtype_str)
    if dt == jnp.float32:
        return tree
    return jax.tree.map(
        lambda d: (dataclasses.replace(d, dtype=dt)
                   if jnp.issubdtype(jnp.dtype(d.dtype), jnp.floating)
                   else d),
        tree, is_leaf=is_decl)


def model_decls(cfg: ModelConfig, axes: MeshAxes):
    layout = residual_layout(cfg, "train")
    d = {"embed": embed_decls(cfg),
         "final_norm": norm_decls(cfg, layout, cfg.d_model),
         "head": head_decls(cfg)}
    if cfg.family == "encdec":
        if axes.pp > 1:
            raise NotImplementedError(
                "pipeline parallelism does not cover encoder-decoder "
                "stacks yet (two heterogeneous stacks)")
        enc = block_decls(cfg, axes, "attn", "mlp", layout)
        dec = block_decls(cfg, axes, "attn", "mlp", layout, cross=True)
        d["enc_layers"] = stack(enc, cfg.encoder_layers)
        d["dec_layers"] = stack(dec, cfg.num_layers)
        d["enc_final_norm"] = norm_decls(cfg, layout, cfg.d_model)
        return _cast_decls(d, cfg.param_dtype)
    per = plan_period(cfg)
    plan = layer_plan(cfg)[:per]
    if per == 1:
        layer = block_decls(cfg, axes, plan[0][0], plan[0][1], layout)
        d["layers"] = stack(layer, cfg.num_layers)
    else:
        sup = {f"sub{i}": block_decls(cfg, axes, mx, ff, layout)
               for i, (mx, ff) in enumerate(plan)}
        d["layers"] = stack(sup, cfg.num_layers // per)
    if axes.pp > 1:
        d["layers"] = _pp_shard_layer_decls(d["layers"], axes.pp)
    return _cast_decls(d, cfg.param_dtype)


def _pp_shard_layer_decls(layers, pp: int):
    """[G, ...] scan-stacked layer decls -> [pp, G/pp, ...] with the
    stage axis sharded over the pipe mesh axis: each pipe rank holds
    exactly its stage's contiguous slice of (super)layer groups.  The
    reshape preserves layer order, and ``materialize`` draws the same
    flat values for either shape, so a pp mesh trains bit-identical
    parameters to the dp×tp mesh."""
    import dataclasses

    def reshape(d):
        G = d.shape[0]
        if G % pp:
            raise ValueError(f"{G} layer groups do not divide into "
                             f"{pp} pipeline stages")
        return dataclasses.replace(
            d, shape=(pp, G // pp) + tuple(d.shape[1:]),
            spec=P(*(("pp",) + tuple(d.spec))))
    return jax.tree.map(reshape, layers, is_leaf=is_decl)


def _layer_decls_unstacked(cfg, axes):
    layout = residual_layout(cfg, "train")
    per = plan_period(cfg)
    plan = layer_plan(cfg)[:per]
    if per == 1:
        return block_decls(cfg, axes, plan[0][0], plan[0][1], layout), plan
    return ({f"sub{i}": block_decls(cfg, axes, mx, ff, layout)
             for i, (mx, ff) in enumerate(plan)}, plan)


# ---------------------------------------------------------------------------
# embedding (+ modality stubs)
# ---------------------------------------------------------------------------

def _embed(cfg, layout, params, decls, batch, axes):
    h = embed_apply(cfg, layout, params["embed"], batch["tokens"], axes,
                    decls["embed"] if cfg.fsdp else None)
    if cfg.frontend == "vision" and "vision_embeds" in batch:
        v = batch["vision_embeds"].astype(h.dtype)         # [B, n_img, d]
        n_img = v.shape[1]
        p = axes.tp
        j = lax.axis_index(axes.tp_name)
        if layout == "fp":
            fsh = h.shape[-1]
            vloc = lax.dynamic_slice_in_dim(v, j * fsh, fsh, 2)
            h = jnp.concatenate([vloc, h[:, n_img:, :]], axis=1)
        elif layout == "sp":
            C = h.shape[1]
            pos = j * C + jnp.arange(C)
            vpad = jnp.pad(v, ((0, 0), (0, C - n_img), (0, 0)))
            h = jnp.where((pos < n_img)[None, :, None], vpad, h)
        else:
            h = jnp.concatenate([v, h[:, n_img:, :]], axis=1)
    return h


def _positions(cfg, batch, B, S):
    if cfg.rope == "mrope":
        return batch["positions"]                          # [3, B, S]
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))


# ---------------------------------------------------------------------------
# decoder-only / hybrid stacks
# ---------------------------------------------------------------------------

def _run_stack(cfg, layout, params, decls_layer, plan, h, positions, axes,
               *, kind, cache=None, pos=None, causal=True):
    """Scan the (super)layer stack.  Returns (h, new_cache, aux)."""
    per = len(plan)
    remat = cfg.remat in ("full", "dots") and kind == "train"

    def body(carry, xs):
        x, aux = carry
        layer_params, layer_cache = xs
        if per == 1:
            x, new_kv, a = block_apply(
                cfg, layout, layer_params, decls_layer, x, positions, axes,
                mixer=plan[0][0], ffn=plan[0][1], kind=kind, causal=causal,
                cache=layer_cache, pos=pos,
                return_kv=(kind == "prefill"))
            aux = aux + a
        else:
            new_kv = {}
            for i, (mx, ff) in enumerate(plan):
                sub = f"sub{i}"
                x, kv_i, a = block_apply(
                    cfg, layout, layer_params[sub], decls_layer[sub], x,
                    positions, axes, mixer=mx, ffn=ff, kind=kind,
                    causal=causal,
                    cache=None if layer_cache is None else layer_cache[sub],
                    pos=pos, return_kv=(kind == "prefill"))
                new_kv[sub] = kv_i
                aux = aux + a
        return (x, aux), new_kv

    if remat:
        # "full": save only the carry (recompute everything in bwd —
        # minimum memory, ~3x fwd HBM traffic in bwd).  "dots": save
        # matmul outputs (skips most recompute, costs the saved-tensor
        # residency — §Perf hillclimb knob).
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat == "dots" else None)
        body = jax.checkpoint(body, policy=policy)

    n_groups = jax.tree.leaves(params["layers"])[0].shape[0]
    if cache is None:
        cache_xs = _none_like_cache(cfg, plan, n_groups)
    else:
        cache_xs = cache
    if cfg.scan_layers:
        (h, aux), new_cache = lax.scan(body, (h, jnp.float32(0)),
                                       (params["layers"], cache_xs))
        return h, new_cache, aux
    # unrolled python loop (dry-run cost analysis: scan bodies are counted
    # once by cost_analysis, so the roofline pass unrolls)
    carry = (h, jnp.float32(0))
    outs = []
    for i in range(n_groups):
        xs_i = jax.tree.map(lambda a: a[i], (params["layers"], cache_xs))
        carry, kv_i = body(carry, xs_i)
        outs.append(kv_i)
    h, aux = carry
    new_cache = (jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
                 if outs and outs[0] is not None else None)
    return h, new_cache, aux


def _none_like_cache(cfg, plan, n_groups):
    """Scan xs stand-in when there is no cache (train): a pytree of Nones
    isn't scannable, so use per-group dummy zeros of shape [n]."""
    if len(plan) == 1:
        return jnp.zeros((n_groups,), jnp.int8)
    return {f"sub{i}": jnp.zeros((n_groups,), jnp.int8)
            for i in range(len(plan))}


# ---------------------------------------------------------------------------
# public forwards (inside shard_map)
# ---------------------------------------------------------------------------

def forward_train(cfg: ModelConfig, axes: MeshAxes, params, batch):
    """batch: tokens/labels [B_loc, S] (+positions/vision_embeds/frames).
    Returns (sum_loss, n_valid, aux) — local (pre-dp-psum) contributions."""
    if cfg.family == "encdec":
        return _encdec_forward_train(cfg, axes, params, batch)
    layout = residual_layout(cfg, "train")
    decls_layer, plan = _layer_decls_unstacked(cfg, axes)
    B, S = batch["tokens"].shape
    h = _embed(cfg, layout, params, model_decls_cache(cfg, axes), batch,
               axes)
    positions = _positions(cfg, batch, B, S)
    h, _, aux = _run_stack(cfg, layout, params, decls_layer, plan, h,
                           positions, axes, kind="train")
    h = norm_apply(cfg, layout, params["final_norm"], h, axes)
    sum_loss, n_valid = xent_loss(cfg, layout, params["head"], h,
                                  batch["labels"], axes)
    return sum_loss, n_valid, aux


def forward_train_pipeline(cfg: ModelConfig, axes: MeshAxes, params, batch,
                           microbatches: int = 1):
    """1F1B pipelined train forward over the ``pipe`` mesh axis: embed on
    stage 0, the (super)layer stack partitioned into contiguous
    per-stage slices (``model_decls`` pipe-shards the scan stack), final
    norm + head + loss on the last stage, microbatch activations
    ppermuted across stage boundaries by ``train/pipeline.py``.

    Same contract as ``forward_train`` — returns each rank's UNIQUE
    (sum_loss, n_valid, aux) contribution: loss/valid counts are masked
    to the last pipe rank, aux covers only this rank's own stage layers.
    On a pp=1 mesh it degrades to a sequential microbatched loop (the
    equivalence reference)."""
    from repro.train.pipeline import pipeline_run, split_batch_microbatches
    if cfg.family == "encdec":
        raise NotImplementedError("no pipeline path for encdec stacks")
    if cfg.rope == "mrope":
        raise NotImplementedError(
            "mrope positions vary per microbatch; the wavefront carries "
            "activations only")
    layout = residual_layout(cfg, "train")
    decls_layer, plan = _layer_decls_unstacked(cfg, axes)
    M = max(microbatches, 1)
    mb = split_batch_microbatches(batch, M)
    B, S = batch["tokens"].shape
    decls = model_decls_cache(cfg, axes)

    # embed every microbatch up front: stage-0 work — on other ranks the
    # wavefront's where() leaves these unselected, so they carry no
    # gradient and the pipe psum in reduce_grads restores embed grads
    h0 = [_embed(cfg, layout, params,
                 decls, jax.tree.map(lambda a, i=i: a[i], mb), axes)
          for i in range(M)]
    x_mb = jnp.stack(h0)
    positions = _positions(cfg, {}, B // M, S)

    def stage_fn(h):
        if axes.pp > 1:
            stage_params = {"layers": jax.tree.map(lambda a: a[0],
                                                   params["layers"])}
        else:
            stage_params = params
        h, _, aux = _run_stack(cfg, layout, stage_params, decls_layer,
                               plan, h, positions, axes, kind="train")
        return h, aux

    y_mb, aux = pipeline_run(stage_fn, x_mb, axes)

    sum_loss = jnp.float32(0)
    n_valid = jnp.int32(0)
    for i in range(M):
        h = norm_apply(cfg, layout, params["final_norm"], y_mb[i], axes)
        sl, nv = xent_loss(cfg, layout, params["head"], h,
                           mb["labels"][i], axes)
        sum_loss = sum_loss + sl
        n_valid = n_valid + nv.astype(jnp.int32)
    if axes.pp > 1:
        is_last = lax.axis_index(axes.pp_name) == axes.pp - 1
        sum_loss = jnp.where(is_last, sum_loss, jnp.zeros_like(sum_loss))
        n_valid = jnp.where(is_last, n_valid, jnp.zeros_like(n_valid))
    return sum_loss, n_valid, aux


def forward_logits(cfg: ModelConfig, axes: MeshAxes, params, batch):
    """Full per-position logits [B, S, V_pad] — test/debug reference path
    (materializes the whole logit tensor; smoke configs only)."""
    from repro.models.layers import padded_vocab, to_full
    layout = residual_layout(cfg, "train")
    if cfg.family == "encdec":
        memory, _ = _enc_stack(cfg, layout, params, axes, batch["frames"])
        B, S = batch["tokens"].shape
        h = embed_apply(cfg, layout, params["embed"], batch["tokens"], axes)
        positions = _positions(cfg, batch, B, S)
        h, _, _ = _dec_stack(cfg, layout, params, axes, h, positions,
                             memory, kind="train")
    else:
        decls_layer, plan = _layer_decls_unstacked(cfg, axes)
        B, S = batch["tokens"].shape
        h = _embed(cfg, layout, params, model_decls_cache(cfg, axes),
                   batch, axes)
        positions = _positions(cfg, batch, B, S)
        h, _, _ = _run_stack(cfg, layout, params, decls_layer, plan, h,
                             positions, axes, kind="train")
    h = norm_apply(cfg, layout, params["final_norm"], h, axes)
    h_full = to_full(h, layout, axes)
    w = params["head"]["w"]
    logits_loc = jnp.einsum("bsd,dv->bsv", h_full.astype(jnp.float32),
                            w.astype(jnp.float32))
    j = lax.axis_index(axes.tp_name)
    vshard = w.shape[1]
    col_ok = (j * vshard + jnp.arange(vshard)) < cfg.vocab_size
    logits_loc = jnp.where(col_ok, logits_loc, -1e30)
    return lax.all_gather(logits_loc, axes.tp_name, axis=-1, tiled=True)


def forward_prefill(cfg: ModelConfig, axes: MeshAxes, params, batch):
    """Returns (last_token_logits [B,1,V], cache)."""
    if cfg.family == "encdec":
        return _encdec_forward_prefill(cfg, axes, params, batch)
    layout = residual_layout(cfg, "prefill")
    decls_layer, plan = _layer_decls_unstacked(cfg, axes)
    B, S = batch["tokens"].shape
    h = _embed(cfg, layout, params, model_decls_cache(cfg, axes), batch,
               axes)
    positions = _positions(cfg, batch, B, S)
    h, cache, _ = _run_stack(cfg, layout, params, decls_layer, plan, h,
                             positions, axes, kind="prefill")
    h = norm_apply(cfg, layout, params["final_norm"], h, axes)
    h_last = _last_position(h, layout, axes)
    logits = head_logits(cfg, layout, params["head"], h_last, axes)
    return logits, cache


def forward_decode(cfg: ModelConfig, axes: MeshAxes, params, cache,
                   tokens, pos):
    """tokens [B_loc, 1]; pos: int32 scalar.  Returns (logits, new_cache)."""
    layout = residual_layout(cfg, "decode")
    decls_layer, plan = _layer_decls_unstacked(cfg, axes)
    if cfg.family == "encdec":
        return _encdec_forward_decode(cfg, axes, params, cache, tokens, pos)
    h = embed_apply(cfg, layout, params["embed"], tokens, axes,
                    model_decls_cache(cfg, axes)["embed"] if cfg.fsdp
                    else None)
    h, new_cache, _ = _run_stack(cfg, layout, params, decls_layer, plan, h,
                                 None, axes, kind="decode", cache=cache,
                                 pos=pos)
    h = norm_apply(cfg, layout, params["final_norm"], h, axes)
    logits = head_logits(cfg, layout, params["head"], h, axes)
    return logits, new_cache


def _last_position(h, layout, axes):
    if layout == "fp":
        return h[:, -1:, :]
    if layout == "sp":
        j = lax.axis_index(axes.tp_name)
        p = axes.tp
        mine = jnp.where(j == p - 1, 1.0, 0.0).astype(h.dtype)
        return lax.psum(h[:, -1:, :] * mine, axes.tp_name)
    return h[:, -1:, :]


# ---------------------------------------------------------------------------
# encoder-decoder (seamless)
# ---------------------------------------------------------------------------

def _enc_stack(cfg, layout, params, axes, frames, kind="train"):
    """frames [B, S_enc, d] replicated input -> (memory_full [B,S,d],
    enc hidden in layout)."""
    decls = block_decls(cfg, axes, "attn", "mlp", layout)
    j = lax.axis_index(axes.tp_name)
    p = axes.tp
    # shard the replicated frames into the residual layout
    if layout == "fp":
        fsh = frames.shape[-1] // p
        h = lax.dynamic_slice_in_dim(frames, j * fsh, fsh, 2)
    else:
        C = frames.shape[1] // p
        h = lax.dynamic_slice_in_dim(frames, j * C, C, 1)
    h = h.astype(cfg.dtype)
    B, S = frames.shape[0], frames.shape[1]
    positions = _positions(cfg, {}, B, S)

    def body(carry, layer_params):
        x, _ = carry
        x, _, a = block_apply(cfg, layout, layer_params, decls, x,
                              positions, axes, mixer="attn", ffn="mlp",
                              kind="train", causal=False)
        return (x, a), None

    bodyf = jax.checkpoint(body) if (cfg.remat == "full"
                                     and kind == "train") else body
    if cfg.scan_layers:
        (h, _), _ = lax.scan(bodyf, (h, jnp.float32(0)),
                             params["enc_layers"])
    else:
        carry = (h, jnp.float32(0))
        n = jax.tree.leaves(params["enc_layers"])[0].shape[0]
        for i in range(n):
            carry, _ = bodyf(carry,
                             jax.tree.map(lambda a: a[i],
                                          params["enc_layers"]))
        h = carry[0]
    h = norm_apply(cfg, layout, params["enc_final_norm"], h, axes)
    from repro.models.layers import to_full
    return to_full(h, layout, axes), h


def _dec_stack(cfg, layout, params, axes, h, positions, memory, *, kind,
               cache=None, pos=None):
    decls = block_decls(cfg, axes, "attn", "mlp", layout, cross=True)

    def body(carry, xs):
        x, aux = carry
        layer_params, layer_cache = xs
        x, new_kv, a = block_apply(
            cfg, layout, layer_params, decls, x, positions, axes,
            mixer="attn", ffn="mlp", kind=kind, causal=True,
            cache=layer_cache, pos=pos, memory=memory,
            return_kv=(kind == "prefill"))
        return (x, aux + a), new_kv

    bodyf = jax.checkpoint(body) if (cfg.remat == "full"
                                     and kind == "train") else body
    n = jax.tree.leaves(params["dec_layers"])[0].shape[0]
    cache_xs = cache if cache is not None else jnp.zeros((n,), jnp.int8)
    if cfg.scan_layers:
        (h, aux), new_cache = lax.scan(bodyf, (h, jnp.float32(0)),
                                       (params["dec_layers"], cache_xs))
        return h, new_cache, aux
    carry = (h, jnp.float32(0))
    outs = []
    for i in range(n):
        carry, kv_i = bodyf(carry, jax.tree.map(
            lambda a: a[i], (params["dec_layers"], cache_xs)))
        outs.append(kv_i)
    h, aux = carry
    new_cache = (jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
                 if outs and outs[0] is not None else None)
    return h, new_cache, aux


def _encdec_forward_train(cfg, axes, params, batch):
    layout = residual_layout(cfg, "train")
    memory, _ = _enc_stack(cfg, layout, params, axes, batch["frames"])
    B, S = batch["tokens"].shape
    h = embed_apply(cfg, layout, params["embed"], batch["tokens"], axes)
    positions = _positions(cfg, batch, B, S)
    h, _, aux = _dec_stack(cfg, layout, params, axes, h, positions, memory,
                           kind="train")
    h = norm_apply(cfg, layout, params["final_norm"], h, axes)
    sum_loss, n_valid = xent_loss(cfg, layout, params["head"], h,
                                  batch["labels"], axes)
    return sum_loss, n_valid, aux


def _encdec_forward_prefill(cfg, axes, params, batch):
    layout = residual_layout(cfg, "prefill")
    memory, _ = _enc_stack(cfg, layout, params, axes, batch["frames"],
                           kind="prefill")
    B, S = batch["tokens"].shape
    h = embed_apply(cfg, layout, params["embed"], batch["tokens"], axes)
    positions = _positions(cfg, batch, B, S)
    h, cache, _ = _dec_stack(cfg, layout, params, axes, h, positions,
                             memory, kind="prefill")
    h = norm_apply(cfg, layout, params["final_norm"], h, axes)
    h_last = _last_position(h, layout, axes)
    logits = head_logits(cfg, layout, params["head"], h_last, axes)
    return logits, cache


def _encdec_forward_decode(cfg, axes, params, cache, tokens, pos):
    layout = residual_layout(cfg, "decode")
    h = embed_apply(cfg, layout, params["embed"], tokens, axes)
    h, new_cache, _ = _dec_stack(cfg, layout, params, axes, h, None, None,
                                 kind="decode", cache=cache, pos=pos)
    h = norm_apply(cfg, layout, params["final_norm"], h, axes)
    logits = head_logits(cfg, layout, params["head"], h, axes)
    return logits, new_cache


# ---------------------------------------------------------------------------
# cache declarations (for serve/dry-run: abstract global shapes + specs)
# ---------------------------------------------------------------------------

def cache_decls(cfg: ModelConfig, axes: MeshAxes, batch: int, max_len: int,
                enc_len: int | None = None):
    """Global-shape ShapeDtypeStruct pytree + PartitionSpec pytree for the
    decode cache, structured to match the scan grouping of model_decls."""
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim()
    bspec = "dp" if batch % max(axes.dp, 1) == 0 and axes.dp > 1 else None

    def attn_cache():
        shape = (batch, max_len, kv, hd)
        return ({"k": jax.ShapeDtypeStruct(shape, jnp.bfloat16),
                 "v": jax.ShapeDtypeStruct(shape, jnp.bfloat16)},
                {"k": P(bspec, "tp", None, None),
                 "v": P(bspec, "tp", None, None)})

    def mamba_cache():
        d_inner, H, N, hdm = ssm_dims(cfg)
        # kv_cache_quant also downgrades the SSD state fp32->bf16
        # (serving §Perf: halves the dominant decode state traffic)
        sdt = jnp.bfloat16 if cfg.kv_cache_quant else jnp.float32
        return ({"conv": jax.ShapeDtypeStruct(
                    (batch, cfg.ssm.conv_width - 1, d_inner), jnp.bfloat16),
                 "ssm": jax.ShapeDtypeStruct((batch, H, hdm, N), sdt)},
                {"conv": P(bspec, None, "tp"),
                 "ssm": P(bspec, "tp", None, None)})

    if cfg.family == "encdec":
        self_sds, self_spec = attn_cache()
        ck = (batch, enc_len or max_len, kv, hd)
        cross_sds = {"k": jax.ShapeDtypeStruct(ck, jnp.bfloat16),
                     "v": jax.ShapeDtypeStruct(ck, jnp.bfloat16)}
        cross_spec = {"k": P(bspec, "tp", None, None),
                      "v": P(bspec, "tp", None, None)}
        sds = {"self": self_sds, "cross": cross_sds}
        spec = {"self": self_spec, "cross": cross_spec}
        return (_stack_sds(sds, cfg.num_layers),
                _stack_spec(spec, cfg.num_layers))

    per = plan_period(cfg)
    plan = layer_plan(cfg)[:per]
    n_groups = cfg.num_layers // per

    def one(mx):
        return attn_cache() if mx == "attn" else mamba_cache()

    if per == 1:
        sds, spec = one(plan[0][0])
        return _stack_sds(sds, n_groups), _stack_spec(spec, n_groups)
    sds = {}
    spec = {}
    for i, (mx, _f) in enumerate(plan):
        s, sp = one(mx)
        sds[f"sub{i}"] = s
        spec[f"sub{i}"] = sp
    return _stack_sds(sds, n_groups), _stack_spec(spec, n_groups)


def _stack_sds(tree, n):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)


def _stack_spec(tree, n):
    return jax.tree.map(lambda s: P(*((None,) + tuple(s))), tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

_DECLS_CACHE = {}


def model_decls_cache(cfg, axes):
    key = (cfg.name, cfg.ffn_impl, cfg.phantom, cfg.projections, axes.tp,
           axes.dp, axes.pp, cfg.fsdp)
    if key not in _DECLS_CACHE:
        _DECLS_CACHE[key] = model_decls(cfg, axes)
    return _DECLS_CACHE[key]


def count_params(cfg: ModelConfig, active_only: bool = False,
                 tp: int = 16) -> int:
    if cfg.family == "ffn":
        from repro.core.ffn import ffn_model_params
        return ffn_model_params(cfg, tp)
    axes = MeshAxes(tp=tp, dp=1, dp_names=("data",))
    decls = model_decls(cfg, axes)
    total = param_count(decls)
    if active_only and cfg.moe is not None:
        m = cfg.moe
        n_moe = sum(1 for _mx, ff in layer_plan(cfg) if ff == "moe")
        per_layer_expert = (m.num_experts * cfg.d_model * m.d_ff_expert
                            * (3 if cfg.mlp == "swiglu" else 2))
        inactive = per_layer_expert * (1 - m.top_k / m.num_experts) * n_moe
        total -= int(inactive)
    return total
