"""GQA attention with three sharding modes (DESIGN.md §6), all explicit
collectives inside shard_map:

* ``head`` — Megatron-style: Q heads column-sharded over the model axis
  (requires H % tp == 0); KV heads column-sharded when kv % tp == 0, else
  the (small) KV projection is replicated and each rank dynamic-slices its
  GQA group's head.  Optionally the projections themselves are *phantom*
  matmuls (the paper's technique applied to attention — beyond-paper).

* ``ring`` — sequence-sharded ring attention for archs whose head counts
  don't divide the model axis (granite 24H, qwen2.5 40H on tp=16): each
  rank holds a seq chunk with FULL heads; KV rotates via ppermute with
  online-softmax accumulation.  Projection weights are sharded on the
  input dim and gathered on use.

* decode — KV cache is *sequence-sharded* over the model axis
  ([L, B, Smax/p, kv, hd] local chunks); every rank computes partial
  attention of the (replicated, tiny) new-token Q over its chunk and the
  partials merge with a flash-decoding log-sum-exp psum.  Works for every
  GQA geometry with zero head-divisibility constraints.

The attention core is blockwise (kv-chunked online softmax) so no
[B, S, S] score tensor is ever materialized — 32k prefill stays within
VMEM-scale working sets.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import PHANTOM_KINDS
from repro.kernels.ops import (flash_attention_supported,
                               flash_attention_vjp,
                               resolve_kernel_backend)
from repro.models import rope as ropemod
from repro.models.layers import (from_partial, gather_fsdp, gather_on_use,
                                 seq_to_feature, to_full)
from repro.parallel.axes import MeshAxes
from repro.parallel.params import ParamDecl
from repro.parallel.strategies import site_strategy

NEG_INF = -1e30

_ATTN_SITES = {"wq": "attn_q", "wk": "attn_k", "wv": "attn_v",
               "wo": "attn_o"}


def _kv_chunk(cfg, full: int, default: int) -> int:
    """-1 = unrolled (single block; dry-run cost accounting), 0 = default
    blockwise size, else explicit."""
    if cfg.attn_kv_chunk == -1:
        return full
    return cfg.attn_kv_chunk or default


def resolve_attn_mode(cfg, axes: MeshAxes) -> str:
    if cfg.attn_shard in ("head", "ring"):
        return cfg.attn_shard
    return "head" if cfg.num_heads % axes.tp == 0 else "ring"


def attn_site_strategies(cfg, axes: MeshAxes, cross: bool = False):
    """Per-site ProjectionStrategy for the four attention projections.

    Phantom-family specs only take effect in head mode with divisible
    head/feature counts (the factorization's layout constraints); any
    site failing the guard silently falls back to its dense strategy —
    the same all-or-nothing conditions the old ``uses_phantom_proj``
    applied, now enforced per site.  Cross-attention K/V read encoder
    memory (replicated, never feature-sharded) so they are always dense.
    """
    d, H, kv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim()
    p = axes.tp
    ok = (resolve_attn_mode(cfg, axes) == "head"
          and H % p == 0 and kv % p == 0 and d % p == 0)
    dims = {"wq": (d, H * hd), "wk": (d, kv * hd), "wv": (d, kv * hd),
            "wo": (H * hd, d)}
    sts = {}
    for name, (ni, no) in dims.items():
        bias = cfg.qkv_bias and name != "wo"
        allow = ok and not (cross and name in ("wk", "wv"))
        sts[name] = site_strategy(cfg, _ATTN_SITES[name], ni, no, p,
                                  dp=axes.dp, bias=bias, fsdp=cfg.fsdp,
                                  allow_phantom=allow)
    return sts


def _is_phantom(st) -> bool:
    return st.kind in PHANTOM_KINDS


def _attn_kernel_backend(sts) -> str:
    """The attention core runs the Pallas flash kernel only when ALL
    four q/k/v/o site specs resolve to the pallas backend (one core, one
    switch — partial selection would silently mix numerics)."""
    backends = {resolve_kernel_backend(st.spec.kernel_backend)
                for st in sts.values()}
    return "pallas" if backends == {"pallas"} else "xla"


# ---------------------------------------------------------------------------
# declarations
# ---------------------------------------------------------------------------

def attn_decls(cfg, axes: MeshAxes, cross: bool = False):
    d, H, kv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim()
    p = axes.tp
    mode = resolve_attn_mode(cfg, axes)
    fs = "dp" if cfg.fsdp else None
    bias = cfg.qkv_bias

    if mode == "ring":
        # input-dim sharded, gathered on use (DESIGN.md §6); the strategy
        # API does not govern ring projections
        dec = {
            "wq": {"w": ParamDecl((d, H * hd), P("tp", None))},
            "wk": {"w": ParamDecl((d, kv * hd), P("tp", None))},
            "wv": {"w": ParamDecl((d, kv * hd), P("tp", None))},
            "wo": {"w": ParamDecl((H * hd, d), P("tp", None))},
        }
        if bias:
            dec["wq"]["b"] = ParamDecl((H * hd,), P(), init="zeros")
            dec["wk"]["b"] = ParamDecl((kv * hd,), P(), init="zeros")
            dec["wv"]["b"] = ParamDecl((kv * hd,), P(), init="zeros")
        return dec

    sts = attn_site_strategies(cfg, axes, cross=cross)
    kv_sharded = kv % p == 0
    dec = {"wq": sts["wq"].decls(), "wo": sts["wo"].decls()}
    if kv_sharded:
        dec["wk"] = sts["wk"].decls()
        dec["wv"] = sts["wv"].decls()
    else:
        # replicated (small) KV projection; each rank slices its GQA head
        dec["wk"] = {"w": ParamDecl((d, kv * hd), P())}
        dec["wv"] = {"w": ParamDecl((d, kv * hd), P())}
        if bias:
            dec["wk"]["b"] = ParamDecl((kv * hd,), P(), init="zeros")
            dec["wv"]["b"] = ParamDecl((kv * hd,), P(), init="zeros")
    return dec


# ---------------------------------------------------------------------------
# blockwise online-softmax attention core
# ---------------------------------------------------------------------------

class AttnAcc(NamedTuple):
    num: jax.Array      # [B, Sq, KV, Hg, hd] fp32 running numerator
    m: jax.Array        # [B, Sq, KV, Hg] running max
    l: jax.Array        # [B, Sq, KV, Hg] running denominator


def init_acc(B, Sq, KV, Hg, hd):
    return AttnAcc(jnp.zeros((B, Sq, KV, Hg, hd), jnp.float32),
                   jnp.full((B, Sq, KV, Hg), NEG_INF, jnp.float32),
                   jnp.zeros((B, Sq, KV, Hg), jnp.float32))


def attn_block_update(acc: AttnAcc, q, k, v, q_pos, kv_pos0, *,
                      causal: bool, kv_limit=None, kv_chunk: int = 512,
                      scores_dtype=jnp.float32):
    """Accumulate attention of q against (k, v), kv-chunked.

    q: [B, Sq, KV, Hg, hd]   (Hg = query heads per kv head)
    k,v: [B, Skv, KV, hd]
    q_pos: [B, Sq] global query positions (int32; per-sequence for the
      continuous-batching decode path)
    kv_pos0: scalar global position of k[:,0]
    kv_limit: optional [B]; kv positions >= kv_limit[b] are masked (decode
      masks unwritten cache slots).
    """
    B, Skv = k.shape[0], k.shape[1]
    hd = q.shape[-1]
    kv_chunk = min(kv_chunk, Skv)
    n = Skv // kv_chunk
    assert Skv % kv_chunk == 0, (Skv, kv_chunk)
    scale = hd ** -0.5

    def body(acc, i):
        ks = lax.dynamic_slice_in_dim(k, i * kv_chunk, kv_chunk, 1)
        vs = lax.dynamic_slice_in_dim(v, i * kv_chunk, kv_chunk, 1)
        # score chain kept END-TO-END in scores_dtype: bf16 halves the
        # dominant HBM traffic of blockwise attention (§Perf; the max
        # shift keeps exp args near 0 so bf16 exp is safe); the running
        # softmax stats and the accumulator stay fp32.
        s = jnp.einsum("bqkgh,bckh->bqkgc", q.astype(scores_dtype),
                       ks.astype(scores_dtype),
                       preferred_element_type=scores_dtype) \
            * jnp.asarray(scale, scores_dtype)
        kv_pos = kv_pos0 + i * kv_chunk + jnp.arange(kv_chunk)
        mask = jnp.ones((B, q.shape[1], kv_chunk), bool)
        if causal:
            mask = mask & (kv_pos[None, None, :] <= q_pos[:, :, None])
        if kv_limit is not None:
            mask = mask & (kv_pos[None, None, :]
                           < kv_limit[:, None, None])
        s = jnp.where(mask[:, :, None, None, :], s,
                      jnp.asarray(NEG_INF, scores_dtype))
        m_new = jnp.maximum(acc.m, jnp.max(s, axis=-1).astype(jnp.float32))
        # guard: fully-masked rows keep m at NEG_INF; exp() underflows to 0
        p_ = jnp.exp(s - m_new[..., None].astype(scores_dtype))
        corr = jnp.exp(acc.m - m_new)
        num = (acc.num * corr[..., None]
               + jnp.einsum("bqkgc,bckh->bqkgh", p_,
                            vs.astype(scores_dtype),
                            preferred_element_type=jnp.float32))
        l_ = acc.l * corr + jnp.sum(p_, axis=-1, dtype=jnp.float32)
        return AttnAcc(num, m_new, l_), None

    acc, _ = lax.scan(body, acc, jnp.arange(n))
    return acc


def finalize_acc(acc: AttnAcc, dtype):
    l_ = jnp.maximum(acc.l, 1e-30)
    out = acc.num / l_[..., None]
    B, Sq, KV, Hg, hd = out.shape
    return out.reshape(B, Sq, KV * Hg, hd).astype(dtype)


def _gqa_q(q, KV):
    """[B, S, H, hd] -> [B, S, KV, H/KV, hd]."""
    B, S, H, hd = q.shape
    return q.reshape(B, S, KV, H // KV, hd)


# ---------------------------------------------------------------------------
# projection helpers
# ---------------------------------------------------------------------------

def _proj(params, x, nheads, hd, dtype, bias_key="b"):
    w = params["w"].astype(dtype)
    y = jnp.einsum("...d,dn->...n", x.astype(dtype), w)
    if bias_key in params:
        y = y + params[bias_key].astype(dtype)
    return y.reshape(*y.shape[:-1], nheads, hd)


def _site_proj(st, params, x_full, x_shard, nh_local, hd, axes, dtype):
    """One head-mode projection through its strategy: phantom consumes the
    feature shard, tensor-col the gathered features; both emit the local
    [..., nh_local, hd] head shard."""
    if _is_phantom(st):
        y = st.apply(params, x_shard, axes=axes, compute_dtype=dtype)
    else:
        y = st.apply(params, x_full, compute_dtype=dtype)
    return y.reshape(*y.shape[:-1], nh_local, hd)


# ---------------------------------------------------------------------------
# main entry
# ---------------------------------------------------------------------------

def attention(cfg, layout: str, params, x, positions, axes: MeshAxes,
              decls=None, *, kind: str = "train", causal: bool = True,
              cache=None, pos=None, memory=None, cross: bool = False,
              return_kv: bool = False):
    """Returns (residual-shard out, new_kv_or_None).

    kind: train | prefill | decode.  memory: encoder output (cross-attn,
    full [B, S_enc, d] per-rank).  cache: decode KV cache {k, v} local
    [B, Smax/p, kv, hd] (cross decode reads it, never writes).  pos:
    decode position.
    """
    mode = resolve_attn_mode(cfg, axes)
    if kind == "decode":
        return _attention_decode(cfg, layout, params, x, axes, decls,
                                 cache=cache, pos=pos, cross=cross)
    if mode == "ring" and not cross:
        return _attention_ring(cfg, layout, params, x, positions, axes,
                               decls, kind=kind, causal=causal,
                               return_kv=return_kv)
    return _attention_head(cfg, layout, params, x, positions, axes, decls,
                           kind=kind, causal=causal,
                           memory=memory if cross else None,
                           return_kv=return_kv)


def _qkv_head_mode(cfg, params, x_full, x_shard, positions, axes, decls,
                   dtype, sts, rope=True):
    """Per-site QKV in head mode. Returns q [B,S,Hloc,hd],
    k/v [B,S,KVloc,hd] (KVloc = kv/p, or full kv if replicated)."""
    H, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim()
    p = axes.tp
    q = _site_proj(sts["wq"], _g(params, decls, "wq", axes), x_full,
                   x_shard, H // p, hd, axes, dtype)
    if kv % p == 0:
        k = _site_proj(sts["wk"], _g(params, decls, "wk", axes), x_full,
                       x_shard, kv // p, hd, axes, dtype)
        v = _site_proj(sts["wv"], _g(params, decls, "wv", axes), x_full,
                       x_shard, kv // p, hd, axes, dtype)
    else:  # replicated KV weights (strategy API not applicable)
        k = _proj(_g(params, decls, "wk", axes), x_full, kv, hd, dtype)
        v = _proj(_g(params, decls, "wv", axes), x_full, kv, hd, dtype)
    if rope and cfg.rope != "none":
        q = ropemod.rope_for(cfg, q, positions)
        k = ropemod.rope_for(cfg, k, positions)
    return q, k, v


def _attention_head(cfg, layout, params, x, positions, axes, decls, *,
                    kind, causal, memory=None, return_kv=False):
    H, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim()
    p = axes.tp
    dtype = jnp.dtype(cfg.dtype)
    sts = attn_site_strategies(cfg, axes, cross=memory is not None)
    if memory is None:
        x_users = [sts["wq"], sts["wk"], sts["wv"]]
    else:
        x_users = [sts["wq"]]                    # cross KV read `memory`
    need_full = any(not _is_phantom(st) for st in x_users) or kv % p != 0
    j = lax.axis_index(axes.tp_name)

    # phantom sites consume the fp feature shard directly (no gather);
    # tensor sites need the gathered features — compute only if used.
    x_shard = x if layout == "fp" else None
    xq = to_full(x, layout, axes) if need_full else None

    if memory is None:
        q, k, v = _qkv_head_mode(cfg, params, xq, x_shard, positions, axes,
                                 decls, dtype, sts)
        kv_positions = positions
    else:
        # cross-attention: q from x, kv from encoder memory (full [B,Se,d])
        q = _site_proj(sts["wq"], _g(params, decls, "wq", axes), xq,
                       x_shard, H // p, hd, axes, dtype)
        kvh = kv // p if kv % p == 0 else kv
        k = _proj(_g(params, decls, "wk", axes), memory, kvh, hd, dtype)
        v = _proj(_g(params, decls, "wv", axes), memory, kvh, hd, dtype)
        causal = False
        kv_positions = None

    B, S = q.shape[0], q.shape[1]
    kv_sharded = (kv % p == 0)
    if not kv_sharded:
        # replicated KV weights: slice this rank's GQA group's head(s)
        grp = (j * kv) // p
        k_use = lax.dynamic_slice_in_dim(k, grp, 1, axis=2)
        v_use = lax.dynamic_slice_in_dim(v, grp, 1, axis=2)
        KV_loc = 1
    else:
        k_use, v_use = k, v
        KV_loc = kv // p

    Hg = (H // p) // KV_loc
    Skv = k_use.shape[1]
    use_flash = (memory is None
                 and _attn_kernel_backend(sts) == "pallas"
                 and flash_attention_supported(S, Skv, H // p, KV_loc))
    if use_flash:
        # fused Pallas core: scores + online softmax stay in VMEM
        out = flash_attention_vjp(q, k_use, v_use,
                                  causal=causal).astype(dtype)
    else:
        qg = _gqa_q(q, KV_loc)
        acc = init_acc(B, S, KV_loc, Hg, hd)
        q_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        sdt = jnp.bfloat16 if cfg.attn_bf16_scores else jnp.float32
        kvc = _kv_chunk(cfg, Skv, 512)
        acc = attn_block_update(acc, qg, k_use, v_use, q_pos, 0,
                                causal=causal, scores_dtype=sdt,
                                kv_chunk=kvc)
        out = finalize_acc(acc, dtype)           # [B, S, Hloc, hd]
    out = out.reshape(B, S, -1)

    if _is_phantom(sts["wo"]):
        z = sts["wo"].apply(_g(params, decls, "wo", axes), out, axes=axes,
                            compute_dtype=dtype)
        res = z                                   # stays feature-sharded
    else:
        z = sts["wo"].apply(_g(params, decls, "wo", axes), out,
                            compute_dtype=dtype)  # partial over tp
        res = from_partial(z, layout, axes)

    new_kv = None
    if return_kv:
        new_kv = _emit_cache_head_mode(k, v, kv_sharded, axes)
    return res, new_kv


def _emit_cache_head_mode(k, v, kv_sharded, axes):
    """Convert prefill-layout KV to the decode cache layout
    [B, S/p, kv, hd] (sequence-sharded)."""
    p = axes.tp
    if kv_sharded:
        # [B, S, kv/p, hd] head-sharded -> all_to_all -> [B, S/p, kv, hd]
        ck = lax.all_to_all(k, axes.tp_name, split_axis=1, concat_axis=2,
                            tiled=True)
        cv = lax.all_to_all(v, axes.tp_name, split_axis=1, concat_axis=2,
                            tiled=True)
        return {"k": ck, "v": cv}
    # replicated KV: every rank holds identical full [B, S, kv, hd];
    # just slice this rank's seq chunk.
    j = lax.axis_index(axes.tp_name)
    chunk = k.shape[1] // p
    ck = lax.dynamic_slice_in_dim(k, j * chunk, chunk, 1)
    cv = lax.dynamic_slice_in_dim(v, j * chunk, chunk, 1)
    return {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# ring attention (sequence-sharded; granite/qwen2.5 train+prefill)
# ---------------------------------------------------------------------------

def _attention_ring(cfg, layout, params, x, positions, axes, decls, *,
                    kind, causal, return_kv=False):
    H, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim()
    p = axes.tp
    dtype = jnp.dtype(cfg.dtype)
    j = lax.axis_index(axes.tp_name)

    # get this rank's seq chunk with full features
    if layout == "sp":
        xc = x                                    # [B, C, d] already
    else:
        x_full = to_full(x, layout, axes)
        C = x_full.shape[1] // p
        xc = lax.dynamic_slice_in_dim(x_full, j * C, C, 1)
    B, C = xc.shape[0], xc.shape[1]

    wq = gather_on_use(_g(params, decls, "wq", axes)["w"], axes)
    wk = gather_on_use(_g(params, decls, "wk", axes)["w"], axes)
    wv = gather_on_use(_g(params, decls, "wv", axes)["w"], axes)
    wo = gather_on_use(_g(params, decls, "wo", axes)["w"], axes)

    def proj(w, b, nh):
        y = jnp.einsum("bcd,dn->bcn", xc.astype(dtype), w.astype(dtype))
        if b is not None:
            y = y + b.astype(dtype)
        return y.reshape(B, C, nh, hd)

    q = proj(wq, params["wq"].get("b"), H)
    k = proj(wk, params["wk"].get("b"), kv)
    v = proj(wv, params["wv"].get("b"), kv)

    # positions of this chunk
    chunk_pos = j * C + jnp.arange(C)
    if cfg.rope != "none":
        if cfg.rope == "mrope":
            pos_c = lax.dynamic_slice_in_dim(positions, j * C, C, 2)
            q = ropemod.rope_for(cfg, q, pos_c)
            k = ropemod.rope_for(cfg, k, pos_c)
        else:
            pos_c = chunk_pos[None, :].astype(jnp.int32)
            q = ropemod.rope_for(cfg, q, jnp.broadcast_to(pos_c, (B, C)))
            k = ropemod.rope_for(cfg, k, jnp.broadcast_to(pos_c, (B, C)))

    qg = _gqa_q(q, kv)
    acc = init_acc(B, C, kv, H // kv, hd)
    sdt = jnp.bfloat16 if cfg.attn_bf16_scores else jnp.float32

    if cfg.attn_ring_gather_kv:
        # gather-KV variant (§Perf cell C): one all-gather of the (small)
        # KV instead of p ppermute hops — same wire bytes, but the online-
        # softmax accumulator is written ONCE instead of p times.  The
        # gathered KV must be in global seq order: gather stacks by rank,
        # which IS seq order for sp sharding.
        k_all = lax.all_gather(k, axes.tp_name, axis=1, tiled=True)
        v_all = lax.all_gather(v, axes.tp_name, axis=1, tiled=True)
        acc = attn_block_update(acc, qg, k_all, v_all,
                                jnp.broadcast_to(chunk_pos, (B, C)),
                                0, causal=causal, scores_dtype=sdt,
                                kv_chunk=_kv_chunk(cfg, p * C, 512))
    else:
        perm = [(s, (s + 1) % p) for s in range(p)]
        k_rot, v_rot = k, v
        for s in range(p):
            src = (j - s) % p
            kv_pos0 = src * C
            acc = attn_block_update(acc, qg, k_rot, v_rot,
                                    jnp.broadcast_to(chunk_pos, (B, C)),
                                    kv_pos0, causal=causal,
                                    scores_dtype=sdt,
                                    kv_chunk=_kv_chunk(cfg, C, 512))
            if s < p - 1:
                k_rot = lax.ppermute(k_rot, axes.tp_name, perm)
                v_rot = lax.ppermute(v_rot, axes.tp_name, perm)

    out = finalize_acc(acc, dtype).reshape(B, C, H * hd)
    z = jnp.einsum("bcn,nd->bcd", out, wo.astype(dtype))   # [B, C, d]

    if layout == "sp":
        res = z
    else:
        res = seq_to_feature(z, axes)             # [B, S, d/p]

    new_kv = {"k": k, "v": v} if return_kv else None   # already seq-sharded
    return res, new_kv


# ---------------------------------------------------------------------------
# decode: seq-sharded cache + LSE-combine (flash-decoding over the mesh)
# ---------------------------------------------------------------------------

def _attention_decode(cfg, layout, params, x, axes, decls, *, cache, pos,
                      cross=False):
    H, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim()
    p = axes.tp
    dtype = jnp.dtype(cfg.dtype)
    j = lax.axis_index(axes.tp_name)
    mode = resolve_attn_mode(cfg, axes)
    sts = attn_site_strategies(cfg, axes, cross=cross)

    x_full = to_full(x, layout, axes)             # [B, 1, d] tiny
    x_shard = x if layout == "fp" else None
    B = x_full.shape[0]

    # --- project the new token; all ranks need FULL heads -> tiny gathers
    if mode == "ring":
        wq = gather_on_use(_g(params, decls, "wq", axes)["w"], axes)
        q = jnp.einsum("btd,dn->btn", x_full.astype(dtype),
                       wq.astype(dtype))
        if "b" in params["wq"]:
            q = q + params["wq"]["b"].astype(dtype)
        q = q.reshape(B, 1, H, hd)
        if not cross:
            wk = gather_on_use(_g(params, decls, "wk", axes)["w"], axes)
            wv = gather_on_use(_g(params, decls, "wv", axes)["w"], axes)
            kn = jnp.einsum("btd,dn->btn", x_full.astype(dtype),
                            wk.astype(dtype))
            vn = jnp.einsum("btd,dn->btn", x_full.astype(dtype),
                            wv.astype(dtype))
            if "b" in params["wk"]:
                kn = kn + params["wk"]["b"].astype(dtype)
                vn = vn + params["wv"]["b"].astype(dtype)
            kn = kn.reshape(B, 1, kv, hd)
            vn = vn.reshape(B, 1, kv, hd)
    else:
        q = _site_proj(sts["wq"], _g(params, decls, "wq", axes,
                                     cfg.fsdp_gather_quant), x_full,
                       x_shard, H // p, hd, axes, dtype)
        q = lax.all_gather(q, axes.tp_name, axis=2, tiled=True)
        if not cross:
            if kv % p == 0:
                kn = _site_proj(sts["wk"], _g(params, decls, "wk", axes),
                                x_full, x_shard, kv // p, hd, axes, dtype)
                vn = _site_proj(sts["wv"], _g(params, decls, "wv", axes),
                                x_full, x_shard, kv // p, hd, axes, dtype)
                kn = lax.all_gather(kn, axes.tp_name, axis=2, tiled=True)
                vn = lax.all_gather(vn, axes.tp_name, axis=2, tiled=True)
            else:
                kn = _proj(_g(params, decls, "wk", axes), x_full, kv, hd,
                           dtype)
                vn = _proj(_g(params, decls, "wv", axes), x_full, kv, hd,
                           dtype)

    # rope on q and new kv at per-sequence positions `pos` [B]
    pos = jnp.asarray(pos, jnp.int32).reshape(B)
    if cfg.rope != "none" and cfg.rope != "mrope":
        pos_b = pos[:, None]                      # [B, 1]
        q = ropemod.rope_for(cfg, q, pos_b)
        if not cross:
            kn = ropemod.rope_for(cfg, kn, pos_b)
    elif cfg.rope == "mrope":
        pos3 = jnp.broadcast_to(pos[None, :, None], (3, B, 1))
        q = ropemod.rope_for(cfg, q, pos3)
        if not cross:
            kn = ropemod.rope_for(cfg, kn, pos3)

    # --- cache update: write each row's new kv into this rank's chunk ----
    chunk = cache["k"].shape[1]
    if not cross:
        local_idx = pos - j * chunk               # [B]
        in_range = (local_idx >= 0) & (local_idx < chunk)
        widx = jnp.clip(local_idx, 0, chunk - 1)
        rows = jnp.arange(B)
        kcur = cache["k"][rows, widx]             # [B, kv, hd]
        vcur = cache["v"][rows, widx]
        sel = in_range[:, None, None]
        kwrite = jnp.where(sel, kn[:, 0].astype(cache["k"].dtype), kcur)
        vwrite = jnp.where(sel, vn[:, 0].astype(cache["v"].dtype), vcur)
        new_cache = {
            "k": cache["k"].at[rows, widx].set(kwrite),
            "v": cache["v"].at[rows, widx].set(vwrite),
        }
    else:
        new_cache = cache

    # --- partial attention over the local chunk --------------------------
    qg = _gqa_q(q, kv)                            # [B, 1, kv, H/kv, hd]
    acc = init_acc(B, 1, kv, H // kv, hd)
    kv_pos0 = j * chunk
    kv_limit = (pos + 1) if not cross else None
    acc = attn_block_update(acc, qg, new_cache["k"], new_cache["v"],
                            pos[:, None], kv_pos0,
                            causal=not cross, kv_limit=kv_limit,
                            kv_chunk=_kv_chunk(cfg, chunk,
                                               min(1024, chunk)),
                            scores_dtype=(jnp.bfloat16
                                          if cfg.attn_bf16_scores
                                          else jnp.float32))

    # --- LSE combine across the model axis (flash-decoding merge) --------
    m_g = lax.pmax(acc.m, axes.tp_name)
    w = jnp.exp(acc.m - m_g)
    num = lax.psum(acc.num * w[..., None], axes.tp_name)
    den = lax.psum(acc.l * w, axes.tp_name)
    out = num / jnp.maximum(den, 1e-30)[..., None]
    out = out.reshape(B, 1, H * hd).astype(dtype)

    # --- output projection ------------------------------------------------
    if mode != "ring" and _is_phantom(sts["wo"]):
        # out is replicated; phantom wo expects feature shard: slice ours
        sl = out.reshape(B, 1, p, (H * hd) // p)
        mine = jnp.take(sl, j, axis=2)
        z = sts["wo"].apply(_g(params, decls, "wo", axes), mine, axes=axes,
                            compute_dtype=dtype)
        res = z
    else:
        wo = _g(params, decls, "wo", axes)["w"]
        if mode == "ring":
            # wo gathered: z is COMPLETE (not a partial sum) on every rank
            wo_f = gather_on_use(wo, axes)
            z = jnp.einsum("btn,nd->btd", out, wo_f.astype(dtype))
            if layout == "fp":  # slice this rank's feature shard
                fsh = z.shape[-1] // p
                res = lax.dynamic_slice_in_dim(z, j * fsh, fsh, 2)
            else:
                res = z
        else:
            # row-parallel: slice our input block, psum
            nshard = wo.shape[0]
            mine = lax.dynamic_slice_in_dim(out, j * nshard, nshard, 2)
            z = jnp.einsum("btn,nd->btd", mine, wo.astype(dtype))
            res = from_partial(z, layout, axes)
    return res, new_cache


def _g(params, decls, key, axes, quant: bool = False):
    """FSDP gather-on-use for a named projection subtree."""
    sub_p = params[key]
    if decls is None:
        return sub_p
    sub_d = decls[key]
    return jax.tree.map(
        lambda w, d: gather_fsdp(w, d.spec, axes, quant=quant), sub_p,
        sub_d, is_leaf=lambda v: isinstance(v, ParamDecl))
