"""Fleet pools: separately-meshed prefill and decode runner pools.

Each pool serves one phase of the disaggregated pipeline on its own
``ServeConfig`` (family x mesh x slots — picked per phase by predicted
joules-per-token, see ``fleet.router.plan_pools``) and runs in one of
two modes:

  * **modeled** (default) — no arrays move; step durations come from
    the calibrated ``serve_step_prediction`` (the modeled accelerator's
    alpha + beta seconds) and step energies from the same account, or
    from the pool step functions' lowered compiled-HLO pricing when
    ``price_hlo`` is on.  This is what makes million-request replays
    tractable: the discrete-event loop advances a virtual clock through
    predicted step times in pure Python.
  * **executed** — real jitted engines on the host mesh: the prefill
    pool runs the actual batched prefill and slices each request's
    cache rows out for migration; every decode replica is a
    ``ServeEngine`` (sharing one compiled step) that ``adopt``s
    migrated pages.  Tokens are real; the *clock* is still the modeled
    accelerator in both modes, so SLO numbers are comparable and the
    executed mode exists to prove token-exactness across the migration
    (tests/test_fleet.py), not to time the host CPU.

Step energy is billed at the full lowered batch shape regardless of
slot occupancy — the same honesty rule as the single-engine serving
path: a half-empty decode step costs what the static-shape step costs,
and the fleet's J/token surfaces the occupancy gap instead of hiding
it (docs/serving.md, "Fleet").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.planner.calibration import Calibration
from repro.serve.fleet.transfer import KVBundle
from repro.serve.kv_cache import PagedKVCache
from repro.serve.router import ServeConfig
from repro.serve.scheduler import bucket_of


class _TokenCount:
    """``len()``-only stand-in for a modeled request's output tokens
    (the SLO tracker and goodput weighting only ever take ``len``)."""

    __slots__ = ("n",)

    def __init__(self, n: int = 0):
        self.n = n

    def __len__(self) -> int:
        return self.n


@dataclass
class FleetRequest:
    """A modeled request — lengths and stamps, no token arrays."""
    req_id: int
    prompt_len: int
    max_new_tokens: int
    arrival_s: float = 0.0
    deadline_ms: float = 0.0
    padded_len: int = 0
    pos: int = 0
    n_out: int = 0
    done: bool = False
    error: Optional[str] = None
    t_submit_s: Optional[float] = None
    t_first_s: Optional[float] = None
    t_done_s: Optional[float] = None
    _slot: int = field(default=-1, repr=False)

    @property
    def out_tokens(self) -> _TokenCount:
        return _TokenCount(self.n_out)


def req_prompt_len(req) -> int:
    """Prompt length of a modeled OR executed request."""
    if isinstance(req, FleetRequest):
        return req.prompt_len
    return len(req.prompt)


def form_group(queue: List, slots: int, page_size: int,
               mixed: bool) -> tuple:
    """FCFS head-bucket group formation (the scheduler's policy,
    restated over either request flavor): the queue head picks the
    padded bucket, up to ``slots`` requests sharing it join.  Mutates
    ``queue``; returns ``(padded_len, group)``."""
    if not queue or slots <= 0:
        return 0, []
    def padded(r):
        s = req_prompt_len(r)
        return bucket_of(s, page_size) if mixed else s
    head = padded(queue[0])
    group = []
    for r in queue:
        if padded(r) == head:
            group.append(r)
            if len(group) == slots:
                break
    taken = set(id(r) for r in group)
    queue[:] = [r for r in queue if id(r) not in taken]
    return head, group


# ---------------------------------------------------------------------------
# per-pool step pricing
# ---------------------------------------------------------------------------

class PoolAccount:
    """Step times and energies for one pool's ``ServeConfig``.

    Durations are always the modeled accelerator (calibrated
    ``serve_step_prediction`` alpha + beta).  Energies default to the
    same prediction; with ``price_hlo`` the pool's own step functions
    are lowered once per bucket and priced through
    ``measured_energy_fields`` — the compiled-HLO measured side of the
    fleet's energy ledger rows, no execution required."""

    def __init__(self, sc: ServeConfig, calib: Calibration, *,
                 price_hlo: bool = False):
        self.sc = sc
        self.calib = calib
        self.cfg = sc.model_config()
        a_s, b_s, _nu = calib.scales_for(sc.strategy_kind)
        self.alpha_scale, self.beta_scale = a_s, b_s
        self.price_hlo = price_hlo
        self._pred_pre: Dict[int, dict] = {}
        self._pred_dec: Optional[dict] = None
        self._hlo_pre: Dict[int, dict] = {}
        self._hlo_dec: Optional[dict] = None
        self._mesh = None
        self._fns = None

    # --- predictions -----------------------------------------------------

    def predicted_prefill(self, S: int) -> dict:
        if S not in self._pred_pre:
            from repro.telemetry.predict import serve_step_prediction
            sc = self.sc
            self._pred_pre[S] = serve_step_prediction(
                self.cfg, sc.tp, sc.slots * S, phase="prefill",
                ctx_tokens=float(S), sequences=sc.slots, dp=sc.dp,
                fits=self.calib.collective_fits,
                alpha_scale=self.alpha_scale,
                beta_scale=self.beta_scale)
        return self._pred_pre[S]

    def predicted_decode(self) -> dict:
        if self._pred_dec is None:
            from repro.telemetry.predict import serve_step_prediction
            sc = self.sc
            self._pred_dec = serve_step_prediction(
                self.cfg, sc.tp, sc.slots, phase="decode",
                ctx_tokens=float(sc.max_len), dp=sc.dp,
                fits=self.calib.collective_fits,
                alpha_scale=self.alpha_scale,
                beta_scale=self.beta_scale)
        return self._pred_dec

    # --- lowered step functions ------------------------------------------

    def ensure_fns(self):
        """Mesh + jitted serve fns for this pool (lowering-only in
        modeled mode; the executed pools call them for real)."""
        if self._fns is None:
            from repro.configs.base import ShapeConfig
            from repro.launch.mesh import make_local_mesh
            from repro.serve.engine import make_serve_fns
            sc = self.sc
            self._mesh = make_local_mesh(sc.dp, sc.tp)
            shape = ShapeConfig("serve", sc.max_len, sc.slots, "decode")
            self._fns = make_serve_fns(self.cfg, self._mesh, shape)
        return self._mesh, self._fns

    def _param_sds(self):
        from repro.models.model import model_decls
        from repro.parallel.axes import MeshAxes
        from repro.parallel.params import abstract
        mesh, _ = self.ensure_fns()
        return abstract(model_decls(self.cfg, MeshAxes.from_mesh(mesh)))

    def measured_prefill(self, S: int) -> dict:
        if S not in self._hlo_pre:
            import jax
            import numpy as np
            from repro.serve.engine import _add_modality_stubs
            from repro.telemetry import (analyze_lowerable,
                                         measured_energy_fields)
            sc = self.sc
            _, fns = self.ensure_fns()
            probe = _add_modality_stubs(
                self.cfg,
                {"tokens": jax.ShapeDtypeStruct((sc.slots, S),
                                                np.int32)},
                sc.slots, S)
            costs = analyze_lowerable(fns[0], self._param_sds(), probe,
                                      default_group=sc.tp)
            self._hlo_pre[S] = measured_energy_fields(
                costs, sc.tp, fits=self.calib.collective_fits)
        return self._hlo_pre[S]

    def measured_decode(self) -> dict:
        if self._hlo_dec is None:
            import jax
            import numpy as np
            from repro.telemetry import (analyze_lowerable,
                                         measured_energy_fields)
            sc = self.sc
            _, fns = self.ensure_fns()
            tok = jax.ShapeDtypeStruct((sc.slots, 1), np.int32)
            pos = jax.ShapeDtypeStruct((sc.slots,), np.int32)
            costs = analyze_lowerable(fns[1], self._param_sds(),
                                      fns[2], tok, pos,
                                      default_group=sc.tp)
            self._hlo_dec = measured_energy_fields(
                costs, sc.tp, fits=self.calib.collective_fits)
        return self._hlo_dec

    # --- step cost -------------------------------------------------------

    def prefill_step(self, S: int) -> tuple:
        """(step_s, energy_j) of one GLOBAL prefill step at bucket S
        (all dp groups; slots*dp prompts)."""
        pred = self.predicted_prefill(S)
        step_s = pred["alpha_s"] + pred["beta_s"]
        src = self.measured_prefill(S) if self.price_hlo else pred
        return step_s, src["energy_j_per_iter"] * self.sc.dp

    def decode_step(self) -> tuple:
        """(step_s, energy_j) of one GLOBAL decode step (slots*dp
        token rows at the full static batch shape)."""
        pred = self.predicted_decode()
        step_s = pred["alpha_s"] + pred["beta_s"]
        src = self.measured_decode() if self.price_hlo else pred
        return step_s, src["energy_j_per_iter"] * self.sc.dp


# ---------------------------------------------------------------------------
# replicas
# ---------------------------------------------------------------------------

@dataclass
class Replica:
    """Shared replica lifecycle state (both pools)."""
    id: int
    state: str = "warming"        # warming | active | draining
    spawn_s: float = 0.0          # when the replica started burning
    ready_s: float = 0.0
    busy: bool = False
    busy_until: float = 0.0
    window_busy_s: float = 0.0    # busy time since the last policy tick
    steps: int = 0


class DecodeReplica(Replica):
    """One decode engine: page table + active requests (modeled), or a
    real ``ServeEngine`` sharing the pool's compiled step (executed)."""

    def __init__(self, rid: int, sc: ServeConfig, engine=None):
        super().__init__(rid)
        self.sc = sc
        self.engine = engine
        self.pages = engine.pages if engine is not None else \
            PagedKVCache(sc.slots, sc.max_len, sc.page_size)
        self.active: List = []        # requests resident in slots
        self.stepping: List = []      # cohort of the in-flight step
        self._free_slots = list(range(sc.slots))

    def n_active(self) -> int:
        return len(self.active)

    def free_slots(self) -> int:
        return self.sc.slots - len(self.active)

    # --- adoption --------------------------------------------------------

    def can_adopt(self, bundle: KVBundle) -> bool:
        if self.state != "active" or not self.free_slots():
            return False
        req = bundle.req
        return self.pages.can_admit(req_prompt_len(req),
                                    req.max_new_tokens,
                                    bundle.prefill_len)

    def adopt(self, bundle: KVBundle):
        req = bundle.req
        if self.engine is not None:
            self.engine.adopt(req, bundle.cache_rows,
                              prefill_len=bundle.prefill_len,
                              pos=bundle.pos, last_tok=bundle.last_tok)
        else:
            slot = self._free_slots.pop(0)
            self.pages.alloc(slot, bundle.prefill_len)
            req._slot = slot
            req.pos = bundle.pos
        self.active.append(req)

    # --- one decode step -------------------------------------------------

    def start_step(self, now_s: float, step_s: float):
        """Snapshot the stepping cohort; executed replicas run the real
        engine NOW with its virtual clock pinned to the completion time
        so finish stamps land on the fleet clock."""
        self.busy = True
        self.busy_until = now_s + step_s
        self.stepping = list(self.active)
        if self.engine is not None:
            self.engine.now_s = now_s + step_s
            self.engine.step()

    def finish_step(self, now_s: float) -> List:
        """Apply the step's effects at its (virtual) completion time;
        returns the requests that finished."""
        self.busy = False
        self.steps += 1
        done = []
        if self.engine is not None:
            # the engine already advanced state/pages and stamped
            # t_first/t_done on the pinned clock — just collect
            done = [r for r in self.stepping if r.done]
        else:
            for req in self.stepping:
                wrote = req.pos
                req.pos += 1
                self.pages.advance(req._slot, wrote)
                req.n_out += 1
                if req.t_first_s is None:
                    req.t_first_s = now_s
                if (req.n_out >= req.max_new_tokens
                        or req.pos >= self.sc.max_len - 1):
                    req.done = True
                    req.t_done_s = now_s
                    self.pages.free(req._slot)
                    self._free_slots.append(req._slot)
                    self._free_slots.sort()
                    done.append(req)
        finished = set(id(r) for r in done)
        self.active = [r for r in self.active
                       if id(r) not in finished]
        self.stepping = []
        return done


# ---------------------------------------------------------------------------
# pools
# ---------------------------------------------------------------------------

class PrefillPool:
    """Stateless prefill replicas: each runs one length-bucketed group
    per step and hands every surviving request to the transfer channel
    as a ``KVBundle``."""

    def __init__(self, sc: ServeConfig, account: PoolAccount, *,
                 executed: bool = False, seed: int = 0,
                 n_init: int = 1):
        self.sc = sc
        self.account = account
        self.executed = executed
        self.queue: List = []
        self.replicas: List[Replica] = []
        self.retired = 0
        self._next_id = 0
        self.energy_j = 0.0           # compute (stepped) joules
        self.steps = 0
        self.steps_by_bucket: Dict[int, int] = {}
        self.prompt_tokens = 0
        self.busy_s = 0.0             # replica-seconds spent stepping
        self.device_s = 0.0           # device-seconds powered (uptime)
        self.params = None
        if executed:
            from repro.parallel.axes import MeshAxes
            from repro.models.model import model_decls
            from repro.parallel.params import materialize
            mesh, _ = account.ensure_fns()
            self.params = materialize(
                model_decls(account.cfg, MeshAxes.from_mesh(mesh)),
                seed)
        for _ in range(n_init):   # 0 = colocated (no prefill replicas)
            rep = self.add_replica(0.0, 0.0)
            rep.state = "active"

    @property
    def mixed_lengths(self) -> bool:
        from repro.serve.engine import RECURRENT_FAMILIES
        return self.account.cfg.family not in RECURRENT_FAMILIES

    def add_replica(self, now_s: float, spinup_s: float) -> Replica:
        rep = Replica(self._next_id, spawn_s=now_s,
                      ready_s=now_s + spinup_s)
        self._next_id += 1
        self.replicas.append(rep)
        return rep

    def n_active(self) -> int:
        return sum(r.state == "active" for r in self.replicas)

    def n_warming(self) -> int:
        return sum(r.state == "warming" for r in self.replicas)

    def retire(self, rep: Replica, now_s: float = 0.0):
        self.replicas.remove(rep)
        self.retired += 1
        self.device_s += self.sc.devices * max(now_s - rep.spawn_s, 0.0)

    def close_uptime(self, end_s: float):
        """Bill the remaining replicas' uptime at the end of a run."""
        for rep in self.replicas:
            self.device_s += self.sc.devices * \
                max(end_s - rep.spawn_s, 0.0)
            rep.spawn_s = end_s

    # --- one prefill step ------------------------------------------------

    def start_group(self, rep: Replica, S: int, group: List,
                    now_s: float) -> tuple:
        """Begin one batched prefill; returns ``(done_t, results)``
        where each result is ``(req, bundle_or_None, first_tok_done)``
        applied by the router at ``done_t``."""
        step_s, e_j = self.account.prefill_step(S)
        if rep is not None:        # colocated: the decode replica hosts
            rep.busy = True        # the step; the router marks it busy
            rep.busy_until = now_s + step_s
            rep.steps += 1
        self.steps += 1
        self.steps_by_bucket[S] = self.steps_by_bucket.get(S, 0) + 1
        self.energy_j += e_j
        self.busy_s += step_s
        self.prompt_tokens += sum(req_prompt_len(r) for r in group)
        if self.executed:
            results = self._execute_group(S, group)
        else:
            results = []
            for req in group:
                exact = req.prompt_len == S
                if exact and req.max_new_tokens <= 1:
                    results.append((req, None, True))
                else:
                    pos = S if exact else req.prompt_len - 1
                    results.append((req, KVBundle(
                        req=req, prefill_len=S, pos=pos, last_tok=0),
                        exact))
        return now_s + step_s, results

    def _execute_group(self, S: int, group: List) -> List:
        """Real batched prefill: run the pool's prefill fn, slice each
        request's cache rows for migration, and sample the first token
        for exact-length prompts (the engine's replay-last-token
        contract, mirrored here so adoption reproduces
        ``_prefill_group`` state exactly)."""
        import jax
        import numpy as np
        from repro.serve.engine import _add_modality_stubs
        from repro.serve.sampling import Sampler
        _, fns = self.account.ensure_fns()
        prefill_fn = fns[0]
        slots = self.sc.slots
        toks = np.zeros((slots, S), np.int32)
        for i, req in enumerate(group):
            toks[i, :len(req.prompt)] = req.prompt
        import jax.numpy as jnp
        batch = _add_modality_stubs(
            self.account.cfg, {"tokens": jnp.asarray(toks)}, slots, S)
        logits, fresh = prefill_fn(self.params, batch)
        logits = np.asarray(logits)
        results = []
        for i, req in enumerate(group):
            rows = jax.tree.map(
                lambda f: np.asarray(f[:, i:i + 1]), fresh)
            wire = float(sum(leaf.nbytes
                             for leaf in jax.tree.leaves(rows)))
            s = len(req.prompt)
            if req._sampler is None:
                req._sampler = Sampler(req.sampling,
                                       self.account.cfg.vocab_size)
            if s == S:
                nxt = req._sampler(logits[i, 0])
                req.out_tokens.append(nxt)
                if nxt == req.eos_id or req.max_new_tokens <= 1:
                    results.append((req, None, True))
                    continue
                bundle = KVBundle(req=req, prefill_len=S, pos=s,
                                  last_tok=int(nxt), cache_rows=rows,
                                  wire_bytes=wire)
                results.append((req, bundle, True))
            else:
                bundle = KVBundle(req=req, prefill_len=S, pos=s - 1,
                                  last_tok=int(req.prompt[s - 1]),
                                  cache_rows=rows, wire_bytes=wire)
                results.append((req, bundle, False))
        return results


class DecodePool:
    """Elastic decode replicas; executed replicas are ``ServeEngine``s
    sharing one compiled step function and parameter tree."""

    def __init__(self, sc: ServeConfig, account: PoolAccount, *,
                 executed: bool = False, seed: int = 0,
                 n_init: int = 1):
        self.sc = sc
        self.account = account
        self.executed = executed
        self.replicas: List[DecodeReplica] = []
        self.retired = 0
        self.replica_peak = 0
        self._next_id = 0
        self.energy_j = 0.0           # compute (stepped) joules
        self.steps = 0
        self.tokens = 0
        self.busy_s = 0.0             # replica-seconds spent stepping
        self.device_s = 0.0           # device-seconds powered (uptime)
        self.params = None
        if executed:
            from repro.models.model import model_decls
            from repro.parallel.axes import MeshAxes
            from repro.parallel.params import materialize
            mesh, _ = account.ensure_fns()
            self.params = materialize(
                model_decls(account.cfg, MeshAxes.from_mesh(mesh)),
                seed)
        for _ in range(max(n_init, 1)):   # decode always has >= 1
            rep = self.add_replica(0.0, 0.0)
            rep.state = "active"

    def _make_engine(self):
        from repro.serve.engine import ServeEngine
        mesh, fns = self.account.ensure_fns()
        eng = ServeEngine(self.account.cfg, mesh, self.params,
                          slots=self.sc.slots, max_len=self.sc.max_len,
                          page_size=self.sc.page_size, serve_fns=fns)
        eng.clock_scale = 0.0      # the fleet clock is authoritative
        return eng

    def add_replica(self, now_s: float,
                    spinup_s: float) -> DecodeReplica:
        engine = self._make_engine() if self.executed else None
        rep = DecodeReplica(self._next_id, self.sc, engine)
        rep.spawn_s = now_s
        rep.ready_s = now_s + spinup_s
        self._next_id += 1
        self.replicas.append(rep)
        self.replica_peak = max(self.replica_peak, len(self.replicas))
        return rep

    def n_active(self) -> int:
        return sum(r.state == "active" for r in self.replicas)

    def n_warming(self) -> int:
        return sum(r.state == "warming" for r in self.replicas)

    def retire(self, rep: DecodeReplica, now_s: float = 0.0):
        self.replicas.remove(rep)
        self.retired += 1
        self.device_s += self.sc.devices * max(now_s - rep.spawn_s, 0.0)

    def close_uptime(self, end_s: float):
        """Bill the remaining replicas' uptime at the end of a run."""
        for rep in self.replicas:
            self.device_s += self.sc.devices * \
                max(end_s - rep.spawn_s, 0.0)
            rep.spawn_s = end_s

    def drain_victim(self) -> Optional[DecodeReplica]:
        """Least-loaded active replica (idle preferred) — draining
        never drops tokens, it just stops adopting."""
        cands = [r for r in self.replicas if r.state == "active"]
        if not cands:
            return None
        return min(cands, key=lambda r: (r.n_active(), r.id))
