"""FleetRouter: disaggregated prefill/decode serving with
joules-per-token autoscaling.

``plan_pools`` picks each pool's ``ServeConfig`` independently by
predicted joules per unit of ITS phase (prefill: J/prompt, decode:
J/token) over the router's candidate table — either priced fresh with
the planner's calibrated constants or consumed from the
``serve-route/v1`` JSON block that ``launch/serve.py --route auto``
persists.  Disaggregation is exactly why per-phase choice matters: the
prefill-optimal config (throughput-bound, big batch-tokens) and the
decode-optimal config (latency-bound, often phantom on a sub-mesh) are
rarely the same deployment.

``FleetRouter.run`` replays a trace through a discrete-event loop on
the virtual clock: admit -> queue -> prefill group on a prefill
replica -> KV-page migration through the ``TransferChannel`` (a priced
wire event) -> adoption into a decode replica -> decode to completion.
An ``Autoscaler`` per pool scales replica counts against live queue
depth and SLO headroom (scale-down drains, never drops).  The run
records fleet-level TTFT/TPOT/goodput plus per-pool and whole-fleet
J/token to the ledger, with the transfer account's
measured/predicted ``transfer_wire_bytes`` ratio band-checked by the
fleet bench exactly like PR 5's stage-boundary wire bytes.

``colocated=True`` turns the same simulator into the single-engine
baseline: one pool config serves both phases on one replica set,
prefill steps stall decode (the ``ServeEngine`` interleave), and the
migration is a free slot splice — the comparison partner for the
fleet's J/token claim.
"""
from __future__ import annotations

import heapq
import json
import os
from collections import deque
from dataclasses import dataclass, field, replace
from typing import List, Optional, Sequence

from repro.core.energy import FRONTIER_B_W
from repro.obs import get_metrics, get_tracer
from repro.planner.calibration import Calibration
from repro.serve.fleet.autoscaler import (AutoscalePolicy, Autoscaler,
                                          PoolStats)
from repro.serve.fleet.runners import (DecodePool, FleetRequest,
                                       PoolAccount, PrefillPool,
                                       form_group, req_prompt_len)
from repro.serve.fleet.transfer import TransferChannel
from repro.serve.router import (PricedConfig, ServeConfig,
                                candidate_configs, price_config,
                                trace_stats)
from repro.serve.scheduler import bucket_of
from repro.serve.traffic import SLOTracker, TraceItem, trace_requests

ROUTE_SCHEMA = "serve-route/v1"


# ---------------------------------------------------------------------------
# serve-route/v1: the persisted candidate J/token table
# ---------------------------------------------------------------------------

def write_route_table(path: str, arch: str, winner: PricedConfig,
                      priced: Sequence[PricedConfig], *,
                      calibration: str = "", stats: Optional[dict] = None,
                      slo_ms: float = 0.0) -> dict:
    """Persist the router's candidate J/token table so the fleet router
    and experiments can consume the pricing pass instead of re-running
    it (docs/serving.md)."""
    block = {
        "schema": ROUTE_SCHEMA,
        "arch": arch,
        "slo_ms": slo_ms,
        "calibration": calibration,
        "trace": dict(stats or {}),
        "winner": winner.config.name,
        "candidates": [pc.as_dict() for pc in priced],
    }
    with open(path, "w") as f:
        json.dump(block, f, indent=1)
    return block


def load_route_table(path: str) -> Optional[dict]:
    """Read a ``serve-route/v1`` block; None when absent, ValueError on
    a schema mismatch (a wrong file should fail loudly, not silently
    re-price)."""
    if not path or not os.path.exists(path):
        return None
    with open(path) as f:
        block = json.load(f)
    if block.get("schema") != ROUTE_SCHEMA:
        raise ValueError(f"{path}: schema {block.get('schema')!r} "
                         f"(want {ROUTE_SCHEMA})")
    return block


def _sc_from_dict(d: dict) -> ServeConfig:
    return ServeConfig(d["arch"], d["impl"], d["dp"], d["tp"],
                       d["slots"], max_len=d.get("max_len", 64),
                       page_size=d.get("page_size", 16),
                       k=d.get("k", 0))


# ---------------------------------------------------------------------------
# per-phase pool planning
# ---------------------------------------------------------------------------

def plan_pools(arch: str, devices: int, calib: Calibration,
               trace: Sequence[TraceItem], *, slo_ms: float = 0.0,
               slots: int = 4, max_len: int = 64, page_size: int = 16,
               route_table: Optional[dict] = None) -> tuple:
    """Choose (prefill_sc, decode_sc, notes): per phase, the candidate
    minimizing predicted joules per unit of that phase among those
    meeting the phase's SLO term (TTFT for prefill, TPOT for decode);
    ties go to fewer devices.  ``route_table`` (a ``serve-route/v1``
    block for the same arch) supplies the priced table instead of a
    fresh pricing pass."""
    stats = trace_stats(trace, page_size)
    rows = []
    if route_table and route_table.get("arch") == arch \
            and route_table.get("candidates"):
        source = "route-table"
        for d in route_table["candidates"]:
            rows.append({
                "config": _sc_from_dict(d["config"]),
                "prefill_energy_j": d["prefill_energy_j"],
                "decode_energy_j": d["decode_energy_j"],
                "ttft_s": d["ttft_s"], "tpot_s": d["tpot_s"],
            })
    else:
        source = "priced"
        cands = candidate_configs(arch, devices,
                                  slots_options=(slots,),
                                  max_len=max_len, page_size=page_size)
        for pc in (price_config(sc, calib, stats, slo_ms=slo_ms)
                   for sc in cands):
            rows.append({
                "config": pc.config,
                "prefill_energy_j": pc.prefill_energy_j,
                "decode_energy_j": pc.decode_energy_j,
                "ttft_s": pc.ttft_s, "tpot_s": pc.tpot_s,
            })
    if not rows:
        raise ValueError(f"no serve candidates for {arch} "
                         f"on {devices} devices")

    def pick(energy_key: str, lat_key: str) -> dict:
        # per-unit: a step covers slots*dp prompts (prefill) or tokens
        # (decode), so normalize before comparing across meshes
        def unit(r):
            sc = r["config"]
            return r[energy_key] / (sc.slots * sc.dp)
        ok = [r for r in rows
              if not slo_ms or r[lat_key] * 1e3 <= slo_ms]
        pool = ok or rows
        return min(pool, key=lambda r: (unit(r), r["config"].devices))

    pre = pick("prefill_energy_j", "ttft_s")
    dec = pick("decode_energy_j", "tpot_s")
    # fleet replicas ARE the data-parallel axis: deploy each pool at
    # dp=1 (one model group per replica) and let the autoscaler stretch
    # the dp dimension elastically.  J/token is dp-invariant so the
    # per-phase pick carries over unchanged.
    pre_sc = replace(pre["config"], dp=1)
    dec_sc = replace(dec["config"], dp=1)
    notes = {
        "source": source,
        "slo_ms": slo_ms,
        "prefill": {"config": pre["config"].name,
                    "j_per_prompt": pre["prefill_energy_j"]
                    / (pre["config"].slots * pre["config"].dp)},
        "decode": {"config": dec["config"].name,
                   "j_per_token": dec["decode_energy_j"]
                   / (dec["config"].slots * dec["config"].dp)},
        "candidates": len(rows),
    }
    return pre_sc, dec_sc, notes


def baseline_config(arch: str, devices: int = 8, *, slots: int = 4,
                    max_len: int = 64,
                    page_size: int = 16) -> ServeConfig:
    """The conventional single-engine deployment the fleet is compared
    against: one TENSOR engine tensor-parallel across the full device
    budget (largest divisible tp), colocating both phases, always on."""
    from repro.configs.base import get_config
    cfg = get_config(arch, smoke=True)
    for tp in sorted({devices, 8, 4, 2}, reverse=True):
        if tp <= devices and cfg.d_model % tp == 0 \
                and (not cfg.num_heads or cfg.num_heads % tp == 0):
            return ServeConfig(arch, "tensor", 1, tp, slots,
                               max_len, page_size)
    return ServeConfig(arch, "tensor", 1, 1, slots, max_len, page_size)


def auto_rate_rps(dec_sc: ServeConfig, calib: Calibration,
                  mean_new_tokens: float, *, replicas: int = 1,
                  utilization: float = 0.6) -> float:
    """Arrival rate that loads the INITIAL decode pool to
    ``utilization`` of its modeled token throughput — so a bursty
    trace's 8x bursts overload it (scale-up) and its quiet phases
    underload it (scale-down), which is what ``--rate auto`` wants a
    100k-request acceptance replay to exhibit."""
    acct = PoolAccount(dec_sc, calib)
    step_s, _ = acct.decode_step()
    tokens_per_s = dec_sc.slots * dec_sc.dp * max(replicas, 1) / step_s
    return utilization * tokens_per_s / max(mean_new_tokens, 1.0)


# ---------------------------------------------------------------------------
# the fleet
# ---------------------------------------------------------------------------

@dataclass
class FleetConfig:
    """One fleet deployment: a pool config per phase, autoscaling
    policies, and the run mode."""
    prefill: ServeConfig
    decode: ServeConfig
    slo_ms: float = 0.0
    executed: bool = False        # real engines (small traces only)
    colocated: bool = False       # single-engine baseline mode
    prefill_replicas: int = 1     # initial pool sizes
    decode_replicas: int = 1
    prefill_policy: AutoscalePolicy = field(
        default_factory=AutoscalePolicy)
    decode_policy: AutoscalePolicy = field(
        default_factory=AutoscalePolicy)

    def as_dict(self) -> dict:
        return {"prefill": self.prefill.as_dict(),
                "decode": self.decode.as_dict(),
                "slo_ms": self.slo_ms, "executed": self.executed,
                "colocated": self.colocated,
                "prefill_replicas": self.prefill_replicas,
                "decode_replicas": self.decode_replicas}


class FleetRouter:
    """Admission, placement, migration and autoscaling over the two
    pools; one ``run()`` = one trace replay on the virtual clock."""

    def __init__(self, fc: FleetConfig, *,
                 calib: Optional[Calibration] = None, ledger=None,
                 price_hlo: bool = False, seed: int = 0):
        if fc.executed and fc.colocated:
            raise NotImplementedError(
                "colocated baseline is modeled-only; executed "
                "single-engine serving is ServeEngine itself")
        if fc.colocated:
            # the baseline is a FIXED single-engine deployment: pin the
            # decode pool to its initial size so autoscaling never fires
            n = max(fc.decode_replicas, 1)
            fc = replace(fc, decode_policy=replace(
                fc.decode_policy, min_replicas=n, max_replicas=n))
        self.fc = fc
        self.calib = calib or Calibration()
        self.ledger = ledger
        self.seed = seed
        dec_acct = PoolAccount(fc.decode, self.calib,
                               price_hlo=price_hlo)
        pre_acct = dec_acct if fc.colocated else \
            PoolAccount(fc.prefill, self.calib, price_hlo=price_hlo)
        self.pre = PrefillPool(
            fc.prefill, pre_acct, executed=fc.executed, seed=seed,
            n_init=0 if fc.colocated else max(fc.prefill_replicas, 1))
        self.dec = DecodePool(
            fc.decode, dec_acct, executed=fc.executed, seed=seed,
            n_init=max(fc.decode_replicas, 1))
        self.channel = TransferChannel(
            dec_acct.cfg, tp_src=fc.prefill.tp, tp_dst=fc.decode.tp,
            fits=self.calib.collective_fits, colocated=fc.colocated)
        self.pre_scaler = Autoscaler(fc.prefill_policy, pool="prefill",
                                     slo_ms=fc.slo_ms)
        self.dec_scaler = Autoscaler(fc.decode_policy, pool="decode",
                                     slo_ms=fc.slo_ms)
        self.finished: List = []
        self.rejected: List = []

    @property
    def mixed(self) -> bool:
        return self.pre.mixed_lengths

    # --- admission -------------------------------------------------------

    def _padded_len(self, prompt_len: int) -> int:
        if self.mixed:
            return bucket_of(prompt_len, self.fc.decode.page_size)
        if prompt_len % self.fc.decode.page_size:
            raise ValueError(
                f"recurrent family: prompt length {prompt_len} must be "
                f"a multiple of {self.fc.decode.page_size}")
        return prompt_len

    def _admit(self, req) -> bool:
        s = req_prompt_len(req)
        if s <= 0:
            req.done, req.error = True, "rejected: empty prompt"
            return False
        try:
            padded = self._padded_len(s)
        except ValueError as exc:
            req.done, req.error = True, f"rejected: {exc}"
            return False
        need = padded + max(req.max_new_tokens, 1)
        if need > self.fc.decode.max_len \
                or padded > self.fc.prefill.max_len:
            req.done = True
            req.error = (f"rejected: padded prompt {padded} + "
                         f"{req.max_new_tokens} new tokens exceeds "
                         f"max_len {self.fc.decode.max_len}")
            return False
        if isinstance(req, FleetRequest):
            req.padded_len = padded
        return True

    # --- the a-priori transfer prediction --------------------------------

    def _transfer_prediction_stats(
            self, trace: Sequence[TraceItem]) -> tuple:
        """(expected migrations, mean padded prompt of migrators) from
        the trace ALONE — the predicted side of the transfer account
        must not peek at the run (same discipline as the stage-boundary
        prediction): a request migrates iff it statically admits and is
        not finished at prefill (exact-length with <=1 new token)."""
        migr, padded_sum = 0, 0.0
        for it in trace:
            s = it.prompt_len
            if s <= 0:
                continue
            try:
                padded = self._padded_len(s)
            except ValueError:
                continue
            if padded + max(it.max_new_tokens, 1) \
                    > self.fc.decode.max_len \
                    or padded > self.fc.prefill.max_len:
                continue
            if s == padded and it.max_new_tokens <= 1:
                continue
            migr += 1
            padded_sum += padded
        return migr, (padded_sum / migr if migr else 0.0)

    # --- the event loop --------------------------------------------------

    def run(self, trace: Sequence[TraceItem], *, sampling=None,
            max_events: int = 0) -> dict:
        fc = self.fc
        if fc.executed:
            reqs = trace_requests(trace,
                                  self.dec.account.cfg.vocab_size,
                                  seed=self.seed, sampling=sampling)
        else:
            reqs = [FleetRequest(req_id=i, prompt_len=it.prompt_len,
                                 max_new_tokens=it.max_new_tokens,
                                 arrival_s=it.arrival_s,
                                 deadline_ms=it.deadline_ms)
                    for i, it in enumerate(trace)]
        admitted = []
        for req in reqs:
            if self._admit(req):
                admitted.append(req)
            else:
                self.rejected.append(req)
        self._arrivals = deque(sorted(admitted,
                                      key=lambda r: r.arrival_s))
        self._heap: List[tuple] = []
        self._eseq = 0
        self._xseq = 0
        self._now = 0.0
        # in-flight transfers (min-heap by completion time) feeding an
        # FCFS adoption queue — O(log n) per bundle at 100k+ scale
        self._xfer: List[tuple] = []
        self._ready: deque = deque()
        self._inflight_prefills = 0
        self._last_tick = 0.0
        stats = trace_stats(trace, fc.decode.page_size)
        self._mean_bucket = bucket_of(
            max(int(round(stats["mean_padded_prompt"])), 1),
            fc.decode.page_size)
        self._mean_new = stats["mean_new_tokens"]
        tick = fc.decode_policy.tick_s
        self._push(tick, "tick", None)
        events = 0
        with get_tracer().span("fleet/run", cat="fleet",
                               requests=len(reqs),
                               executed=fc.executed,
                               colocated=fc.colocated):
            while True:
                self._ingest()
                self._dispatch()
                if not self._heap:
                    if self._arrivals:
                        self._now = self._arrivals[0].arrival_s
                        continue
                    break
                t, _, kind, payload = heapq.heappop(self._heap)
                self._now = max(self._now, t)
                self._handle(kind, payload)
                events += 1
                if max_events and events >= max_events:
                    break
        return self._report(trace, stats)

    def _push(self, t: float, kind: str, payload):
        self._eseq += 1
        heapq.heappush(self._heap, (t, self._eseq, kind, payload))

    def _ingest(self):
        while self._arrivals \
                and self._arrivals[0].arrival_s <= self._now:
            req = self._arrivals.popleft()
            req.t_submit_s = req.arrival_s
            self.pre.queue.append(req)

    def _has_work(self) -> bool:
        return bool(
            self.pre.queue or self._ready or self._xfer
            or self._inflight_prefills
            or any(r.busy or r.active for r in self.dec.replicas))

    def _over_min(self) -> bool:
        return (self.dec.n_active() > self.fc.decode_policy.min_replicas
                or self.pre.n_active()
                > self.fc.prefill_policy.min_replicas)

    # --- dispatch --------------------------------------------------------

    def _dispatch(self):
        self._adopt_ready()
        for rep in self.dec.replicas:
            if rep.state == "warming" or rep.busy:
                continue
            if self.fc.colocated and self.pre.queue \
                    and rep.free_slots():
                # the single-engine interleave: prefill a refill group
                # ON the decode replica, stalling its decode (exactly
                # ServeEngine's eager refill policy)
                S, group = form_group(self.pre.queue,
                                      min(rep.free_slots(),
                                          self.fc.decode.slots),
                                      self.fc.decode.page_size,
                                      self.mixed)
                if group:
                    done_t, results = self.pre.start_group(
                        None, S, group, self._now)
                    rep.busy = True
                    rep.busy_until = done_t
                    self._inflight_prefills += 1
                    self._push(done_t, "prefill_done",
                               (None, rep, S, results))
                    continue
            if rep.active:
                self._start_decode(rep)
        if not self.fc.colocated:
            for prep in self.pre.replicas:
                if prep.state != "active" or prep.busy \
                        or not self.pre.queue:
                    continue
                S, group = form_group(self.pre.queue,
                                      self.fc.prefill.slots,
                                      self.fc.prefill.page_size,
                                      self.mixed)
                if not group:
                    break
                with get_tracer().span("fleet/prefill", cat="fleet",
                                       bucket=S, group=len(group),
                                       replica=prep.id):
                    done_t, results = self.pre.start_group(
                        prep, S, group, self._now)
                self._inflight_prefills += 1
                self._push(done_t, "prefill_done",
                           (prep, None, S, results))

    def _start_decode(self, rep):
        step_s, e_j = self.dec.account.decode_step()
        self.dec.energy_j += e_j
        self.dec.steps += 1
        self.dec.busy_s += step_s
        with get_tracer().span("fleet/decode", cat="fleet",
                               replica=rep.id,
                               active=rep.n_active()):
            rep.start_step(self._now, step_s)
        self._push(rep.busy_until, "decode_done", rep)

    def _adopt_ready(self):
        while self._xfer and self._xfer[0][0] <= self._now:
            self._ready.append(heapq.heappop(self._xfer)[2])
        while self._ready:
            bundle = self._ready[0]
            # bin-pack: fullest adoptable replica first keeps decode
            # occupancy (and therefore J/token) honest
            cands = [r for r in self.dec.replicas
                     if r.can_adopt(bundle)]
            if not cands:
                break               # FCFS: the head waits for capacity
            rep = max(cands, key=lambda r: (r.n_active(), -r.id))
            rep.adopt(bundle)
            self._ready.popleft()

    # --- event handlers --------------------------------------------------

    def _handle(self, kind: str, payload):
        if kind == "prefill_done":
            self._on_prefill_done(*payload)
        elif kind == "decode_done":
            self._on_decode_done(payload)
        elif kind == "bundle_ready":
            pass                        # a wake-up; dispatch adopts
        elif kind == "replica_ready":
            _pool, rep = payload
            if rep.state == "warming":
                rep.state = "active"
        elif kind == "tick":
            self._on_tick()

    def _on_prefill_done(self, prep, colo_rep, S, results):
        self._inflight_prefills -= 1
        step_rep = prep if prep is not None else colo_rep
        if step_rep is not None:
            step_rep.window_busy_s += \
                self.pre.account.prefill_step(S)[0]
            step_rep.busy = False
        for req, bundle, first_tok in results:
            if first_tok:
                req.t_first_s = self._now
                if isinstance(req, FleetRequest):
                    req.n_out = max(req.n_out, 1)
            if bundle is None:
                # finished AT prefill (exact length, <=1 new token)
                req.done = True
                req.t_done_s = self._now
                self.finished.append(req)
                continue
            self.channel.send(bundle, self._now)
            if self.fc.colocated:
                colo_rep.adopt(bundle)
            else:
                self._xseq += 1
                heapq.heappush(self._xfer,
                               (bundle.ready_s, self._xseq, bundle))
                self._push(bundle.ready_s, "bundle_ready", None)
        if prep is not None and prep.state == "draining":
            self.pre.retire(prep, self._now)

    def _on_decode_done(self, rep):
        step_s, _ = self.dec.account.decode_step()
        rep.window_busy_s += step_s
        cohort = len(rep.stepping)   # one token per stepping request
        done = rep.finish_step(self._now)
        self.dec.tokens += cohort
        self.finished.extend(done)
        get_metrics().counter(
            "fleet_decode_tokens_total",
            "tokens produced by fleet decode steps").inc(cohort)
        if rep.state == "draining" and not rep.active:
            self.dec.retire(rep, self._now)

    def _on_tick(self):
        dt = max(self._now - self._last_tick, 1e-9)
        self._last_tick = self._now
        mx = get_metrics()
        pre_item_s = self.pre.account.prefill_step(
            self._mean_bucket)[0] / max(self.fc.prefill.slots, 1)
        dec_step_s = self.dec.account.decode_step()[0]
        dec_item_s = dec_step_s * max(self._mean_new, 1.0) \
            / max(self.fc.decode.slots, 1)
        plans = []
        if not self.fc.colocated:
            plans.append((self.pre, self.pre_scaler,
                          self.fc.prefill_policy,
                          len(self.pre.queue), pre_item_s))
        dec_depth = len(self._ready) + len(self._xfer) \
            + (len(self.pre.queue) if self.fc.colocated else 0)
        plans.append((self.dec, self.dec_scaler, self.fc.decode_policy,
                      dec_depth, dec_item_s))
        for pool, scaler, policy, depth, item_s in plans:
            n_act = pool.n_active()
            busy = sum(r.window_busy_s for r in pool.replicas)
            util = min(busy / (dt * max(n_act, 1)), 1.0)
            for r in pool.replicas:
                r.window_busy_s = 0.0
            act = scaler.evaluate(self._now, PoolStats(
                queue_depth=depth, n_active=n_act,
                n_warming=pool.n_warming(),
                service_s_per_item=item_s, busy_fraction=util))
            if act:
                self._execute_scale(pool, scaler, policy, act)
        mx.gauge("fleet_prefill_replicas",
                 "active prefill replicas").set(self.pre.n_active())
        mx.gauge("fleet_decode_replicas",
                 "active decode replicas").set(self.dec.n_active())
        mx.gauge("fleet_prefill_queue_depth",
                 "requests waiting for a prefill slot").set(
                     len(self.pre.queue))
        mx.gauge("fleet_decode_queue_depth",
                 "KV bundles waiting for a decode slot").set(
                     len(self._ready) + len(self._xfer))
        if self._has_work() or self._arrivals or self._over_min():
            self._push(self._now + self.fc.decode_policy.tick_s,
                       "tick", None)

    def _execute_scale(self, pool, scaler, policy: AutoscalePolicy,
                       action: str):
        ev = scaler.events[-1]
        with get_tracer().span("fleet/scale", cat="fleet",
                               pool=ev.pool, action=action,
                               replicas=ev.replicas,
                               reason=ev.reason):
            if action == "up":
                rep = pool.add_replica(self._now, policy.spinup_s)
                self._push(rep.ready_s, "replica_ready",
                           (ev.pool, rep))
            elif pool is self.dec:
                victim = self.dec.drain_victim()
                if victim is not None:
                    victim.state = "draining"
                    if not victim.active and not victim.busy:
                        self.dec.retire(victim, self._now)
            else:
                idle = [r for r in pool.replicas
                        if r.state == "active" and not r.busy]
                if idle:
                    pool.retire(idle[-1], self._now)
                else:
                    busy = [r for r in pool.replicas
                            if r.state == "active"]
                    if busy:
                        busy[-1].state = "draining"

    # --- reporting -------------------------------------------------------

    def _report(self, trace, stats) -> dict:
        fc = self.fc
        tracker = SLOTracker(slo_ttft_ms=fc.slo_ms)
        tracker.observe_all(self.finished)
        slo = tracker.report()
        tokens = max(slo.get("generated_tokens", 0), 1)
        migr_pred, mean_padded_pred = \
            self._transfer_prediction_stats(trace)
        xfer_meas = self.channel.measured()
        xfer_pred = self.channel.predicted(migr_pred, mean_padded_pred)
        ratio_wire = (xfer_meas["transfer_wire_bytes"]
                      / xfer_pred["transfer_wire_bytes"]
                      if xfer_pred["transfer_wire_bytes"] else 0.0)
        # a replica that is up but not stepping burns static power B on
        # its devices — THIS is what scale-down saves, and what keeps
        # an over-provisioned fleet from looking free
        end_s = self._now
        self.pre.close_uptime(end_s)
        self.dec.close_uptime(end_s)
        pre_idle = FRONTIER_B_W * max(
            self.pre.device_s
            - self.fc.prefill.devices * self.pre.busy_s, 0.0)
        # colocated: prefill steps ran ON decode replicas, so their
        # busy time offsets decode idle (their step energy is already
        # billed in the prefill pool's compute account)
        dec_busy_s = self.dec.busy_s + (
            self.pre.busy_s if fc.colocated else 0.0)
        dec_idle = FRONTIER_B_W * max(
            self.dec.device_s
            - self.fc.decode.devices * dec_busy_s, 0.0)
        j_pre = (self.pre.energy_j + pre_idle) / tokens
        j_dec = (self.dec.energy_j + dec_idle) / tokens
        j_xfer = self.channel.energy_j() / tokens
        events = (self.pre_scaler.events + self.dec_scaler.events)
        events.sort(key=lambda e: e.t_s)
        report = {
            "mode": "executed" if fc.executed else "modeled",
            "colocated": fc.colocated,
            "fleet": fc.as_dict(),
            "slo": slo,
            "requests": {"trace": len(trace),
                         "finished": len(self.finished),
                         "rejected": len(self.rejected)},
            "pools": {
                "prefill": {
                    "config": fc.prefill.name,
                    "steps": self.pre.steps,
                    "steps_by_bucket": dict(self.pre.steps_by_bucket),
                    "compute_j": self.pre.energy_j,
                    "idle_j": pre_idle,
                    "busy_s": self.pre.busy_s,
                    "device_s": self.pre.device_s,
                    "replicas_final": len(self.pre.replicas),
                    "replicas_retired": self.pre.retired,
                    "j_per_token": j_pre,
                },
                "decode": {
                    "config": fc.decode.name,
                    "steps": self.dec.steps,
                    "compute_j": self.dec.energy_j,
                    "idle_j": dec_idle,
                    "busy_s": dec_busy_s,
                    "device_s": self.dec.device_s,
                    "tokens": self.dec.tokens,
                    "replicas_final": len(self.dec.replicas),
                    "replicas_peak": self.dec.replica_peak,
                    "replicas_retired": self.dec.retired,
                    "j_per_token": j_dec,
                },
            },
            "transfer": {
                "measured": xfer_meas,
                "predicted": xfer_pred,
                "ratio_wire_bytes": ratio_wire,
                "ratio_migrations": (
                    xfer_meas["migrations"] / migr_pred
                    if migr_pred else 0.0),
            },
            "scale_events": [e.as_dict() for e in events],
            "scale_ups": sum(e.action == "up" for e in events),
            "scale_downs": sum(e.action == "down" for e in events),
            "j_per_token": {"prefill": j_pre, "decode": j_dec,
                            "transfer": j_xfer,
                            "fleet": j_pre + j_dec + j_xfer},
        }
        if self.ledger is not None:
            self._record(report, stats)
        return report

    def _pool_energy_rows(self, pool, phase: str) -> tuple:
        """(measured, predicted) per-step energy dicts for one pool —
        predicted from the calibrated serve prediction, measured from
        the lowered-HLO pricing when the account carries it."""
        acct = pool.account
        dp = acct.sc.dp
        if phase == "prefill":
            steps = max(pool.steps, 1)
            pred_e = sum(
                acct.predicted_prefill(S)["energy_j_per_iter"] * dp * n
                for S, n in pool.steps_by_bucket.items())
            meas_e = sum(
                acct.measured_prefill(S)["energy_j_per_iter"] * dp * n
                for S, n in pool.steps_by_bucket.items()) \
                if acct.price_hlo else None
        else:
            steps = max(pool.steps, 1)
            pred_e = acct.predicted_decode()["energy_j_per_iter"] \
                * dp * pool.steps
            meas_e = (acct.measured_decode()["energy_j_per_iter"]
                      * dp * pool.steps) if acct.price_hlo else None
        predicted = {"energy_j_per_iter": pred_e / steps,
                     "energy_j": pred_e, "iterations": pool.steps}
        measured = None
        if meas_e is not None:
            measured = {"energy_j_per_iter": meas_e / steps,
                        "energy_j": meas_e, "iterations": pool.steps}
        return measured, predicted

    def _record(self, report: dict, stats: dict):
        from repro.telemetry import LedgerEntry
        fc = self.fc
        arch = fc.decode.arch
        tag = "baseline" if fc.colocated else "fleet"
        if not fc.colocated:
            self.ledger.record(LedgerEntry(
                name=f"fleet_transfer_{arch}", suite="fleet",
                kind="transfer", arch=arch,
                impl=f"{fc.prefill.impl}->{fc.decode.impl}",
                p=fc.decode.tp,
                measured=report["transfer"]["measured"],
                predicted=report["transfer"]["predicted"],
                extra={"ratio_wire_bytes":
                       report["transfer"]["ratio_wire_bytes"]}))
        for pool, phase, sc in ((self.pre, "prefill", fc.prefill),
                                (self.dec, "decode", fc.decode)):
            if not pool.steps:
                continue
            measured, predicted = self._pool_energy_rows(pool, phase)
            self.ledger.record(LedgerEntry(
                name=f"{tag}_{phase}_{sc.name}", suite="fleet",
                kind=phase, arch=arch, impl=sc.impl, p=sc.tp,
                measured=measured, predicted=predicted,
                extra={"pool": report["pools"][phase]}))
        self.ledger.record(LedgerEntry(
            name=f"{tag}_summary_{arch}", suite="fleet",
            kind="analytic", arch=arch,
            impl=f"{fc.prefill.impl}+{fc.decode.impl}",
            p=fc.decode.tp,
            extra={"slo": report["slo"],
                   "j_per_token": report["j_per_token"],
                   "requests": report["requests"],
                   "scale_events": report["scale_events"],
                   "transfer_ratio":
                       report["transfer"]["ratio_wire_bytes"],
                   "trace": stats}))
