"""Queue-depth / SLO-headroom autoscaling policy for the fleet pools.

Pure decision logic — the ``FleetRouter`` owns execution (spinning
replicas up through their warmup delay, draining and retiring them) so
the policy stays unit-testable without a simulation behind it.

The policy is deliberately boring (threshold + cooldown, the shape
production autoscalers actually run):

  * **scale up** when the estimated queue wait exceeds the SLO headroom
    budget — ``queue_depth * service_s_per_item / n_active`` against
    ``headroom * slo_s`` (with no SLO, against ``default_wait_s``);
  * **scale down** when a pool has been under ``scale_down_util`` busy
    fraction for ``idle_ticks`` consecutive ticks with an empty queue —
    the router then *drains* the victim (no new work) and retires it
    once empty, so scale-down never drops tokens;
  * a per-pool ``cooldown_s`` between decisions and ``min_replicas`` /
    ``max_replicas`` clamps bound the oscillation; new replicas serve
    only after ``spinup_s`` of (virtual) warmup, which the wait
    estimate counts as capacity already ordered — no thundering herd
    of scale-ups while one is still warming.

Joules enter through sizing, not the decision: a pool scaled beyond
its load burns full-shape decode steps at low occupancy, which the
fleet's J/token report makes visible (docs/serving.md, "Fleet").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional


@dataclass(frozen=True)
class AutoscalePolicy:
    min_replicas: int = 1
    max_replicas: int = 4
    tick_s: float = 0.25          # policy evaluation cadence (virtual)
    headroom: float = 0.7         # fraction of the SLO the queue may eat
    default_wait_s: float = 0.5   # wait budget when no SLO is set
    scale_down_util: float = 0.35
    idle_ticks: int = 4           # low-util ticks before draining
    cooldown_s: float = 1.0       # min gap between decisions
    spinup_s: float = 0.5         # warmup before a new replica serves

    def wait_budget_s(self, slo_ms: float) -> float:
        return (self.headroom * slo_ms * 1e-3 if slo_ms
                else self.default_wait_s)


@dataclass
class ScaleEvent:
    t_s: float
    pool: str                     # "prefill" | "decode"
    action: str                   # "up" | "down"
    replicas: int                 # pool size after the decision
    reason: str

    def as_dict(self) -> dict:
        return {"t_s": self.t_s, "pool": self.pool,
                "action": self.action, "replicas": self.replicas,
                "reason": self.reason}


@dataclass
class PoolStats:
    """The autoscaler's view of one pool at a tick."""
    queue_depth: int              # items waiting for a replica
    n_active: int
    n_warming: int
    service_s_per_item: float     # replica-seconds one queued item needs
    busy_fraction: float          # busy share since the last tick


class Autoscaler:
    """Threshold policy over ``PoolStats`` ticks for one pool."""

    def __init__(self, policy: AutoscalePolicy, *, pool: str,
                 slo_ms: float = 0.0):
        self.policy = policy
        self.pool = pool
        self.slo_ms = slo_ms
        self._last_decision_s = -1e18
        self._low_util_ticks = 0
        self.events: List[ScaleEvent] = []

    def est_wait_s(self, stats: PoolStats) -> float:
        """Queue wait if today's queue drains at today's capacity —
        warming replicas count (capacity already ordered)."""
        cap = max(stats.n_active + stats.n_warming, 1)
        return stats.queue_depth * stats.service_s_per_item / cap

    def evaluate(self, now_s: float, stats: PoolStats) -> Optional[str]:
        """Return ``"up"``, ``"down"``, or ``None``; records the event.
        Clamps and cooldown are enforced here so callers just execute."""
        pol = self.policy
        n_total = stats.n_active + stats.n_warming
        if stats.busy_fraction < pol.scale_down_util \
                and not stats.queue_depth:
            self._low_util_ticks += 1
        else:
            self._low_util_ticks = 0
        if now_s - self._last_decision_s < pol.cooldown_s:
            return None
        wait = self.est_wait_s(stats)
        if wait > pol.wait_budget_s(self.slo_ms) \
                and n_total < pol.max_replicas:
            self._last_decision_s = now_s
            self._low_util_ticks = 0
            self.events.append(ScaleEvent(
                now_s, self.pool, "up", n_total + 1,
                f"est_wait={wait * 1e3:.1f}ms > "
                f"budget={pol.wait_budget_s(self.slo_ms) * 1e3:.1f}ms "
                f"(queue={stats.queue_depth})"))
            return "up"
        if self._low_util_ticks >= pol.idle_ticks \
                and stats.n_active > pol.min_replicas:
            self._last_decision_s = now_s
            self._low_util_ticks = 0
            self.events.append(ScaleEvent(
                now_s, self.pool, "down", n_total - 1,
                f"util<{pol.scale_down_util:.0%} for "
                f"{pol.idle_ticks} ticks, queue empty"))
            return "down"
        return None
