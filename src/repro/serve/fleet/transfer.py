"""KV-page transfer channel between the prefill and decode pools.

Disaggregated serving moves each admitted request's prefilled KV-cache
rows from the prefill pool's mesh to a decode replica.  That migration
is a first-class wire event here, priced exactly like PR 5's pipeline
stage boundaries: a point-to-point hop (Eqn. 26 ``c1 + c2*m``, no
``log2(p)`` factor) per migration, billed at static power ``B`` across
the endpoint devices of both pools while the pages move.

The channel owns the MEASURED side of the transfer account: every
``send`` adds the bundle's actual byte count (executed mode: the numpy
``nbytes`` of the sliced cache rows; modeled mode: the page table's
live-token bytes at the request's padded prefill length).  The
PREDICTED side — ``telemetry.predict.kv_transfer_prediction`` from the
trace's a-priori length statistics — joins it in the ledger, and the
fleet bench pins the measured/predicted ``transfer_wire_bytes`` ratio
to [0.9, 1.1] (docs/energy_model.md, "KV transfer wire term").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

from repro.core.energy import FRONTIER_B_W, comm_time_us
from repro.obs import get_metrics, get_tracer

FLOAT_BYTES = 4.0


@dataclass
class KVBundle:
    """One request's migration payload: the decode-side state the
    replay-last-token contract needs (``pos`` / ``last_tok``) plus, in
    executed mode, the actual cache rows (a pytree matching the engine
    cache with batch axis 1)."""
    req: Any
    prefill_len: int              # padded prompt rows the cache holds
    pos: int
    last_tok: int
    cache_rows: Any = None        # executed mode only
    wire_bytes: float = 0.0       # measured bytes (stamped by send)
    ready_s: float = 0.0          # virtual time the transfer completes
    src_replica: int = -1


class TransferChannel:
    """Prices (and, in executed mode, carries) prefill->decode KV-page
    migrations, accumulating the measured transfer account."""

    def __init__(self, cfg, *, tp_src: int = 1, tp_dst: int = 1,
                 fits=None, B: float = FRONTIER_B_W,
                 colocated: bool = False):
        from repro.telemetry.predict import kv_cache_token_bytes
        self.cfg = cfg
        self.tp_src = max(tp_src, 1)
        self.tp_dst = max(tp_dst, 1)
        self.fits = fits
        self.B = B
        # colocated: both "pools" are the same engine — the migration is
        # a slot splice, not a wire event (the single-engine baseline)
        self.colocated = colocated
        self.per_token_bytes, self.per_seq_bytes = \
            kv_cache_token_bytes(cfg)
        self.migrations = 0
        self.wire_bytes = 0.0
        self.comm_s = 0.0

    # --- pricing ---------------------------------------------------------

    def modeled_bytes(self, tokens: int) -> float:
        """Cache bytes of one request at ``tokens`` live rows."""
        return self.per_seq_bytes + tokens * self.per_token_bytes

    def latency_s(self, nbytes: float) -> float:
        """One p2p hop for the bundle (same single-hop pricing as the
        pipeline's stage boundaries)."""
        if self.colocated:
            return 0.0
        us = comm_time_us("collective_permute", nbytes / FLOAT_BYTES, 2,
                          self.fits)
        return us * 1e-6

    # --- sending ---------------------------------------------------------

    def send(self, bundle: KVBundle, now_s: float) -> KVBundle:
        """Price one migration and stamp its completion time.  The
        measured byte count prefers the bundle's actual array sizes
        (executed mode sets ``wire_bytes`` from ``nbytes``); modeled
        bundles are billed at the page table's padded residency."""
        nbytes = bundle.wire_bytes or self.modeled_bytes(
            bundle.prefill_len)
        if self.colocated:
            nbytes = 0.0
        lat = self.latency_s(nbytes)
        bundle.wire_bytes = nbytes
        bundle.ready_s = now_s + lat
        self.migrations += 1
        self.wire_bytes += nbytes
        self.comm_s += lat
        if not self.colocated:
            rid = getattr(bundle.req, "req_id", -1)
            get_tracer().instant("fleet/transfer", cat="fleet",
                                 req=rid, bytes=nbytes,
                                 latency_us=lat * 1e6)
            get_metrics().counter(
                "fleet_transfer_bytes_total",
                "KV-cache bytes migrated prefill->decode").inc(nbytes)
            get_metrics().counter(
                "fleet_migrations_total",
                "requests migrated prefill->decode").inc()
        return bundle

    # --- the measured account --------------------------------------------

    def energy_j(self) -> float:
        """Transfer seconds billed at static power across both pools'
        endpoint devices (the compute account sees them idle while
        pages move)."""
        return self.comm_s * self.B * (self.tp_src + self.tp_dst)

    def measured(self) -> dict:
        return {
            "transfer_wire_bytes": self.wire_bytes,
            "migrations": self.migrations,
            "comm_us": self.comm_s * 1e6,
            "beta_s": self.comm_s,
            "energy_j": self.energy_j(),
            "bytes_per_migration": (self.wire_bytes / self.migrations
                                    if self.migrations else 0.0),
        }

    def predicted(self, migrations: int, mean_tokens: float,
                  fits: Optional[dict] = None) -> dict:
        """The a-priori transfer account for ``migrations`` requests at
        the trace's mean padded prompt length (the join partner for
        ``measured()`` in the ledger)."""
        from repro.telemetry.predict import kv_transfer_prediction
        return kv_transfer_prediction(
            self.cfg, migrations, mean_tokens, tp_src=self.tp_src,
            tp_dst=self.tp_dst, fits=fits or self.fits, B=self.B)
