"""Disaggregated prefill/decode serving fleet (docs/serving.md,
"Fleet"): separately-meshed pools, a priced KV-page transfer channel,
and joules-per-token-aware autoscaling over a virtual-clock
discrete-event replay."""
from repro.serve.fleet.autoscaler import (AutoscalePolicy, Autoscaler,
                                          PoolStats, ScaleEvent)
from repro.serve.fleet.router import (ROUTE_SCHEMA, FleetConfig,
                                      FleetRouter, auto_rate_rps,
                                      baseline_config,
                                      load_route_table, plan_pools,
                                      write_route_table)
from repro.serve.fleet.runners import (DecodePool, DecodeReplica,
                                       FleetRequest, PoolAccount,
                                       PrefillPool, form_group)
from repro.serve.fleet.transfer import KVBundle, TransferChannel

__all__ = [
    "AutoscalePolicy", "Autoscaler", "PoolStats", "ScaleEvent",
    "ROUTE_SCHEMA", "FleetConfig", "FleetRouter", "auto_rate_rps",
    "baseline_config", "load_route_table", "plan_pools",
    "write_route_table",
    "DecodePool", "DecodeReplica", "FleetRequest", "PoolAccount",
    "PrefillPool", "form_group", "KVBundle", "TransferChannel",
]
