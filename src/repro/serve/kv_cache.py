"""Block/paged KV-cache manager over the sequence-sharded cache layout.

The physical decode cache is still one dense, statically-shaped jax
array per layer group (``[groups, slots, max_len, kv, hd]``, seq dim
sharded over the model axis) — XLA's static-shape world rules out
vLLM-style scatter-addressed physical pages.  What pages buy us here is
everything *around* the tensor:

  * **admission control** — a request is admitted only if its worst-case
    page need (padded prompt + ``max_new_tokens``) fits the slot's frame
    budget, instead of silently truncating at ``max_len``;
  * **occupancy accounting** — the old engine zero-filled ``max_len``
    rows per slot and reported nothing; the page table knows exactly how
    many 16-token pages are live, the high-water mark, and the internal
    fragmentation of the current residency (live tokens / paged tokens);
  * **alloc/free invariants** — every allocated frame is owned by
    exactly one slot, frees return the slot's frames in full, and the
    pool-wide free list stays in **address order**, which ``check()``
    verifies and the churn tests exercise.

Pages are ``page_size`` tokens (default 16 — the sequence-sharding
divisibility unit, so a page never straddles a model-axis shard
boundary for tp <= 16).  Frames are drawn from a pool-wide free list
(``slots * max_len // page_size`` frames): prefill reserves the frames
covering the padded prompt and decode allocates one more frame each
time the write position crosses a page boundary.

Freed frames re-enter the free list **in address order**
(``bisect.insort``), not append order.  Under long bursty replays the
append-order free list of the original implementation became a shuffle
of the address space, so the reported external fragmentation (share of
free frames not in the longest contiguous run) drifted upward across
bursts even when occupancy returned to zero; ordered reinsertion makes
the metric a true residency property — an empty table always reports
``external_fragmentation() == 0`` (pinned by the churn test in
``tests/test_serve_runtime.py``).
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import List


@dataclass
class PageAllocation:
    """One slot's live page-table row."""
    slot: int
    pages: int = 0          # frames currently allocated to the slot
    live_tokens: int = 0    # cache rows actually written (pos + 1)
    frames: List[int] = field(default_factory=list)   # pool frame ids


class CacheOverflow(RuntimeError):
    """A (prompt, max_new_tokens) request cannot fit a slot's frames."""


class PagedKVCache:
    """Page table for a ``slots x max_len`` sequence-sharded cache."""

    def __init__(self, slots: int, max_len: int, page_size: int = 16):
        if page_size <= 0:
            raise ValueError(f"page_size must be positive, got {page_size}")
        if max_len % page_size:
            raise ValueError(f"max_len {max_len} must be a multiple of "
                             f"page_size {page_size}")
        self.slots = slots
        self.max_len = max_len
        self.page_size = page_size
        self.frames_per_slot = max_len // page_size
        self.total_pages = slots * self.frames_per_slot
        self._table: dict[int, PageAllocation] = {}
        # pool-wide free list of frame addresses, ALWAYS ascending —
        # alloc pops from the head (lowest address first), free
        # re-inserts in address order
        self._free: List[int] = list(range(self.total_pages))
        # counters for the stats/ledger report
        self.page_allocs = 0
        self.page_frees = 0
        self.requests_admitted = 0
        self.requests_freed = 0
        self.high_water_pages = 0

    # --- sizing ----------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        """Frames needed to hold ``n_tokens`` cache rows."""
        return max(0, -(-int(n_tokens) // self.page_size))

    def can_admit(self, prompt_len: int, max_new_tokens: int,
                  padded_len: int = 0) -> bool:
        """Worst-case fit: padded prompt + every new token + the final
        write position (decode writes at ``pos`` before the finish
        check, so the last generated token still needs a row)."""
        need = max(padded_len, prompt_len) + max(max_new_tokens, 1)
        return need <= self.max_len and \
            self.pages_for(need) <= self.frames_per_slot

    # --- frame pool ------------------------------------------------------

    def _take_frames(self, n: int) -> List[int]:
        taken, self._free = self._free[:n], self._free[n:]
        return taken

    def _return_frames(self, frames: List[int]):
        """Freed frames re-enter the free list in ADDRESS order — the
        append-order alternative shuffles the list under bursty churn
        and makes external fragmentation drift upward permanently."""
        for f in frames:
            bisect.insort(self._free, f)

    # --- alloc / advance / free ------------------------------------------

    def alloc(self, slot: int, n_tokens: int) -> PageAllocation:
        """Admit a request into ``slot``, reserving pages for its first
        ``n_tokens`` cache rows (the padded prefill length)."""
        if slot in self._table:
            raise RuntimeError(f"slot {slot} already allocated "
                               f"({self._table[slot]})")
        pages = self.pages_for(n_tokens)
        if pages > self.frames_per_slot:
            raise CacheOverflow(
                f"{n_tokens} tokens need {pages} pages > "
                f"{self.frames_per_slot} frames/slot "
                f"(max_len={self.max_len}, page={self.page_size})")
        rec = PageAllocation(slot=slot, pages=pages, live_tokens=n_tokens,
                             frames=self._take_frames(pages))
        self._table[slot] = rec
        self.page_allocs += pages
        self.requests_admitted += 1
        self.high_water_pages = max(self.high_water_pages,
                                    self.allocated_pages)
        return rec

    def advance(self, slot: int, pos: int) -> int:
        """Decode wrote a cache row at ``pos``; allocate any new page
        that write crossed into.  Returns pages newly allocated."""
        rec = self._table[slot]
        rec.live_tokens = max(rec.live_tokens, pos + 1)
        need = self.pages_for(rec.live_tokens)
        grew = 0
        if need > rec.pages:
            if need > self.frames_per_slot:
                raise CacheOverflow(
                    f"slot {slot}: position {pos} is past the last frame "
                    f"({self.frames_per_slot} x {self.page_size})")
            grew = need - rec.pages
            rec.frames += self._take_frames(grew)
            rec.pages = need
            self.page_allocs += grew
            self.high_water_pages = max(self.high_water_pages,
                                        self.allocated_pages)
        return grew

    def free(self, slot: int) -> int:
        """Request finished: return every page the slot held."""
        rec = self._table.pop(slot)
        self._return_frames(rec.frames)
        self.page_frees += rec.pages
        self.requests_freed += 1
        return rec.pages

    # --- stats / invariants ----------------------------------------------

    @property
    def allocated_pages(self) -> int:
        return sum(r.pages for r in self._table.values())

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_tokens(self) -> int:
        return sum(r.live_tokens for r in self._table.values())

    def occupancy(self) -> float:
        """Fraction of the page pool currently allocated."""
        return self.allocated_pages / self.total_pages

    def fragmentation(self) -> float:
        """1 - live/paged tokens: the share of allocated cache rows not
        holding a live token (page-rounding waste; the zero-filled
        monolith this replaces sat at 1 - live/(slots*max_len))."""
        paged = self.allocated_pages * self.page_size
        return 1.0 - (self.live_tokens / paged) if paged else 0.0

    def external_fragmentation(self) -> float:
        """Share of FREE frames outside the longest contiguous free run
        (1 - longest_run / free).  Because frees re-enter the list in
        address order this is a pure residency property: it returns to
        exactly 0.0 whenever occupancy does, no matter how bursty the
        preceding churn was."""
        if not self._free:
            return 0.0
        best = run = 1
        for prev, cur in zip(self._free, self._free[1:]):
            run = run + 1 if cur == prev + 1 else 1
            best = max(best, run)
        return 1.0 - best / len(self._free)

    def stats(self) -> dict:
        return {
            "page_size": self.page_size,
            "total_pages": self.total_pages,
            "allocated_pages": self.allocated_pages,
            "free_pages": self.free_pages,
            "occupancy": self.occupancy(),
            "high_water_pages": self.high_water_pages,
            "live_tokens": self.live_tokens,
            "fragmentation": self.fragmentation(),
            "external_fragmentation": self.external_fragmentation(),
            "page_allocs": self.page_allocs,
            "page_frees": self.page_frees,
            "requests_admitted": self.requests_admitted,
            "requests_freed": self.requests_freed,
        }

    def check(self):
        """Raise if any page-table invariant is violated."""
        seen: set[int] = set()
        for slot, rec in self._table.items():
            assert 0 <= slot < self.slots, f"slot {slot} out of range"
            assert 0 < rec.pages <= self.frames_per_slot, rec
            assert len(rec.frames) == rec.pages, rec
            assert rec.live_tokens <= rec.pages * self.page_size, rec
            assert self.pages_for(rec.live_tokens) == rec.pages, \
                f"slot {slot}: {rec.pages} pages but " \
                f"{rec.live_tokens} live tokens"
            dup = seen & set(rec.frames)
            assert not dup, f"frames {dup} owned by two slots"
            seen |= set(rec.frames)
        assert all(b > a for a, b in zip(self._free, self._free[1:])), \
            "free list out of address order"
        assert not (seen & set(self._free)), "allocated frame in free list"
        assert len(seen) + len(self._free) == self.total_pages, \
            (len(seen), len(self._free), self.total_pages)
        assert self.allocated_pages <= self.total_pages
        assert self.page_allocs - self.page_frees == self.allocated_pages, \
            (self.page_allocs, self.page_frees, self.allocated_pages)
        assert self.requests_admitted - self.requests_freed \
            == len(self._table)

    def __repr__(self):
        return (f"PagedKVCache(slots={self.slots}, "
                f"pages={self.allocated_pages}/{self.total_pages}, "
                f"page={self.page_size})")
