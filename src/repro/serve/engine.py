"""Batched serving engine: prefill + decode steps over the mesh, with
continuous batching (slot-based request scheduling with per-slot
positions — finished slots are refilled without stalling the running
batch).

The decode KV cache is sequence-sharded over the model axis and the
partial-attention merge is a flash-decoding LSE psum (DESIGN.md §6), so
any GQA geometry serves on any mesh.  Around that physical cache the
runtime layers (docs/serving.md):

  * ``kv_cache.PagedKVCache``  — page-table admission/occupancy over
    the slots (alloc on prefill, grow on decode, free on completion);
  * ``scheduler.Scheduler``    — length-bucketed refill groups (mixed
    prompt lengths padded to a shared bucket), EDF/FCFS ordering and
    the prefill/decode interleave policy;
  * ``sampling.Sampler``       — per-request greedy/temperature/top-k/
    top-p decoding with per-request PRNG streams;
  * a virtual clock            — wall time of executed steps, which the
    traffic replay uses for arrivals and the SLO tracker for TTFT/TPOT.

Bucket-padded prompts decode correctly via last-token replay: a prompt
of true length ``s`` padded to ``S`` leaves garbage cache rows at
positions ``s..S-1``, but decode masks cache positions ``>= pos + 1``,
so the engine sets ``pos = s - 1``, feeds the last real prompt token as
the first decode input (recomputing exactly the row prefill wrote at
``s - 1``), and samples the first output token from that step's logits.
Every later write lands at the current ``pos``, overwriting each pad
row before it ever becomes attendable.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.specs import cache_specs, input_specs
from repro.models.model import (forward_decode, forward_prefill,
                                model_decls)
from repro.obs import get_metrics, get_tracer
from repro.parallel.axes import MeshAxes, resolve_spec
from repro.parallel.params import specs
from repro.parallel.compat import shard_map
from repro.serve.kv_cache import PagedKVCache
from repro.serve.sampling import Sampler, SamplingParams
from repro.serve.scheduler import Scheduler
from repro.telemetry import LedgerEntry, StepMeter

# model families whose prefill folds tokens into a recurrent state —
# these cannot be right-padded, so their refill groups are exact-length
RECURRENT_FAMILIES = ("ssm", "hybrid", "encdec")


def make_serve_fns(cfg: ModelConfig, mesh, shape: ShapeConfig):
    """Returns (prefill_fn, decode_fn, cache_sds, cache_spec_resolved).

    prefill_fn(params, batch) -> (logits [B,1,V], cache)
    decode_fn(params, cache, tokens [B,1], pos [B]) -> (logits, cache)
    """
    axes = MeshAxes.from_mesh(mesh)
    decls = model_decls(cfg, axes)
    pspecs = jax.tree.map(lambda s: resolve_spec(s, axes), specs(decls))
    c_sds, c_spec = cache_specs(cfg, shape, axes)
    cspecs = jax.tree.map(lambda s: resolve_spec(s, axes), c_spec,
                          is_leaf=lambda x: isinstance(x, P))
    in_sds, in_spec = input_specs(
        cfg, ShapeConfig(shape.name, shape.seq_len, shape.global_batch,
                         "prefill"), axes)
    bspecs = jax.tree.map(lambda s: resolve_spec(s, axes), in_spec,
                          is_leaf=lambda x: isinstance(x, P))
    tok_spec = bspecs["tokens"]
    pos_spec = P(tok_spec[0])

    def prefill(params, batch):
        return forward_prefill(cfg, axes, params, batch)

    def decode(params, cache, tokens, pos):
        return forward_decode(cfg, axes, params, cache, tokens, pos)

    logits_spec = P(tok_spec[0], None, None)
    prefill_fn = jax.jit(shard_map(
        prefill, mesh=mesh,
        in_specs=(pspecs, bspecs), out_specs=(logits_spec, cspecs),
        check_vma=False))
    decode_fn = jax.jit(shard_map(
        decode, mesh=mesh,
        in_specs=(pspecs, cspecs, tok_spec, pos_spec),
        out_specs=(logits_spec, cspecs),
        check_vma=False), donate_argnums=(1,))
    return prefill_fn, decode_fn, c_sds, cspecs


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

@dataclass
class Request:
    prompt: np.ndarray                  # [S_prompt] int32
    max_new_tokens: int = 32
    eos_id: int = -1                    # -1: never stops early
    sampling: SamplingParams = field(default_factory=SamplingParams)
    req_id: int = -1
    arrival_s: float = 0.0              # trace time (virtual clock)
    deadline_ms: float = 0.0            # e2e deadline; 0 = none
    out_tokens: list = field(default_factory=list)
    done: bool = False
    error: Optional[str] = None         # admission rejection reason
    # SLO stamps on the engine's virtual clock
    t_submit_s: Optional[float] = None
    t_first_s: Optional[float] = None
    t_done_s: Optional[float] = None
    _seq: int = field(default=0, repr=False)
    _sampler: Optional[Sampler] = field(default=None, repr=False)


class ServeEngine:
    """Slot-based continuous batching.

    All slots decode together each step with per-slot positions; the
    scheduler refills finished slots by running a batched prefill for a
    length-bucketed group of pending prompts and splicing their cache
    rows in (a jitted masked merge, so cache sharding is preserved).
    """

    def __init__(self, cfg: ModelConfig, mesh, params, *, slots: int = 8,
                 max_len: int = 256, ledger=None, page_size: int = 16,
                 order: str = "fcfs", min_free_for_prefill: int = 1,
                 scheduler: Optional[Scheduler] = None, serve_fns=None):
        self.cfg, self.mesh, self.params = cfg, mesh, params
        self.slots = slots
        self.max_len = max_len
        self.ledger = ledger
        self.prefill_meter = StepMeter(f"prefill_{cfg.name}", warmup=1)
        self.decode_meter = StepMeter(f"decode_{cfg.name}", warmup=1)
        self._ledger_window = 0
        self._closed = False
        # the cache seq dim is sharded over the model axis, so every
        # prefill length (= a bucket multiple) must divide tp — the
        # invariant the old `S % 16 == 0` assert enforced
        tp = MeshAxes.from_mesh(mesh).tp
        if page_size % tp:
            raise ValueError(
                f"page_size {page_size} must be a multiple of the "
                f"model-axis size {tp} (sequence-shard divisibility of "
                f"bucket-padded prefills)")
        self.pages = PagedKVCache(slots, max_len, page_size)
        self.scheduler = scheduler or Scheduler(
            bucket=page_size, order=order,
            mixed_lengths=cfg.family not in RECURRENT_FAMILIES,
            min_free_for_prefill=min_free_for_prefill, pages=self.pages)
        # virtual clock: wall seconds of executed steps x clock_scale
        self.now_s = 0.0
        self.clock_scale = 1.0
        # fleet decode pools pass one shared ``make_serve_fns`` tuple so
        # every replica reuses the same jitted (compiled-once) steps
        shape = ShapeConfig("serve", max_len, slots, "decode")
        self.prefill_fn, self.decode_fn, self.cache_sds, self.cspecs = \
            serve_fns if serve_fns is not None \
            else make_serve_fns(cfg, mesh, shape)
        self.cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.cache_sds)
        self.pos = np.zeros((slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.last_tok = np.zeros((slots, 1), np.int32)

        def merge(cache, fresh, mask):
            def m(c, f):
                # batch dim is axis 1 (axis 0 is the layer-stacked axis)
                return jnp.where(
                    mask.reshape((1, -1) + (1,) * (c.ndim - 2)), f, c)
            return jax.tree.map(m, cache, fresh)

        self._merge = jax.jit(merge)

        def adopt_merge(cache, rows, slot):
            def m(c, r):
                start = (jnp.int32(0), jnp.int32(slot)) + \
                    (jnp.int32(0),) * (c.ndim - 2)
                return jax.lax.dynamic_update_slice(
                    c, r.astype(c.dtype), start)
            return jax.tree.map(m, cache, rows)

        self._adopt_merge = jax.jit(adopt_merge)

    # --- clock -----------------------------------------------------------

    def advance_clock(self, dt_s: float):
        """Jump the virtual clock forward (idle gaps in a trace replay)."""
        self.now_s += max(0.0, dt_s)

    def _timed(self, meter, fn, *args):
        t0 = time.perf_counter()
        out = meter.call(fn, *args)
        self.now_s += (time.perf_counter() - t0) * self.clock_scale
        return out

    def has_active(self) -> bool:
        return any(r is not None for r in self.active)

    def warmup(self, bucket_lens=()):
        """Compile the decode step and one prefill per bucket length
        OUTSIDE the meters and the virtual clock — a trace replay would
        otherwise bill multi-second XLA compiles as TTFT.  Real
        deployments warm their known buckets at startup the same way."""
        for S in sorted(set(bucket_lens)):
            batch = _add_modality_stubs(
                self.cfg, {"tokens": jnp.zeros((self.slots, S),
                                               jnp.int32)},
                self.slots, S)
            jax.block_until_ready(self.prefill_fn(self.params, batch))
        cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.cache_sds)
        out, _ = self.decode_fn(self.params, cache,
                                jnp.asarray(self.last_tok),
                                jnp.asarray(self.pos))
        jax.block_until_ready(out)

    @property
    def queue(self):
        return self.scheduler.queue

    # --- scheduling ------------------------------------------------------

    def submit(self, requests: List[Request]):
        """Enqueue requests (admission-checked) and refill free slots.
        Unlike the old engine, submitting is cumulative — a trace replay
        feeds arrivals in as the clock passes them."""
        for req in requests:
            if len(req.prompt) == 0:
                req.done, req.error = True, "rejected: empty prompt"
                self.scheduler.rejected.append(req)
                continue
            req.t_submit_s = self.now_s
            req._sampler = Sampler(req.sampling, self.cfg.vocab_size)
            self.scheduler.add([req])
        self._fill_slots()

    def _fill_slots(self):
        """Refill free slots with length-bucketed prefill groups, per
        the scheduler's interleave policy.  One group = one batched
        prefill call."""
        free = [i for i in range(self.slots) if self.active[i] is None]
        while free:
            n_active = self.slots - len(free)
            if not self.scheduler.should_refill(len(free), n_active):
                return
            S, group = self.scheduler.next_group(len(free))
            if not group:
                return
            self._prefill_group(S, group, free)

    def _prefill_group(self, S: int, group: List[Request],
                       free: List[int]):
        """Batched prefill for ``group`` (prompts padded to ``S``),
        splicing the new cache rows into the popped free slots."""
        slot_ids = [free.pop(0) for _ in group]
        toks = np.zeros((self.slots, S), np.int32)
        for i, req in zip(slot_ids, group):
            toks[i, :len(req.prompt)] = req.prompt
        batch = {"tokens": jnp.asarray(toks)}
        batch = _add_modality_stubs(self.cfg, batch, self.slots, S)
        with get_tracer().span("serve/prefill", cat="serve", bucket=S,
                               group=len(group)):
            logits, fresh_full = self._timed(self.prefill_meter,
                                             self.prefill_fn,
                                             self.params, batch)
        get_metrics().counter(
            "serve_prefill_tokens_total",
            "real (unpadded) prompt tokens prefilled").inc(
                sum(len(r.prompt) for r in group))
        # prefill used seq S; splice into the max_len cache rows
        fresh = jax.tree.map(
            lambda f, c: _pad_cache_seq(f, c), fresh_full, self.cache)
        logits = np.asarray(logits)
        mask = np.zeros((self.slots,), bool)
        for i, req in zip(slot_ids, group):
            mask[i] = True
            self.active[i] = req
            self.pages.alloc(i, S)
            s = len(req.prompt)
            if s == S:
                # exact-length: prefill's last-position logits ARE the
                # first output token
                nxt = req._sampler(logits[i, 0])
                req.out_tokens.append(nxt)
                req.t_first_s = self.now_s
                self.last_tok[i, 0] = nxt
                self.pos[i] = s
                # a prefill-produced token can already terminate: eos,
                # or a max_new_tokens=1 request (no decode step burned)
                if nxt == req.eos_id or req.max_new_tokens <= 1:
                    self._finish(i, req)
                    free.append(i)
            else:
                # bucket-padded: replay the last real prompt token as
                # the first decode input (see module docstring)
                self.last_tok[i, 0] = req.prompt[s - 1]
                self.pos[i] = s - 1
        self.cache = self._merge(self.cache, fresh, jnp.asarray(mask))

    def adopt(self, req: Request, cache_rows, *, prefill_len: int,
              pos: int, last_tok: int) -> int:
        """Install a request whose KV cache was computed ELSEWHERE (a
        fleet prefill pool) into a free slot: page admission, a jitted
        dynamic-update of the slot's cache rows, and the decode state
        (``pos`` / ``last_tok``) exactly as ``_prefill_group`` would
        have left them — so the replay-last-token contract survives the
        migration.  ``cache_rows`` is a pytree matching ``self.cache``
        with batch axis 1 (seq may be the padded prefill length; it is
        right-padded to ``max_len`` here).  Returns the slot id; raises
        ``RuntimeError`` when no slot is free and ``CacheOverflow`` when
        the request cannot fit a slot's frames."""
        free = [i for i in range(self.slots) if self.active[i] is None]
        if not free:
            raise RuntimeError("adopt: no free slot")
        if req.done:
            raise RuntimeError(f"adopt: request {req.req_id} already done")
        slot = free[0]
        self.pages.alloc(slot, prefill_len)
        if req._sampler is None:
            req._sampler = Sampler(req.sampling, self.cfg.vocab_size)
        rows = jax.tree.map(
            lambda r, c: _pad_cache_seq(jnp.asarray(r), c[:, :1]),
            cache_rows, self.cache)
        self.cache = self._adopt_merge(self.cache, rows,
                                       jnp.int32(slot))
        self.active[slot] = req
        self.pos[slot] = pos
        self.last_tok[slot, 0] = last_tok
        return slot

    def _finish(self, slot: int, req: Request):
        req.done = True
        req.t_done_s = self.now_s
        self.active[slot] = None
        self.pages.free(slot)

    # --- decode ----------------------------------------------------------

    def step(self):
        if not self.has_active():
            self._fill_slots()
            if not self.has_active():
                return
        n_active = sum(r is not None for r in self.active)
        with get_tracer().span("serve/decode", cat="serve",
                               active=n_active):
            logits, self.cache = self._timed(
                self.decode_meter, self.decode_fn, self.params,
                self.cache, jnp.asarray(self.last_tok),
                jnp.asarray(self.pos))
        get_metrics().counter(
            "serve_decode_tokens_total",
            "tokens produced by decode steps").inc(n_active)
        logits = np.asarray(logits)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            wrote = int(self.pos[i])          # decode wrote this row
            self.pos[i] += 1
            self.pages.advance(i, wrote)
            nxt = req._sampler(logits[i, 0])
            if req.t_first_s is None:         # replayed-prompt first token
                req.t_first_s = self.now_s
            req.out_tokens.append(nxt)
            self.last_tok[i, 0] = nxt
            if (len(req.out_tokens) >= req.max_new_tokens
                    or nxt == req.eos_id
                    or self.pos[i] >= self.max_len - 1):
                self._finish(i, req)
        self._fill_slots()

    def run(self, requests: List[Request], max_steps: int = 10_000):
        self.submit(requests)
        steps = 0
        while (self.has_active() or len(self.scheduler)) \
                and steps < max_steps:
            self.step()
            steps += 1
        if self.ledger is not None:
            self.record_to(self.ledger)
        return requests

    # --- shutdown --------------------------------------------------------

    def close(self):
        """Flush the telemetry window and mark the engine closed.

        Short serving sessions (a few ``step()`` calls, no ``run()``)
        otherwise drop their tail records: the meters only reach the
        ledger when ``run()`` completes.  Idempotent — a window already
        flushed by ``run()`` has empty meters and records nothing."""
        if self._closed:
            return
        self._closed = True
        if self.ledger is not None:
            if self.prefill_meter.calls or self.decode_meter.calls:
                self.record_to(self.ledger)
            self.ledger.flush()

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False

    # --- telemetry -------------------------------------------------------

    def telemetry(self) -> dict:
        """Wall-time summaries for the prefill and decode meters, plus
        the page-table occupancy stats."""
        return {"prefill": self.prefill_meter.summary(),
                "decode": self.decode_meter.summary(),
                "pages": self.pages.stats()}

    def record_to(self, ledger, predicted=None, extra=None,
                  measured_extra=None):
        """Flush one serving entry per metered step kind to a Ledger.

        The meters are reset afterwards, so repeated ``run()`` calls
        record disjoint windows rather than overlapping cumulative
        summaries (the ``window`` counter in ``extra`` orders them).
        ``predicted`` / ``measured_extra`` are optional per-kind dicts
        (``{"prefill": {...}, "decode": {...}}``) — the router passes
        the analytic serve prediction and the compiled-HLO measured
        fields so the entries join into energy ratios."""
        axes = MeshAxes.from_mesh(self.mesh)
        impl = ("phantom" if self.cfg.uses_phantom_sites() else "dense")
        out = []
        for kind, meter in (("prefill", self.prefill_meter),
                            ("decode", self.decode_meter)):
            if not meter.calls:
                continue
            ex = {"slots": self.slots, "max_len": self.max_len,
                  "window": self._ledger_window,
                  "pages": self.pages.stats()}
            ex.update(extra or {})
            measured = meter.summary()
            if measured_extra and measured_extra.get(kind):
                measured.update(measured_extra[kind])
            out.append(ledger.record(LedgerEntry(
                name=f"serve_{kind}_{self.cfg.name}", suite="serve",
                kind=kind, arch=self.cfg.name, impl=impl, p=axes.tp,
                measured=measured,
                predicted=predicted.get(kind) if predicted else None,
                extra=ex)))
            meter.reset(warm=True)
        self._ledger_window += 1
        return out


def _pad_cache_seq(fresh, target):
    """Right-pad prefill cache (seq S) to the engine's max_len cache."""
    if fresh.shape == target.shape:
        return fresh
    pads = []
    for a, b in zip(fresh.shape, target.shape):
        pads.append((0, b - a))
    return jnp.pad(fresh, pads)


def _add_modality_stubs(cfg, batch, B, S):
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros((B, S, cfg.d_model), jnp.float32)
    if cfg.frontend == "vision":
        from repro.models.model import n_vision_tokens
        nv = n_vision_tokens(cfg, S)
        batch["vision_embeds"] = jnp.zeros((B, nv, cfg.d_model),
                                           jnp.float32)
    if cfg.rope == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        batch["positions"] = jnp.stack([pos, pos, pos])
    return batch
