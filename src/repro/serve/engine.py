"""Batched serving engine: prefill + decode steps over the mesh, greedy
generation, and continuous batching (slot-based request scheduling with
per-slot positions — finished slots are refilled without stalling the
running batch).

The decode KV cache is sequence-sharded over the model axis and the
partial-attention merge is a flash-decoding LSE psum (DESIGN.md §6), so
any GQA geometry serves on any mesh.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.specs import cache_specs, input_specs
from repro.models.model import (forward_decode, forward_prefill,
                                model_decls)
from repro.parallel.axes import MeshAxes, resolve_spec
from repro.parallel.params import specs
from repro.parallel.compat import shard_map
from repro.telemetry import LedgerEntry, StepMeter


def make_serve_fns(cfg: ModelConfig, mesh, shape: ShapeConfig):
    """Returns (prefill_fn, decode_fn, cache_sds, cache_spec_resolved).

    prefill_fn(params, batch) -> (logits [B,1,V], cache)
    decode_fn(params, cache, tokens [B,1], pos [B]) -> (logits, cache)
    """
    axes = MeshAxes.from_mesh(mesh)
    decls = model_decls(cfg, axes)
    pspecs = jax.tree.map(lambda s: resolve_spec(s, axes), specs(decls))
    c_sds, c_spec = cache_specs(cfg, shape, axes)
    cspecs = jax.tree.map(lambda s: resolve_spec(s, axes), c_spec,
                          is_leaf=lambda x: isinstance(x, P))
    in_sds, in_spec = input_specs(
        cfg, ShapeConfig(shape.name, shape.seq_len, shape.global_batch,
                         "prefill"), axes)
    bspecs = jax.tree.map(lambda s: resolve_spec(s, axes), in_spec,
                          is_leaf=lambda x: isinstance(x, P))
    tok_spec = bspecs["tokens"]
    pos_spec = P(tok_spec[0])

    def prefill(params, batch):
        return forward_prefill(cfg, axes, params, batch)

    def decode(params, cache, tokens, pos):
        return forward_decode(cfg, axes, params, cache, tokens, pos)

    logits_spec = P(tok_spec[0], None, None)
    prefill_fn = jax.jit(shard_map(
        prefill, mesh=mesh,
        in_specs=(pspecs, bspecs), out_specs=(logits_spec, cspecs),
        check_vma=False))
    decode_fn = jax.jit(shard_map(
        decode, mesh=mesh,
        in_specs=(pspecs, cspecs, tok_spec, pos_spec),
        out_specs=(logits_spec, cspecs),
        check_vma=False), donate_argnums=(1,))
    return prefill_fn, decode_fn, c_sds, cspecs


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

@dataclass
class Request:
    prompt: np.ndarray                  # [S_prompt] int32
    max_new_tokens: int = 32
    eos_id: int = -1                    # -1: never stops early
    out_tokens: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Slot-based continuous batching.

    All slots decode together each step with per-slot positions; finished
    slots are refilled from the queue by running a fresh batched prefill
    for the pending prompts and splicing their cache rows in (a jitted
    masked merge, so cache sharding is preserved).
    """

    def __init__(self, cfg: ModelConfig, mesh, params, *, slots: int = 8,
                 max_len: int = 256, ledger=None):
        self.cfg, self.mesh, self.params = cfg, mesh, params
        self.slots = slots
        self.max_len = max_len
        self.ledger = ledger
        self.prefill_meter = StepMeter(f"prefill_{cfg.name}", warmup=1)
        self.decode_meter = StepMeter(f"decode_{cfg.name}", warmup=1)
        self._ledger_window = 0
        self._closed = False
        shape = ShapeConfig("serve", max_len, slots, "decode")
        self.prefill_fn, self.decode_fn, self.cache_sds, self.cspecs = \
            make_serve_fns(cfg, mesh, shape)
        self.cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.cache_sds)
        self.pos = np.zeros((slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.last_tok = np.zeros((slots, 1), np.int32)

        def merge(cache, fresh, mask):
            def m(c, f):
                # batch dim is axis 1 (axis 0 is the layer-stacked axis)
                return jnp.where(
                    mask.reshape((1, -1) + (1,) * (c.ndim - 2)), f, c)
            return jax.tree.map(m, cache, fresh)

        self._merge = jax.jit(merge)

    def submit(self, requests: List[Request]):
        self.queue = list(requests)
        self._fill_slots()

    def _fill_slots(self):
        pending = []
        slot_ids = []
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                self.active[i] = req
                pending.append(req)
                slot_ids.append(i)
        if not pending:
            return
        # batched prefill for ALL slots, then splice the new rows in.
        # Prompts within one refill group must share a length (real
        # deployments bucket by length); right-padding would misplace the
        # last-token logits otherwise.
        lens = {len(r.prompt) for r in pending}
        assert len(lens) == 1, ("prompts in one refill group must have "
                                f"equal length, got {sorted(lens)}")
        S = max(len(r.prompt) for r in pending)
        assert S % 16 == 0, ("prompt length must be a multiple of 16 "
                             "(sequence-sharding divisibility), got "
                             f"{S}")
        toks = np.zeros((self.slots, S), np.int32)
        for i, req in zip(slot_ids, pending):
            toks[i, :len(req.prompt)] = req.prompt
        batch = {"tokens": jnp.asarray(toks)}
        batch = _add_modality_stubs(self.cfg, batch, self.slots, S)
        logits, fresh_full = self.prefill_meter.call(
            self.prefill_fn, self.params, batch)
        # prefill used seq S; splice into the max_len cache rows
        fresh = jax.tree.map(
            lambda f, c: _pad_cache_seq(f, c), fresh_full, self.cache)
        mask = np.zeros((self.slots,), bool)
        for i, req in zip(slot_ids, pending):
            mask[i] = True
            self.pos[i] = len(req.prompt)
            nxt = int(np.argmax(np.asarray(logits)[i, 0]))
            self.last_tok[i, 0] = nxt
            req.out_tokens.append(nxt)
        self.cache = self._merge(self.cache, fresh, jnp.asarray(mask))

    def step(self):
        logits, self.cache = self.decode_meter.call(
            self.decode_fn, self.params, self.cache,
            jnp.asarray(self.last_tok), jnp.asarray(self.pos))
        logits = np.asarray(logits)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[i] += 1
            nxt = int(np.argmax(logits[i, 0]))
            req.out_tokens.append(nxt)
            self.last_tok[i, 0] = nxt
            if (len(req.out_tokens) >= req.max_new_tokens
                    or nxt == req.eos_id
                    or self.pos[i] >= self.max_len - 1):
                req.done = True
                self.active[i] = None
        self._fill_slots()

    def run(self, requests: List[Request], max_steps: int = 10_000):
        self.submit(requests)
        steps = 0
        while any(r is not None for r in self.active) and steps < max_steps:
            self.step()
            steps += 1
        if self.ledger is not None:
            self.record_to(self.ledger)
        return requests

    # --- shutdown --------------------------------------------------------

    def close(self):
        """Flush the telemetry window and mark the engine closed.

        Short serving sessions (a few ``step()`` calls, no ``run()``)
        otherwise drop their tail records: the meters only reach the
        ledger when ``run()`` completes.  Idempotent — a window already
        flushed by ``run()`` has empty meters and records nothing."""
        if self._closed:
            return
        self._closed = True
        if self.ledger is not None and (self.prefill_meter.calls
                                        or self.decode_meter.calls):
            self.record_to(self.ledger)

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False

    # --- telemetry -------------------------------------------------------

    def telemetry(self) -> dict:
        """Wall-time summaries for the prefill and decode meters."""
        return {"prefill": self.prefill_meter.summary(),
                "decode": self.decode_meter.summary()}

    def record_to(self, ledger, predicted=None):
        """Flush one serving entry per metered step kind to a Ledger.

        The meters are reset afterwards, so repeated ``run()`` calls
        record disjoint windows rather than overlapping cumulative
        summaries (the ``window`` counter in ``extra`` orders them)."""
        axes = MeshAxes.from_mesh(self.mesh)
        impl = ("phantom" if self.cfg.uses_phantom_sites() else "dense")
        out = []
        for kind, meter in (("prefill", self.prefill_meter),
                            ("decode", self.decode_meter)):
            if not meter.calls:
                continue
            out.append(ledger.record(LedgerEntry(
                name=f"serve_{kind}_{self.cfg.name}", suite="serve",
                kind=kind, arch=self.cfg.name, impl=impl, p=axes.tp,
                measured=meter.summary(),
                predicted=predicted.get(kind) if predicted else None,
                extra={"slots": self.slots, "max_len": self.max_len,
                       "window": self._ledger_window})))
            meter.reset(warm=True)
        self._ledger_window += 1
        return out


def _pad_cache_seq(fresh, target):
    """Right-pad prefill cache (seq S) to the engine's max_len cache."""
    if fresh.shape == target.shape:
        return fresh
    pads = []
    for a, b in zip(fresh.shape, target.shape):
        pads.append((0, b - a))
    return jnp.pad(fresh, pads)


def _add_modality_stubs(cfg, batch, B, S):
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros((B, S, cfg.d_model), jnp.float32)
    if cfg.frontend == "vision":
        from repro.models.model import n_vision_tokens
        nv = n_vision_tokens(cfg, S)
        batch["vision_embeds"] = jnp.zeros((B, nv, cfg.d_model),
                                           jnp.float32)
    if cfg.rope == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        batch["positions"] = jnp.stack([pos, pos, pos])
    return batch
