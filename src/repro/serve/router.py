"""Energy-aware serve routing: price candidate configs in predicted
joules-per-token, route a trace to the cheapest one meeting the SLO,
and record measured-vs-predicted serve energy to the Ledger.

A ``ServeConfig`` is one way to stand the serving engine up: projection
family (tensor vs phantom at the MLP sites — the paper's technique on
the inference path), mesh shape (dp x tp), and slot count.  Like the
training planner, phantom candidates may use FEWER devices than the
budget: the claim under test is that a phantom config on a smaller mesh
can meet the same SLO at lower joules-per-token.

Pricing reuses the planner's calibrated constants
(``planner.load_calibration``: PLAN_report.json's fitted block when a
planning pass ran, else a fresh ledger fit, else paper defaults) and
``telemetry.predict.serve_step_prediction`` — the fwd-only per-step
account of the very strategy objects that execute, priced by
E = p·(A·α + B·β).  Joules-per-token for a trace with mean padded
prompt length S, mean output length G, at full slot occupancy:

    J/tok = (E_prefill_step / slots + G · E_decode_step / slots) / G

(the prefill step serves ``slots`` prompts, each decode step yields
``slots`` tokens).  Predicted TTFT/TPOT are the α+β step times of the
MODELED accelerator (paper Frontier/TPU constants) — the SLO gate is a
model-based feasibility screen; the measured SLO report comes from the
replay itself.

After routing, ``run_config`` replays the trace, lowers the engine's
own prefill/decode functions to read the MEASURED compiled-HLO account
(``telemetry.predict.measured_energy_fields``), and records joined
ledger rows whose ``ratios.energy_j_per_iter`` CI pins to [0.5, 2.0].
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import (ModelConfig, ProjectionMap, ProjectionSpec,
                                get_config)
from repro.core.energy import FRONTIER_A_W, FRONTIER_B_W, TPU_PEAK_FLOPS
from repro.planner.calibration import Calibration
from repro.serve.scheduler import bucket_of
from repro.serve.traffic import SLOTracker, TraceItem, replay, trace_requests

# the ffn sites the phantom candidates factorize (the paper's technique;
# attention projections stay dense on the serving path)
_PHANTOM_FFN = ("ffn_gate", "ffn_up", "ffn_down")


@dataclass(frozen=True)
class ServeConfig:
    """One candidate serving configuration."""
    arch: str
    impl: str                    # "tensor" | "phantom"
    dp: int
    tp: int
    slots: int
    max_len: int = 64
    page_size: int = 16
    k: int = 0                   # ghost width; 0 = the arch's default

    @property
    def devices(self) -> int:
        return self.dp * self.tp

    @property
    def name(self) -> str:
        tag = f"{self.arch}-{self.impl}-mesh{self.dp}x{self.tp}" \
              f"-slots{self.slots}"
        if self.impl == "phantom" and self.k:
            tag += f"-k{self.k}"
        return tag

    @property
    def strategy_kind(self) -> str:
        """The calibration table key for this config's MLP strategy."""
        return "phantom" if self.impl == "phantom" else "tensor_col"

    def model_config(self) -> ModelConfig:
        """The ModelConfig this candidate serves.  ``scan_layers=False``
        so the compiled-HLO measured account is exact (XLA counts scan
        bodies once — the dry-run caveat)."""
        cfg = get_config(self.arch, smoke=True)
        if self.impl == "phantom":
            ph = ProjectionSpec(kind="phantom",
                                k=self.k or cfg.phantom.k)
            pm = ProjectionMap(**{s: ph for s in _PHANTOM_FFN})
        else:
            pm = ProjectionMap(default=ProjectionSpec(kind="tensor"))
        return cfg.replace(name=self.name, projections=pm,
                           scan_layers=False)

    def as_dict(self) -> dict:
        return {"name": self.name, "arch": self.arch, "impl": self.impl,
                "dp": self.dp, "tp": self.tp, "devices": self.devices,
                "slots": self.slots, "max_len": self.max_len,
                "page_size": self.page_size, "k": self.k}


def candidate_configs(arch: str, devices: int = 8, *,
                      slots_options: Sequence[int] = (4, 8),
                      max_len: int = 64,
                      page_size: int = 16) -> List[ServeConfig]:
    """Enumerate candidates: tensor configs use the FULL device budget
    (idling paid-for devices under the baseline would make the phantom
    comparison trivially winnable — same rule as the training planner);
    phantom configs may downsize to sub-meshes."""
    cfg = get_config(arch, smoke=True)
    out = []
    # tp >= 2 only: the router arbitrates MODEL-PARALLEL serving
    # configs (sequence-sharded cache, phantom-vs-tensor projections);
    # a tp=1 pure-replication deployment has no collectives at all and
    # would trivially win the latency-dominated energy model — it is
    # still reachable explicitly via ``--route fixed --tp 1``.
    for tp in (2, 4, 8, 16):
        if tp > devices or cfg.d_model % tp:
            continue
        if cfg.num_heads and cfg.num_heads % tp:
            continue
        for slots in slots_options:
            if devices % tp == 0:
                out.append(ServeConfig(arch, "tensor", devices // tp, tp,
                                       slots, max_len, page_size))
            # phantom needs >= 2 model ranks and ffn divisibility
            if tp >= 2 and cfg.d_ff and cfg.d_ff % tp == 0:
                for dp in (1, 2):
                    if dp * tp <= devices:
                        out.append(ServeConfig(arch, "phantom", dp, tp,
                                               slots, max_len, page_size))
    # dedupe (tensor tp==devices appears once per slots already)
    seen, uniq = set(), []
    for sc in out:
        if sc.name not in seen:
            seen.add(sc.name)
            uniq.append(sc)
    return uniq


# ---------------------------------------------------------------------------
# pricing
# ---------------------------------------------------------------------------

@dataclass
class PricedConfig:
    config: ServeConfig
    j_per_token: float
    prefill_energy_j: float       # per prefill step (slots prompts)
    decode_energy_j: float        # per decode step (slots tokens)
    ttft_s: float                 # modeled prefill step time
    tpot_s: float                 # modeled decode step time
    meets_slo: bool
    notes: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {"config": self.config.as_dict(),
                "j_per_token": self.j_per_token,
                "prefill_energy_j": self.prefill_energy_j,
                "decode_energy_j": self.decode_energy_j,
                "ttft_s": self.ttft_s, "tpot_s": self.tpot_s,
                "meets_slo": self.meets_slo, "notes": self.notes}


def trace_stats(trace: Sequence[TraceItem], page_size: int = 16) -> dict:
    """Mean padded prompt length / output length the pricing uses."""
    pads = [bucket_of(t.prompt_len, page_size) for t in trace]
    outs = [t.max_new_tokens for t in trace]
    return {"n": len(trace),
            "mean_padded_prompt": float(np.mean(pads)) if pads else 0.0,
            "mean_new_tokens": float(np.mean(outs)) if outs else 1.0,
            "max_padded_prompt": max(pads) if pads else 0}


def serve_predictions(sc: ServeConfig, calib: Calibration,
                      stats: dict) -> Tuple[dict, dict]:
    """(prefill, decode) ``serve_step_prediction`` blocks for one
    candidate under a trace's length statistics."""
    from repro.telemetry.predict import serve_step_prediction
    cfg = sc.model_config()
    a_s, b_s, _nu = calib.scales_for(sc.strategy_kind)
    S = max(stats["mean_padded_prompt"], 1.0)
    G = max(stats["mean_new_tokens"], 1.0)
    del G  # step counts, not per-step shape, carry the output length
    # ctx_tokens follows EXECUTED attention windows (what the energy
    # model must price and the lowered HLO counts): blockwise attention
    # computes the full masked window — S keys per prefill query token,
    # the whole max_len cache per decode token
    pre = serve_step_prediction(
        cfg, sc.tp, int(round(sc.slots * S)), phase="prefill",
        ctx_tokens=S, sequences=sc.slots, dp=sc.dp,
        fits=calib.collective_fits, alpha_scale=a_s, beta_scale=b_s)
    dec = serve_step_prediction(
        cfg, sc.tp, sc.slots, phase="decode",
        ctx_tokens=float(sc.max_len), dp=sc.dp,
        fits=calib.collective_fits, alpha_scale=a_s, beta_scale=b_s)
    return pre, dec


def price_config(sc: ServeConfig, calib: Calibration, stats: dict, *,
                 slo_ms: float = 0.0) -> PricedConfig:
    """Predicted joules-per-generated-token + modeled step times."""
    pre, dec = serve_predictions(sc, calib, stats)
    G = max(stats["mean_new_tokens"], 1.0)
    # E = p*(A*alpha+B*beta) in the prediction is per MODEL group; a
    # dp-replicated mesh runs dp copies of the step for dp x the rows,
    # so per-step energy scales by dp while tokens/step scales the same
    # way — j/token is dp-invariant, total power is not.  Price per
    # GLOBAL step (all dp groups) over global tokens.
    e_pre = pre["energy_j_per_iter"] * sc.dp
    e_dec = dec["energy_j_per_iter"] * sc.dp
    tokens_per_step = sc.slots * sc.dp
    j_tok = (e_pre / tokens_per_step + G * e_dec / tokens_per_step) / G
    ttft = pre["alpha_s"] + pre["beta_s"]
    tpot = dec["alpha_s"] + dec["beta_s"]
    meets = (not slo_ms) or (ttft * 1e3 <= slo_ms and tpot * 1e3 <= slo_ms)
    return PricedConfig(
        config=sc, j_per_token=j_tok, prefill_energy_j=e_pre,
        decode_energy_j=e_dec, ttft_s=ttft, tpot_s=tpot, meets_slo=meets,
        notes={"alpha_scale": pre["alpha_scale"],
               "beta_scale": pre["beta_scale"],
               "calibration": calib.source,
               "mean_padded_prompt": stats["mean_padded_prompt"],
               "mean_new_tokens": stats["mean_new_tokens"]})


def route(candidates: Sequence[ServeConfig], calib: Calibration,
          trace: Sequence[TraceItem], *, slo_ms: float = 0.0
          ) -> Tuple[PricedConfig, List[PricedConfig]]:
    """Price every candidate and pick the cheapest j/token among those
    meeting the (modeled) SLO; with no feasible candidate, fall back to
    the lowest-latency one so serving still comes up."""
    if not candidates:
        raise ValueError("no serve candidates to route over")
    from repro.obs import get_tracer
    with get_tracer().span("serve/route", cat="serve",
                           candidates=len(candidates)) as sp:
        stats = trace_stats(trace, candidates[0].page_size)
        priced = [price_config(sc, calib, stats, slo_ms=slo_ms)
                  for sc in candidates]
        # ties in j/token (dp-invariant pricing) go to the SMALLER mesh
        # — fewer devices at the same joules-per-token is strictly better
        priced.sort(key=lambda pc: (pc.j_per_token, pc.config.devices))
        feasible = [pc for pc in priced if pc.meets_slo]
        winner = feasible[0] if feasible else \
            min(priced, key=lambda pc: pc.ttft_s)
        sp.annotate(winner=winner.config.name, feasible=len(feasible),
                    j_per_token=winner.j_per_token)
    return winner, priced


# ---------------------------------------------------------------------------
# routed execution
# ---------------------------------------------------------------------------

def run_config(sc: ServeConfig, trace: Sequence[TraceItem], *,
               ledger=None, calib: Optional[Calibration] = None,
               seed: int = 0, slo_ms: float = 0.0,
               sampling=None, mesh=None, order: str = "fcfs",
               max_steps: int = 100_000) -> dict:
    """Stand up the engine for ``sc``, replay ``trace`` through it, and
    record joined measured-vs-predicted serve rows to ``ledger``.

    Returns ``{"slo": <SLO report>, "measured": ..., "predicted": ...,
    "energy_ratio": ..., "j_per_token_measured": ...}``."""
    import jax
    from repro.launch.mesh import make_local_mesh
    from repro.models.model import model_decls
    from repro.parallel.axes import MeshAxes
    from repro.parallel.params import materialize
    from repro.serve.engine import ServeEngine
    from repro.telemetry import analyze_lowerable, measured_energy_fields

    calib = calib or Calibration()
    cfg = sc.model_config()
    mesh = mesh or make_local_mesh(sc.dp, sc.tp)
    axes = MeshAxes.from_mesh(mesh)
    params = materialize(model_decls(cfg, axes), seed)
    stats = trace_stats(trace, sc.page_size)
    reqs = trace_requests(trace, cfg.vocab_size, seed=seed,
                          sampling=sampling)

    eng = ServeEngine(cfg, mesh, params, slots=sc.slots,
                      max_len=sc.max_len, page_size=sc.page_size,
                      order=order)
    eng.warmup(bucket_of(t.prompt_len, sc.page_size) for t in trace)
    tracker = SLOTracker(slo_ttft_ms=slo_ms)
    from repro.obs import get_tracer
    with get_tracer().span("serve/replay", cat="serve",
                           config=sc.name, requests=len(reqs)):
        replay(eng, reqs, tracker=tracker, max_steps=max_steps)
    slo_report = tracker.report()
    pages = eng.pages.stats()

    # measured compiled-HLO account of the engine's OWN step functions
    p_sds = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    tok_sds = jax.ShapeDtypeStruct((sc.slots, 1), np.int32)
    pos_sds = jax.ShapeDtypeStruct((sc.slots,), np.int32)
    dec_costs = analyze_lowerable(eng.decode_fn, p_sds, eng.cache_sds,
                                  tok_sds, pos_sds, default_group=sc.tp)
    S_probe = int(stats["max_padded_prompt"] or sc.page_size)
    from repro.serve.engine import _add_modality_stubs
    probe_batch = _add_modality_stubs(
        cfg, {"tokens": jax.ShapeDtypeStruct((sc.slots, S_probe),
                                             np.int32)},
        sc.slots, S_probe)
    pre_costs = analyze_lowerable(eng.prefill_fn, p_sds, probe_batch,
                                  default_group=sc.tp)

    measured = {
        "prefill": measured_energy_fields(pre_costs, sc.tp,
                                          fits=calib.collective_fits),
        "decode": measured_energy_fields(dec_costs, sc.tp,
                                         fits=calib.collective_fits),
    }
    # the prediction prices the MEAN padded prompt; the probe lowered
    # the max bucket — rescale the prediction to the probed shape so
    # the ratio compares like with like
    probe_stats = dict(stats, mean_padded_prompt=float(S_probe))
    pred_pre, pred_dec = serve_predictions(sc, calib, probe_stats)
    predicted = {"prefill": pred_pre, "decode": pred_dec}

    g_tok = slo_report.get("generated_tokens", 0)
    e_meas_total = (measured["prefill"]["energy_j_per_iter"] * sc.dp
                    * eng.prefill_meter.calls
                    + measured["decode"]["energy_j_per_iter"] * sc.dp
                    * eng.decode_meter.calls)
    out = {
        "config": sc.as_dict(),
        "slo": slo_report,
        "pages": pages,
        "measured": measured,
        "predicted": predicted,
        "energy_ratio": {
            k: measured[k]["energy_j_per_iter"]
            / predicted[k]["energy_j_per_iter"]
            for k in ("prefill", "decode")
            if predicted[k]["energy_j_per_iter"]},
        "j_per_token_measured": (e_meas_total / g_tok) if g_tok else 0.0,
        "prefill_steps": eng.prefill_meter.calls,
        "decode_steps": eng.decode_meter.calls,
    }
    if ledger is not None:
        eng.record_to(ledger, predicted=predicted,
                      measured_extra=measured,
                      extra={"config": sc.as_dict(), "slo": slo_report,
                             "j_per_token_measured":
                                 out["j_per_token_measured"]})
    eng.close()
    return out
