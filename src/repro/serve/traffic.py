"""Synthetic serving workloads and the SLO report.

``make_trace`` draws a reproducible request trace — Poisson or bursty
(two-state modulated Poisson) arrivals, lognormal or uniform prompt and
output length distributions — entirely from one ``RandomState`` seed,
so a trace name + seed identifies the workload exactly (the serve bench
replays the same trace through every candidate config).

``SLOTracker`` turns per-request timestamps the engine stamps (submit,
first token, done — on the engine's virtual clock) into the serving
report: TTFT / TPOT / e2e p50/p95/p99, throughput, and goodput under
deadline (the fraction of requests that finished within their own
deadline AND met the global TTFT SLO, weighted by generated tokens —
tokens delivered late count for nothing).

``replay`` drives an engine through a trace against the engine's
virtual clock: requests become visible to the scheduler only once the
clock passes their arrival time, and the clock advances by the measured
wall time of each engine step (scaled by ``speedup`` so a "60 s @ 2
rps" trace replays in CPU-test time).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

TRACE_KINDS = ("poisson", "bursty", "closed")


@dataclass(frozen=True)
class TraceItem:
    """One request of a workload trace (lengths only — prompts are
    materialized per-arch by ``trace_requests``)."""
    arrival_s: float
    prompt_len: int
    max_new_tokens: int
    deadline_ms: float = 0.0     # e2e deadline; 0 = none
    seed: int = 0                # per-request sampling seed


def make_trace(kind: str = "poisson", *, n: int = 32,
               rate_rps: float = 4.0, burst_factor: float = 8.0,
               burst_fraction: float = 0.25,
               prompt_len_range=(4, 48), prompt_len_dist: str = "lognormal",
               new_tokens_range=(4, 24), deadline_ms: float = 0.0,
               max_requests: int = 0, seed: int = 0) -> List[TraceItem]:
    """Draw ``n`` requests.  ``bursty`` alternates between a quiet
    Poisson phase at ``rate_rps`` and bursts at ``burst_factor x`` the
    rate (``burst_fraction`` of requests arrive in bursts); ``closed``
    is the degenerate all-at-once trace (arrival 0) the old launcher
    effectively ran.

    ``max_requests`` truncates the trace WITHOUT changing the draw: the
    length/output arrays are still drawn at size ``n``, so
    ``make_trace(n=N, max_requests=M)`` is exactly the first ``M`` items
    of ``make_trace(n=N)`` (a prefix, seeded-deterministic — the
    property the fleet's trace-capping relies on).  Note this is NOT
    ``make_trace(n=M)``, whose vectorized draws differ."""
    if kind not in TRACE_KINDS:
        raise ValueError(f"unknown trace kind {kind!r}; "
                         f"known: {TRACE_KINDS}")
    rng = np.random.RandomState(seed)
    lo, hi = prompt_len_range
    if prompt_len_dist == "lognormal":
        # median near the geometric middle of the range, clipped
        mu = np.log(np.sqrt(max(lo, 1) * hi))
        lens = np.clip(np.round(rng.lognormal(mu, 0.6, n)), lo, hi)
    elif prompt_len_dist == "uniform":
        lens = rng.randint(lo, hi + 1, n)
    elif prompt_len_dist == "fixed":
        lens = np.full(n, hi)
    else:
        raise ValueError(f"unknown prompt_len_dist {prompt_len_dist!r}")
    news = rng.randint(new_tokens_range[0], new_tokens_range[1] + 1, n)

    t = 0.0
    items = []
    stop = min(n, max_requests) if max_requests else n
    for i in range(stop):
        if kind == "closed":
            gap = 0.0
        elif kind == "bursty" and rng.rand() < burst_fraction:
            gap = rng.exponential(1.0 / (rate_rps * burst_factor))
        else:
            gap = rng.exponential(1.0 / rate_rps)
        t += gap
        items.append(TraceItem(
            arrival_s=round(t, 6), prompt_len=int(lens[i]),
            max_new_tokens=int(news[i]), deadline_ms=deadline_ms,
            seed=int(rng.randint(0, 2 ** 31 - 1))))
    return items


def trace_requests(trace: Sequence[TraceItem], vocab_size: int, *,
                   seed: int = 0, sampling=None):
    """Materialize engine ``Request``s for a trace: prompt token ids are
    drawn from one ``RandomState(seed)`` stream in trace order, so the
    same (trace, seed, vocab) produces identical prompts in every
    config replayed by the bench."""
    from repro.serve.engine import Request
    rng = np.random.RandomState(seed)
    reqs = []
    for i, it in enumerate(trace):
        prompt = rng.randint(0, vocab_size, it.prompt_len).astype(np.int32)
        kw = {}
        if sampling is not None:
            from dataclasses import replace as dc_replace
            kw["sampling"] = dc_replace(sampling, seed=it.seed)
        reqs.append(Request(prompt=prompt, max_new_tokens=it.max_new_tokens,
                            req_id=i, arrival_s=it.arrival_s,
                            deadline_ms=it.deadline_ms, **kw))
    return reqs


# ---------------------------------------------------------------------------
# SLO tracking
# ---------------------------------------------------------------------------

def _pcts(xs: List[float]) -> dict:
    if not xs:
        return {}
    a = np.asarray(xs)
    return {"p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)),
            "p99": float(np.percentile(a, 99)),
            "mean": float(np.mean(a)), "max": float(np.max(a))}


@dataclass
class SLOTracker:
    """Aggregates finished requests into the serving SLO report."""

    slo_ttft_ms: float = 0.0        # 0 = no TTFT SLO
    finished: list = field(default_factory=list)

    def observe(self, req):
        if req.t_done_s is not None:
            self.finished.append(req)

    def observe_all(self, requests):
        for r in requests:
            self.observe(r)

    def report(self) -> dict:
        from repro.obs import get_metrics
        mx = get_metrics()
        # serving latencies are milliseconds; default buckets top out
        # at 10 so spread explicit ms buckets instead
        ms_buckets = (1, 2.5, 5, 10, 25, 50, 100, 250, 500, 1000,
                      2500, 5000, 10000)
        ttft_h = mx.histogram("serve_ttft_ms",
                              "time to first token (ms)",
                              buckets=ms_buckets)
        tpot_h = mx.histogram("serve_tpot_ms",
                              "time per output token (ms)",
                              buckets=ms_buckets)
        ttft, tpot, e2e = [], [], []
        good_tokens = total_tokens = 0
        met = 0
        last_done = 0.0
        for r in self.finished:
            n = len(r.out_tokens)
            total_tokens += n
            t_ttft = (r.t_first_s - r.arrival_s) * 1e3
            t_e2e = (r.t_done_s - r.arrival_s) * 1e3
            ttft.append(t_ttft)
            ttft_h.observe(t_ttft)
            e2e.append(t_e2e)
            if n > 1:
                t_tpot = (r.t_done_s - r.t_first_s) * 1e3 / (n - 1)
                tpot.append(t_tpot)
                tpot_h.observe(t_tpot)
            last_done = max(last_done, r.t_done_s)
            ok = (not self.slo_ttft_ms or t_ttft <= self.slo_ttft_ms) and \
                 (not r.deadline_ms or t_e2e <= r.deadline_ms)
            if ok:
                met += 1
                good_tokens += n
        out = {
            "requests": len(self.finished),
            "generated_tokens": total_tokens,
            "ttft_ms": _pcts(ttft),
            "tpot_ms": _pcts(tpot),
            "e2e_ms": _pcts(e2e),
            "slo_ttft_ms": self.slo_ttft_ms,
            "slo_met_fraction": (met / len(self.finished)
                                 if self.finished else 0.0),
            "goodput_tokens": good_tokens,
        }
        if last_done > 0:
            out["duration_s"] = last_done
            out["tokens_per_s"] = total_tokens / last_done
            out["goodput_tokens_per_s"] = good_tokens / last_done
            mx.gauge("serve_goodput_tokens_per_s",
                     "deadline+TTFT-qualified tokens per second").set(
                         out["goodput_tokens_per_s"])
        mx.gauge("serve_slo_met_fraction",
                 "fraction of requests meeting their SLOs").set(
                     out["slo_met_fraction"])
        return out


# ---------------------------------------------------------------------------
# trace replay
# ---------------------------------------------------------------------------

def replay(engine, requests, *, tracker: Optional[SLOTracker] = None,
           speedup: float = 1.0, max_steps: int = 100_000) -> SLOTracker:
    """Open-loop replay: feed ``requests`` to ``engine`` as the engine's
    virtual clock (wall time of executed steps x ``speedup``) passes
    each arrival time; decode until everything finishes."""
    tracker = tracker or SLOTracker()
    pending = sorted(requests, key=lambda r: r.arrival_s)
    engine.clock_scale = speedup
    steps = 0
    while (pending or engine.has_active()) and steps < max_steps:
        ready = []
        while pending and pending[0].arrival_s <= engine.now_s:
            ready.append(pending.pop(0))
        if ready:
            # one submit for every ready arrival, so simultaneous
            # arrivals land in one length-bucketed prefill group
            engine.submit(ready)
        if not engine.has_active():
            if pending:
                # idle gap: jump the clock to the next arrival
                engine.advance_clock(pending[0].arrival_s - engine.now_s)
            continue
        engine.step()
        steps += 1
    tracker.observe_all(requests)
    return tracker
