"""Per-request token sampling: greedy (default), temperature, top-k and
top-p (nucleus), with a seeded PRNG per request.

Sampling happens on host, on the ``[V]`` logits row the engine already
pulls back each step — a few hundred floats for the smoke vocabularies,
so there is nothing to win by keeping it on device, and host numpy gives
us a per-request ``Generator`` stream: a request's samples depend only
on its own seed and its own logits, never on which slot it landed in or
what else shared the batch.  That is what makes sampled serving
reproducible under continuous batching.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding parameters.  ``temperature == 0`` is greedy
    (argmax) and ignores the other knobs."""
    temperature: float = 0.0
    top_k: int = 0              # 0 = no top-k cut
    top_p: float = 1.0          # 1.0 = no nucleus cut
    seed: Optional[int] = None  # None = seed 0

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


class Sampler:
    """One request's sampling state (its own PRNG stream)."""

    def __init__(self, params: SamplingParams = GREEDY,
                 vocab_size: int = 0):
        self.params = params
        self.vocab_size = vocab_size
        self._rng = None
        if not params.greedy:
            self._rng = np.random.default_rng(
                params.seed if params.seed is not None else 0)

    def __call__(self, logits: np.ndarray) -> int:
        """logits: ``[V_padded]`` float row -> sampled token id."""
        if self.vocab_size:
            logits = logits[:self.vocab_size]
        if self.params.greedy:
            return int(np.argmax(logits))
        return int(sample_token(logits, self.params, self._rng))


def sample_token(logits: np.ndarray, params: SamplingParams,
                 rng: np.random.Generator) -> int:
    """Temperature -> top-k -> top-p -> categorical draw."""
    scores = logits.astype(np.float64) / max(params.temperature, 1e-6)
    if params.top_k and params.top_k < scores.size:
        kth = np.partition(scores, -params.top_k)[-params.top_k]
        scores = np.where(scores < kth, -np.inf, scores)
    probs = _softmax(scores)
    if params.top_p < 1.0:
        order = np.argsort(-probs)
        csum = np.cumsum(probs[order])
        # keep the smallest prefix reaching top_p (always >= 1 token)
        cut = int(np.searchsorted(csum, params.top_p)) + 1
        mask = np.zeros_like(probs, dtype=bool)
        mask[order[:cut]] = True
        probs = np.where(mask, probs, 0.0)
        probs = probs / probs.sum()
    return int(rng.choice(probs.size, p=probs))


def _softmax(x: np.ndarray) -> np.ndarray:
    x = x - np.max(x[np.isfinite(x)], initial=-np.inf)
    e = np.where(np.isfinite(x), np.exp(x), 0.0)
    return e / e.sum()
