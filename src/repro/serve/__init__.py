"""The energy-aware serving runtime (docs/serving.md).

  * ``engine``    — slot-based continuous batching over the mesh
  * ``kv_cache``  — paged KV-cache manager (admission, occupancy, churn)
  * ``scheduler`` — length-bucketed refill groups, EDF/FCFS, interleave
  * ``sampling``  — per-request greedy/temperature/top-k/top-p
  * ``traffic``   — synthetic workload traces + the SLO tracker
  * ``router``    — joules-per-token pricing and SLO-aware routing
"""
from repro.serve.engine import Request, ServeEngine, make_serve_fns
from repro.serve.kv_cache import CacheOverflow, PagedKVCache
from repro.serve.sampling import Sampler, SamplingParams
from repro.serve.scheduler import Scheduler, bucket_of
from repro.serve.traffic import (SLOTracker, TraceItem, make_trace,
                                 replay, trace_requests)
from repro.serve.router import (PricedConfig, ServeConfig,
                                candidate_configs, price_config, route,
                                run_config, serve_predictions,
                                trace_stats)

__all__ = [
    "Request", "ServeEngine", "make_serve_fns",
    "CacheOverflow", "PagedKVCache",
    "Sampler", "SamplingParams",
    "Scheduler", "bucket_of",
    "SLOTracker", "TraceItem", "make_trace", "replay", "trace_requests",
    "PricedConfig", "ServeConfig", "candidate_configs", "price_config",
    "route", "run_config", "serve_predictions", "trace_stats",
]
