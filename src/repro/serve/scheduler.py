"""Admission + length-bucketed continuous-batching scheduler.

The old engine's refill path asserted that every prompt in a refill
group had the *same* length and that the length was a multiple of 16.
The scheduler removes both footguns by bucketing: a prompt of length
``s`` is padded (right, with zeros) to ``bucket_of(s) = ceil(s / bucket)
* bucket`` and only requests sharing a padded length are prefillled
together.  The engine then decodes padded requests correctly by
*replaying* the last real prompt token as the first decode step (see
``engine._fill_slots``) — pad rows in the KV cache are never attended
because decode masks cache positions ``>= pos + 1``, and each pad row is
overwritten before the write position reaches it.

Families with a recurrent prefill state (ssm / hybrid / encdec) cannot
be right-padded — the pad tokens are folded into the SSD/conv state
irreversibly — so for them the scheduler falls back to exact-length
groups (``mixed_lengths=False``), which is precisely the old contract,
now stated instead of asserted.

Policy knobs:

  * ``order`` — ``"fcfs"`` (arrival order) or ``"edf"`` (earliest
    deadline first, with FCFS tie-break; requests without a deadline
    sort last).
  * ``min_free_for_prefill`` — prefill/decode interleaving: a refill
    prefill recompiles nothing but does stall the running decode batch
    for one prefill step, so ``min_free_for_prefill > 1`` batches
    refills until enough slots have drained (amortizing the stall),
    while the default ``1`` is the eager policy.  A fully idle engine
    always refills regardless, so the knob can never deadlock.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.serve.kv_cache import PagedKVCache


def bucket_of(length: int, bucket: int) -> int:
    """Padded prefill length for a prompt of ``length`` tokens."""
    return max(bucket, -(-int(length) // bucket) * bucket)


class Scheduler:
    """Queue + admission + refill-group formation."""

    def __init__(self, *, bucket: int = 16, order: str = "fcfs",
                 mixed_lengths: bool = True,
                 min_free_for_prefill: int = 1,
                 pages: Optional[PagedKVCache] = None):
        if order not in ("fcfs", "edf"):
            raise ValueError(f"unknown order {order!r} (fcfs|edf)")
        self.bucket = bucket
        self.order = order
        self.mixed_lengths = mixed_lengths
        self.min_free_for_prefill = max(1, min_free_for_prefill)
        self.pages = pages
        self.queue: List = []          # pending Requests
        self.rejected: List = []       # admission failures
        self._seq = 0                  # arrival tiebreak counter

    # --- admission -------------------------------------------------------

    def add(self, requests: Sequence) -> List:
        """Enqueue requests, rejecting any that can never fit a slot's
        page frames (prompt bucket + max_new_tokens > max_len).  Returns
        the rejected requests (also marked ``done`` with an ``error``)."""
        bad = []
        for req in requests:
            self._seq += 1
            req._seq = self._seq
            try:
                padded = self.padded_len(len(req.prompt))
            except ValueError as exc:
                # exact-length mode (recurrent families): an unpaddable
                # prompt is an ADMISSION failure, not a session crash
                req.done = True
                req.error = f"rejected: {exc}"
                bad.append(req)
                continue
            if self.pages is not None and not self.pages.can_admit(
                    len(req.prompt), req.max_new_tokens, padded):
                req.done = True
                req.error = (
                    f"rejected: prompt {len(req.prompt)} (padded "
                    f"{padded}) + {req.max_new_tokens} new tokens "
                    f"exceeds max_len {self.pages.max_len}")
                bad.append(req)
                continue
            self.queue.append(req)
        self.rejected.extend(bad)
        return bad

    def padded_len(self, prompt_len: int) -> int:
        if self.mixed_lengths:
            return bucket_of(prompt_len, self.bucket)
        # exact-length mode still needs the sequence-shard divisibility
        if prompt_len % self.bucket:
            raise ValueError(
                f"this model family keeps recurrent prefill state, so "
                f"prompts cannot be bucket-padded: length {prompt_len} "
                f"must be a multiple of {self.bucket}")
        return prompt_len

    # --- refill policy ---------------------------------------------------

    def should_refill(self, free_slots: int, active_slots: int) -> bool:
        """Prefill/decode interleaving: refill when enough slots drained
        (or the engine is fully idle — never starve an empty engine)."""
        if not self.queue or free_slots <= 0:
            return False
        if active_slots == 0:
            return True
        return free_slots >= min(self.min_free_for_prefill,
                                 len(self.queue))

    def next_group(self, free_slots: int) -> Tuple[int, List]:
        """Form one refill group: order the queue by policy, let the
        head request pick the bucket, then take up to ``free_slots``
        queued requests sharing that bucket (in policy order).

        Returns ``(padded_len, requests)``; ``(0, [])`` when empty."""
        if not self.queue or free_slots <= 0:
            return 0, []
        ordered = sorted(self.queue, key=self._key)
        head_bucket = self.padded_len(len(ordered[0].prompt))
        group = [r for r in ordered
                 if self.padded_len(len(r.prompt)) == head_bucket]
        group = group[:free_slots]
        taken = set(id(r) for r in group)
        self.queue = [r for r in self.queue if id(r) not in taken]
        return head_bucket, group

    def _key(self, req):
        if self.order == "edf":
            dl = (req.arrival_s + req.deadline_ms * 1e-3
                  if req.deadline_ms else float("inf"))
            return (dl, req._seq)
        return (req._seq,)

    def __len__(self):
        return len(self.queue)

    def __repr__(self):
        return (f"Scheduler(pending={len(self.queue)}, "
                f"order={self.order}, bucket={self.bucket}, "
                f"mixed={self.mixed_lengths})")
