"""mamba2-370m  [ssm]

48L d_model=1024 (attention-free) vocab=50280, ssm_state=128 — SSD
(state-space duality).  [arXiv:2405.21060; unverified]

d_inner = 2*d_model = 2048, head_dim = 64 -> 32 SSD heads.
Phantom applicability: in/out projections only (DESIGN.md §Arch-applicability);
the SSD scan itself has no cross-rank weight block to factorize.
Runs ``long_500k`` (sub-quadratic by construction).
"""
from repro.configs.base import phantom_projection_map, ModelConfig, SSMConfig, PhantomConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        num_layers=48,
        d_model=1024,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        attn_period=-1,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4),
        phantom=PhantomConfig(k=8),
        projections=phantom_projection_map(8, attn=True),
        rope="none",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        vocab_size=256,
        attn_period=-1,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_width=4,
                      chunk=32),
        phantom=PhantomConfig(k=4),
        projections=phantom_projection_map(4, attn=True),
        rope="none",
        loss_chunk=64,
    )
