"""phi3-mini-3.8b  [dense]

32L d_model=3072 32H (kv=32 -> MHA) d_ff=8192 vocab=32064 — RoPE, SwiGLU,
RMSNorm.  [arXiv:2404.14219; unverified]
"""
from repro.configs.base import phantom_projection_map, ModelConfig, PhantomConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b",
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=32064,
        attn_shard="head",
        phantom=PhantomConfig(k=12),
        projections=phantom_projection_map(12, ffn=True),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        attn_shard="head",
        phantom=PhantomConfig(k=4),
        projections=phantom_projection_map(4, ffn=True),
        loss_chunk=64,
    )
