"""chatglm3-6b  [dense]

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024 — 2d RoPE (rotary on
half the head dims), GQA.  [arXiv:2406.12793]
"""
from repro.configs.base import phantom_projection_map, ModelConfig, PhantomConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b",
        family="dense",
        num_layers=28,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        d_ff=13696,
        vocab_size=65024,
        attn_shard="head",
        rope="partial",
        rope_fraction=0.5,
        phantom=PhantomConfig(k=16),
        projections=phantom_projection_map(16, ffn=True),
        qkv_bias=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        attn_shard="head",
        rope="partial",
        rope_fraction=0.5,
        phantom=PhantomConfig(k=4),
        projections=phantom_projection_map(4, ffn=True),
        qkv_bias=True,
        loss_chunk=64,
    )
