"""granite-moe-3b-a800m  [moe]

32L d_model=1536 24H (GQA kv=8) d_ff=512/expert vocab=49155, MoE 40 experts
top-8.  [hf:ibm-granite family]

Notes (DESIGN.md §5): 40 % 16 != 0 -> experts tensor-partitioned (each
expert's d_ff sharded over the model axis).  24 heads % 16 != 0 -> ring
(sequence-sharded) attention.
"""
from repro.configs.base import phantom_projection_map, ModelConfig, MoEConfig, PhantomConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        num_layers=32,
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        moe=MoEConfig(num_experts=40, top_k=8, d_ff_expert=512,
                      partition="tensor"),
        attn_shard="ring",
        # Phantom is INAPPLICABLE here (DESIGN.md §Arch-applicability):
        # ring attention keeps activations sequence-sharded (no cross-rank
        # feature blocks to factorize) and the experts are tiny (d_ff=512)
        # tensor-partitioned FFNs.  The arch runs without the technique.
        phantom=PhantomConfig(k=8),
        projections=phantom_projection_map(8),
        rope="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=32,
        vocab_size=256,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                      partition="tensor"),
        attn_shard="ring",
        phantom=PhantomConfig(k=4),
        projections=phantom_projection_map(4),
        rope="full",
        loss_chunk=64,
    )
