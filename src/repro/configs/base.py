"""Config system for the phantom-parallelism framework.

Plain dataclasses (no external deps). A ``ModelConfig`` fully describes one
architecture; ``ShapeConfig`` describes one (seq_len, global_batch, kind)
cell; ``RunConfig`` binds the two to a mesh and training hyper-params.

Every assigned architecture lives in ``src/repro/configs/<id>.py`` and
exposes ``config()`` (the exact published geometry) and ``smoke_config()``
(a reduced same-family geometry for CPU tests).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


# ---------------------------------------------------------------------------
# sub-configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    # apply MoE on layers where (layer_idx % every_n) == offset
    every_n: int = 1
    offset: int = 0
    # "expert": shard the expert dim over the model axis (needs E % tp == 0)
    # "tensor": shard each expert's d_ff over the model axis
    partition: str = "expert"
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128          # SSD chunk length
    ngroups: int = 1


@dataclass(frozen=True)
class PhantomConfig:
    """The paper's technique — knobs for where/how it is applied.

    DEPRECATED selection surface: ``apply_ffn``/``apply_attn_proj`` (and
    ``ModelConfig.ffn_impl``) are legacy shims that expand to per-site
    ``ProjectionSpec`` entries via ``ModelConfig.projection_spec()``.  New
    code should set ``ModelConfig.projections`` directly.
    """
    k: int = 64                     # ghost neurons per phantom layer
    apply_ffn: bool = True          # factorize the MLP projections
    apply_attn_proj: bool = False   # factorize QKV/O projections (beyond-paper)
    include_self_term: bool = False # False = faithful (self block excluded)
    variant: str = "fused"          # "faithful" | "fused" | "ring"
    kernel_backend: str = "xla"     # "xla" | "pallas" | "auto" (fused only)
    # faithful: per-source decompress GEMMs + custom_vjp AllGather (paper Alg. 1)
    # fused:    single concatenated decompress GEMM (TPU/MXU adaptation)
    # ring:     ppermute ring with overlapped partial decompress GEMMs


@dataclass(frozen=True)
class PipelineConfig:
    """Layer-to-stage partitioning for pipeline-parallel (pp) training.

    ``stages`` is a MODEL property (how the layer stack is cut), the mesh's
    ``pipe`` axis is the resource it maps onto: a config with S stages runs
    1F1B on a pp=S mesh, or sequentially (stage by stage, per microbatch)
    on a pp=1 mesh — both compute the identical function, which is what
    the equivalence suite pins.  ``stage_specs`` optionally gives each
    stage its own ``ProjectionSpec`` (tensor or phantom per stage, the
    paper-FFN subject); empty means every stage uses the site's spec.
    """
    stages: int = 1
    stage_specs: tuple = ()          # per-stage ProjectionSpec overrides

    def __post_init__(self):
        if self.stages < 1:
            raise ValueError(f"pipeline stages must be >= 1, "
                             f"got {self.stages}")
        if self.stage_specs and len(self.stage_specs) != self.stages:
            raise ValueError(
                f"stage_specs has {len(self.stage_specs)} entries for "
                f"{self.stages} stages")
        if self.stages == 1 and self.stage_specs:
            raise ValueError(
                "stage_specs requires stages > 1 — a single-stage config "
                "takes its strategy from the projection site spec")

    @property
    def mixed(self) -> bool:
        """True when stages run DIFFERENT strategies (per-stage param
        subtrees + runtime dispatch instead of one pipe-sharded stack)."""
        return bool(self.stage_specs) and len(set(self.stage_specs)) > 1


# ---------------------------------------------------------------------------
# projection strategy selection (the ProjectionStrategy API's config side)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ProjectionSpec:
    """Selects and parameterizes one projection strategy at one site.

    ``kind`` is a key into ``repro.parallel.strategies`` registry:
    ``tensor_col`` | ``tensor_row`` | ``phantom`` | ``lowrank_distill`` —
    or the pseudo-kind ``tensor`` which resolves to the site's natural
    dense sharding (col for input-side projections, row for output-side).
    The remaining fields only matter for the phantom-family kinds.
    """
    kind: str = "tensor"
    k: int = 64                     # ghost width (phantom family)
    variant: str = "fused"          # faithful | fused | ring
    include_self_term: bool = False
    # Executing kernel for the hot inner op at this site: "xla" composes
    # the GEMMs in XLA; "pallas" runs the fused Pallas kernels (phantom
    # fused projection / flash-attention core); "auto" picks pallas on
    # TPU, xla elsewhere.  See docs/kernels.md.
    kernel_backend: str = "xla"     # xla | pallas | auto


# every projection site the model families expose, with its natural dense
# strategy (what `kind="tensor"` resolves to)
PROJECTION_SITES = {
    "ffn_layer": "tensor_col",      # paper square FFN (core/ffn.py)
    "ffn_gate": "tensor_col",
    "ffn_up": "tensor_col",
    "ffn_down": "tensor_row",
    "attn_q": "tensor_col",
    "attn_k": "tensor_col",
    "attn_v": "tensor_col",
    "attn_o": "tensor_row",
    "ssm_in": "tensor_col",
    "ssm_out": "tensor_row",
    "moe_experts": "tensor_col",
}

_FFN_SITES = ("ffn_gate", "ffn_up", "ffn_down")
_PROJ_LEGACY_ATTN_SITES = ("attn_q", "attn_k", "attn_v", "attn_o",
                           "ssm_in", "ssm_out")

PHANTOM_KINDS = ("phantom", "lowrank_distill")


@dataclass(frozen=True)
class ProjectionMap:
    """Per-site ProjectionSpec overrides.  ``default`` applies to any site
    without an explicit entry; ``None`` everywhere falls back to the
    legacy ``ffn_impl``/``PhantomConfig.apply_*`` shim."""
    default: Optional[ProjectionSpec] = None
    ffn_layer: Optional[ProjectionSpec] = None
    ffn_gate: Optional[ProjectionSpec] = None
    ffn_up: Optional[ProjectionSpec] = None
    ffn_down: Optional[ProjectionSpec] = None
    attn_q: Optional[ProjectionSpec] = None
    attn_k: Optional[ProjectionSpec] = None
    attn_v: Optional[ProjectionSpec] = None
    attn_o: Optional[ProjectionSpec] = None
    ssm_in: Optional[ProjectionSpec] = None
    ssm_out: Optional[ProjectionSpec] = None
    moe_experts: Optional[ProjectionSpec] = None

    def get(self, site: str) -> Optional[ProjectionSpec]:
        return getattr(self, site) or self.default


def dense_projection_map() -> ProjectionMap:
    """Every site at its natural dense (Megatron-TP) strategy — the
    explicit replacement for the old ``ffn_impl="dense"`` /
    ``apply_*=False`` combination (shadows the legacy shim)."""
    return ProjectionMap(default=ProjectionSpec(kind="tensor"))


def with_phantom_overrides(cfg: "ModelConfig", **kw) -> "ModelConfig":
    """Apply ``PhantomConfig``-style overrides (``k``, ``variant``,
    ``include_self_term``) to the legacy phantom sub-config AND to every
    phantom-family entry of the explicit ``ProjectionMap`` — the CLI
    ``--variant`` / ``phantom.k`` override path, which must keep working
    now that shipped configs carry explicit per-site specs."""
    spec_kw = {key: v for key, v in kw.items()
               if key in ("k", "variant", "include_self_term",
                          "kernel_backend")}
    entries = {}
    for f in dataclasses.fields(ProjectionMap):
        spec = getattr(cfg.projections, f.name)
        if spec is not None and spec.kind in PHANTOM_KINDS and spec_kw:
            spec = dataclasses.replace(spec, **spec_kw)
        entries[f.name] = spec
    return cfg.replace(phantom=dataclasses.replace(cfg.phantom, **kw),
                       projections=ProjectionMap(**entries))


def phantom_projection_map(k: int, *, variant: str = "fused",
                           include_self_term: bool = False,
                           ffn: bool = False, attn: bool = False,
                           ffn_layer: bool = False,
                           kernel_backend: str = "xla") -> ProjectionMap:
    """The explicit per-site ``ProjectionMap`` equivalent of the
    deprecated ``ffn_impl`` / ``PhantomConfig.apply_*`` flags: phantom
    at the selected site families, the natural dense strategy
    everywhere else (``default="tensor"`` shadows the legacy shim
    completely, so configs built this way never consult it).

      ffn_layer  the paper square-FFN site (old ``ffn_impl="phantom"``)
      ffn        the MLP sites           (old ``apply_ffn=True``)
      attn       QKV/O + SSM in/out      (old ``apply_attn_proj=True``)
    """
    ph = ProjectionSpec(kind="phantom", k=k, variant=variant,
                        include_self_term=include_self_term,
                        kernel_backend=kernel_backend)
    entries: dict = {"default": ProjectionSpec(kind="tensor")}
    if ffn_layer:
        entries["ffn_layer"] = ph
    if ffn:
        entries.update({s: ph for s in _FFN_SITES})
    if attn:
        entries.update({s: ph for s in _PROJ_LEGACY_ATTN_SITES})
    return ProjectionMap(**entries)


def with_kernel_backend(cfg: "ModelConfig",
                        backend: str) -> "ModelConfig":
    """Config with ``kernel_backend`` set on every explicit projection
    entry AND the legacy phantom sub-config (so sites falling through to
    the shim pick it up too) — the launcher ``--kernel-backend`` path.
    The switch takes effect at phantom ``fused`` sites (the fused
    projection kernel) and at the attn q/k/v/o sites (the
    flash-attention core); all other strategies ignore it."""
    entries = {}
    for f in dataclasses.fields(ProjectionMap):
        spec = getattr(cfg.projections, f.name)
        entries[f.name] = (None if spec is None else
                           dataclasses.replace(spec,
                                               kernel_backend=backend))
    return cfg.replace(
        projections=ProjectionMap(**entries),
        phantom=dataclasses.replace(cfg.phantom, kernel_backend=backend))


# ---------------------------------------------------------------------------
# model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm | ffn
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0               # 0 -> d_model // num_heads

    # encoder/decoder (seamless)
    encoder_layers: int = 0

    # norm / activation / misc
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    mlp: str = "swiglu"             # swiglu | gelu | relu
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # rope
    rope: str = "full"              # full | partial | mrope | none
    rope_fraction: float = 1.0      # chatglm3 "2d rope" == rotate half the dims
    rope_theta: float = 10000.0

    # hybrid interleave: one attention layer per `attn_period` layers
    # (0 = every layer is attention, -1 = attention-free)
    attn_period: int = 0

    # frontends (stubbed per spec: input_specs() yields embeddings)
    frontend: str = "none"          # none | audio | vision

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # --- parallelism / technique selection -------------------------------
    # DEPRECATED: ffn_impl + phantom.apply_* are legacy shims; they expand
    # into per-site ProjectionSpecs via projection_spec() below.
    ffn_impl: str = "dense"         # dense (Megatron TP baseline) | phantom
    phantom: PhantomConfig = field(default_factory=PhantomConfig)
    # per-site strategy selection (wins over the legacy shim when set)
    projections: ProjectionMap = field(default_factory=ProjectionMap)
    # pipeline-parallel layer-to-stage partitioning (pp mesh axis)
    pipeline: PipelineConfig = field(default_factory=PipelineConfig)
    attn_shard: str = "auto"        # auto | head | ring
    # decode-time: model axis factors into (gcd(kv,p) kv-groups x seq chunks)

    # --- numerics / memory -----------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"             # full | none  (checkpoint each block)
    optimizer: str = "adamw"        # adamw | adafactor | sgd
    fsdp: bool = False              # additionally shard params over data axis
    loss_chunk: int = 2048          # seq chunk for sharded cross-entropy
    microbatches: int = 1           # gradient-accumulation microbatching
    attn_bf16_scores: bool = False  # bf16 attention score blocks (§Perf)
    kv_cache_quant: bool = False    # int8 KV cache w/ per-head scales
    attn_kv_chunk: int = 0          # 0 = default blockwise chunking;
                                    # -1 = unrolled (dry-run cost analysis:
                                    # XLA counts scan bodies once)
    scan_layers: bool = True        # False = python-loop layer stack
                                    # (dry-run cost analysis only)
    fsdp_gather_quant: bool = False  # int8-quantize FSDP weight gathers
                                     # (serving: halves gather wire bytes)
    attn_ring_gather_kv: bool = False  # ring mode: gather KV once instead
                                       # of p ppermute hops (same wire
                                       # bytes, 1 accumulator pass instead
                                       # of p — §Perf cell C)

    # paper-FFN-specific (family == "ffn")
    ffn_width: int = 0
    ffn_depth: int = 0

    def projection_spec(self, site: str) -> ProjectionSpec:
        """Resolve the ProjectionSpec governing one projection site.

        Order: explicit per-site entry in ``projections`` > ``projections.
        default`` > the legacy ``ffn_impl``/``PhantomConfig.apply_*`` shim
        > the site's natural dense strategy.  The pseudo-kind ``tensor``
        resolves to the site default (col/row).
        """
        if site not in PROJECTION_SITES:
            raise KeyError(f"unknown projection site {site!r}; "
                           f"known: {sorted(PROJECTION_SITES)}")
        spec = self.projections.get(site)
        if spec is None:
            spec = self._legacy_projection_spec(site)
        if spec.kind == "tensor":
            spec = dataclasses.replace(spec, kind=PROJECTION_SITES[site])
        return spec

    def _legacy_projection_spec(self, site: str) -> ProjectionSpec:
        """Deprecation shim: expand ffn_impl / PhantomConfig.apply_* flags
        into the equivalent per-site spec.  Warns when the shim ACTIVELY
        selects phantom (a plain dense config hitting the fallback is
        not using the deprecated surface, just its default)."""
        pp = self.phantom

        def ph() -> ProjectionSpec:
            import warnings
            warnings.warn(
                f"config {self.name!r} selects phantom at site {site!r} "
                f"through the deprecated ffn_impl/PhantomConfig.apply_* "
                f"shim; set ModelConfig.projections (e.g. "
                f"phantom_projection_map) instead",
                DeprecationWarning, stacklevel=4)
            return ProjectionSpec(kind="phantom", k=pp.k,
                                  variant=pp.variant,
                                  include_self_term=pp.include_self_term,
                                  kernel_backend=pp.kernel_backend)

        if site == "ffn_layer":
            return ph() if self.ffn_impl == "phantom" else ProjectionSpec()
        if site in _FFN_SITES and pp.apply_ffn \
                and self.ffn_impl != "dense_force":
            return ph()
        if site in _PROJ_LEGACY_ATTN_SITES and pp.apply_attn_proj:
            return ph()
        return ProjectionSpec()

    def stage_projection_spec(self, stage: int,
                              site: str = "ffn_layer") -> ProjectionSpec:
        """The ProjectionSpec governing `site` on pipeline stage `stage`
        (per-stage override when ``pipeline.stage_specs`` is set, else the
        site's spec)."""
        if self.pipeline.stage_specs:
            spec = self.pipeline.stage_specs[stage]
            if spec.kind == "tensor":
                spec = dataclasses.replace(spec, kind=PROJECTION_SITES[site])
            return spec
        return self.projection_spec(site)

    def uses_phantom_sites(self, sites=None) -> bool:
        """True if any (given) projection site resolves to a phantom-family
        strategy — decides the residual-stream layout (fp)."""
        sites = sites or tuple(PROJECTION_SITES)
        return any(self.projection_spec(s).kind in PHANTOM_KINDS
                   for s in sites)

    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # rough parameter counts, used for MODEL_FLOPS and memory napkin math ---
    def param_count(self) -> int:
        from repro.models.model import count_params  # lazy, avoids cycle
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params
        return count_params(self, active_only=True)


# ---------------------------------------------------------------------------
# shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",  524_288,    1, "decode"),
}


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    microbatches: int = 1            # gradient accumulation
    seed: int = 0


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

ARCH_IDS = [
    "granite-moe-3b-a800m",
    "olmoe-1b-7b",
    "seamless-m4t-large-v2",
    "chatglm3-6b",
    "qwen2.5-14b",
    "stablelm-3b",
    "phi3-mini-3.8b",
    "mamba2-370m",
    "qwen2-vl-72b",
    "jamba-1.5-large-398b",
]

_MODULES = {
    "granite-moe-3b-a800m": "granite_moe_3b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large",
    "chatglm3-6b": "chatglm3_6b",
    "qwen2.5-14b": "qwen2_5_14b",
    "stablelm-3b": "stablelm_3b",
    "phi3-mini-3.8b": "phi3_mini",
    "mamba2-370m": "mamba2_370m",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    # the paper's own FFN models
    "paper-ffn-4k": "paper_ffn",
    "paper-ffn-16k": "paper_ffn",
    "paper-ffn-64k": "paper_ffn",
    "paper-ffn-131k": "paper_ffn",
    "paper-ffn-262k": "paper_ffn",
}


def get_config(arch: str, smoke: bool = False, **overrides) -> ModelConfig:
    """Load an architecture config by id (``--arch`` flag)."""
    import importlib
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    if arch.startswith("paper-ffn"):
        cfg = (mod.smoke_config if smoke else mod.config)(arch)
    else:
        cfg = (mod.smoke_config if smoke else mod.config)()
    if overrides:
        cfg = cfg.replace(**overrides)
    return cfg


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """Which of the 4 assigned shapes apply to this architecture.

    ``long_500k`` needs sub-quadratic attention: only SSM/hybrid run it
    (skip recorded for full-attention archs, per DESIGN.md).
    """
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in ("ssm", "hybrid"):
        names.append("long_500k")
    return names
