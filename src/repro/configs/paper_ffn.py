"""The paper's own FFN models (§VI): width-n, depth-L fully-connected
stacks trained on the Gaussian-teacher dataset with MSE loss.

Sizes from the paper: n in {4096, 16384, 65536, 131072, 262144},
L in {2, 6}; ghost width k in {2..64}.
"""
from repro.configs.base import (ModelConfig, PhantomConfig,
                                phantom_projection_map)

_SIZES = {
    "paper-ffn-4k": (4_096, 2, 3),
    "paper-ffn-16k": (16_384, 2, 16),
    "paper-ffn-64k": (65_536, 6, 64),
    "paper-ffn-131k": (131_072, 2, 64),
    "paper-ffn-262k": (262_144, 2, 64),
}


def config(arch: str = "paper-ffn-16k") -> ModelConfig:
    n, L, k = _SIZES[arch]
    return ModelConfig(
        name=arch,
        family="ffn",
        num_layers=L,
        d_model=n,
        ffn_width=n,
        ffn_depth=L,
        phantom=PhantomConfig(k=k),
        projections=phantom_projection_map(k, ffn_layer=True, ffn=True),
        mlp="relu",
    )


def smoke_config(arch: str = "paper-ffn-16k") -> ModelConfig:
    _, L, _ = _SIZES[arch]
    return ModelConfig(
        name=arch + "-smoke",
        family="ffn",
        num_layers=L,
        d_model=128,
        ffn_width=128,
        ffn_depth=L,
        phantom=PhantomConfig(k=4),
        projections=phantom_projection_map(4, ffn_layer=True, ffn=True),
        mlp="relu",
    )
