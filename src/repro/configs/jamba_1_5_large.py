"""jamba-1.5-large-398b  [hybrid]

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16 experts
top-2 — Mamba+attention 1:7 interleave.  [arXiv:2403.19887]

72 layers = 9 superblocks x (1 attn + 7 mamba); MoE on every other layer
(even offsets).  Adafactor (Adam fp32 states would not fit 16 GB/chip at
398B/256 chips — DESIGN.md §5).  FSDP over data axis.  Runs ``long_500k``.
"""
from repro.configs.base import phantom_projection_map, ModelConfig, MoEConfig, SSMConfig, PhantomConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=24576,
        vocab_size=65536,
        attn_period=8,            # 1 attention layer per 8 (1:7 interleave)
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576,
                      every_n=2, offset=1, partition="expert"),
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4),
        attn_shard="head",
        phantom=PhantomConfig(k=32),
        projections=phantom_projection_map(32, ffn=True),
        fsdp=True,
        optimizer="adafactor",
        param_dtype="bfloat16",   # 398B: fp32 params would not fit
        microbatches=8,           # activation footprint /8 at train_4k
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke",
        family="hybrid",
        num_layers=8,             # one superblock
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        attn_period=8,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                      every_n=2, offset=1, partition="expert"),
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_width=4,
                      chunk=32),
        attn_shard="head",
        phantom=PhantomConfig(k=4),
        projections=phantom_projection_map(4, ffn=True),
        loss_chunk=64,
    )
