"""qwen2.5-14b  [dense]

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064 — GQA, QKV bias.
[hf:Qwen/Qwen2.5 family]

40 heads % 16 != 0 -> ring (sequence-sharded) attention (DESIGN.md §5).
"""
from repro.configs.base import phantom_projection_map, ModelConfig, PhantomConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b",
        family="dense",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=13824,
        vocab_size=152064,
        attn_shard="ring",
        qkv_bias=True,
        phantom=PhantomConfig(k=16),
        projections=phantom_projection_map(16, ffn=True),
        optimizer="adamw",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        attn_shard="ring",
        qkv_bias=True,
        phantom=PhantomConfig(k=4),
        projections=phantom_projection_map(4, ffn=True),
        loss_chunk=64,
    )
