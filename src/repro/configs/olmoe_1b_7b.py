"""olmoe-1b-7b  [moe]

16L d_model=2048 16H (GQA kv=16) d_ff=1024/expert vocab=50304, MoE 64
experts top-8.  [arXiv:2409.02060]

64 % 16 == 0 -> experts expert-partitioned over the model axis (4/rank).
"""
from repro.configs.base import phantom_projection_map, ModelConfig, MoEConfig, PhantomConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1024,
        vocab_size=50304,
        moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024,
                      partition="expert"),
        attn_shard="head",
        phantom=PhantomConfig(k=8),
        projections=phantom_projection_map(8, attn=True),
        rope="full",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=32,
        vocab_size=256,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                      partition="expert"),
        attn_shard="head",
        phantom=PhantomConfig(k=4),
        projections=phantom_projection_map(4, attn=True),
        rope="full",
        loss_chunk=64,
    )
