"""stablelm-3b  [dense]

32L d_model=2560 32H (kv=32) d_ff=6912 vocab=50304.
[hf:stabilityai/stablelm family; unverified]

StableLM-2 style: partial rotary (25%), LayerNorm, SwiGLU MLP.
"""
from repro.configs.base import phantom_projection_map, ModelConfig, PhantomConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b",
        family="dense",
        num_layers=32,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        d_ff=6912,
        vocab_size=50304,
        attn_shard="head",
        norm="layernorm",
        rope="partial",
        rope_fraction=0.25,
        phantom=PhantomConfig(k=8),
        projections=phantom_projection_map(8, ffn=True),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        attn_shard="head",
        norm="layernorm",
        rope="partial",
        rope_fraction=0.25,
        phantom=PhantomConfig(k=4),
        projections=phantom_projection_map(4, ffn=True),
        loss_chunk=64,
    )
