"""seamless-m4t-large-v2  [audio]

24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206 — encoder-decoder,
multimodal.  [arXiv:2308.11596]

Backbone only (per spec): the audio frontend is a stub — ``input_specs()``
yields precomputed frame embeddings ``[B, S, d]``.  "24L" is read as 24
encoder + 24 decoder layers (DESIGN.md §5).
"""
from repro.configs.base import phantom_projection_map, ModelConfig, PhantomConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="encdec",
        num_layers=24,            # decoder layers
        encoder_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=8192,
        vocab_size=256206,
        frontend="audio",
        attn_shard="head",
        phantom=PhantomConfig(k=8),
        projections=phantom_projection_map(8, ffn=True),
        norm="layernorm",
        mlp="gelu",
        rope="none",              # seamless uses learned/relative positions;
                                  # backbone stub uses none + frame embeddings
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke",
        family="encdec",
        num_layers=2,
        encoder_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        frontend="audio",
        attn_shard="head",
        phantom=PhantomConfig(k=4),
        projections=phantom_projection_map(4, ffn=True),
        norm="layernorm",
        mlp="gelu",
        rope="none",
        loss_chunk=64,
    )
