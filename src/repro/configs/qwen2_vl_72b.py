"""qwen2-vl-72b  [vlm]

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 — M-RoPE, dynamic
resolution.  [arXiv:2409.12191]

Backbone only (per spec): vision frontend stubbed; ``input_specs()`` yields
patch embeddings merged at fixed positions plus 3-axis M-RoPE position ids.
FSDP over the data axis on top of TP (72B does not fit TP-only).
"""
from repro.configs.base import phantom_projection_map, ModelConfig, PhantomConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=29568,
        vocab_size=152064,
        attn_shard="head",
        rope="mrope",
        qkv_bias=True,
        frontend="vision",
        phantom=PhantomConfig(k=32),
        projections=phantom_projection_map(32, ffn=True),
        fsdp=True,
        optimizer="adafactor",
        param_dtype="bfloat16",   # 72B: fp32 params would not fit
        microbatches=4,           # activation footprint /4 at train_4k
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        attn_shard="head",
        rope="mrope",
        qkv_bias=True,
        frontend="vision",
        phantom=PhantomConfig(k=4),
        projections=phantom_projection_map(4, ffn=True),
        loss_chunk=64,
    )
