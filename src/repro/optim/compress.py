"""Gradient compression for the data-parallel reduction — the phantom idea
applied to gradients (beyond-paper; DESIGN.md §2).

The paper compresses *activations* crossing the model axis into k ghost
neurons.  The same structure applies to gradients crossing the data axis:
PowerSGD-style rank-k factorization

    G [n, m]  ~=  P Q^T,   P [n, k], Q [m, k]

with a warm-started Q and one subspace iteration per step.  The all-reduce
then carries k(n+m) floats instead of n*m — the dp-axis analogue of the
paper's k-wide ghost collectives.  Error feedback keeps the scheme
convergent (the residual G - P Q^T is added to the next step's gradient).

Used by the paper-FFN training pipeline via ``compressed_dp_psum`` (see
examples/train_ffn_compressed.py) and unit-tested for the exact-when-
low-rank property.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax


def _orthonormalize(q):
    """Gram-Schmidt columns (k is tiny: O(m k^2))."""
    qt, _ = jnp.linalg.qr(q)
    return qt


def compress_grad(g2d, q, axis_names):
    """One PowerSGD round on a 2D grad shard (replicated over dp).

    g2d [n, m], q [m, k] warm-start.  Returns (approx [n, m], new_q).
    The two psums are the only cross-dp communication: k*(n+m) floats.
    """
    p_ = g2d @ q                                   # [n, k]
    p_ = lax.psum(p_, axis_names)                  # k*n floats on the wire
    p_ = _orthonormalize(p_)
    q_new = g2d.T @ p_                             # [m, k]
    q_new = lax.psum(q_new, axis_names)            # k*m floats
    approx = p_ @ q_new.T / lax.psum(1, axis_names)
    return approx, q_new


def compressed_dp_psum(grads, q_state, err_state, axes, rank: int = 4):
    """Tree-wide compressed gradient reduction with error feedback.

    2D leaves >= 2*rank in both dims go through PowerSGD; small/1D leaves
    psum exactly.  Returns (reduced_grads, new_q_state, new_err_state).
    """
    names = axes.dp_names

    def one(g, q, err):
        if g.ndim != 2 or min(g.shape) < 2 * rank:
            return lax.pmean(g, names), q, err
        g_fb = g + err
        approx, q_new = compress_grad(g_fb, q, names)
        return approx, q_new, g_fb - approx

    flat_g, tdef = jax.tree.flatten(grads)
    flat_q = jax.tree.leaves(q_state)
    flat_e = jax.tree.leaves(err_state)
    outs = [one(g, q, e) for g, q, e in zip(flat_g, flat_q, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]),
            jax.tree.unflatten(tdef, [o[2] for o in outs]))


def init_compress_state(params, rank: int = 4, seed: int = 0):
    """(q_state, err_state) matching the params tree."""
    key = jax.random.key(seed)

    def q0(p):
        if p.ndim != 2 or min(p.shape) < 2 * rank:
            return jnp.zeros((1,), jnp.float32)
        k2 = jax.random.fold_in(key, p.shape[0] * 7919 + p.shape[1])
        return jax.random.normal(k2, (p.shape[1], rank), jnp.float32)

    def e0(p):
        if p.ndim != 2 or min(p.shape) < 2 * rank:
            return jnp.zeros((1,), jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    return jax.tree.map(q0, params), jax.tree.map(e0, params)
