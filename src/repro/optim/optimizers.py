"""Optimizers in pure JAX (optax is not available in this container).

Each optimizer exposes ``state_decls(param_decls)`` so that the dry-run can
construct *abstract* optimizer state with the right sharding (optimizer
states inherit the parameter's logical PartitionSpec; Adafactor's factored
second moments drop the corresponding axis entries).

AdamW   — fp32 m/v, decoupled weight decay, bias correction.
Adafactor — factored second moments over the last two dims (used for the
            >=72B archs where Adam's fp32 states do not fit; DESIGN.md §5).
SGD     — momentum optional; used by the paper-FFN reproduction to match
          the paper's fixed-hyperparameter TP-vs-PP comparisons.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.params import ParamDecl, is_decl


def _zeros_like_decl(d: ParamDecl) -> ParamDecl:
    return replace(d, init="zeros", dtype=jnp.float32)


def _drop_axis(d: ParamDecl, axis: int) -> ParamDecl:
    shape = tuple(s for i, s in enumerate(d.shape) if i != axis % len(d.shape))
    spec_entries = list(d.spec) + [None] * (len(d.shape) - len(d.spec))
    spec = list(e for i, e in enumerate(spec_entries)
                if i != axis % len(d.shape))
    while spec and spec[-1] is None:   # canonical form: no trailing Nones
        spec.pop()
    return ParamDecl(shape, P(*spec), init="zeros", dtype=jnp.float32)


class Optimizer:
    def state_decls(self, param_decls):
        raise NotImplementedError

    def init(self, params):
        raise NotImplementedError

    def update(self, grads, state, params, step):
        """Returns (new_params, new_state). step: int32 scalar."""
        raise NotImplementedError


class SGD(Optimizer):
    def __init__(self, lr: Callable | float, momentum: float = 0.0,
                 weight_decay: float = 0.0):
        self.lr = lr if callable(lr) else (lambda _s, v=lr: jnp.float32(v))
        self.momentum = momentum
        self.weight_decay = weight_decay

    def state_decls(self, param_decls):
        if not self.momentum:
            return {}
        return {"m": jax.tree.map(_zeros_like_decl, param_decls,
                                  is_leaf=is_decl)}

    def init(self, params):
        if not self.momentum:
            return {}
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                  params)}

    def update(self, grads, state, params, step):
        lr = self.lr(step)
        if self.momentum:
            m = jax.tree.map(
                lambda mi, g: self.momentum * mi + g.astype(jnp.float32),
                state["m"], grads)
            upd = m
            state = {"m": m}
        else:
            upd = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32)
                          - lr * (u + self.weight_decay * p.astype(jnp.float32))
                          ).astype(p.dtype),
            params, upd)
        return new_params, state


class AdamW(Optimizer):
    def __init__(self, lr: Callable | float, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
        self.lr = lr if callable(lr) else (lambda _s, v=lr: jnp.float32(v))
        self.b1, self.b2, self.eps = b1, b2, eps
        self.weight_decay = weight_decay

    def state_decls(self, param_decls):
        z = jax.tree.map(_zeros_like_decl, param_decls, is_leaf=is_decl)
        return {"m": z, "v": jax.tree.map(lambda d: d, z, is_leaf=is_decl)}

    def init(self, params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.copy, z)}

    def update(self, grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        lr = self.lr(step)
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda vi, g: b2 * vi + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, mi, vi):
            mhat = mi / bc1
            vhat = vi / bc2
            u = mhat / (jnp.sqrt(vhat) + self.eps)
            return (p.astype(jnp.float32)
                    - lr * (u + self.weight_decay * p.astype(jnp.float32))
                    ).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v}


class Adafactor(Optimizer):
    """Factored second moments (Shazeer & Stern 2018), no momentum.

    For params with ndim >= 2 the second moment is stored as a row vector
    (mean over the last axis) and a column vector (mean over the second-to-
    last axis): O(n+m) memory instead of O(n*m).
    """

    def __init__(self, lr: Callable | float, decay: float = 0.8,
                 eps: float = 1e-30, clip_rms: float = 1.0,
                 weight_decay: float = 0.0):
        self.lr = lr if callable(lr) else (lambda _s, v=lr: jnp.float32(v))
        self.decay = decay
        self.eps = eps
        self.clip_rms = clip_rms
        self.weight_decay = weight_decay

    def _factored(self, shape):
        return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1

    def state_decls(self, param_decls):
        def vr(d):
            return (_drop_axis(d, -1) if self._factored(d.shape)
                    else _zeros_like_decl(d))

        def vc(d):
            return (_drop_axis(d, -2) if self._factored(d.shape)
                    else ParamDecl((1,), P(), init="zeros", dtype=jnp.float32))

        return {"vr": jax.tree.map(vr, param_decls, is_leaf=is_decl),
                "vc": jax.tree.map(vc, param_decls, is_leaf=is_decl)}

    def init(self, params):
        def vr(p):
            return (jnp.zeros(p.shape[:-1], jnp.float32)
                    if self._factored(p.shape)
                    else jnp.zeros_like(p, jnp.float32))

        def vc(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if self._factored(p.shape) else jnp.zeros((1,), jnp.float32))

        return {"vr": jax.tree.map(vr, params),
                "vc": jax.tree.map(vc, params)}

    def update(self, grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** (-self.decay)
        lr = self.lr(step)

        def upd(p, g, vr, vc):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + self.eps
            if self._factored(p.shape):
                vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = (vr[..., None] / jnp.mean(vr, axis=-1,
                                                  keepdims=True)[..., None]
                         ) * vc[..., None, :]
                u = g * jax.lax.rsqrt(denom + self.eps)
            else:
                vr = beta2 * vr + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(vr + self.eps)
            # RMS update clipping
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / self.clip_rms)
            newp = (p.astype(jnp.float32)
                    - lr * (u + self.weight_decay * p.astype(jnp.float32)))
            return newp.astype(p.dtype), vr, vc

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_vr = jax.tree.leaves(state["vr"])
        flat_vc = jax.tree.leaves(state["vc"])
        outs = [upd(p, g, vr, vc) for p, g, vr, vc
                in zip(flat_p, flat_g, flat_vr, flat_vc)]
        new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_vr = jax.tree.unflatten(tdef, [o[1] for o in outs])
        new_vc = jax.tree.unflatten(tdef, [o[2] for o in outs])
        return new_params, {"vr": new_vr, "vc": new_vc}


def make_optimizer(name: str, lr, weight_decay: float = 0.0,
                   **kw) -> Optimizer:
    if name == "adamw":
        return AdamW(lr, weight_decay=weight_decay, **kw)
    if name == "adafactor":
        return Adafactor(lr, weight_decay=weight_decay, **kw)
    if name == "sgd":
        return SGD(lr, weight_decay=weight_decay, **kw)
    raise KeyError(name)
