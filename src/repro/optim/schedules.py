"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def sched(step):
        return jnp.asarray(lr, jnp.float32)
    return sched


def warmup_linear(lr: float, warmup: int, total: int, floor: float = 0.0):
    def sched(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, warm, lr + (floor - lr) * frac)
    return sched


def warmup_cosine(lr: float, warmup: int, total: int, floor_frac: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, lr * cos)
    return sched
