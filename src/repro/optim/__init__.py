from repro.optim.optimizers import (  # noqa: F401
    Optimizer, AdamW, Adafactor, SGD, make_optimizer,
)
from repro.optim.schedules import (  # noqa: F401
    constant, warmup_cosine, warmup_linear,
)
