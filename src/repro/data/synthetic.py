"""Synthetic datasets.

1. The paper's Gaussian-teacher dataset (§VI "Data and Hardware"):
   a fixed standard-Gaussian W in R^{n x n}; samples (x, y) with
   y = sigma(W sigma(x)), sigma = ReLU.  Used to train TP and PP FFNs to a
   fixed loss for the energy comparisons (Table I / Fig. 7).

2. Deterministic token streams for the LM architectures: a fixed-seed
   zipf-ish categorical over the vocab with a simple induction pattern so
   a ~100M model's loss visibly decreases within a few hundred steps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def gaussian_teacher(n: int, seed: int = 0, scale: float | None = None):
    """The paper's fixed teacher matrix W ~ N(0,1)^{n x n} (scaled for
    numerical sanity; the paper uses standard normal)."""
    rng = np.random.default_rng(seed)
    scale = scale if scale is not None else n ** -0.5
    return jnp.asarray(rng.standard_normal((n, n)) * scale, jnp.float32)


def teacher_batch(W, batch: int, seed: int):
    """(x, y) with y = relu(W relu(x)) — paper §VI."""
    key = jax.random.fold_in(jax.random.key(17), seed)
    x = jax.random.normal(key, (batch, W.shape[0]), jnp.float32)
    y = jax.nn.relu(jax.nn.relu(x) @ W)
    return x, y


class TeacherDataset:
    """Streaming batches of the paper's dataset, deterministic per step."""

    def __init__(self, n: int, batch: int, seed: int = 0):
        self.W = gaussian_teacher(n, seed)
        self.batch = batch
        self._make = jax.jit(lambda s: teacher_batch(self.W, batch, s))

    def __call__(self, step: int):
        return self._make(jnp.int32(step))


def lm_token_batch(vocab: int, batch: int, seq: int, seed: int,
                   pattern_period: int = 17):
    """Deterministic pseudo-text: categorical tokens + a copy pattern every
    `pattern_period` positions, so next-token loss is learnable."""
    key = jax.random.fold_in(jax.random.key(29), seed)
    base = jax.random.randint(key, (batch, seq), 0, vocab)
    pos = jnp.arange(seq)
    shifted = jnp.roll(base, pattern_period, axis=1)
    tokens = jnp.where((pos % pattern_period == 0)[None, :], shifted, base)
    return tokens.astype(jnp.int32)


class LMDataset:
    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed = seed
        self._make = jax.jit(
            lambda s: lm_token_batch(vocab, batch, seq, s))

    def __call__(self, step: int):
        toks = self._make(jnp.int32(step) + self.seed * 100003)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
