"""Predicted per-step costs, summed from the very ``ProjectionStrategy``
objects that execute.

This is the *predicted* half of the ledger.  Everything here is a thin
sum over ``strategy.flops()`` / ``strategy.comm_events()`` — the same
per-operator account ``core/energy.py`` prices (paper Eqns. 1-2, 24-26)
— plus the ring-model conversion of a ``CommEvent`` to wire bytes, which
is deliberately the SAME formula ``launch/hlo_analysis.py`` applies to
measured HLO collectives, so measured/predicted ratios compare like with
like:

  all_gather      m·(p-1)·itemsize   (gathered result = m·p, ring wire
                                      = result·(p-1)/p)
  reduce_scatter  m·(p-1)·itemsize   (result = m, ring wire = result·(p-1))
  all_reduce      2·m·(p-1)/p·itemsize

with ``m`` the per-rank message in floats (the ``CommEvent`` unit).
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Sequence

from repro.core.energy import (FRONTIER_A_W, FRONTIER_B_W, TPU_PEAK_FLOPS,
                               comm_time_us, costs_from_strategies,
                               energy_per_iteration)
from repro.parallel.strategies.base import CommEvent

FLOAT_BYTES = 4.0


def event_wire_bytes(ev: CommEvent, p: int,
                     itemsize: float = FLOAT_BYTES) -> float:
    """Per-device ring wire bytes for one strategy collective — the
    prediction the HLO parser's measured wire bytes are compared to."""
    if p <= 1:
        return 0.0
    m = ev.m_floats * itemsize
    if ev.collective == "all_gather":
        return m * (p - 1)
    if ev.collective == "reduce_scatter":
        return m * (p - 1)
    if ev.collective == "all_reduce":
        return 2.0 * m * (p - 1) / p
    if ev.collective == "all_to_all":
        return m * (p - 1) / p
    return m                                  # collective_permute: one hop


def events_for(strategies: Sequence, batch: int,
               training: bool = True) -> List[CommEvent]:
    """All collectives the strategies issue per pass; inference drops the
    backward-phase events (no gradient collectives at serving time)."""
    out = []
    for st in strategies:
        for ev in st.comm_events(batch):
            if not training and ev.phase == "bwd":
                continue
            out.append(ev)
    return out


def strategy_prediction(strategies: Sequence, p: int, L: int, batch: int,
                        *, training: bool = True,
                        peak_flops: float = TPU_PEAK_FLOPS,
                        fits=None, A: float = FRONTIER_A_W,
                        B: float = FRONTIER_B_W,
                        itemsize: float = FLOAT_BYTES) -> dict:
    """The ledger's ``predicted`` block for a step executing each of
    ``strategies`` once per layer, ``L`` layers.

    Keys are aligned with ``CompiledCosts.measured_fields()`` so the
    ledger can ratio them directly; the energy projection applies the
    paper's E = p·(A·α + B·β) per iteration.
    """
    alpha_s, beta_s = costs_from_strategies(
        strategies, p, L, batch, peak_flops, fits, training=training)
    events = events_for(strategies, batch, training)
    wire = sum(event_wire_bytes(ev, p, itemsize) for ev in events) * L
    m_floats = sum(ev.m_floats for ev in events) * L
    comm_us = sum(comm_time_us(ev.collective, ev.m_floats, p, fits)
                  for ev in events) * L
    return {
        "flops_per_device": alpha_s * peak_flops,
        "collective_wire_bytes_per_device": wire,
        "collective_m_floats": m_floats,
        "comm_us": comm_us,
        "alpha_s": alpha_s,
        "beta_s": beta_s,
        "energy_j_per_iter": energy_per_iteration(alpha_s, beta_s, p,
                                                  A, B),
        "training": training,
        "model": "E = nu*p*(A*alpha + B*beta)",
        "A_w": A, "B_w": B,
        "peak_flops": peak_flops,
    }


def ffn_step_prediction(cfg, p: int, global_batch: int, *,
                        training: bool = True,
                        peak_flops: float = TPU_PEAK_FLOPS,
                        fits=None, A: float = FRONTIER_A_W,
                        B: float = FRONTIER_B_W) -> dict:
    """Prediction for one paper-FFN step (the strategy ``cfg`` selects at
    the ``ffn_layer`` site, applied once per layer)."""
    from repro.core.ffn import ffn_strategy
    st = ffn_strategy(cfg, p)
    pred = strategy_prediction([st], p, cfg.num_layers, global_batch,
                               training=training, peak_flops=peak_flops,
                               fits=fits, A=A, B=B)
    pred["strategy"] = st.kind
    pred["param_count"] = st.param_count() * cfg.num_layers
    return pred
