"""Predicted per-step costs, summed from the very ``ProjectionStrategy``
objects that execute.

This is the *predicted* half of the ledger.  Everything here is a thin
sum over ``strategy.flops()`` / ``strategy.comm_events()`` — the same
per-operator account ``core/energy.py`` prices (paper Eqns. 1-2, 24-26)
— plus the ring-model conversion of a ``CommEvent`` to wire bytes, which
is deliberately the SAME formula ``launch/hlo_analysis.py`` applies to
measured HLO collectives, so measured/predicted ratios compare like with
like:

  all_gather      m·(p-1)·itemsize   (gathered result = m·p, ring wire
                                      = result·(p-1)/p)
  reduce_scatter  m·(p-1)·itemsize   (result = m, ring wire = result·(p-1))
  all_reduce      2·m·(p-1)/p·itemsize

with ``m`` the per-rank message in floats (the ``CommEvent`` unit).
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Sequence

from repro.core.energy import (FRONTIER_A_W, FRONTIER_B_W, TPU_PEAK_FLOPS,
                               comm_time_us, costs_from_strategies,
                               energy_per_iteration)
from repro.parallel.strategies.base import CommEvent

FLOAT_BYTES = 4.0


def event_wire_bytes(ev: CommEvent, p: int,
                     itemsize: float = FLOAT_BYTES) -> float:
    """Per-device ring wire bytes for one strategy collective — the
    prediction the HLO parser's measured wire bytes are compared to."""
    if p <= 1:
        return 0.0
    m = ev.m_floats * itemsize
    if ev.collective == "all_gather":
        return m * (p - 1)
    if ev.collective == "reduce_scatter":
        return m * (p - 1)
    if ev.collective == "all_reduce":
        return 2.0 * m * (p - 1) / p
    if ev.collective == "all_to_all":
        return m * (p - 1) / p
    return m                                  # collective_permute: one hop


def events_for(strategies: Sequence, batch: int,
               training: bool = True) -> List[CommEvent]:
    """All collectives the strategies issue per pass; inference drops the
    backward-phase events (no gradient collectives at serving time)."""
    out = []
    for st in strategies:
        for ev in st.comm_events(batch):
            if not training and ev.phase == "bwd":
                continue
            out.append(ev)
    return out


def strategy_prediction(strategies: Sequence, p: int, L: int, batch: int,
                        *, training: bool = True,
                        peak_flops: float = TPU_PEAK_FLOPS,
                        fits=None, A: float = FRONTIER_A_W,
                        B: float = FRONTIER_B_W,
                        itemsize: float = FLOAT_BYTES) -> dict:
    """The ledger's ``predicted`` block for a step executing each of
    ``strategies`` once per layer, ``L`` layers.

    Keys are aligned with ``CompiledCosts.measured_fields()`` so the
    ledger can ratio them directly; the energy projection applies the
    paper's E = p·(A·α + B·β) per iteration.
    """
    alpha_s, beta_s = costs_from_strategies(
        strategies, p, L, batch, peak_flops, fits, training=training)
    events = events_for(strategies, batch, training)
    wire = sum(event_wire_bytes(ev, p, itemsize) for ev in events) * L
    m_floats = sum(ev.m_floats for ev in events) * L
    comm_us = sum(comm_time_us(ev.collective, ev.m_floats, p, fits)
                  for ev in events) * L
    return {
        "flops_per_device": alpha_s * peak_flops,
        "collective_wire_bytes_per_device": wire,
        "collective_m_floats": m_floats,
        "comm_us": comm_us,
        "alpha_s": alpha_s,
        "beta_s": beta_s,
        "energy_j_per_iter": energy_per_iteration(alpha_s, beta_s, p,
                                                  A, B),
        "training": training,
        "model": "E = nu*p*(A*alpha + B*beta)",
        "A_w": A, "B_w": B,
        "peak_flops": peak_flops,
    }


def serve_site_strategies(cfg, p: int, dp: int = 1) -> List:
    """The per-layer ProjectionStrategy objects a transformer serving
    config executes: the four attention projections plus the MLP sites
    (the same objects ``models/attention.py`` / ``models/layers.py``
    instantiate at run time, so the predicted account prices exactly
    what executes).  Dense/attention families only — recurrent families
    would need their own site list."""
    from repro.models.attention import attn_site_strategies
    from repro.models.layers import mlp_strategies
    from repro.parallel.axes import MeshAxes
    axes = MeshAxes(tp=p, dp=dp, dp_names=("data",))
    sts = list(attn_site_strategies(cfg, axes).values())
    if cfg.d_ff:
        sts += list(mlp_strategies(cfg, axes, cfg.d_model,
                                   cfg.d_ff).values())
    return sts


def serve_overhead_events(cfg, p: int, rows: int, phase: str,
                          sequences: int = 0):
    """Serving-path collectives beyond the projection strategies' own
    events, per the decode/prefill code paths in ``models/attention.py``
    and ``models/model.py``.  Latency (the Eqn. 26 c1 term) dominates
    these at serving message sizes, so the COUNT structure matters more
    than the exact byte sizes.  Returns ``(per_layer, per_step)`` event
    lists:

      * decode, head mode — q (and, when kv divides p, k/v) head
        gathers plus the flash-decoding LSE merge (pmax + psum);
      * decode, phantom MLP sites — the gather-on-use ghost decompress
        per site;
      * prefill in the fp residual layout (phantom configs) — attention
        reads the full residual: gather + scatter per layer;
      * both phases — the vocab-sharded head's logits all-gather and
        the last-position/embed psum, once per step.
    """
    from repro.configs.base import PHANTOM_KINDS
    if p <= 1:
        return [], []
    H, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim()
    d, V = cfg.d_model, cfg.vocab_size
    head_rows = sequences or rows
    per_layer, per_step = [], []
    phantom_mlp = [s for s in ("ffn_gate", "ffn_up", "ffn_down")
                   if cfg.projection_spec(s).kind in PHANTOM_KINDS]
    if phase == "decode":
        per_layer.append(CommEvent("all_gather", rows * H * hd / p))
        if kv and kv % p == 0:
            per_layer += [CommEvent("all_gather", rows * kv * hd / p)] * 2
        per_layer += [CommEvent("all_reduce", rows * H * hd)] * 2
        for site in phantom_mlp:
            k = cfg.projection_spec(site).k
            per_layer.append(CommEvent("all_gather", d * k / p))
    elif cfg.uses_phantom_sites():
        per_layer += [CommEvent("all_gather", rows * d / p),
                      CommEvent("reduce_scatter", rows * d / p)]
    per_step += [CommEvent("all_gather", head_rows * V / p),
                 CommEvent("all_reduce", head_rows * d)]
    return per_layer, per_step


def serve_step_events(cfg, p: int, rows: int, phase: str,
                      sequences: int = 0, dp: int = 1):
    """The full per-step serving collective account: the projection
    strategies' own events plus the ``serve_overhead_events`` terms,
    as ``(CommEvent, repeats)`` pairs (all on the model axis — the
    serving path issues no data-axis collectives).  Shared by
    ``serve_step_prediction`` and the static audit's collective-
    accounting rule, so the audit checks exactly the account the
    ledger prices."""
    sts = serve_site_strategies(cfg, p, dp)
    ov_layer, ov_step = serve_overhead_events(cfg, p, rows, phase,
                                              sequences)
    events = [(ev, cfg.num_layers)
              for ev in events_for(sts, rows, training=False)]
    events += [(ev, cfg.num_layers) for ev in ov_layer]
    events += [(ev, 1) for ev in ov_step]
    return events


def serve_step_prediction(cfg, p: int, rows: int, *, phase: str = "decode",
                          ctx_tokens: float = 0.0, sequences: int = 0,
                          dp: int = 1,
                          fits=None, alpha_scale: float = 1.0,
                          beta_scale: float = 1.0,
                          peak_flops: float = TPU_PEAK_FLOPS,
                          A: float = FRONTIER_A_W, B: float = FRONTIER_B_W,
                          itemsize: float = FLOAT_BYTES) -> dict:
    """The ledger's ``predicted`` block for ONE serving step.

    ``rows`` is the token rows through the per-layer projections
    (prefill: ``slots * padded_len``; decode: ``slots``);
    ``ctx_tokens`` the EXECUTED attention window per query token —
    blockwise attention computes the full masked window, so prefill
    passes the padded length S and decode the cache ``max_len``.  On
    top of the projection strategies' account this adds the serving
    terms the strategy objects don't own: the attention score/value
    GEMMs (``4·H·hd·ctx`` flops per query token, sharded over the
    model axis in both head and sequence sharding), the vocab-sharded
    LM head (last position per sequence at prefill, every row at
    decode), and the ``serve_overhead_events`` collectives.
    ``alpha_scale``/``beta_scale`` are the planner's calibrated
    measured/predicted correction scales for the executing strategy
    kind (docs/planner.md)."""
    sts = serve_site_strategies(cfg, p, dp)
    alpha_s, _ = costs_from_strategies(
        sts, p, cfg.num_layers, rows, peak_flops, fits, training=False)
    H, hd = cfg.num_heads, cfg.resolved_head_dim()
    attn_flops = 4.0 * H * hd * max(ctx_tokens, 0.0) * rows \
        * cfg.num_layers / max(p, 1)
    # LM head runs on one row per sequence at prefill (last position
    # only), on every row at decode
    head_rows = sequences or rows
    head_flops = 2.0 * cfg.d_model * cfg.vocab_size * head_rows / max(p, 1)
    alpha_s += (attn_flops + head_flops) / peak_flops
    alpha_s *= alpha_scale
    events = serve_step_events(cfg, p, rows, phase, sequences, dp)
    wire = sum(event_wire_bytes(ev, p, itemsize) * n for ev, n in events)
    m_floats = sum(ev.m_floats * n for ev, n in events)
    comm_us = sum(comm_time_us(ev.collective, ev.m_floats, p, fits) * n
                  for ev, n in events)
    beta_s = comm_us * 1e-6 * beta_scale
    return {
        "flops_per_device": alpha_s * peak_flops,
        "collective_wire_bytes_per_device": wire * beta_scale,
        "collective_m_floats": m_floats,
        "comm_us": comm_us,
        "alpha_s": alpha_s,
        "beta_s": beta_s,
        "energy_j_per_iter": energy_per_iteration(alpha_s, beta_s, p,
                                                  A, B),
        "phase": phase, "rows": rows, "ctx_tokens": ctx_tokens,
        "training": False,
        "model": "E = p*(A*alpha + B*beta), serving (fwd-only)",
        "A_w": A, "B_w": B, "peak_flops": peak_flops,
        "alpha_scale": alpha_scale, "beta_scale": beta_scale,
    }


def measured_energy_fields(costs, p: int, *, fits=None,
                           peak_flops: float = TPU_PEAK_FLOPS,
                           A: float = FRONTIER_A_W,
                           B: float = FRONTIER_B_W) -> dict:
    """Price the MEASURED compiled-HLO account of one step with the same
    E = p·(A·α + B·β) the predictions use: α from the lowered flop
    count, β from the lowered collectives' per-event message sizes run
    through the Eqn. 26 comm model.  This is what makes the serving
    ledger's measured/predicted ``energy_j_per_iter`` ratio a pure
    model-accuracy number (same constants both sides, CPU wall time out
    of the picture).  ``costs`` is a ``CompiledCosts``."""
    from repro.core.energy import PAPER_COLLECTIVE_FITS
    from repro.telemetry.compiled import HLO_TO_PAPER
    alpha_s = costs.flops / peak_flops
    table = dict(fits or PAPER_COLLECTIVE_FITS)
    # collectives without a Table III fit of their own are priced at the
    # nearest fitted shape: a2a moves (p-1)/p of a gather's wire, a
    # permute hop is broadcast-like
    fallback = {"all_to_all": "all_gather",
                "collective_permute": "broadcast"}
    us = 0.0
    for op, rec in costs.collectives.items():
        paper = HLO_TO_PAPER.get(op)
        count = rec.get("count", 0)
        if paper is None or not count:
            continue
        if paper not in table:
            paper = fallback.get(paper, "all_gather")
            if paper not in table:
                continue
        m_total = rec["result_bytes"] / 4.0
        if op == "all-gather":
            m_total /= max(p, 1)
        us += comm_time_us(paper, m_total / count, p, table) * count
    beta_s = us * 1e-6
    return {
        "flops_per_device": costs.flops,
        "hbm_bytes_per_device": costs.hbm_bytes,
        "collective_wire_bytes_per_device": costs.collective_wire_bytes,
        "collective_m_floats": costs.collective_m_floats,
        "alpha_s": alpha_s,
        "beta_s": beta_s,
        "energy_j_per_iter": energy_per_iteration(alpha_s, beta_s, p,
                                                  A, B),
    }


def pipeline_ffn_step_events(cfg, pp: int, tp: int, dp: int,
                             global_batch: int, *,
                             executed: bool = True) -> dict:
    """The per-step collective account of the pipelined paper-FFN step
    as ``(CommEvent, group, repeats)`` triples, with the schedule /
    strategy context the prediction needs.  ``group`` is the mesh-axis
    size each event runs over (permute -> pp, gradient all-reduce ->
    dp, layer collectives -> tp).  Shared by
    ``pipeline_ffn_step_prediction`` and the static audit's
    collective-accounting rule."""
    from repro.core.ffn import ffn_stage_strategies
    from repro.train.pipeline import PipelineSchedule

    if cfg.pipeline.mixed:
        raise ValueError("per-device prediction needs homogeneous stages "
                         "(mixed stages run different per-rank programs)")
    M = max(cfg.microbatches, 1)
    sched = PipelineSchedule(stages=pp, microbatches=M)
    st = ffn_stage_strategies(cfg, tp)[0]
    L_loc = cfg.num_layers // max(pp, 1)
    rows_mb = global_batch / max(dp, 1) / M
    reps = sched.num_ticks if executed else M

    layer_events = [(ev, reps * L_loc) for ev in st.comm_events(rows_mb)]
    m_boundary = rows_mb * cfg.ffn_width / max(tp, 1)
    p2p = sched.p2p_events(m_boundary, executed=executed)
    events = layer_events + [(ev, 1) for ev in p2p]
    if dp > 1:
        # dp gradient sync of this device's stage-local (tp-sharded)
        # param grads — once per step (the probe psums after the
        # wavefront, like the train step)
        m_grads = L_loc * st.param_count() / max(tp, 1)
        events.append((CommEvent("all_reduce", m_grads, "bwd"), 1))

    def group(ev):
        if ev.collective in ("collective_permute", "p2p"):
            return pp
        return dp if ev.collective == "all_reduce" else tp

    return {
        "events": [(ev, group(ev), n) for ev, n in events],
        "p2p": p2p,
        "schedule": sched,
        "strategy": st,
        "rows_mb": rows_mb,
        "L_loc": L_loc,
        "reps": reps,
    }


def pipeline_ffn_step_prediction(cfg, pp: int, tp: int, dp: int,
                                 global_batch: int, *,
                                 executed: bool = True,
                                 peak_flops: float = TPU_PEAK_FLOPS,
                                 fits=None, A: float = FRONTIER_A_W,
                                 B: float = FRONTIER_B_W,
                                 itemsize: float = FLOAT_BYTES) -> dict:
    """The ledger's ``predicted`` block for one PIPELINED paper-FFN step
    on a pp×dp×tp mesh (homogeneous stages).

    ``executed=True`` predicts what the SPMD 1F1B emulation actually
    lowers — every rank applies its stage at every wavefront tick
    (bubbles compute on masked garbage) and ppermutes at every tick but
    the last, forward and transposed-backward alike — so measured/
    predicted ledger ratios pin at ~1.  ``executed=False`` is the ideal
    deployment account (bubbles idle; M sends per boundary per
    direction), which is what the planner prices.

    The stage-boundary message is the carried feature shard:
    ``rows_mb * n / tp`` floats per device per hop — a PHANTOM stage
    carries the same shard but pays k-wide layer collectives, which is
    how phantom shrinks total boundary-adjacent traffic.
    """
    acct = pipeline_ffn_step_events(cfg, pp, tp, dp, global_batch,
                                    executed=executed)
    sched, st = acct["schedule"], acct["strategy"]
    M = sched.microbatches

    alpha_s = (3.0 * acct["reps"] * acct["L_loc"]
               * st.flops(acct["rows_mb"])) / peak_flops
    events = acct["events"]
    wire = sum(event_wire_bytes(ev, g, itemsize) * nrep
               for ev, g, nrep in events)
    boundary_wire = sum(event_wire_bytes(ev, pp, itemsize)
                        for ev in acct["p2p"])
    m_floats = sum(ev.m_floats * nrep for ev, _, nrep in events)
    comm_us = sum(comm_time_us(ev.collective, ev.m_floats, g, fits)
                  * nrep for ev, g, nrep in events)
    beta_s = comm_us * 1e-6
    devices = pp * dp * tp
    return {
        "flops_per_device": alpha_s * peak_flops,
        "collective_wire_bytes_per_device": wire,
        "boundary_wire_bytes_per_device": boundary_wire,
        "collective_m_floats": m_floats,
        "comm_us": comm_us,
        "alpha_s": alpha_s,
        "beta_s": beta_s,
        "energy_j_per_iter": energy_per_iteration(alpha_s, beta_s,
                                                  devices, A, B),
        "training": True,
        "model": "E = nu*p*(A*alpha + B*beta), 1F1B pipeline",
        "A_w": A, "B_w": B, "peak_flops": peak_flops,
        "pp": pp, "tp": tp, "dp": dp, "microbatches": M,
        "ticks": sched.num_ticks,
        "bubble_fraction": sched.bubble_fraction,
        "executed": executed,
        "strategy": st.kind,
    }


def kv_cache_token_bytes(cfg) -> tuple:
    """``(per_token_bytes, per_sequence_bytes)`` of ONE request's decode
    cache rows at the model's true cache dtypes (bf16 k/v, fp32 SSD
    state unless quantized) — the unit the fleet's KV-page transfer
    channel is priced in (docs/energy_model.md §transfer wire term).

    Computed by differencing ``cache_decls`` at two lengths, so
    length-proportional leaves (attention k/v, encdec cross k/v) land in
    the per-token term and fixed-size recurrent state (Mamba conv/SSD)
    in the per-sequence term, with no per-family arithmetic to drift
    out of sync with the real cache layout."""
    import jax
    from repro.models.model import cache_decls
    from repro.parallel.axes import MeshAxes
    axes = MeshAxes(tp=1, dp=1, dp_names=("data",))

    def total_bytes(n_tokens: int) -> float:
        sds, _ = cache_decls(cfg, axes, 1, n_tokens)
        return float(sum(
            leaf.size * leaf.dtype.itemsize
            for leaf in jax.tree.leaves(sds)))

    step = 16
    b1, b2 = total_bytes(step), total_bytes(2 * step)
    per_token = (b2 - b1) / step
    per_seq = b1 - per_token * step
    return per_token, max(per_seq, 0.0)


def kv_transfer_prediction(cfg, migrations: int, mean_tokens: float, *,
                           tp_src: int = 1, tp_dst: int = 1,
                           fits=None, B: float = FRONTIER_B_W) -> dict:
    """The ``predicted`` block for the fleet's prefill->decode KV-page
    migrations: ``migrations`` requests, each carrying ``mean_tokens``
    padded prompt rows of cache across the pool boundary.

    The wire term is a point-to-point hop (Eqn. 26 ``c1 + c2·m``, the
    same single-hop pricing as PR 5's pipeline stage boundaries); the
    energy term bills the transfer seconds at static power ``B`` across
    the endpoint devices of both pools (the accelerators sit idle from
    the compute account's view while pages move).  The measured side is
    the TransferChannel's actual byte count, and the fleet bench pins
    the measured/predicted ``transfer_wire_bytes`` ratio to
    [0.9, 1.1]."""
    per_tok, per_seq = kv_cache_token_bytes(cfg)
    bytes_each = per_seq + mean_tokens * per_tok
    wire = migrations * bytes_each
    hop_us = comm_time_us("collective_permute", bytes_each / FLOAT_BYTES,
                          2, fits)
    comm_us = migrations * hop_us
    beta_s = comm_us * 1e-6
    devices = max(tp_src, 1) + max(tp_dst, 1)
    return {
        "transfer_wire_bytes": wire,
        "migrations": migrations,
        "bytes_per_migration": bytes_each,
        "cache_bytes_per_token": per_tok,
        "cache_bytes_per_sequence": per_seq,
        "comm_us": comm_us,
        "beta_s": beta_s,
        "energy_j": beta_s * B * devices,
        "model": "E = B*(tp_src+tp_dst)*beta, p2p hop c1 + c2*m",
        "B_w": B, "tp_src": tp_src, "tp_dst": tp_dst,
    }


# assumed checkpoint-store bandwidth for pricing ckpt IO seconds when a
# measured duration is unavailable (local NVMe-class, docs/elastic.md)
CKPT_DISK_BW_BPS = 1.0e9


def recovery_account(phases: Sequence[dict],
                     recoveries: Sequence[dict] = (), *,
                     A: float = FRONTIER_A_W, B: float = FRONTIER_B_W,
                     disk_bw_bps: float = CKPT_DISK_BW_BPS) -> dict:
    """Joules-to-target-loss INCLUDING the recovery overhead — the
    elastic runtime's first-class energy account (docs/elastic.md).

    ``phases`` — one dict per mesh/plan the run executed on::

        {"steps": int,            # steps this phase executed
         "replayed_steps": int,   # of those, re-runs of lost progress
         "devices": int,
         "energy_j_per_iter": float,   # calibrated analytic price
         "ckpt_io_bytes": float,  # bytes this phase's saves wrote
         "ckpt_io_s": float,      # measured write seconds (0 = derive
                                  # from bytes at ``disk_bw_bps``)
         "compile_s": float,      # restart compile time (phase > 0)
         "wall_s": float}         # measured phase wall time

    ``recoveries`` — one dict per fault handled, with measured
    ``restore_s`` / ``replan_s`` and ``devices_after``.

    Accounting: useful and replayed steps are priced at the phase's
    calibrated per-iteration energy (the same E = ν·p·(A·α + B·β) the
    planner scores with), so ``replay_overhead_ratio`` — replayed over
    total STEP energy — is a pure schedule quantity, independent of this
    host's wall-clock speed; it is the band the elastic smoke suite
    checks.  Checkpoint IO and restart time (restore + re-plan +
    compile) are idle-from-the-accelerator's-view host seconds, priced
    at static power B across the devices that sat waiting;
    ``recovery_overhead_ratio`` folds those in, and is reported but not
    band-checked (host-measured seconds dwarf the analytic per-iter
    joules of the tiny CPU-mesh subject)."""
    useful_j = replay_j = ckpt_j = restart_j = 0.0
    steps = replayed = 0
    io_bytes = io_s = compile_s = wall_s = 0.0
    for ph in phases:
        e = float(ph.get("energy_j_per_iter", 0.0))
        n = int(ph.get("steps", 0))
        r = min(int(ph.get("replayed_steps", 0)), n)
        dev = int(ph.get("devices", 1))
        useful_j += e * (n - r)
        replay_j += e * r
        steps += n
        replayed += r
        b = float(ph.get("ckpt_io_bytes", 0.0))
        s = float(ph.get("ckpt_io_s", 0.0)) or b / disk_bw_bps
        ckpt_j += s * B * dev
        io_bytes += b
        io_s += s
        c = float(ph.get("compile_s", 0.0))
        compile_s += c
        restart_j += c * B * dev
        wall_s += float(ph.get("wall_s", 0.0))
    restore_s = replan_s = 0.0
    for rec in recoveries:
        dev = int(rec.get("devices_after", 1))
        rs = float(rec.get("restore_s", 0.0))
        ps = float(rec.get("replan_s", 0.0))
        restore_s += rs
        replan_s += ps
        restart_j += (rs + ps) * B * dev
    step_j = useful_j + replay_j
    total_j = step_j + ckpt_j + restart_j
    return {
        "schema": "recovery-account/v1",
        "energy_j_useful": useful_j,
        "energy_j_replay": replay_j,
        "energy_j_ckpt_io": ckpt_j,
        "energy_j_restart": restart_j,
        "energy_j_total": total_j,
        "replay_overhead_ratio": (replay_j / step_j) if step_j else 0.0,
        "recovery_overhead_ratio": ((total_j - useful_j) / total_j)
        if total_j else 0.0,
        "steps_total": steps,
        "replayed_steps": replayed,
        "restarts": len(list(recoveries)),
        "ckpt_io_bytes": io_bytes,
        "ckpt_io_s": io_s,
        "compile_s": compile_s,
        "restore_s": restore_s,
        "replan_s": replan_s,
        "wall_s": wall_s,
        "disk_bw_bps": disk_bw_bps,
        "A_w": A, "B_w": B,
    }


def ffn_step_prediction(cfg, p: int, global_batch: int, *,
                        training: bool = True,
                        peak_flops: float = TPU_PEAK_FLOPS,
                        fits=None, A: float = FRONTIER_A_W,
                        B: float = FRONTIER_B_W) -> dict:
    """Prediction for one paper-FFN step (the strategy ``cfg`` selects at
    the ``ffn_layer`` site, applied once per layer)."""
    from repro.core.ffn import ffn_strategy
    st = ffn_strategy(cfg, p)
    pred = strategy_prediction([st], p, cfg.num_layers, global_batch,
                               training=training, peak_flops=peak_flops,
                               fits=fits, A=A, B=B)
    pred["strategy"] = st.kind
    pred["param_count"] = st.param_count() * cfg.num_layers
    return pred


def fused_kernel_step_events(cfg, p: int, rows: int,
                             training: bool = True) -> List[tuple]:
    """(CommEvent, layer-repeats) account of a phantom FFN step running
    with ``kernel_backend="pallas"`` — IDENTICAL to the XLA path's
    account by construction: the fused kernel moves GEMM HBM traffic,
    never collectives (the ghost all-gather / reduce-scatter stay
    outside the custom_vjp op), so this re-exports the strategy's own
    ``comm_events``.  Shared by ``fused_ffn_step_prediction`` and the
    audit's ``kernel_unit``; golden-cost-pinned to prove zero drift."""
    from repro.core.ffn import ffn_strategy
    st = ffn_strategy(cfg, p)
    return [(ev, cfg.num_layers)
            for ev in events_for([st], rows, training)]


def fused_ffn_step_prediction(cfg, p: int, global_batch: int, *,
                              training: bool = True,
                              itemsize: float = FLOAT_BYTES,
                              **kw) -> dict:
    """``ffn_step_prediction`` for the Pallas kernel backend: same flops,
    same collectives, same energy projection (zero drift), annotated
    with what fusion DOES change — the decompress GEMM accumulates into
    the local GEMM's VMEM tile instead of issuing a second read+write
    pass of z over HBM (one saved round-trip per layer per pass)."""
    pred = ffn_step_prediction(cfg, p, global_batch,
                               training=training, **kw)
    from repro.core.ffn import ffn_strategy
    st = ffn_strategy(cfg, p)
    z_bytes = global_batch * (st.n_out // p) * itemsize
    passes = 3 if training else 1          # fwd + fused dgrad + wgrad
    pred["kernel_backend"] = cfg.projection_spec("ffn_layer").kernel_backend
    pred["hbm_bytes_saved_per_device"] = (2.0 * z_bytes * passes
                                          * cfg.num_layers)
    return pred
