"""Wall-clock metering for jitted step functions.

``StepMeter`` wraps a compiled train/prefill/decode step and records the
wall time of every call (blocking on the result, so async dispatch cannot
hide the device work).  The first ``warmup`` calls — compilation plus
cache warm-up — are timed but excluded from the summary statistics, which
is what the measured-vs-predicted ledger joins against.

``measure(fn, *args)`` is the one-shot variant used by the benchmark
suites (median of ``iters`` timed calls after ``warmup`` untimed ones).
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import numpy as np


class StepMeter:
    """Records per-call wall time for one step function.

    Use either as a wrapper (``meter.wrap(fn)`` / ``meter(fn, *args)``)
    or as a context-free stopwatch (``with meter.measure(): ...``).
    """

    def __init__(self, name: str, warmup: int = 1):
        self.name = name
        self.warmup = warmup
        self.times_us: list[float] = []

    # --- recording -------------------------------------------------------
    def call(self, fn: Callable, *args, **kwargs):
        """Call ``fn``, block until its outputs are ready, record."""
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        out = jax.block_until_ready(out)
        self.times_us.append((time.perf_counter() - t0) * 1e6)
        return out

    def wrap(self, fn: Callable) -> Callable:
        """Returns ``fn`` with every call metered."""
        def wrapped(*args, **kwargs):
            return self.call(fn, *args, **kwargs)
        wrapped.__name__ = getattr(fn, "__name__", self.name)
        return wrapped

    def record_us(self, us: float):
        """Record an externally-timed call (e.g. a loop that must not
        block every step: time the whole chunk, record the mean)."""
        self.times_us.append(float(us))

    # --- statistics ------------------------------------------------------
    @property
    def calls(self) -> int:
        return len(self.times_us)

    @property
    def steady(self) -> list[float]:
        """Post-warmup samples.  Empty until more than ``warmup`` calls
        have been recorded — a lone first call is compile+execute and
        must not be reported as steady wall time."""
        return self.times_us[self.warmup:]

    def mean_us(self) -> float:
        s = self.steady
        return float(np.mean(s)) if s else 0.0

    def median_us(self) -> float:
        s = self.steady
        return float(np.median(s)) if s else 0.0

    def total_s(self) -> float:
        return float(np.sum(self.times_us)) * 1e-6

    def summary(self) -> dict:
        """The ledger's ``measured`` wall-time fields."""
        s = self.steady
        out = {"name": self.name, "calls": self.calls,
               "warmup": min(self.warmup, self.calls),
               "total_s": self.total_s()}
        if s:
            out.update({
                "wall_us_mean": float(np.mean(s)),
                "wall_us_median": float(np.median(s)),
                "wall_us_min": float(np.min(s)),
                "wall_us_max": float(np.max(s)),
            })
        return out

    def reset(self, warm: bool = False):
        """Drop recorded samples.  ``warm=True`` also zeroes the warmup
        count: the wrapped function stays compiled across a ledger-window
        flush, so the next window's first call is already steady."""
        self.times_us = []
        if warm:
            self.warmup = 0

    def __repr__(self):
        return (f"StepMeter({self.name!r}, calls={self.calls}, "
                f"median={self.median_us():.1f}us)")


def measure(fn: Callable, *args, warmup: int = 2, iters: int = 5,
            meter: Optional[StepMeter] = None) -> float:
    """Median wall time per call in microseconds (blocks on ready).

    The historical ``benchmarks.common.timeit`` contract; optionally
    records every timed call into ``meter`` as well.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        us = (time.perf_counter() - t0) * 1e6
        ts.append(us)
        if meter is not None:
            meter.record_us(us)
    return float(np.median(ts))
