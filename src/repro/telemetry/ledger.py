"""The measured-vs-predicted energy ledger.

One ``LedgerEntry`` per metered step or benchmark row, holding up to
three views of the same computation:

  * ``measured``  — wall time from a ``StepMeter`` and/or compiled-HLO
    costs from ``analyze_compiled`` (what actually ran / was lowered)
  * ``predicted`` — the analytic account from ``strategy_prediction``
    (the same ``ProjectionStrategy`` objects that executed, priced by
    the paper's Eqn. 26 comm model and E = ν·p·(A·α + B·β))
  * ``ratios``    — measured/predicted for every key present in both,
    computed at serialization time.  A ratio near 1.0 means the analytic
    energy model is accounting for the operators the compiler actually
    emitted; a drift is a model bug or an unmodeled operator.

``Ledger`` collects entries (optionally streaming each to a JSONL file
as it is recorded) and writes the aggregate ``BENCH_report.json`` that
`benchmarks/run.py` drops at the repo root — the single reporting path
for the trainer, the serving engine, the dry-run and every benchmark
suite.
"""
from __future__ import annotations

import atexit
import dataclasses
import json
import os
import time
import weakref
from dataclasses import dataclass, field
from typing import List, Optional

SCHEMA = "bench-ledger/v1"

# all live ledgers, flushed once at interpreter exit so JSONL tails
# (and a configured report) survive crashes/interrupts — the same
# guarantee CheckpointManager gives queued saves
_LEDGERS: "weakref.WeakSet" = weakref.WeakSet()


@atexit.register
def _flush_all_ledgers():
    for led in list(_LEDGERS):
        try:
            led.close()
        except Exception:
            pass

# measured keys ratioed against same-named predicted keys
_RATIO_KEYS = (
    "flops_per_device",
    "hbm_bytes_per_device",
    "collective_wire_bytes_per_device",
    "boundary_wire_bytes_per_device",   # pipeline stage-boundary p2p
    "transfer_wire_bytes",              # fleet prefill->decode KV pages
    "migrations",                       # fleet KV-page migration count
    "collective_m_floats",
    "energy_j_per_iter",
    "iterations",
)


@dataclass
class LedgerEntry:
    name: str                          # unique row id, e.g. fig5a_hlo_wire
    suite: str = ""                    # producing subsystem/suite
    kind: str = "step"                 # train|prefill|decode|collective|
                                       # analytic|step
    arch: str = ""                     # model/config name
    impl: str = ""                     # tensor_col|phantom|dense|...
    p: int = 0                         # parallel width (model axis)
    measured: Optional[dict] = None
    predicted: Optional[dict] = None
    extra: dict = field(default_factory=dict)

    def ratios(self) -> dict:
        """measured/predicted for the curated ``_RATIO_KEYS`` present in
        both dicts — only keys where the two sides measure the SAME
        quantity on the same hardware (e.g. the comm_model suite's
        CPU-fitted c1/c2 are deliberately not ratioed against the
        paper's Frontier constants)."""
        if not self.measured or not self.predicted:
            return {}
        out = {}
        for key in _RATIO_KEYS:
            m, pr = self.measured.get(key), self.predicted.get(key)
            if isinstance(m, (int, float)) and isinstance(pr, (int, float)) \
                    and pr:
                out[key] = m / pr
        return out

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["ratios"] = self.ratios()
        return {k: v for k, v in d.items() if v not in (None, {}, "")}


class Ledger:
    """Collects LedgerEntry rows; one instance per process/run.

    Tail-write guarantees: the JSONL stream is held open and flushed
    after every row, ``close()`` (idempotent; also the context-manager
    exit and an atexit hook) fsyncs the tail and writes the aggregate
    report when ``report_path`` is configured — so a crash or interrupt
    mid-run loses at most the row being serialized, never the stream.
    ``ServeEngine.close()`` and the ``Trainer`` finally-path flush
    through here.
    """

    def __init__(self, run: str = "", jsonl_path: Optional[str] = None,
                 meta: Optional[dict] = None,
                 report_path: Optional[str] = None):
        self.run = run
        self.meta = dict(meta or {})
        self.entries: List[LedgerEntry] = []
        self.suite_status: dict = {}       # suite -> ok|failed: <error>
        self._jsonl_path = jsonl_path
        self.report_path = report_path
        self._jsonl_f = None
        self._closed = False
        if jsonl_path:
            os.makedirs(os.path.dirname(os.path.abspath(jsonl_path)),
                        exist_ok=True)
            # truncate: one JSONL stream per run; the handle stays open
            # (line-flushed per record) so tails survive interrupts
            self._jsonl_f = open(jsonl_path, "w")
        _LEDGERS.add(self)

    # --- recording -------------------------------------------------------
    def record(self, entry: LedgerEntry) -> LedgerEntry:
        self.entries.append(entry)
        if self._jsonl_path:
            if self._jsonl_f is None or self._jsonl_f.closed:
                self._jsonl_f = open(self._jsonl_path, "a")
                self._closed = False    # re-arm close() for the new tail
            self._jsonl_f.write(json.dumps(entry.as_dict()) + "\n")
            self._jsonl_f.flush()
        return entry

    # --- durability ------------------------------------------------------
    def flush(self):
        """Push the JSONL tail to the OS and fsync it to disk."""
        if self._jsonl_f is not None and not self._jsonl_f.closed:
            self._jsonl_f.flush()
            try:
                os.fsync(self._jsonl_f.fileno())
            except OSError:
                pass

    def close(self):
        """Flush + close the stream; write ``report_path`` if set.
        Idempotent — safe from finally-paths AND the atexit sweep."""
        if self._closed:
            return
        self._closed = True
        self.flush()
        if self._jsonl_f is not None and not self._jsonl_f.closed:
            self._jsonl_f.close()
        if self.report_path:
            self.write_report(self.report_path)

    def __enter__(self) -> "Ledger":
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False

    def entry(self, name: str, **kw) -> LedgerEntry:
        return self.record(LedgerEntry(name=name, **kw))

    def suite_ok(self, suite: str, seconds: float = 0.0):
        self.suite_status[suite] = {"status": "ok", "seconds": seconds}

    def suite_failed(self, suite: str, error: str, seconds: float = 0.0):
        self.suite_status[suite] = {"status": "failed", "error": error,
                                    "seconds": seconds}

    # --- reporting -------------------------------------------------------
    def joined(self) -> List[LedgerEntry]:
        """Entries whose measured and predicted accounts share at least
        one ratio-able key — the rows that falsify (or confirm) the
        energy model."""
        return [e for e in self.entries if e.ratios()]

    def report(self) -> dict:
        entries = [e.as_dict() for e in self.entries]
        n_joined = len(self.joined())
        return {
            "schema": SCHEMA,
            "run": self.run,
            "generated_at": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                          time.gmtime()),
            "meta": self.meta,
            "suites": self.suite_status,
            "counts": {"entries": len(entries), "joined": n_joined},
            "entries": entries,
        }

    def write_report(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.report(), f, indent=1)
        return path

    def __len__(self):
        return len(self.entries)

    def __repr__(self):
        return (f"Ledger(run={self.run!r}, entries={len(self.entries)}, "
                f"joined={len(self.joined())})")


def load_report(path: str) -> dict:
    with open(path) as f:
        rec = json.load(f)
    if rec.get("schema") != SCHEMA:
        raise ValueError(f"{path}: unknown ledger schema "
                         f"{rec.get('schema')!r} (want {SCHEMA})")
    return rec
