"""Measured per-step costs from a compiled XLA executable.

This is the *measured* half of the energy ledger: where the analytic
model predicts flops and collective traffic from ``ProjectionStrategy``
objects, ``analyze_compiled`` reads what the compiler actually lowered —

  * ``cost_analysis()``   per-device FLOPs and HBM bytes accessed
  * ``memory_analysis()`` per-device buffer footprint (proves it fits)
  * the post-optimization HLO text, parsed for collective ops and
    converted to per-device wire bytes under the ring model
    (``launch/hlo_analysis.py``)

Caveat that the dry-run already documents: XLA counts each ``scan`` /
while-loop body ONCE, so for exact totals compile with layers unrolled
(``cfg.scan_layers=False``; the FFN probe and the bench suites do).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.launch.hlo_analysis import collective_bytes, collective_m_floats

# HLO op name -> the paper's collective name (Eqn. 26 / Table III keys).
HLO_TO_PAPER = {
    "all-gather": "all_gather",
    "all-reduce": "all_reduce",
    "reduce-scatter": "reduce_scatter",
    "all-to-all": "all_to_all",
    "collective-permute": "collective_permute",
}


@dataclass
class CompiledCosts:
    """Per-device measured costs of one compiled step."""
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_m_floats: float = 0.0   # paper Eqn. 26 message units
    collectives: dict = field(default_factory=dict)  # per-HLO-op breakdown
    memory: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_wire_bytes_per_device": self.collective_wire_bytes,
            "collective_m_floats": self.collective_m_floats,
            "collectives": self.collectives,
            "memory": self.memory,
        }

    def measured_fields(self) -> dict:
        """The subset the ledger joins against predictions."""
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_wire_bytes_per_device": self.collective_wire_bytes,
            "collective_m_floats": self.collective_m_floats,
        }


def _cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):     # older jax: one dict per device
        ca = ca[0] if ca else {}
    return dict(ca)


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    return {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
        "output_bytes": getattr(ma, "output_size_in_bytes", None),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
        "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
        "code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
    }


# One analysis per lowered module: every analysis call site (dry-run
# cells, planner HBM-fit checks, probes) funnels through these caches so
# a module is compiled and parsed at most once per process.  Keys hash
# the HLO text — the canonical identity of a lowered/compiled module —
# plus the collective group size the parse assumes.  Analysis results
# are small dicts; compiled executables pin device programs, so that
# cache is a bounded LRU (a long benchmark run compiling dozens of
# distinct modules must not retain them all).
_ANALYSIS_CACHE: dict = {}     # (hlo_hash, group) -> CompiledCosts
_COMPILE_CACHE: "OrderedDict" = None   # lowered_hlo_hash -> executable
_COMPILE_CACHE_MAX = 8


def _compile_cache():
    global _COMPILE_CACHE
    if _COMPILE_CACHE is None:
        from collections import OrderedDict
        _COMPILE_CACHE = OrderedDict()
    return _COMPILE_CACHE


def clear_analysis_cache():
    _ANALYSIS_CACHE.clear()
    _compile_cache().clear()


def analyze_compiled(compiled, default_group: int = 1) -> CompiledCosts:
    """Extract measured per-device costs from a ``lowered.compile()``
    result.  ``default_group`` is the collective group size assumed when
    an HLO op carries no ``replica_groups`` (normally the model-axis
    size).  Results are memoized on the optimized-HLO text, so repeated
    analysis of the same executable (dry-run + cost-fix + planner) pays
    for the parse once."""
    text = compiled.as_text()
    key = (hash(text), default_group)
    if key in _ANALYSIS_CACHE:
        return _ANALYSIS_CACHE[key]
    ca = _cost_dict(compiled)
    wire, breakdown = collective_bytes(text, default_group=default_group)
    costs = CompiledCosts(
        flops=float(ca.get("flops", 0.0)),
        hbm_bytes=float(ca.get("bytes accessed", 0.0)),
        collective_wire_bytes=float(wire),
        collective_m_floats=collective_m_floats(breakdown, default_group),
        collectives=breakdown,
        memory=_memory_dict(compiled),
    )
    _ANALYSIS_CACHE[key] = costs
    return costs


def compile_lowered(lowered):
    """LRU-cached ``lowered.compile()`` keyed on the lowered HLO text —
    call sites that re-lower an identical module (the planner checking
    HBM fit for a plan the dry-run already compiled, cost-fix reruns)
    skip the compile entirely."""
    cache = _compile_cache()
    lkey = hash(lowered.as_text())
    compiled = cache.get(lkey)
    if compiled is None:
        compiled = lowered.compile()
        cache[lkey] = compiled
        while len(cache) > _COMPILE_CACHE_MAX:
            cache.popitem(last=False)
    else:
        cache.move_to_end(lkey)
    return compiled


def analyze_lowered(lowered, default_group: int = 1,
                    keep_compiled: bool = False):
    """Compile (cached) + analyze a ``fn.lower(...)`` result."""
    compiled = compile_lowered(lowered)
    costs = analyze_compiled(compiled, default_group=default_group)
    if keep_compiled:
        return costs, compiled
    return costs


def analyze_lowerable(fn, *args, default_group: int = 1,
                      keep_compiled: bool = False):
    """Lower + compile ``fn(*args)`` (ShapeDtypeStructs are fine) and
    analyze it.  Returns ``CompiledCosts`` or, with ``keep_compiled``,
    ``(CompiledCosts, compiled)`` so callers can also execute it."""
    return analyze_lowered(fn.lower(*args), default_group=default_group,
                           keep_compiled=keep_compiled)
