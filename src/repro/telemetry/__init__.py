"""Telemetry: the measured-vs-predicted energy ledger.

Three pieces, one join:

  * ``StepMeter`` / ``measure``      — wall time of executed steps
  * ``analyze_compiled``             — flops / HBM bytes / collective
    wire bytes read from the lowered HLO of the step that ran
  * ``strategy_prediction`` et al.   — the analytic account summed from
    the same ``ProjectionStrategy`` objects, priced by the paper's
    energy model (docs/energy_model.md)

``Ledger`` records entries joining the views and writes the repo-root
``BENCH_report.json`` (plus a JSONL stream) that every reporting path —
trainer, serving engine, dry-run, benchmark suites — goes through.
"""
from repro.telemetry.compiled import (CompiledCosts, HLO_TO_PAPER,
                                      analyze_compiled, analyze_lowerable,
                                      analyze_lowered,
                                      clear_analysis_cache,
                                      compile_lowered)
from repro.telemetry.ledger import (SCHEMA, Ledger, LedgerEntry,
                                    load_report)
from repro.telemetry.meter import StepMeter, measure
from repro.telemetry.predict import (event_wire_bytes, events_for,
                                     ffn_step_prediction,
                                     kv_cache_token_bytes,
                                     kv_transfer_prediction,
                                     measured_energy_fields,
                                     pipeline_ffn_step_prediction,
                                     recovery_account,
                                     serve_site_strategies,
                                     serve_step_prediction,
                                     strategy_prediction)
from repro.telemetry.probe import (make_ffn_pipeline_probe_step,
                                   make_ffn_probe_step,
                                   measure_ffn_pipeline_step,
                                   measure_ffn_step)

__all__ = [
    "CompiledCosts", "HLO_TO_PAPER", "analyze_compiled",
    "analyze_lowerable", "analyze_lowered", "clear_analysis_cache",
    "compile_lowered", "SCHEMA", "Ledger", "LedgerEntry", "load_report",
    "StepMeter", "measure", "event_wire_bytes", "events_for",
    "ffn_step_prediction", "kv_cache_token_bytes",
    "kv_transfer_prediction", "measured_energy_fields",
    "pipeline_ffn_step_prediction", "recovery_account",
    "serve_site_strategies",
    "serve_step_prediction", "strategy_prediction",
    "make_ffn_pipeline_probe_step", "make_ffn_probe_step",
    "measure_ffn_pipeline_step", "measure_ffn_step",
]
