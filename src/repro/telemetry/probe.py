"""Measured-vs-predicted probe for the paper-FFN step.

``make_ffn_probe_step`` builds a pure fwd+bwd step (loss + grads w.r.t.
params AND inputs, no optimizer) for the strategy ``cfg`` selects, as one
``shard_map`` over the mesh — the same operator schedule as
``core/ffn.make_ffn_train_step`` with two deliberate differences that
make the per-operator account exact:

  * layers are compiled UNROLLED (``cfg.scan_layers=False`` is forced):
    XLA's cost analysis counts a scan body once, so totals from a
    scanned compile are per-layer-scale, not per-step;
  * input gradients are requested too: the analytic Table II schedule
    charges every layer an AG fwd + RS bwd, but the first layer's
    backward collective (and its input-grad GEMM) is dead code when the
    input is a constant — differentiating w.r.t. the input keeps the
    schedule complete so measured/predicted ratios pin to ~1.

``measure_ffn_step`` compiles the probe, extracts measured HLO costs,
optionally executes a few metered steps, and returns the (measured,
predicted) pair the ledger joins.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.parallel.axes import MeshAxes, resolve_spec
from repro.parallel.compat import shard_map
from repro.parallel.params import abstract, materialize, specs
from repro.telemetry.compiled import analyze_compiled
from repro.telemetry.meter import StepMeter
from repro.telemetry.predict import ffn_step_prediction


def make_ffn_probe_step(cfg, mesh, global_batch: int):
    """Returns (jit probe_fn(params, x, y) -> (loss, grads), decls)."""
    from repro.core.ffn import ffn_apply, ffn_decls
    cfg = cfg.replace(scan_layers=False)
    axes = MeshAxes.from_mesh(mesh)
    decls = ffn_decls(cfg, axes)
    n = cfg.ffn_width

    def probe(params, x, y):
        def loss_fn(p_, x_):
            out = ffn_apply(cfg, axes, p_, x_)
            return jnp.sum(jnp.square(out - y)) / (global_batch * n)

        loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(params, x)
        return lax.psum(loss, axes.all_names), grads

    pspecs = jax.tree.map(lambda s: resolve_spec(s, axes), specs(decls))
    bspec = resolve_spec(P("dp", "tp"), axes)
    fn = shard_map(probe, mesh=mesh, in_specs=(pspecs, bspec, bspec),
                   out_specs=(P(), (pspecs, bspec)), check_vma=False)
    return jax.jit(fn), decls


def measure_ffn_step(cfg, mesh, global_batch: int, *, steps: int = 0,
                     seed: int = 0,
                     meter: Optional[StepMeter] = None
                     ) -> Tuple[dict, dict]:
    """Compile + analyze the FFN probe; run ``steps`` metered executions.

    Returns ``(measured, predicted)`` dicts ready for a LedgerEntry:
    measured carries the compiled-HLO flops / HBM / collective wire bytes
    (and wall stats when ``steps > 0``); predicted is
    ``ffn_step_prediction`` summed from the same strategy objects.
    """
    axes = MeshAxes.from_mesh(mesh)
    p = axes.tp
    fn, decls = make_ffn_probe_step(cfg, mesh, global_batch)
    n = cfg.ffn_width
    x_sds = jax.ShapeDtypeStruct((global_batch, n), jnp.float32)
    compiled = fn.lower(abstract(decls), x_sds, x_sds).compile()
    costs = analyze_compiled(compiled, default_group=p)
    measured = costs.measured_fields()
    measured["collectives"] = {
        op: {"count": rec["count"], "wire_bytes": rec["wire_bytes"]}
        for op, rec in costs.collectives.items()}

    if steps > 0:
        meter = meter or StepMeter(f"ffn_probe_{cfg.name}", warmup=1)
        params = materialize(decls, seed)
        key = jax.random.PRNGKey(seed + 1)
        kx, ky = jax.random.split(key)
        x = jax.random.normal(kx, (global_batch, n), jnp.float32)
        y = jax.random.normal(ky, (global_batch, n), jnp.float32)
        for _ in range(steps + meter.warmup):
            meter.call(compiled, params, x, y)
        for k, v in meter.summary().items():
            if k != "name":
                measured[k] = v

    predicted = ffn_step_prediction(cfg, p, global_batch, training=True)
    return measured, predicted
