"""Measured-vs-predicted probe for the paper-FFN step.

``make_ffn_probe_step`` builds a pure fwd+bwd step (loss + grads w.r.t.
params AND inputs, no optimizer) for the strategy ``cfg`` selects, as one
``shard_map`` over the mesh — the same operator schedule as
``core/ffn.make_ffn_train_step`` with two deliberate differences that
make the per-operator account exact:

  * layers are compiled UNROLLED (``cfg.scan_layers=False`` is forced):
    XLA's cost analysis counts a scan body once, so totals from a
    scanned compile are per-layer-scale, not per-step;
  * input gradients are requested too: the analytic Table II schedule
    charges every layer an AG fwd + RS bwd, but the first layer's
    backward collective (and its input-grad GEMM) is dead code when the
    input is a constant — differentiating w.r.t. the input keeps the
    schedule complete so measured/predicted ratios pin to ~1.

``measure_ffn_step`` compiles the probe, extracts measured HLO costs,
optionally executes a few metered steps, and returns the (measured,
predicted) pair the ledger joins.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.parallel.axes import MeshAxes, resolve_spec
from repro.parallel.compat import shard_map
from repro.parallel.params import abstract, materialize, specs
from repro.telemetry.compiled import analyze_compiled
from repro.telemetry.meter import StepMeter
from repro.telemetry.predict import ffn_step_prediction


def make_ffn_probe_step(cfg, mesh, global_batch: int):
    """Returns (jit probe_fn(params, x, y) -> (loss, grads), decls)."""
    from repro.core.ffn import ffn_apply, ffn_decls
    cfg = cfg.replace(scan_layers=False)
    axes = MeshAxes.from_mesh(mesh)
    decls = ffn_decls(cfg, axes)
    n = cfg.ffn_width

    def probe(params, x, y):
        def loss_fn(p_, x_):
            out = ffn_apply(cfg, axes, p_, x_)
            return jnp.sum(jnp.square(out - y)) / (global_batch * n)

        loss, (gp, gx) = jax.value_and_grad(loss_fn,
                                            argnums=(0, 1))(params, x)
        # dp grad sync (the train step's reduction) so returned param
        # grads are global — a no-op collective on the dp=1 bench meshes
        if axes.dp > 1:
            gp = jax.tree.map(lambda g: lax.psum(g, axes.dp_names), gp)
        return lax.psum(loss, axes.all_names), (gp, gx)

    pspecs = jax.tree.map(lambda s: resolve_spec(s, axes), specs(decls))
    bspec = resolve_spec(P("dp", "tp"), axes)
    fn = shard_map(probe, mesh=mesh, in_specs=(pspecs, bspec, bspec),
                   out_specs=(P(), (pspecs, bspec)), check_vma=False)
    return jax.jit(fn), decls


def make_ffn_pipeline_probe_step(cfg, mesh, global_batch: int):
    """Pipelined analogue of ``make_ffn_probe_step``: the 1F1B wavefront
    with the tick loop AND the per-stage layer loops unrolled, input
    grads kept — so the lowered HLO contains every wavefront tick's
    collectives (XLA counts a scanned tick body once, exactly like the
    layer scan) and the ppermute count is deterministic."""
    from repro.core.ffn import ffn_decls, make_ffn_stage_fn
    from repro.train.pipeline import pipeline_run, split_microbatches
    cfg = cfg.replace(scan_layers=False)
    axes = MeshAxes.from_mesh(mesh)
    decls = ffn_decls(cfg, axes)
    n = cfg.ffn_width
    M = max(cfg.microbatches, 1)

    def probe(params, x, y):
        def loss_fn(p_, x_):
            x_mb = split_microbatches(x_, M)
            y_mb = split_microbatches(y, M)
            stage_fn = make_ffn_stage_fn(cfg, axes, p_)
            y_hat, _aux = pipeline_run(stage_fn, x_mb, axes, unroll=True)
            sse = jnp.sum(jnp.square(y_hat - y_mb))
            if axes.pp > 1:
                is_last = lax.axis_index(axes.pp_name) == axes.pp - 1
                sse = jnp.where(is_last, sse, jnp.float32(0))
            return sse / (global_batch * n)

        loss, (gp, gx) = jax.value_and_grad(loss_fn,
                                            argnums=(0, 1))(params, x)
        # the train step's reduction: dp grad sync, plus the pipe psum
        # that restores mixed-stage (pipe-replicated) subtree grads —
        # returned grads are the TRUE global gradients (the equivalence
        # suite compares them across meshes)
        red = (axes.dp_names if axes.dp > 1 else ()) \
            + (axes.pp_names if cfg.pipeline.mixed else ())
        if red:
            gp = jax.tree.map(lambda g: lax.psum(g, red), gp)
        return lax.psum(loss, axes.all_names), (gp, gx)

    pspecs = jax.tree.map(lambda s: resolve_spec(s, axes), specs(decls))
    bspec = resolve_spec(P("dp", "tp"), axes)
    fn = shard_map(probe, mesh=mesh, in_specs=(pspecs, bspec, bspec),
                   out_specs=(P(), (pspecs, bspec)), check_vma=False)
    return jax.jit(fn), decls


def measure_ffn_pipeline_step(cfg, mesh, global_batch: int, *,
                              steps: int = 0, seed: int = 0,
                              meter: Optional[StepMeter] = None
                              ) -> Tuple[dict, dict]:
    """Compile + analyze the pipelined FFN probe on a pp mesh; returns
    the ``(measured, predicted)`` ledger join, with the stage-boundary
    (collective-permute) wire bytes split out on BOTH sides so the
    pipeline_smoke suite can pin their ratio."""
    from repro.telemetry.predict import pipeline_ffn_step_prediction
    axes = MeshAxes.from_mesh(mesh)
    fn, decls = make_ffn_pipeline_probe_step(cfg, mesh, global_batch)
    n = cfg.ffn_width
    x_sds = jax.ShapeDtypeStruct((global_batch, n), jnp.float32)
    compiled = fn.lower(abstract(decls), x_sds, x_sds).compile()
    costs = analyze_compiled(compiled, default_group=axes.tp)
    measured = costs.measured_fields()
    measured["boundary_wire_bytes_per_device"] = (
        costs.collectives.get("collective-permute", {}).get("wire_bytes",
                                                            0.0))
    measured["collectives"] = {
        op: {"count": rec["count"], "wire_bytes": rec["wire_bytes"]}
        for op, rec in costs.collectives.items()}

    if steps > 0:
        meter = meter or StepMeter(f"ffn_pipe_probe_{cfg.name}", warmup=1)
        params = materialize(decls, seed)
        key = jax.random.PRNGKey(seed + 1)
        kx, ky = jax.random.split(key)
        x = jax.random.normal(kx, (global_batch, n), jnp.float32)
        y = jax.random.normal(ky, (global_batch, n), jnp.float32)
        for _ in range(steps + meter.warmup):
            meter.call(compiled, params, x, y)
        for k, v in meter.summary().items():
            if k != "name":
                measured[k] = v

    predicted = pipeline_ffn_step_prediction(
        cfg, axes.pp, axes.tp, axes.dp, global_batch, executed=True)
    return measured, predicted


def measure_ffn_step(cfg, mesh, global_batch: int, *, steps: int = 0,
                     seed: int = 0,
                     meter: Optional[StepMeter] = None
                     ) -> Tuple[dict, dict]:
    """Compile + analyze the FFN probe; run ``steps`` metered executions.

    Returns ``(measured, predicted)`` dicts ready for a LedgerEntry:
    measured carries the compiled-HLO flops / HBM / collective wire bytes
    (and wall stats when ``steps > 0``); predicted is
    ``ffn_step_prediction`` summed from the same strategy objects.
    """
    axes = MeshAxes.from_mesh(mesh)
    p = axes.tp
    fn, decls = make_ffn_probe_step(cfg, mesh, global_batch)
    n = cfg.ffn_width
    x_sds = jax.ShapeDtypeStruct((global_batch, n), jnp.float32)
    compiled = fn.lower(abstract(decls), x_sds, x_sds).compile()
    costs = analyze_compiled(compiled, default_group=p)
    measured = costs.measured_fields()
    measured["collectives"] = {
        op: {"count": rec["count"], "wire_bytes": rec["wire_bytes"]}
        for op, rec in costs.collectives.items()}

    if steps > 0:
        meter = meter or StepMeter(f"ffn_probe_{cfg.name}", warmup=1)
        params = materialize(decls, seed)
        key = jax.random.PRNGKey(seed + 1)
        kx, ky = jax.random.split(key)
        x = jax.random.normal(kx, (global_batch, n), jnp.float32)
        y = jax.random.normal(ky, (global_batch, n), jnp.float32)
        for _ in range(steps + meter.warmup):
            meter.call(compiled, params, x, y)
        for k, v in meter.summary().items():
            if k != "name":
                measured[k] = v

    predicted = ffn_step_prediction(cfg, p, global_batch, training=True)
    return measured, predicted
