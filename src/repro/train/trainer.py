"""Training step builder + training loop.

``make_train_step`` assembles the whole step — forward, backward,
spec-aware grad reduction, optional gradient-accumulation microbatching,
grad clipping, optimizer update — as ONE ``shard_map`` over the mesh with
explicit collectives (DESIGN.md §6), jit-compiled with donated state.

The ``Trainer`` adds the production loop around it: data pipeline,
checkpointing (async, elastic), fault tolerance hooks, and telemetry — a
``StepMeter`` wraps every executed step so wall time feeds the
measured-vs-predicted energy ledger (docs/energy_model.md) alongside
loss/throughput logging.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.model import (AUX_LOSS_WEIGHT, forward_train,
                                forward_train_pipeline, model_decls)
from repro.obs import get_metrics, get_tracer
from repro.parallel.axes import MeshAxes, resolve_spec
from repro.parallel.compat import shard_map
from repro.parallel.grads import reduce_grads
from repro.parallel.params import (ParamDecl, abstract, is_decl,
                                   materialize, specs)
from repro.telemetry import LedgerEntry, StepMeter, analyze_compiled
from repro.train.pipeline import PipelineSchedule  # noqa: F401 (re-export)


def _global_norm(grads, decls, axes: MeshAxes):
    """Spec-aware global grad norm: shard-local sq-sums weighted so every
    element is counted exactly once, psum'd over the full mesh."""
    from repro.parallel.grads import _spec_axes
    total = jnp.float32(0)
    for g, d in zip(jax.tree.leaves(grads),
                    jax.tree.leaves(decls, is_leaf=is_decl)):
        ax = _spec_axes(d.spec)
        repl = 1
        if "dp" not in ax:
            repl *= axes.dp
        if "tp" not in ax:
            repl *= axes.tp
        if "pp" not in ax:
            repl *= axes.pp
        total = total + jnp.sum(jnp.square(g.astype(jnp.float32))) / repl
    return jnp.sqrt(lax.psum(total, axes.all_names))


def make_train_step(cfg: ModelConfig, mesh, optimizer, *,
                    microbatches: int = 1, grad_clip: float = 1.0,
                    batch_spec=None):
    """Returns (jit step_fn(params, opt, step, batch) -> (params, opt,
    metrics), decls, opt_decls).

    On a mesh with a ``pipe`` axis the step runs the 1F1B pipeline:
    ``microbatches`` feeds the wavefront (stage-boundary ppermutes)
    instead of the sequential accumulation scan, layer stacks are
    pipe-sharded per stage, and the spec-aware grad reduction restores
    embed/head gradients across stages via the pipe psum."""
    axes = MeshAxes.from_mesh(mesh)
    decls = model_decls(cfg, axes)
    opt_decls = optimizer.state_decls(decls)
    pipelined = axes.pp > 1

    def loss_fn_pipeline(params, batch):
        # forward_train_pipeline masks loss/valid counts to the last pipe
        # rank and keeps aux stage-local, so each device still
        # differentiates its UNIQUE share of the global objective — the
        # pipe psums below only aggregate for reporting/normalization.
        # Normalization is GLOBAL per-token (sum over all microbatches /
        # global valid count) — the exact microbatches=1 objective.  The
        # accumulation path's mean-of-per-microbatch-means only differs
        # on ragged batches, where per-token weighting is the more
        # faithful objective, so the pipeline keeps it.
        sum_loss, n_valid, aux = forward_train_pipeline(
            cfg, axes, params, batch, microbatches)
        red = axes.pp_names + axes.dp_names
        nv_g = lax.psum(n_valid, red).astype(jnp.float32)
        nv_g = jnp.maximum(nv_g, 1.0)
        # aux is a per-microbatch MEAN summed over the M wavefront
        # microbatches — divide by M so the effective aux weight matches
        # the accumulation path (which averages grads over microbatches)
        mb = max(microbatches, 1)
        obj = (sum_loss / nv_g
               + AUX_LOSS_WEIGHT * aux / (axes.dp * mb)) / axes.tp
        ce_report = lax.psum(sum_loss, red) / nv_g
        return obj, ce_report

    def loss_fn(params, batch):
        sum_loss, n_valid, aux = forward_train(cfg, axes, params, batch)
        # Differentiate each device's UNIQUE share of the global objective:
        # psum-ing the scalar pre-grad would inflate grads by the device
        # count (psum's transpose under shard_map is psum).  The xent sum
        # is replicated across tp (every tp rank computes all local
        # tokens), hence the 1/tp; cross-dp sums happen in reduce_grads.
        nv_g = lax.psum(n_valid, axes.dp_names).astype(jnp.float32)
        nv_g = jnp.maximum(nv_g, 1.0)
        obj = (sum_loss / nv_g
               + AUX_LOSS_WEIGHT * aux / axes.dp) / axes.tp
        ce_report = lax.psum(sum_loss, axes.dp_names) / nv_g
        return obj, ce_report

    def step_fn(params, opt_state, step, batch):
        if pipelined:
            (total, ce), grads = jax.value_and_grad(
                loss_fn_pipeline, has_aux=True)(params, batch)
        elif microbatches == 1:
            (total, ce), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            from repro.train.pipeline import split_batch_microbatches
            mb_batch = split_batch_microbatches(batch, microbatches)

            def acc_body(carry, mb):
                g_acc, ce_acc = carry
                (_t, ce), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, ce_acc + ce), None

            g0 = jax.tree.map(lambda d: jnp.zeros(_local_shape(d, axes),
                                                  jnp.float32),
                              decls, is_leaf=is_decl)
            (grads, ce), _ = lax.scan(acc_body, (g0, jnp.float32(0)),
                                      mb_batch)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            ce = ce / microbatches

        grads = reduce_grads(grads, decls, axes)
        gnorm = _global_norm(grads, decls, axes)
        if grad_clip > 0:
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        params, opt_state = optimizer.update(grads, opt_state, params, step)
        return params, opt_state, {"loss": ce, "grad_norm": gnorm}

    pspecs = jax.tree.map(lambda s: resolve_spec(s, axes), specs(decls))
    ospecs = jax.tree.map(lambda s: resolve_spec(s, axes), specs(opt_decls))
    if batch_spec is None:
        batch_spec = P("dp", None)   # prefix spec: [B, S]-shaped leaves
    bspecs = jax.tree.map(lambda s: resolve_spec(s, axes), batch_spec,
                          is_leaf=lambda x: isinstance(x, P))

    sharded = shard_map(
        step_fn, mesh=mesh,
        in_specs=(pspecs, ospecs, P(), bspecs),
        out_specs=(pspecs, ospecs, P()),
        check_vma=False)
    return (jax.jit(sharded, donate_argnums=(0, 1)), decls, opt_decls)


def _local_shape(d: ParamDecl, axes: MeshAxes):
    shape = list(d.shape)
    for dim, e in enumerate(d.spec):
        if e is None:
            continue
        entries = e if isinstance(e, tuple) else (e,)
        f = 1
        for name in entries:
            f *= axes.tp if name == "tp" else axes.dp
        shape[dim] //= f
    return tuple(shape)


# ---------------------------------------------------------------------------
# production loop
# ---------------------------------------------------------------------------

@dataclass
class TrainState:
    params: object
    opt_state: object
    step: int


class Trainer:
    """Production training loop: data, checkpoints, fault tolerance."""

    def __init__(self, cfg: ModelConfig, mesh, optimizer, dataset, *,
                 microbatches: int = 1, grad_clip: float = 1.0,
                 batch_spec=None, checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 100, keep_checkpoints: int = 3,
                 log_every: int = 10, log_fn: Callable = print,
                 meter: Optional[StepMeter] = None, ledger=None,
                 straggler=None, restart_policy=None, watchdog=None):
        self.cfg, self.mesh, self.optimizer = cfg, mesh, optimizer
        self.dataset = dataset
        self.log_every, self.log_fn = log_every, log_fn
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.keep_checkpoints = keep_checkpoints
        self.meter = meter or StepMeter(f"train_{cfg.name}", warmup=1)
        self.ledger = ledger
        self.straggler = straggler          # StragglerDetector | None
        self.restart_policy = restart_policy  # RestartPolicy | None
        self.watchdog = watchdog            # EnergyDriftWatchdog | None
        self._ledger_window = 0
        self.step_fn, self.decls, self.opt_decls = make_train_step(
            cfg, mesh, optimizer, microbatches=microbatches,
            grad_clip=grad_clip, batch_spec=batch_spec)
        self._ckpt = None
        if checkpoint_dir:
            from repro.train.checkpoint import CheckpointManager
            self._ckpt = CheckpointManager(
                checkpoint_dir, keep=keep_checkpoints)

    def init_state(self, seed: int = 0) -> TrainState:
        params = materialize(self.decls, seed)
        return TrainState(params, self.optimizer.init(params), 0)

    def restore_or_init(self, seed: int = 0) -> TrainState:
        if self._ckpt is not None:
            restored = self._ckpt.restore_latest(self.decls, self.opt_decls,
                                                 self.mesh)
            if restored is not None:
                self.log_fn(f"[trainer] restored step {restored.step}")
                return restored
        return self.init_state(seed)

    def run(self, state: TrainState, num_steps: int) -> TrainState:
        from repro.train.fault import note_step_time
        params, opt_state = state.params, state.opt_state
        step = state.step
        losses = []
        axes = MeshAxes.from_mesh(self.mesh)
        impl = ("phantom" if self.cfg.uses_phantom_sites() else "dense")
        tracer = get_tracer()
        mx = get_metrics()
        steps_c = mx.counter("train_steps_total",
                             "executed training steps")
        step_h = mx.histogram("train_step_seconds",
                              "metered train step wall seconds")
        loss_g = mx.gauge("train_loss", "last observed training loss")
        run_span = tracer.begin("train/run", cat="train",
                                arch=self.cfg.name, impl=impl,
                                start_step=step, num_steps=num_steps)
        try:
            while step < num_steps:
                batch = self.dataset(step)
                with tracer.span("train/step", cat="train", step=step,
                                 arch=self.cfg.name):
                    if self.watchdog is not None and \
                            self.watchdog.capture_pending():
                        params, opt_state, metrics = self.watchdog.capture(
                            self.meter.call, self.step_fn, params,
                            opt_state, jnp.int32(step), batch)
                    else:
                        params, opt_state, metrics = self.meter.call(
                            self.step_fn, params, opt_state,
                            jnp.int32(step), batch)
                step += 1
                losses.append(metrics)
                dt_s = self.meter.times_us[-1] * 1e-6
                steps_c.inc(suite="trainer")
                step_h.observe(dt_s, suite="trainer")
                loss_g.set(float(metrics["loss"]), suite="trainer")
                if self.watchdog is not None:
                    # step already advanced: name the step that ran
                    self.watchdog.observe(step - 1, dt_s)
                # straggler wiring: a flagged slow step emits a ledger
                # event and may ask for an out-of-cadence checkpoint
                decision = note_step_time(
                    self.straggler, self.restart_policy, step,
                    self.meter.times_us[-1] * 1e-6, self.ledger,
                    name=f"straggler_{self.cfg.name}", arch=self.cfg.name,
                    impl=impl, p=axes.tp)
                if step % self.log_every == 0:
                    m = jax.tree.map(lambda *xs: float(sum(map(float, xs)))
                                     / len(xs), *losses)
                    recent = self.meter.times_us[-self.log_every:]
                    dt_ms = sum(recent) / len(recent) / 1e3
                    self.log_fn(
                        f"[trainer] step {step} loss {m['loss']:.4f} "
                        f"gnorm {m['grad_norm']:.3f} {dt_ms:.0f} ms/it")
                    losses = []
                if self._ckpt is not None and (
                        step % self.checkpoint_every == 0
                        or decision == "checkpoint"):
                    self._ckpt.save_async(step, params, opt_state)
        finally:
            # a crash mid-loop must not abandon a queued async save —
            # errors already in flight take precedence over flush errors
            if self._ckpt is not None:
                self._ckpt.flush(raise_errors=False)
            if self.ledger is not None:
                self.ledger.flush()
        if self._ckpt is not None:
            self._ckpt.flush()
        if self.ledger is not None:
            # link BEFORE end(): the event dict is copied at end time
            run_span.link_ledger(self.record_to(self.ledger))
        tracer.end(run_span.annotate(final_step=step))
        return TrainState(params, opt_state, step)

    # --- telemetry -------------------------------------------------------

    def measure_compiled(self, state: TrainState, batch):
        """Measured per-device costs (flops / HBM / collective wire
        bytes) of the lowered train step, for the energy ledger."""
        axes = MeshAxes.from_mesh(self.mesh)
        compiled = self.step_fn.lower(
            state.params, state.opt_state, jnp.int32(state.step),
            batch).compile()
        return analyze_compiled(compiled, default_group=axes.tp)

    def record_to(self, ledger, predicted=None, name=None,
                  measured_extra=None) -> "LedgerEntry":
        """Flush this trainer's metered steps to a Ledger.  Resets the
        meter so repeated ``run()`` calls record disjoint windows."""
        axes = MeshAxes.from_mesh(self.mesh)
        measured = self.meter.summary()
        if measured_extra:
            measured.update(measured_extra)
        impl = ("phantom" if self.cfg.uses_phantom_sites() else "dense")
        entry = ledger.record(LedgerEntry(
            name=name or f"train_{self.cfg.name}", suite="trainer",
            kind="train", arch=self.cfg.name, impl=impl, p=axes.tp,
            measured=measured, predicted=predicted,
            extra={"window": self._ledger_window, "pp": axes.pp,
                   "dp": axes.dp}))
        self.meter.reset(warm=True)
        self._ledger_window += 1
        return entry


# ---------------------------------------------------------------------------
# pilot runs (the planner's iso-loss measurements)
# ---------------------------------------------------------------------------

@dataclass
class PilotResult:
    """One small training run the planner fits loss curves from."""
    name: str
    strategy: str                  # projection kind at the planned site
    width: int
    tp: int
    k: int
    steps_run: int
    final_loss: float
    losses: list                   # per-step loss trajectory
    target_loss: Optional[float] = None
    iters_to_target: Optional[int] = None   # None = censored (never hit)
    wall_us_median: float = 0.0

    def as_dict(self) -> dict:
        return {"name": self.name, "strategy": self.strategy,
                "width": self.width, "tp": self.tp, "k": self.k,
                "steps_run": self.steps_run, "final_loss": self.final_loss,
                "target_loss": self.target_loss,
                "iters_to_target": self.iters_to_target,
                "wall_us_median": self.wall_us_median}


def pilot_ffn_run(cfg: ModelConfig, mesh, *, steps: int, batch: int,
                  target_loss: Optional[float] = None, lr: float = 3e-3,
                  seed: int = 0, ledger=None,
                  stop_at_target: bool = False) -> PilotResult:
    """Train a small paper-FFN on the Gaussian-teacher dataset and
    record the loss trajectory — the planner's quality measurement.

    Runs ``steps`` iterations, recording the FIRST step at which
    ``target_loss`` is reached (the measured ν the iso-loss frontier
    prices plans with) while continuing to the full budget so the final
    loss is comparable across pilots (``stop_at_target=True`` restores
    the cheap early-exit when only ν is wanted).  Every executed step
    is metered and the run lands in ``ledger`` (suite ``planner``) so
    pilot costs are auditable in the same report as everything else."""
    from repro.core.ffn import ffn_strategy, init_ffn, make_ffn_train_step
    from repro.data.synthetic import TeacherDataset
    from repro.optim import AdamW

    axes = MeshAxes.from_mesh(mesh)
    st = ffn_strategy(cfg, axes.tp)
    opt = AdamW(lr, weight_decay=0.0)
    step_fn, decls, _ = make_ffn_train_step(cfg, mesh, opt, batch)
    params, opt_state = init_ffn(cfg, mesh, opt, seed=seed)
    ds = TeacherDataset(cfg.ffn_width, batch, seed=seed)
    meter = StepMeter(f"pilot_{cfg.name}", warmup=1)

    losses = []
    iters_to_target = None
    pilot_span = get_tracer().begin(
        "plan/pilot", cat="plan", arch=cfg.name, strategy=st.kind,
        width=cfg.ffn_width, tp=axes.tp, k=getattr(st, "k", 0))
    for s in range(steps):
        x, y = ds(s)
        params, opt_state, loss = meter.call(
            step_fn, params, opt_state, jnp.int32(s), x, y)
        losses.append(float(loss))
        if target_loss is not None and iters_to_target is None \
                and losses[-1] <= target_loss:
            iters_to_target = s + 1
            if stop_at_target:
                break
    get_metrics().counter("plan_pilot_steps_total",
                          "training steps spent in planner pilots").inc(
                              len(losses), arch=cfg.name)

    res = PilotResult(
        name=f"pilot_{cfg.name}", strategy=st.kind, width=cfg.ffn_width,
        tp=axes.tp, k=getattr(st, "k", 0), steps_run=len(losses),
        final_loss=losses[-1] if losses else float("nan"), losses=losses,
        target_loss=target_loss, iters_to_target=iters_to_target,
        wall_us_median=meter.median_us())
    if ledger is not None:
        pilot_span.link_ledger(ledger.record(LedgerEntry(
            name=res.name, suite="planner", kind="pilot", arch=cfg.name,
            impl=st.kind, p=axes.tp, measured=dict(
                meter.summary(), final_loss=res.final_loss,
                iterations=iters_to_target or len(losses)),
            extra={"width": res.width, "k": res.k,
                   "target_loss": target_loss,
                   "censored": iters_to_target is None})))
    get_tracer().end(pilot_span.annotate(
        steps_run=res.steps_run, final_loss=res.final_loss,
        iters_to_target=iters_to_target))
    return res
