"""Fault tolerance & elasticity for 1000+-node runs.

What a real multi-pod deployment needs, and what this module provides:

1. **Checkpoint/restart** — delegated to ``CheckpointManager`` (atomic
   commits, the `latest`-is-always-complete invariant, corrupt-checkpoint
   fallback, truly-async writes with a flush-on-exit guarantee).  The
   Trainer checkpoints every N steps; on restart, ``restore_or_init``
   resumes bit-exact (tested).

2. **Failure detection** — ``Heartbeat``: every worker bumps a per-host
   counter file (on real clusters: etcd/GCS object or jax coordination
   service KV); the elected monitor declares hosts dead after
   ``timeout_s`` and triggers a restart-from-checkpoint with the surviving
   host set.  Single-process containers exercise the same code path via
   ``SimulatedCluster``; with ``virtual=True`` the cluster runs on a
   manually-advanced ``VirtualClock`` so fault-injection tests are
   deterministic and sleep-free.

3. **Straggler mitigation** — ``StragglerDetector``: tracks per-step wall
   times; a step slower than ``threshold x`` the trailing median marks the
   step (on TPU pods the usual culprits are a host in thermal throttle or
   an input-pipeline stall).  ``note_step_time`` is the wiring every
   metered loop (Trainer, elastic runner) calls: a flagged straggler
   emits a ledger event (kind ``fault``) and asks the ``RestartPolicy``
   for a decision — checkpoint-now by default, so a wounded run leaves a
   fresh restore point before it degrades further.

4. **Elastic rescale** — checkpoints store GLOBAL arrays + logical specs,
   so restore works on a different device count; ``train/elastic.py``
   goes further and RE-PLANS dp×tp×pp×k for the survivors (including the
   paper-sanctioned downsize onto a phantom plan), converting the host
   tree across model classes when the re-planned strategy differs.
   ``FaultScript`` injects deterministic device-loss events into the
   simulated cluster (the fault-injection campaign's driver).
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


class VirtualClock:
    """Manually-advanced clock for deterministic fault tests."""

    def __init__(self, t0: float = 0.0):
        self.t = t0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float):
        self.t += dt


class Heartbeat:
    """File-based heartbeat registry (stand-in for etcd/coordination-KV).

    ``clock`` is injectable (``VirtualClock`` in tests) so liveness is a
    pure function of recorded beats, not wall-time sleeps."""

    def __init__(self, directory: str, host_id: str, timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.time):
        self.dir = directory
        self.host_id = host_id
        self.timeout_s = timeout_s
        self.clock = clock
        os.makedirs(directory, exist_ok=True)

    def beat(self, step: int):
        path = os.path.join(self.dir, f"{self.host_id}.hb")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"t": self.clock(), "step": step}, f)
        os.replace(tmp, path)

    def alive_hosts(self) -> Dict[str, dict]:
        now = self.clock()
        out = {}
        for name in os.listdir(self.dir):
            if not name.endswith(".hb"):
                continue
            try:
                with open(os.path.join(self.dir, name)) as f:
                    rec = json.load(f)
            except (json.JSONDecodeError, OSError):
                continue
            if now - rec["t"] <= self.timeout_s:
                out[name[:-3]] = rec
        return out

    def dead_hosts(self, expected: List[str]) -> List[str]:
        alive = self.alive_hosts()
        return [h for h in expected if h not in alive]


@dataclass
class StragglerDetector:
    """Flags steps slower than `threshold` x trailing median."""
    window: int = 50
    threshold: float = 2.0
    _times: list = field(default_factory=list)
    flagged: list = field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        hist = self._times[-self.window:]
        is_straggler = False
        if len(hist) >= 10:
            med = sorted(hist)[len(hist) // 2]
            if dt > self.threshold * med:
                is_straggler = True
                self.flagged.append((step, dt, med))
        self._times.append(dt)
        return is_straggler


@dataclass
class RestartPolicy:
    """What the monitor does when a failure/straggler fires."""
    max_restarts: int = 100
    checkpoint_on_straggler: bool = True
    restarts: int = 0

    def on_host_failure(self, dead: List[str], trainer) -> str:
        self.restarts += 1
        if self.restarts > self.max_restarts:
            return "abort"
        # real deployment: re-launch jax.distributed with survivors and a
        # (possibly smaller) mesh; here: restore-from-checkpoint.
        return "restore"

    def on_straggler(self, step: int, dt: float,
                     median: Optional[float] = None) -> str:
        """A straggler is a warning, not a failure: it does not consume
        the restart budget.  Checkpoint-now (the default) banks a restore
        point while the run is still healthy enough to produce one."""
        return "checkpoint" if self.checkpoint_on_straggler else "log"


def note_step_time(detector: Optional[StragglerDetector],
                   policy: Optional[RestartPolicy], step: int, dt_s: float,
                   ledger=None, *, name: str = "straggler", arch: str = "",
                   impl: str = "", p: int = 0) -> Optional[str]:
    """The metered-loop straggler wiring (Trainer + elastic runner).

    Records the step time; when the detector flags a straggler, emits a
    ledger event (kind ``fault``) and returns the policy's decision
    (``checkpoint`` | ``log``) for the caller to act on.  Returns None
    on healthy steps or when no detector is installed."""
    if detector is None or not detector.record(step, dt_s):
        return None
    _, _, median = detector.flagged[-1]
    decision = (policy.on_straggler(step, dt_s, median)
                if policy is not None else "log")
    from repro.obs import get_metrics, get_tracer
    get_metrics().counter(
        "straggler_events_total",
        "steps flagged slower than threshold x trailing median").inc(
            decision=decision)
    get_tracer().instant(
        "fault/straggler", cat="fault", step=step, dt_s=dt_s,
        median_s=median, decision=decision)
    if ledger is not None:
        from repro.telemetry import LedgerEntry
        ledger.record(LedgerEntry(
            name=f"{name}_step{step}", suite="fault", kind="fault",
            arch=arch, impl=impl, p=p,
            measured={"step": step, "dt_s": dt_s, "median_s": median,
                      "slowdown": dt_s / median if median else 0.0},
            extra={"event": "straggler", "decision": decision,
                   "threshold": detector.threshold}))
    return decision


@dataclass(frozen=True)
class FaultScript:
    """Deterministic device-loss injection: ``kills`` is a tuple of
    ``(step, host)`` pairs — at the start of ``step``, ``host`` stops
    heartbeating.  The monitor then detects the loss after the heartbeat
    timeout elapses (virtual clock: timeout_s / dt ticks later), which is
    exactly the detection lag a real deployment pays."""
    kills: Tuple[Tuple[int, str], ...] = ()

    def hosts_at(self, step: int) -> List[str]:
        return [h for s, h in self.kills if s == step]

    @property
    def kill_steps(self) -> List[int]:
        return sorted({s for s, _ in self.kills})


class SimulatedCluster:
    """Drives the fault path in a single process (used by tests):
    N simulated hosts heartbeat; killing one makes the monitor restore.

    ``virtual=True`` gives the cluster a ``VirtualClock`` shared by all
    heartbeats — ``advance(dt)`` moves simulated time, so a killed
    host's staleness (and hence detection latency) is deterministic."""

    def __init__(self, tmpdir: str, hosts: int = 4, timeout_s: float = 0.5,
                 virtual: bool = False):
        self.clock: Callable[[], float] = (VirtualClock() if virtual
                                           else time.time)
        self.hosts = [f"host{i}" for i in range(hosts)]
        self.hbs = {h: Heartbeat(tmpdir, h, timeout_s, clock=self.clock)
                    for h in self.hosts}
        self.monitor = Heartbeat(tmpdir, "monitor", timeout_s,
                                 clock=self.clock)
        self.killed = set()

    def tick(self, step: int):
        for h, hb in self.hbs.items():
            if h not in self.killed:
                hb.beat(step)

    def advance(self, dt: float):
        if isinstance(self.clock, VirtualClock):
            self.clock.advance(dt)

    def kill(self, host: str):
        self.killed.add(host)

    def check(self) -> List[str]:
        return self.monitor.dead_hosts(self.hosts)
