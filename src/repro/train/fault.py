"""Fault tolerance & elasticity for 1000+-node runs.

What a real multi-pod deployment needs, and what this module provides:

1. **Checkpoint/restart** — delegated to ``CheckpointManager`` (atomic
   commits, corrupt-checkpoint fallback, async writes).  The Trainer
   checkpoints every N steps; on restart, ``restore_or_init`` resumes
   bit-exact (tested).

2. **Failure detection** — ``Heartbeat``: every worker bumps a per-host
   counter file (on real clusters: etcd/GCS object or jax coordination
   service KV); the elected monitor declares hosts dead after
   ``timeout_s`` and triggers a restart-from-checkpoint with the surviving
   host set.  Single-process containers exercise the same code path via
   ``SimulatedCluster`` (tests/test_fault.py kills simulated hosts).

3. **Straggler mitigation** — ``StragglerDetector``: tracks per-step wall
   times; a step slower than ``threshold x`` the trailing median marks the
   step (on TPU pods the usual culprits are a host in thermal throttle or
   an input-pipeline stall).  Policy hooks: log / checkpoint-now /
   request-elastic-reshard.  Detection is cheap (host-side timestamps
   around the donated step call, which blocks on the previous step's
   completion — the jax dispatch model makes per-step host timing a good
   proxy at scale).

4. **Elastic rescale** — checkpoints store GLOBAL arrays + logical specs,
   so restore works on a different device count (e.g. drop from 2 pods to
   1 after a pod loss, halving `dp`): ``CheckpointManager.restore`` simply
   device_puts onto the new mesh's NamedShardings.  Batch schedule
   adjusts: global batch stays fixed, per-device batch doubles (or
   gradient accumulation doubles when memory-bound).
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


class Heartbeat:
    """File-based heartbeat registry (stand-in for etcd/coordination-KV)."""

    def __init__(self, directory: str, host_id: str, timeout_s: float = 60.0):
        self.dir = directory
        self.host_id = host_id
        self.timeout_s = timeout_s
        os.makedirs(directory, exist_ok=True)

    def beat(self, step: int):
        path = os.path.join(self.dir, f"{self.host_id}.hb")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"t": time.time(), "step": step}, f)
        os.replace(tmp, path)

    def alive_hosts(self) -> Dict[str, dict]:
        now = time.time()
        out = {}
        for name in os.listdir(self.dir):
            if not name.endswith(".hb"):
                continue
            try:
                with open(os.path.join(self.dir, name)) as f:
                    rec = json.load(f)
            except (json.JSONDecodeError, OSError):
                continue
            if now - rec["t"] <= self.timeout_s:
                out[name[:-3]] = rec
        return out

    def dead_hosts(self, expected: List[str]) -> List[str]:
        alive = self.alive_hosts()
        return [h for h in expected if h not in alive]


@dataclass
class StragglerDetector:
    """Flags steps slower than `threshold` x trailing median."""
    window: int = 50
    threshold: float = 2.0
    _times: list = field(default_factory=list)
    flagged: list = field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        hist = self._times[-self.window:]
        is_straggler = False
        if len(hist) >= 10:
            med = sorted(hist)[len(hist) // 2]
            if dt > self.threshold * med:
                is_straggler = True
                self.flagged.append((step, dt, med))
        self._times.append(dt)
        return is_straggler


@dataclass
class RestartPolicy:
    """What the monitor does when a failure/straggler fires."""
    max_restarts: int = 100
    restarts: int = 0

    def on_host_failure(self, dead: List[str], trainer) -> str:
        self.restarts += 1
        if self.restarts > self.max_restarts:
            return "abort"
        # real deployment: re-launch jax.distributed with survivors and a
        # (possibly smaller) mesh; here: restore-from-checkpoint.
        return "restore"


class SimulatedCluster:
    """Drives the fault path in a single process (used by tests):
    N simulated hosts heartbeat; killing one makes the monitor restore."""

    def __init__(self, tmpdir: str, hosts: int = 4, timeout_s: float = 0.5):
        self.hosts = [f"host{i}" for i in range(hosts)]
        self.hbs = {h: Heartbeat(tmpdir, h, timeout_s) for h in self.hosts}
        self.monitor = Heartbeat(tmpdir, "monitor", timeout_s)
        self.killed = set()

    def tick(self, step: int):
        for h, hb in self.hbs.items():
            if h not in self.killed:
                hb.beat(step)

    def kill(self, host: str):
        self.killed.add(host)

    def check(self) -> List[str]:
        return self.monitor.dead_hosts(self.hosts)
