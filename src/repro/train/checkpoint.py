"""Checkpointing: sharded, asynchronous, elastic.

No orbax/tensorstore in this container, so the manager is self-contained:

* **Sharded save** — each param leaf is written as a .npy blob under a
  step directory, with a JSON index holding the pytree structure, global
  shapes and an optional caller-supplied ``meta`` block (the elastic
  runtime stores the executing plan there so restore knows the model
  class it is converting FROM).
* **Async** — device->host transfer happens on the caller thread
  (cheap), file IO on a single serial background worker; ``save_async``
  returns immediately and ``flush()`` (aliased ``wait()``) joins every
  pending write.  A process-exit hook flushes all live managers, so a
  trainer that crashes out of its loop never abandons a queued save.
* **Atomic commits + the ``latest`` invariant** — a save writes to
  ``step_N.tmp``, places the ``COMMITTED`` marker last, renames the
  directory, and only THEN atomically updates the ``latest`` pointer
  file.  ``latest`` therefore always names a complete checkpoint: a
  crash mid-write leaves a ``.tmp`` orphan (swept on the next manager
  construction) and an untouched ``latest``.  ``_gc`` runs after the
  commit, never deletes the ``latest`` target, and keeps the newest
  ``keep`` complete checkpoints.
* **Elastic restore** — blobs store GLOBAL arrays, so restore works on
  any mesh shape/device count: arrays are re-sharded by device_put with
  the target mesh's NamedSharding.  ``load_host`` exposes the raw host
  tree for model-class conversion (``train/elastic.py``).
* **Fault tolerance** — ``restore_latest`` prefers the ``latest``
  pointer, skips corrupt/partial checkpoints and falls back to the
  previous one.  ``invalidate_after(step)`` truncates checkpoints from
  an abandoned timeline after an elastic restore (without it, a later
  crash would resume from post-fault state that was never trained
  through).
* **IO accounting** — ``io_stats()`` reports cumulative write seconds /
  bytes / save count, the measured side of the recovery energy account
  (``telemetry.recovery_account``).
"""
from __future__ import annotations

import atexit
import json
import os
import queue
import shutil
import threading
import time
import weakref
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.axes import MeshAxes, resolve_spec
from repro.parallel.params import is_decl

_LATEST = "latest"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


# all live managers, flushed once at interpreter exit so a queued save
# can never be lost to the daemon worker dying with the process
_MANAGERS: "weakref.WeakSet" = weakref.WeakSet()


@atexit.register
def _flush_all_managers():
    for mgr in list(_MANAGERS):
        try:
            mgr.flush(raise_errors=False)
        except Exception:
            pass


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._queue: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._errors: list = []
        self.io_seconds = 0.0
        self.io_bytes = 0
        self.saves = 0
        self._sweep_orphans()
        _MANAGERS.add(self)

    # ----------------------------------------------------------------- save
    def save_async(self, step: int, params, opt_state, extra=None,
                   meta: Optional[dict] = None):
        """Snapshot to host NOW (so donated device buffers are safe to
        reuse), then enqueue the file write — returns without blocking
        on IO.  Writes are serialized on one background worker, so a
        fast save cadence can queue several steps; ``flush()`` joins
        them all."""
        host = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                            {"params": params, "opt": opt_state,
                             "extra": extra if extra is not None else {}})
        self._ensure_worker()
        self._queue.put((step, host, dict(meta or {})))

    def save(self, step: int, params, opt_state, extra=None,
             meta: Optional[dict] = None):
        self.save_async(step, params, opt_state, extra, meta)
        self.flush()

    def _ensure_worker(self):
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(
                target=self._worker_loop, daemon=True,
                name=f"ckpt-writer:{self.dir}")
            self._worker.start()

    def _worker_loop(self):
        while True:
            job = self._queue.get()
            try:
                self._write(*job)
            except Exception as exc:    # surfaced at the next flush()
                self._errors.append(exc)
            finally:
                self._queue.task_done()

    def _write(self, step: int, host_tree, meta: dict):
        from repro.obs import get_metrics, get_tracer
        t0 = time.perf_counter()
        # emitted from the writer thread: the span lands on its own
        # trace row, showing save IO overlapping the training steps
        span = get_tracer().begin("ckpt/save", cat="ckpt", step=step)
        tmp = os.path.join(self.dir, f"step_{step:010d}.tmp")
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat, _ = _flatten_with_paths(host_tree)
        index = {"step": step, "leaves": {}, "meta": meta}
        nbytes = 0
        for i, (key, leaf) in enumerate(flat):
            fn = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fn), leaf)
            nbytes += leaf.nbytes
            index["leaves"][key] = {"file": fn,
                                    "shape": list(leaf.shape),
                                    "dtype": str(leaf.dtype)}
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
        # marker written LAST: its presence == checkpoint is complete
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write(str(step))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        # `latest` moves only AFTER the rename — it always names a
        # complete checkpoint, and _gc never collects its target
        self._set_latest(step)
        self._gc()
        dt = time.perf_counter() - t0
        self.io_seconds += dt
        self.io_bytes += nbytes
        self.saves += 1
        get_tracer().end(span.annotate(bytes=nbytes))
        get_metrics().counter("ckpt_saves_total",
                              "committed checkpoint saves").inc()
        get_metrics().counter("ckpt_bytes_total",
                              "bytes written by checkpoint saves").inc(
                                  nbytes)
        get_metrics().histogram("ckpt_write_seconds",
                                "checkpoint write wall seconds").observe(
                                    dt)

    def flush(self, raise_errors: bool = True):
        """Join every pending write.  Write errors collected by the
        worker are raised here (the save itself is non-blocking, so this
        is the first point the caller can observe them)."""
        self._queue.join()
        if self._errors and raise_errors:
            exc, self._errors = self._errors[0], []
            raise exc

    # back-compat alias (seed-era API)
    def wait(self):
        self.flush()

    def close(self):
        self.flush()

    def __enter__(self):
        return self

    def __exit__(self, *exc_info):
        self.flush(raise_errors=exc_info[0] is None)
        return False

    def io_stats(self) -> dict:
        return {"io_seconds": self.io_seconds, "io_bytes": self.io_bytes,
                "saves": self.saves}

    # ----------------------------------------------------- latest & hygiene
    def _set_latest(self, step: int):
        tmp = os.path.join(self.dir, _LATEST + ".tmp")
        with open(tmp, "w") as f:
            f.write(str(step))
        os.replace(tmp, os.path.join(self.dir, _LATEST))

    def latest_step(self) -> Optional[int]:
        """The step the ``latest`` pointer names, verified complete;
        falls back to the newest COMMITTED directory."""
        path = os.path.join(self.dir, _LATEST)
        if os.path.exists(path):
            try:
                with open(path) as f:
                    step = int(f.read().strip())
                marker = os.path.join(self.dir, f"step_{step:010d}",
                                      "COMMITTED")
                if os.path.exists(marker):
                    return step
            except (ValueError, OSError):
                pass
        steps = self.available_steps()
        return steps[-1] if steps else None

    def _sweep_orphans(self):
        """Remove torn ``.tmp`` partials (crash mid-write) and repair a
        ``latest`` pointer naming a missing/incomplete checkpoint."""
        for name in os.listdir(self.dir):
            if name.startswith("step_") and name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)
        path = os.path.join(self.dir, _LATEST)
        if os.path.exists(path):
            try:
                with open(path) as f:
                    step = int(f.read().strip())
                ok = os.path.exists(os.path.join(
                    self.dir, f"step_{step:010d}", "COMMITTED"))
            except (ValueError, OSError):
                ok = False
            if not ok:
                steps = self.available_steps()
                if steps:
                    self._set_latest(steps[-1])
                else:
                    os.remove(path)

    def invalidate_after(self, step: int):
        """Drop checkpoints with step > ``step`` — the stale timeline
        left behind when an elastic restore rewinds training.  Joins
        pending writes first so an in-flight save of abandoned state
        cannot commit afterwards."""
        self.flush(raise_errors=False)
        for s in self.available_steps():
            if s > step:
                shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                              ignore_errors=True)
        remaining = self.available_steps()
        path = os.path.join(self.dir, _LATEST)
        if remaining:
            self._set_latest(remaining[-1])
        elif os.path.exists(path):
            os.remove(path)

    def _gc(self):
        steps = self.available_steps()
        latest = self.latest_step()
        for s in steps[:-self.keep]:
            if s == latest:
                continue
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def available_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            if os.path.exists(os.path.join(self.dir, name, "COMMITTED")):
                out.append(int(name.split("_")[1]))
        return out

    def load_host(self, step: int):
        """Raw access: ``(index, {key: np.ndarray})`` with keys the
        ``/``-joined tree paths.  The elastic runtime converts this host
        tree across model classes before placing it on the new mesh."""
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "index.json")) as f:
            index = json.load(f)
        leaves = {key: np.load(os.path.join(path, rec["file"]))
                  for key, rec in index["leaves"].items()}
        return index, leaves

    def meta(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step:010d}",
                               "index.json")) as f:
            return json.load(f).get("meta", {})

    def restore(self, step: int, decls, opt_decls, mesh=None):
        """Rebuild (TrainState-like) from a step dir; reshards to `mesh`
        (elastic: any device count)."""
        from repro.obs import get_tracer
        with get_tracer().span("ckpt/restore", cat="ckpt", step=step):
            index, leaves = self.load_host(step)
            skeleton = {"params": decls, "opt": opt_decls, "extra": {}}
            flat, treedef = _flatten_with_paths(skeleton)
            placed = [self._place(leaves[key], decl, mesh)
                      for key, decl in flat]
            tree = jax.tree_util.tree_unflatten(treedef, placed)
        from repro.train.trainer import TrainState
        return TrainState(tree["params"], tree["opt"], step)

    def restore_latest(self, decls, opt_decls, mesh=None):
        steps = self.available_steps()
        latest = self.latest_step()
        order = ([latest] if latest is not None else []) \
            + [s for s in reversed(steps) if s != latest]
        for step in order:
            try:
                return self.restore(step, decls, opt_decls, mesh)
            except Exception as e:  # corrupt checkpoint: fall back
                print(f"[checkpoint] step {step} unreadable ({e}); "
                      f"falling back")
        return None

    def _place(self, arr, decl, mesh):
        if mesh is None:
            return jnp.asarray(arr)
        axes = MeshAxes.from_mesh(mesh)
        spec = resolve_spec(decl.spec, axes) if is_decl(decl) else None
        if spec is None:
            return jnp.asarray(arr)
        from jax.sharding import NamedSharding
        return jax.device_put(arr, NamedSharding(mesh, spec))
