"""Checkpointing: sharded, asynchronous, elastic.

No orbax/tensorstore in this container, so the manager is self-contained:

* **Sharded save** — each param leaf is written as a .npy blob under a
  step directory, with an index (msgpack if available, else JSON) holding
  the pytree structure, global shapes and logical PartitionSpecs.
* **Async** — device->host transfer happens on the caller thread (cheap),
  file IO on a background thread; ``wait()`` joins before exit.  A save is
  atomic: written to ``step_N.tmp`` then renamed.
* **Elastic restore** — blobs store GLOBAL arrays, so restore works on any
  mesh shape/device count: arrays are re-sharded by device_put with the
  target mesh's NamedSharding (tested by tests/test_checkpoint.py with
  save-on-(2,4) -> restore-on-(1,2)).
* **Fault tolerance** — ``restore_latest`` skips corrupt/partial
  checkpoints (crash mid-save) and falls back to the previous one.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.axes import MeshAxes, resolve_spec
from repro.parallel.params import is_decl, specs as decl_specs


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ----------------------------------------------------------------- save
    def save_async(self, step: int, params, opt_state, extra=None):
        """Snapshot to host, then write on a background thread."""
        self.wait()
        host = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                            {"params": params, "opt": opt_state,
                             "extra": extra if extra is not None else {}})
        self._thread = threading.Thread(
            target=self._write, args=(step, host), daemon=True)
        self._thread.start()

    def save(self, step: int, params, opt_state, extra=None):
        self.save_async(step, params, opt_state, extra)
        self.wait()

    def _write(self, step: int, host_tree):
        tmp = os.path.join(self.dir, f"step_{step:010d}.tmp")
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat, _ = _flatten_with_paths(host_tree)
        index = {"step": step, "leaves": {}}
        for i, (key, leaf) in enumerate(flat):
            fn = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fn), leaf)
            index["leaves"][key] = {"file": fn,
                                    "shape": list(leaf.shape),
                                    "dtype": str(leaf.dtype)}
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
        # marker written LAST: its presence == checkpoint is complete
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write(str(step))
        os.replace(tmp, final)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.available_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def available_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            if os.path.exists(os.path.join(self.dir, name, "COMMITTED")):
                out.append(int(name.split("_")[1]))
        return out

    def restore(self, step: int, decls, opt_decls, mesh=None):
        """Rebuild (TrainState-like) from a step dir; reshards to `mesh`
        (elastic: any device count)."""
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "index.json")) as f:
            index = json.load(f)
        skeleton = {"params": decls, "opt": opt_decls, "extra": {}}
        flat, treedef = _flatten_with_paths(skeleton)
        leaves = []
        for key, decl in flat:
            meta = index["leaves"][key]
            arr = np.load(os.path.join(path, meta["file"]))
            leaves.append(self._place(arr, decl, mesh))
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        from repro.train.trainer import TrainState
        return TrainState(tree["params"], tree["opt"], step)

    def restore_latest(self, decls, opt_decls, mesh=None):
        for step in reversed(self.available_steps()):
            try:
                return self.restore(step, decls, opt_decls, mesh)
            except Exception as e:  # corrupt checkpoint: fall back
                print(f"[checkpoint] step {step} unreadable ({e}); "
                      f"falling back")
        return None

    def _place(self, arr, decl, mesh):
        if mesh is None:
            return jnp.asarray(arr)
        axes = MeshAxes.from_mesh(mesh)
        spec = resolve_spec(decl.spec, axes) if is_decl(decl) else None
        if spec is None:
            return jnp.asarray(arr)
        from jax.sharding import NamedSharding
        return jax.device_put(arr, NamedSharding(mesh, spec))
