"""Elastic fault-tolerant training with energy-aware re-planning.

The failure loop the paper's deployment story needs (ROADMAP: the last
seed-stub subsystem), on the paper-FFN subject:

1. A ``SimulatedCluster`` of N hosts heartbeats on a virtual clock while
   the metered step loop trains toward a target loss, checkpointing
   asynchronously on a step cadence (``CheckpointManager`` — atomic
   commits, ``latest``-is-always-complete).
2. On detected device loss the runner flushes pending saves, asks the
   ``RestartPolicy`` for a decision, and RE-SOLVES dp×tp×pp×k for the
   surviving device count with the calibrated energy planner
   (``enumerate_plans`` → HBM filter → ``score_plans`` → sort by total
   energy; tensor pins to the full surviving budget, phantom may
   downsize further).  The winning plan must
   pass the PR-6 static sharding/energy audit before anything executes
   — an un-priceable mesh is rejected and the next-cheapest tried.
3. Training resumes from the latest complete checkpoint on the new
   mesh.  Checkpoints hold GLOBAL host arrays, so a same-model-class
   re-plan (dense→dense on any mesh; phantom→phantom at the same
   (k, tp)) restores EXACTLY — flat [L, ...] stacks and pipelined
   [S, L/S, ...] stage stacks are pure reshapes of each other.  A
   model-CLASS change — the paper-sanctioned downsize from tensor onto
   a phantom plan with fewer devices — reconstructs each layer's dense
   equivalent and re-factors it through the truncated-SVD phantom
   initializer (``core/lowrank.svd_phantom_init``, the lowrank-distill
   path); optimizer moments cannot survive a class change and restart
   at zero (a priced recovery cost: the replayed-step count covers the
   re-warming iterations).
4. Every recovery is priced first-class: ``telemetry.recovery_account``
   joins the calibrated per-iteration step energy (useful vs replayed)
   with checkpoint IO and restart time (restore + re-plan + compile,
   charged at static power B across the waiting devices), and the run
   lands in the ledger (kind ``elastic``) with the account in its
   ``extra`` — the BENCH_report.json columns the elastic smoke suite
   and CI band-check.

``python -m repro.launch.train --elastic --kill-at-step N`` drives this
loop from the CLI; ``benchmarks/elastic_smoke.py`` asserts the
replay-overhead ratio band end-to-end.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PHANTOM_KINDS
from repro.obs import get_metrics, get_tracer
from repro.parallel.axes import MeshAxes, resolve_spec
from repro.planner.space import PlanCandidate
from repro.telemetry import LedgerEntry, StepMeter, recovery_account
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import (FaultScript, RestartPolicy,
                               SimulatedCluster, StragglerDetector,
                               note_step_time)


# ---------------------------------------------------------------------------
# configuration & results
# ---------------------------------------------------------------------------

@dataclass
class ElasticConfig:
    """One elastic training run (paper-FFN teacher-matching subject)."""
    workdir: str                    # checkpoint + heartbeat directories
    devices: int = 8                # full-fleet device budget
    hosts: int = 4                  # simulated hosts (devices % hosts == 0)
    width: int = 256                # FFN width n (fixed across re-plans)
    depth: int = 2                  # layers L
    batch: int = 64                 # global rows per step
    target_loss: float = 0.05
    max_steps: int = 300
    checkpoint_every: int = 10
    keep_checkpoints: int = 3
    strategies: Tuple[str, ...] = ("tensor_col", "phantom")
    initial_strategy: Optional[str] = None   # pin phase-0 family
    ks: Tuple[int, ...] = (4, 8, 16)
    pps: Tuple[int, ...] = (1,)
    hbm_gb: float = 16.0
    lr: float = 3e-3
    seed: int = 0
    max_restarts: int = 4
    heartbeat_timeout_s: float = 2.5   # virtual seconds
    virtual_dt: float = 1.0            # virtual seconds per step
    audit_replan: bool = True          # PR-6 static audit gate
    straggler_window: int = 50
    straggler_threshold: float = 4.0
    # watchdog fixtures: sleep inside the metered call at these steps so
    # the step runs ~slow_factor x its healthy wall time (the injected
    # anomaly the energy-drift watchdog must trip on)
    slow_steps: Tuple[int, ...] = ()
    slow_factor: float = 6.0


@dataclass
class ElasticResult:
    reached_target: bool
    aborted: bool
    final_loss: float
    final_step: int
    phases: List[dict]
    recoveries: List[dict]
    account: dict
    plan_names: List[str] = field(default_factory=list)
    losses: List[float] = field(default_factory=list)

    def as_dict(self) -> dict:
        return {"reached_target": self.reached_target,
                "aborted": self.aborted, "final_loss": self.final_loss,
                "final_step": self.final_step, "phases": self.phases,
                "recoveries": self.recoveries, "account": self.account,
                "plan_names": self.plan_names}


# ---------------------------------------------------------------------------
# energy-aware re-planning (with the PR-6 static audit gate)
# ---------------------------------------------------------------------------

def plan_from_dict(d: dict) -> PlanCandidate:
    """Rebuild the checkpoint-meta plan record (``PlanCandidate.
    as_dict``) — restore needs the class it is converting FROM."""
    return PlanCandidate(
        dp=int(d["dp"]), tp=int(d["tp"]), strategy=d["strategy"],
        width=int(d["width"]), depth=int(d["depth"]),
        batch=int(d["batch"]), k=int(d.get("k", 0)),
        pp=int(d.get("pp", 1)), site=d.get("site", "ffn_layer"),
        microbatches=int(d.get("microbatches", 1)))


def solve_plan(device_budget: int, cfg: ElasticConfig, calib, *,
               strategies: Optional[Sequence[str]] = None,
               audit: Optional[bool] = None,
               mesh_cache: Optional[dict] = None):
    """Re-solve dp×tp×pp×k for ``device_budget`` devices.

    The enumeration keeps the planner's family semantics: tensor plans
    pin to the FULL surviving budget (idling paid-for devices under the
    baseline would make every comparison trivially winnable), while
    phantom plans may downsize further — the paper-sanctioned "fewer
    devices at the same loss" option.  Candidates are filtered for HBM
    fit, priced with the calibrated model, and the energy-sorted list
    is walked until one passes the static audit (skipped when ``audit``
    is off).  Returns ``(ScoredPlan, audit_results)``; raises
    RuntimeError when no plan survives."""
    from repro.planner import (Constraints, enumerate_plans,
                               filter_feasible, score_plans)
    audit = cfg.audit_replan if audit is None else audit
    candidates = enumerate_plans(
        device_budget, width=cfg.width, depth=cfg.depth, batch=cfg.batch,
        strategies=tuple(strategies or cfg.strategies), ks=cfg.ks,
        pps=cfg.pps)
    feasible, _rej = filter_feasible(candidates, Constraints(
        max_devices=device_budget,
        hbm_bytes_per_device=cfg.hbm_gb * 2 ** 30))
    if not feasible:
        raise RuntimeError(
            f"no feasible plan for {device_budget} device(s) "
            f"(width={cfg.width}, strategies={cfg.strategies})")
    scored = score_plans(feasible, calib, iterations=float(cfg.max_steps))
    scored.sort(key=lambda s: (s.energy_j_total, s.plan.name))
    audit_results: Dict[str, dict] = {}
    if not audit:
        return scored[0], audit_results
    from repro.analysis import audit_plans
    from repro.launch.mesh import make_local_mesh
    mesh_cache = mesh_cache if mesh_cache is not None else {}
    for s in scored:
        key = (s.plan.dp, s.plan.tp, s.plan.pp)
        if key not in mesh_cache:
            mesh_cache[key] = make_local_mesh(*key)
        res = audit_plans([s.plan], mesh_cache=mesh_cache)
        audit_results.update(res)
        if res[s.plan.name]["ok"]:
            s.notes["audit_ok"] = True
            return s, audit_results
    raise RuntimeError(
        f"static audit rejected every plan for {device_budget} "
        f"device(s): { {k: v['errors'][:1] for k, v in audit_results.items()} }")


# ---------------------------------------------------------------------------
# cross-mesh / cross-class parameter conversion
# ---------------------------------------------------------------------------

def _plan_class(plan: PlanCandidate) -> tuple:
    """The model class a plan trains: the phantom family is (k, tp)-
    dependent (paper Table I), the dense family is mesh-independent."""
    if plan.strategy in PHANTOM_KINDS:
        return ("phantom", plan.k, plan.tp)
    return ("dense",)


def _nest(flat: Dict[str, np.ndarray]) -> dict:
    root: dict = {}
    for key, arr in flat.items():
        parts = key.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = arr
    return root


def _to_flat_layers(plan: PlanCandidate, tree: dict) -> Dict[str, np.ndarray]:
    """Collapse a host param tree to flat [L, ...] leaf stacks: the
    pipelined layout {"stages": [S, L/S, ...]} is a reshape of the flat
    {"layers": [L, ...]} layout (homogeneous stages; global arrays)."""
    if plan.pp > 1:
        st = tree["stages"]
        return {k: np.asarray(v).reshape((plan.depth,) + v.shape[2:])
                for k, v in st.items()}
    return {k: np.asarray(v) for k, v in tree["layers"].items()}


def _from_flat_layers(plan: PlanCandidate,
                      flat: Dict[str, np.ndarray]) -> dict:
    if plan.pp > 1:
        S, L_loc = plan.pp, plan.depth // plan.pp
        return {"stages": {k: v.reshape((S, L_loc) + v.shape[1:])
                           for k, v in flat.items()}}
    return {"layers": dict(flat)}


def convert_ffn_params(plan_old: PlanCandidate, plan_new: PlanCandidate,
                       host_params: dict, host_opt: Optional[dict] = None):
    """Convert a GLOBAL host param tree between plans.

    Same model class → exact (reshape only; dense is mesh-independent,
    phantom at fixed (k, tp) likewise — dp/pp only re-shard).  Class
    change → per-layer dense reconstruction, then either direct use
    (→ tensor) or truncated-SVD re-factoring (→ phantom, the
    paper-sanctioned lowrank-distill downsize).  Returns ``(params,
    opt_or_None, distilled)``; the optimizer tree only survives the
    exact path (same reshape on every moment leaf)."""
    if plan_old.width != plan_new.width or plan_old.depth != plan_new.depth:
        raise ValueError("elastic re-plans keep the task fixed: width/"
                         f"depth changed {plan_old.name}->{plan_new.name}")
    flat_p = _to_flat_layers(plan_old, host_params)
    if _plan_class(plan_old) == _plan_class(plan_new):
        new_p = _from_flat_layers(plan_new, flat_p)
        new_opt = None
        if host_opt is not None:
            new_opt = {moment: _from_flat_layers(
                plan_new, _to_flat_layers(plan_old, sub))
                for moment, sub in host_opt.items()}
        return new_p, new_opt, False
    L = plan_old.depth
    n = plan_old.width
    dense: List[Tuple[np.ndarray, np.ndarray]] = []
    if plan_old.strategy in PHANTOM_KINDS:
        from repro.core.phantom import phantom_dense_equivalent
        for layer in range(L):
            W = np.asarray(phantom_dense_equivalent(
                {k: flat_p[k][layer] for k in ("L", "C", "D")}))
            b = (np.asarray(flat_p["b"][layer]) if "b" in flat_p
                 else np.zeros(n, np.float32))
            dense.append((W, b))
    else:
        for layer in range(L):
            b = (np.asarray(flat_p["b"][layer]) if "b" in flat_p
                 else np.zeros(n, np.float32))
            dense.append((np.asarray(flat_p["w"][layer]), b))
    if plan_new.strategy in PHANTOM_KINDS:
        from repro.core.lowrank import svd_phantom_init
        cols = {k: [] for k in ("L", "C", "D")}
        bs = []
        for W, b in dense:
            fac = svd_phantom_init(W, plan_new.tp, plan_new.k)
            for k in cols:
                cols[k].append(np.asarray(fac[k], np.float32))
            bs.append(np.asarray(b, np.float32))
        flat_new = {k: np.stack(v) for k, v in cols.items()}
        flat_new["b"] = np.stack(bs)
    else:
        flat_new = {
            "w": np.stack([W for W, _ in dense]).astype(np.float32),
            "b": np.stack([b for _, b in dense]).astype(np.float32)}
    return _from_flat_layers(plan_new, flat_new), None, True


def place_host_tree(host_tree: dict, decls, mesh):
    """device_put a GLOBAL host tree onto ``mesh`` with each decl's
    NamedSharding (the elastic restore's final hop)."""
    from jax.sharding import NamedSharding
    axes = MeshAxes.from_mesh(mesh)

    def place(decl, arr):
        spec = resolve_spec(decl.spec, axes)
        return jax.device_put(np.asarray(arr), NamedSharding(mesh, spec))

    from repro.parallel.params import is_decl
    return jax.tree.map(place, decls, host_tree, is_leaf=is_decl)


# ---------------------------------------------------------------------------
# the failure loop
# ---------------------------------------------------------------------------

class _Phase:
    """Bookkeeping for one plan/mesh the run executed on."""

    def __init__(self, scored, start_step: int, replayed: int,
                 compile_s: float, restart: bool):
        self.scored = scored
        self.plan = scored.plan
        self.start_step = start_step
        self.steps = 0
        self.replayed = replayed
        self.compile_s = compile_s
        self.restart = restart
        self.t0 = time.perf_counter()
        self.io0 = (0.0, 0)   # (io_seconds, io_bytes) at phase start
        self.ckpt_io_s = 0.0
        self.ckpt_io_bytes = 0.0
        self.wall_s = 0.0

    def close(self, mgr: CheckpointManager):
        self.ckpt_io_s = mgr.io_seconds - self.io0[0]
        self.ckpt_io_bytes = mgr.io_bytes - self.io0[1]
        self.wall_s = time.perf_counter() - self.t0

    def as_dict(self) -> dict:
        return {"plan": self.plan.name, "strategy": self.plan.strategy,
                "mesh": [self.plan.dp, self.plan.tp, self.plan.pp],
                "k": self.plan.k, "devices": self.plan.devices,
                "start_step": self.start_step, "steps": self.steps,
                "replayed_steps": self.replayed,
                "energy_j_per_iter": self.scored.energy_j_per_iter,
                "compile_s": self.compile_s, "restart": self.restart,
                "ckpt_io_s": self.ckpt_io_s,
                "ckpt_io_bytes": self.ckpt_io_bytes,
                "wall_s": self.wall_s}


def _build_runtime(plan: PlanCandidate, cfg: ElasticConfig, mesh_cache,
                   params_host=None, opt_host=None):
    """Mesh + compiled step + placed state for one plan.  Returns the
    runtime dict and the measured build+compile seconds.

    The step is warmed on a throwaway init-state call (jit compiles at
    first execution, not construction) so restart compile time lands in
    the recovery account's ``compile_s`` instead of polluting the first
    resumed step's wall time (and the straggler detector)."""
    from repro.core.ffn import init_ffn, make_ffn_train_step
    from repro.data.synthetic import TeacherDataset
    from repro.launch.mesh import make_local_mesh
    from repro.optim import AdamW

    t0 = time.perf_counter()
    key = (plan.dp, plan.tp, plan.pp)
    if key not in mesh_cache:
        mesh_cache[key] = make_local_mesh(*key)
    mesh = mesh_cache[key]
    mcfg = plan.model_config()
    opt = AdamW(cfg.lr, weight_decay=0.0)
    step_fn, decls, opt_decls = make_ffn_train_step(mcfg, mesh, opt,
                                                    cfg.batch)
    if params_host is None:
        params, opt_state = init_ffn(mcfg, mesh, opt, seed=cfg.seed)
    else:
        params = place_host_tree(params_host, decls, mesh)
        opt_state = (place_host_tree(opt_host, opt_decls, mesh)
                     if opt_host is not None else opt.init(params))
    # warm the executable on a donated throwaway copy of the init state
    dummy_p, dummy_o = init_ffn(mcfg, mesh, opt, seed=cfg.seed)
    xw, yw = TeacherDataset(cfg.width, cfg.batch, seed=cfg.seed)(0)
    out = step_fn(dummy_p, dummy_o, jnp.int32(0), xw, yw)
    jax.block_until_ready(out[2])
    rt = {"mesh": mesh, "cfg": mcfg, "opt": opt, "step_fn": step_fn,
          "decls": decls, "opt_decls": opt_decls,
          "params": params, "opt_state": opt_state}
    return rt, time.perf_counter() - t0


def run_elastic(cfg: ElasticConfig, *, ledger=None,
                fault_script: Optional[FaultScript] = None,
                calibration=None, watchdog=None,
                log_fn=print) -> ElasticResult:
    """Train to ``cfg.target_loss`` through scripted device losses.

    Detection → policy → flush → re-plan (audited) → restore/convert →
    resume; the returned ``ElasticResult.account`` is the priced
    recovery account (also recorded to ``ledger``, kind ``elastic``)."""
    from repro.data.synthetic import TeacherDataset
    from repro.planner.calibration import calibrate_from_ledger

    os.makedirs(cfg.workdir, exist_ok=True)
    if cfg.devices % cfg.hosts:
        raise ValueError(f"{cfg.devices} devices do not divide over "
                         f"{cfg.hosts} hosts")
    devices_per_host = cfg.devices // cfg.hosts
    calib = calibration or calibrate_from_ledger()
    cluster = SimulatedCluster(os.path.join(cfg.workdir, "hb"),
                               hosts=cfg.hosts,
                               timeout_s=cfg.heartbeat_timeout_s,
                               virtual=True)
    mgr = CheckpointManager(os.path.join(cfg.workdir, "ckpt"),
                            keep=cfg.keep_checkpoints)
    policy = RestartPolicy(max_restarts=cfg.max_restarts)
    detector = StragglerDetector(window=cfg.straggler_window,
                                 threshold=cfg.straggler_threshold)
    ds = TeacherDataset(cfg.width, cfg.batch, seed=cfg.seed)
    meter = StepMeter(f"elastic_ffn{cfg.width}", warmup=1)
    mesh_cache: dict = {}
    fault_script = fault_script or FaultScript()
    tracer = get_tracer()
    metrics = get_metrics()

    run_span = tracer.begin("elastic/run", cat="elastic",
                            devices=cfg.devices, width=cfg.width)
    with tracer.span("elastic/plan", cat="elastic",
                     devices=cfg.devices) as sp:
        scored, _ = solve_plan(
            cfg.devices, cfg, calib, mesh_cache=mesh_cache,
            strategies=((cfg.initial_strategy,) if cfg.initial_strategy
                        else None))
        sp.annotate(plan=scored.plan.name)
    log_fn(f"[elastic] initial plan {scored.plan.name} "
           f"({scored.plan.devices} devices)")
    # every _build_runtime is an elastic/compile span: the recovery
    # account's compile_s sums phase-0 AND restart builds
    with tracer.span("elastic/compile", cat="elastic",
                     plan=scored.plan.name):
        rt, compile_s = _build_runtime(scored.plan, cfg, mesh_cache)
    phases: List[_Phase] = [_Phase(scored, 0, 0, compile_s,
                                   restart=False)]
    recoveries: List[dict] = []
    handled_dead: set = set()
    step = 0
    loss = float("nan")
    losses: List[float] = []
    reached = False
    aborted = False
    replay_until = 0               # steps below this re-run lost work
    phases[-1].io0 = (mgr.io_seconds, mgr.io_bytes)

    fired: set = set()
    while step < cfg.max_steps:
        for host in fault_script.hosts_at(step):
            if (step, host) in fired:
                continue    # a rewind replays the step; the host is
            fired.add((step, host))   # already dead
            cluster.kill(host)
            log_fn(f"[elastic] step {step}: host {host} lost")
        cluster.advance(cfg.virtual_dt)
        cluster.tick(step)
        new_dead = [h for h in cluster.check() if h not in handled_dead]
        if new_dead:
            handled_dead.update(new_dead)
            tracer.instant("elastic/detect", cat="elastic", step=step,
                           dead_hosts=sorted(new_dead))
            metrics.counter(
                "elastic_host_failures_total",
                "hosts declared dead by the heartbeat monitor").inc(
                    len(new_dead))
            mgr.flush(raise_errors=False)   # join any in-flight save
            phases[-1].close(mgr)
            decision = policy.on_host_failure(new_dead, None)
            survivors = cfg.hosts - len(handled_dead)
            alive = devices_per_host * survivors
            if decision == "abort" or alive < 1:
                log_fn(f"[elastic] step {step}: {decision if alive else 'no survivors'}"
                       f" ({len(handled_dead)}/{cfg.hosts} hosts dead)")
                aborted = True
                break
            with tracer.span("elastic/replan", cat="elastic",
                             alive_devices=alive) as sp:
                t_replan = time.perf_counter()
                new_scored, _ = solve_plan(alive, cfg, calib,
                                           mesh_cache=mesh_cache)
                replan_s = time.perf_counter() - t_replan
                sp.annotate(plan=new_scored.plan.name)
            with tracer.span("elastic/restore", cat="elastic") as sp:
                t_restore = time.perf_counter()
                latest = mgr.latest_step()
                params_host = opt_host = None
                distilled = False
                restored_step = 0
                if latest is not None:
                    index, flat = mgr.load_host(latest)
                    restored_step = int(index["step"])
                    nested = _nest(flat)
                    meta_plan = index.get("meta", {}).get("plan")
                    plan_old = (plan_from_dict(meta_plan) if meta_plan
                                else phases[-1].plan)
                    params_host, opt_host, distilled = convert_ffn_params(
                        plan_old, new_scored.plan,
                        nested.get("params", {}),
                        nested.get("opt") or None)
                    mgr.invalidate_after(restored_step)
                restore_s = time.perf_counter() - t_restore
                sp.annotate(distilled=distilled,
                            restored_step=restored_step)
            with tracer.span("elastic/compile", cat="elastic",
                             plan=new_scored.plan.name):
                rt, compile_s = _build_runtime(
                    new_scored.plan, cfg, mesh_cache, params_host,
                    opt_host)
            replayed = max(step - restored_step, 0)
            recoveries.append({
                "detect_step": step, "restored_step": restored_step,
                "dead_hosts": sorted(handled_dead),
                "devices_before": phases[-1].plan.devices,
                "devices_after": new_scored.plan.devices,
                "plan_before": phases[-1].plan.name,
                "plan_after": new_scored.plan.name,
                "replayed_steps": replayed, "distilled": distilled,
                "from_scratch": latest is None,
                "restore_s": restore_s, "replan_s": replan_s,
                "decision": decision,
                "audit_ok": bool(new_scored.notes.get("audit_ok",
                                                      not cfg.audit_replan)),
            })
            log_fn(f"[elastic] step {step}: re-planned onto "
                   f"{new_scored.plan.name} ({new_scored.plan.devices} of "
                   f"{alive} surviving devices), restored "
                   f"step {restored_step}"
                   + (" [distilled]" if distilled else "")
                   + f", replaying {replayed} step(s)")
            metrics.counter(
                "elastic_recoveries_total",
                "elastic re-plan/restore/resume cycles").inc(
                    distilled=str(distilled).lower())
            phases.append(_Phase(new_scored, restored_step, replayed,
                                 compile_s, restart=True))
            phases[-1].io0 = (mgr.io_seconds, mgr.io_bytes)
            replay_until = step
            step = restored_step
            continue

        x, y = ds(step)
        step_fn = rt["step_fn"]
        if step in cfg.slow_steps:
            base = (watchdog.reference_s()
                    if watchdog is not None else None)
            if not base:
                base = meter.median_us() * 1e-6 or 0.02
            delay = base * max(cfg.slow_factor - 1.0, 0.0)

            def step_fn(p, o, s, xx, yy, _inner=rt["step_fn"],
                        _delay=delay):
                out = _inner(p, o, s, xx, yy)
                jax.block_until_ready(out[2])
                time.sleep(_delay)   # the injected anomaly
                return out

        def run_metered(_fn=step_fn, _step=step, _x=x, _y=y):
            return meter.call(_fn, rt["params"], rt["opt_state"],
                              jnp.int32(_step), _x, _y)

        with tracer.span("elastic/step", cat="train", step=step,
                         plan=phases[-1].plan.name,
                         replay=step < replay_until):
            if watchdog is not None and watchdog.capture_pending():
                out = watchdog.capture(run_metered)
            else:
                out = run_metered()
        rt["params"], rt["opt_state"], loss_dev = out
        loss = float(loss_dev)
        losses.append(loss)
        phases[-1].steps += 1
        step += 1
        dt_s = meter.times_us[-1] / 1e6
        metrics.counter("train_steps_total",
                        "executed training steps").inc(
                            suite="elastic")
        metrics.histogram("train_step_seconds",
                          "metered train step wall seconds").observe(
                              dt_s, suite="elastic")
        metrics.gauge("train_loss", "last observed training loss").set(
            loss, suite="elastic")
        if watchdog is not None:
            # step already advanced: the anomaly row must name the
            # step that actually ran (the one --slow-step injects at)
            watchdog.observe(step - 1, dt_s)
        straggle = note_step_time(
            detector, policy, step, dt_s, ledger,
            name="elastic_straggler", arch=f"ffn{cfg.width}",
            impl=phases[-1].plan.strategy, p=phases[-1].plan.tp)
        save_now = (step % cfg.checkpoint_every == 0
                    or straggle == "checkpoint")
        if save_now:
            mgr.save_async(step, rt["params"], rt["opt_state"],
                           meta={"plan": phases[-1].plan.as_dict()})
        if loss <= cfg.target_loss:
            reached = True
            break

    mgr.flush(raise_errors=False)
    if not aborted:
        phases[-1].close(mgr)
    phase_dicts = [p.as_dict() for p in phases]
    account = recovery_account(phase_dicts, recoveries)
    account["target_loss"] = cfg.target_loss
    account["reached_target"] = reached
    result = ElasticResult(
        reached_target=reached, aborted=aborted, final_loss=loss,
        final_step=step, phases=phase_dicts, recoveries=recoveries,
        account=account, plan_names=[p.plan.name for p in phases],
        losses=losses)
    entry = None
    if ledger is not None:
        last = phases[-1].plan
        entry = ledger.record(LedgerEntry(
            name=f"elastic_ffn{cfg.width}", suite="elastic",
            kind="elastic", arch=f"ffn{cfg.width}x{cfg.depth}",
            impl=last.strategy, p=last.tp,
            measured=dict(meter.summary(), final_loss=loss,
                          steps=step, wall_s=account["wall_s"]),
            predicted={"energy_j_total": account["energy_j_total"],
                       "energy_j_useful": account["energy_j_useful"],
                       "energy_j_replay": account["energy_j_replay"]},
            extra={"recovery": account, "phases": phase_dicts,
                   "recoveries": recoveries,
                   "plans": [p.plan.name for p in phases],
                   "reached_target": reached, "aborted": aborted,
                   "target_loss": cfg.target_loss,
                   "straggler_flags": len(detector.flagged)}))
        ledger.flush()
    if entry is not None:
        run_span.link_ledger(entry)
    run_span.annotate(final_step=step, reached_target=reached,
                      recoveries=len(recoveries))
    tracer.end(run_span)
    log_fn(f"[elastic] done: step {step} loss {loss:.4f} "
           f"target {'REACHED' if reached else 'missed'}, "
           f"{len(recoveries)} recovery(ies), replay ratio "
           f"{account['replay_overhead_ratio']:.3f}")
    return result
