"""Pipeline parallelism: the 1F1B schedule and its SPMD execution engine.

Two halves, one object each:

  * ``PipelineSchedule`` — the ANALYTIC side.  Given (stages, microbatches)
    it produces the canonical non-interleaved 1F1B order (warmup/steady/
    drain), the bubble fraction, the 1F1B in-flight activation bound, and
    the per-device stage-boundary ``CommEvent`` account that
    ``core/energy.py`` / ``telemetry/predict.py`` price (the PIE-P-style
    per-component extension: point-to-point activation/grad transfers are
    first-class comm events, like the Table II collectives).

  * ``pipeline_run`` — the EXECUTED side.  A wavefront loop over
    ``ticks = M + S - 1`` clock ticks inside one ``shard_map``: at tick t,
    pipe rank s computes microbatch ``t - s`` through its own stage and
    ``lax.ppermute``s the activation to rank ``s+1``.  Bubble ticks
    compute on masked garbage (zeroed before the send, so gradients
    through them vanish) — in SPMD emulation a bubble burns flops instead
    of idling, which the executed-account prediction mirrors exactly so
    measured/predicted ledger ratios stay ~1.  Reverse-mode autodiff
    transposes each ppermute into the opposite-direction hop, so
    differentiating this loop IS the backward pipeline — per-microbatch
    losses and gradients match the non-pipelined reference to float
    reassociation (pinned by tests/test_hypothesis.py).

On a pp=1 mesh the same entry points degrade to a sequential
microbatched loop over all stages — the reference the equivalence suite
compares against, and the path non-pipeline meshes keep using.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import jax.numpy as jnp
from jax import lax

from repro.parallel.axes import MeshAxes
from repro.parallel.strategies.base import CommEvent


# ---------------------------------------------------------------------------
# the schedule (analytic)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PipelineSchedule:
    """Non-interleaved 1F1B over ``microbatches`` microbatches and
    ``stages`` pipeline stages."""

    stages: int
    microbatches: int

    def __post_init__(self):
        if self.stages < 1 or self.microbatches < 1:
            raise ValueError(f"need stages >= 1 and microbatches >= 1, "
                             f"got {self.stages}/{self.microbatches}")
        from repro.obs import get_metrics
        get_metrics().gauge(
            "pipeline_bubble_fraction",
            "idle fraction of the 1F1B timeline, (S-1)/(M+S-1)").set(
                self.bubble_fraction, stages=str(self.stages),
                microbatches=str(self.microbatches))

    # --- wavefront geometry ------------------------------------------------

    @property
    def num_ticks(self) -> int:
        """Clock ticks of one forward (or one backward) wavefront."""
        return self.microbatches + self.stages - 1

    @property
    def bubble_fraction(self) -> float:
        """Idle fraction of the 1F1B timeline: (S-1)/(M+S-1)."""
        return (self.stages - 1) / self.num_ticks

    def makespan_ticks(self, t_fwd: float = 1.0, t_bwd: float = 2.0) -> float:
        """1F1B makespan in stage-compute units: (M + S - 1)(t_f + t_b).
        The useful work per stage is M(t_f + t_b); the rest is bubble."""
        return self.num_ticks * (t_fwd + t_bwd)

    def warmup(self, stage: int) -> int:
        """Forward microbatches stage ``stage`` runs before its first
        backward (the 1F1B warmup depth)."""
        return min(self.stages - 1 - stage, self.microbatches)

    def max_in_flight(self, stage: int) -> int:
        """Peak activations stage ``stage`` holds under 1F1B — min(M, S-s),
        versus GPipe's M.  This is the bound the planner's HBM napkin math
        charges for pipelined plans."""
        return min(self.microbatches, self.stages - stage)

    def table(self, stage: int) -> List[Tuple[str, int]]:
        """The canonical per-stage 1F1B op order: [("F", mb) | ("B", mb)].
        Warmup forwards, then strict 1F1B alternation, then the drain."""
        M, w = self.microbatches, self.warmup(stage)
        ops: List[Tuple[str, int]] = [("F", i) for i in range(w)]
        b = 0
        for f in range(w, M):
            ops.append(("F", f))
            ops.append(("B", b))
            b += 1
        ops.extend(("B", i) for i in range(b, M))
        return ops

    # --- stage-boundary communication account ------------------------------

    def stage_bounds(self, num_layers: int) -> List[Tuple[int, int]]:
        """[lo, hi) layer range per stage (earlier stages take the
        remainder when the stack doesn't divide evenly)."""
        S = self.stages
        base, extra = divmod(num_layers, S)
        bounds, lo = [], 0
        for s in range(S):
            hi = lo + base + (1 if s < extra else 0)
            bounds.append((lo, hi))
            lo = hi
        return bounds

    def p2p_events(self, m_floats: float, *,
                   executed: bool = False) -> List[CommEvent]:
        """Per-device stage-boundary transfers for ONE iteration, in paper
        Eqn. 26 units (m = per-rank message floats).

        ``executed=False`` is the ideal deployment account: every interior
        boundary moves each microbatch once forward (activation) and once
        backward (activation grad) — M sends per device per direction.

        ``executed=True`` is the SPMD-emulation account ``pipeline_run``
        actually lowers (ledger joins compare against this): the unrolled
        wavefront issues a ppermute at every tick but the last, forward
        and transposed-backward alike — (M + S - 2) per direction.
        """
        if self.stages <= 1:
            return []
        n = (self.num_ticks - 1) if executed else self.microbatches
        return ([CommEvent("collective_permute", m_floats, "fwd")] * n
                + [CommEvent("collective_permute", m_floats, "bwd")] * n)


# ---------------------------------------------------------------------------
# the engine (executed, inside shard_map)
# ---------------------------------------------------------------------------

def pipeline_run(stage_fn, x_mb, axes: MeshAxes, *, unroll: bool = False):
    """Run the pipeline wavefront over pre-split microbatch inputs.

    ``stage_fn(x) -> (z, aux)`` applies THIS pipe rank's stage to one
    microbatch activation (under pp=1 it must apply ALL stages
    sequentially); ``x_mb`` is ``[M, ...]`` of stage-0 inputs (local
    shards, replicated over the pipe axis).  Returns ``(y, aux_sum)``
    where ``y`` is ``[M, ...]`` of stage outputs per microbatch — the
    FINAL model outputs on the LAST pipe rank (callers mask their loss to
    ``axis_index == pp - 1``), intermediate stage outputs elsewhere — and
    ``aux_sum`` this rank's summed auxiliary losses over valid ticks.

    ``unroll=True`` lowers the tick loop as straight-line HLO so compiled
    cost analysis counts every tick (the same reason the FFN probe unrolls
    layers); it also skips the dead last-tick send explicitly, so the
    lowered ppermute count is deterministic rather than DCE-dependent.
    """
    M = x_mb.shape[0]
    pp = axes.pp
    if pp == 1:
        def body(carry, x):
            z, aux = stage_fn(x)
            return carry + aux, z
        if unroll:
            aux_sum = jnp.float32(0)
            ys = []
            for i in range(M):
                aux_sum, z = body(aux_sum, x_mb[i])
                ys.append(z)
            return jnp.stack(ys), aux_sum
        aux_sum, ys = lax.scan(body, jnp.float32(0), x_mb)
        return ys, aux_sum

    T = M + pp - 1
    s = lax.axis_index(axes.pp_name)
    perm = [(i, i + 1) for i in range(pp - 1)]
    pad = jnp.zeros((pp - 1,) + x_mb.shape[1:], x_mb.dtype)
    xs_in = jnp.concatenate([x_mb, pad], axis=0)          # [T, ...]
    ticks = jnp.arange(T, dtype=jnp.int32)

    def tick_body(recv, aux_acc, xt, t):
        """One wavefront tick on this rank: (z to forward, aux')."""
        x_in = jnp.where(s == 0, xt, recv)
        z, aux = stage_fn(x_in)
        # bubble ticks (microbatch index t - s out of range) compute on
        # garbage; zeroing z kills both the value and every gradient
        # flowing through it
        valid = jnp.logical_and(t - s >= 0, t - s < M)
        z = jnp.where(valid, z, jnp.zeros_like(z))
        return z, aux_acc + jnp.where(valid, aux, jnp.float32(0))

    def tick(carry, xs):
        recv, aux_acc = carry
        z, aux_acc = tick_body(recv, aux_acc, *xs)
        return (lax.ppermute(z, axes.pp_name, perm), aux_acc), z

    recv0 = jnp.zeros(x_mb.shape[1:], x_mb.dtype)
    if unroll:
        recv, aux_sum = recv0, jnp.float32(0)
        zs = []
        for t in range(T):
            z, aux_sum = tick_body(recv, aux_sum, xs_in[t],
                                   jnp.int32(t))
            # the last tick's send is dead — skip it explicitly so the
            # lowered ppermute count is deterministic, not DCE-dependent
            recv = (lax.ppermute(z, axes.pp_name, perm) if t < T - 1
                    else recv0)
            zs.append(z)
        ys = jnp.stack(zs)
    else:
        (_, aux_sum), ys = lax.scan(tick, (recv0, jnp.float32(0)),
                                    (xs_in, ticks))
    # the last stage emits microbatch i's final output at tick i + pp - 1
    return ys[pp - 1:], aux_sum


def split_batch_microbatches(batch, M: int):
    """Split every leaf of a batch pytree into M microbatches along its
    batch axis (axis 0, except mrope ``positions`` whose batch axis
    is 1) — the same convention as the trainer's accumulation splitter."""
    import jax

    def _split(path, x):
        ax = 1 if (path and getattr(path[-1], "key", None)
                   == "positions") else 0
        return split_microbatches(x, M, axis=ax)

    return jax.tree_util.tree_map_with_path(_split, batch)


def split_microbatches(x, M: int, axis: int = 0):
    """[..., B, ...] -> [M, ..., B/M, ...] along ``axis`` (leading
    microbatch dim), preserving row order within each microbatch."""
    B = x.shape[axis]
    if B % M:
        raise ValueError(f"batch axis {B} not divisible by "
                         f"{M} microbatches")
    xs = x.reshape(x.shape[:axis] + (M, B // M) + x.shape[axis + 1:])
    return jnp.moveaxis(xs, axis, 0)
