"""Counters, gauges and histograms with Prometheus + JSONL export.

A ``MetricsRegistry`` owns named metrics; the runtime layers (trainer,
pipeline, elastic, fault, serve, planner) register and update them
through the module-level default registry, and the launchers export the
final state via ``--metrics-out`` — Prometheus text exposition format
for ``.prom``/``.txt`` paths, one JSON snapshot line appended for
``.jsonl`` (a scrape-less stand-in for a pushgateway).

Thread-safe: one lock per registry covers registration and every
update (the checkpoint writer thread and the step loop both record).
Metric and label names follow Prometheus conventions (base units in
the name: ``_seconds``, ``_total``); docs/observability.md lists every
metric this repo emits.
"""
from __future__ import annotations

import json
import math
import os
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

SNAPSHOT_SCHEMA = "obs-metrics/v1"

_DEF_BUCKETS = (.001, .0025, .005, .01, .025, .05, .1, .25, .5, 1.0,
                2.5, 5.0, 10.0)

LabelKey = Tuple[Tuple[str, str], ...]


def _labelkey(labels: dict) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(key: LabelKey) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str, registry: "MetricsRegistry"):
        self.name = name
        self.help = help
        self._reg = registry

    def _lock(self):
        return self._reg._lock


class Counter(_Metric):
    kind = "counter"

    def __init__(self, name, help, registry):
        super().__init__(name, help, registry)
        self._values: Dict[LabelKey, float] = {}

    def inc(self, n: float = 1.0, **labels):
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        key = _labelkey(labels)
        with self._lock():
            self._values[key] = self._values.get(key, 0.0) + n

    def value(self, **labels) -> float:
        with self._lock():
            return self._values.get(_labelkey(labels), 0.0)

    def expose(self) -> List[str]:
        return [f"{self.name}{_fmt_labels(k)} {_fmt_value(v)}"
                for k, v in sorted(self._values.items())]

    def snapshot(self) -> dict:
        return {_fmt_labels(k) or "": v
                for k, v in sorted(self._values.items())}


class Gauge(_Metric):
    kind = "gauge"

    def __init__(self, name, help, registry):
        super().__init__(name, help, registry)
        self._values: Dict[LabelKey, float] = {}

    def set(self, v: float, **labels):
        with self._lock():
            self._values[_labelkey(labels)] = float(v)

    def inc(self, n: float = 1.0, **labels):
        key = _labelkey(labels)
        with self._lock():
            self._values[key] = self._values.get(key, 0.0) + n

    def value(self, **labels) -> float:
        with self._lock():
            return self._values.get(_labelkey(labels), 0.0)

    expose = Counter.expose
    snapshot = Counter.snapshot


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name, help, registry,
                 buckets: Sequence[float] = _DEF_BUCKETS):
        super().__init__(name, help, registry)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError(f"histogram {self.name}: no buckets")
        self._counts: Dict[LabelKey, List[int]] = {}
        self._sum: Dict[LabelKey, float] = {}
        self._n: Dict[LabelKey, int] = {}

    def observe(self, v: float, **labels):
        key = _labelkey(labels)
        with self._lock():
            counts = self._counts.setdefault(
                key, [0] * (len(self.buckets) + 1))
            i = 0
            while i < len(self.buckets) and v > self.buckets[i]:
                i += 1
            counts[i] += 1
            self._sum[key] = self._sum.get(key, 0.0) + float(v)
            self._n[key] = self._n.get(key, 0) + 1

    def count(self, **labels) -> int:
        with self._lock():
            return self._n.get(_labelkey(labels), 0)

    def sum(self, **labels) -> float:
        with self._lock():
            return self._sum.get(_labelkey(labels), 0.0)

    def expose(self) -> List[str]:
        out = []
        for key in sorted(self._counts):
            cum = 0
            for b, c in zip(self.buckets, self._counts[key]):
                cum += c
                lk = _fmt_labels(key + (("le", _fmt_value(b)),))
                out.append(f"{self.name}_bucket{lk} {cum}")
            cum += self._counts[key][-1]
            lk = _fmt_labels(key + (("le", "+Inf"),))
            out.append(f"{self.name}_bucket{lk} {cum}")
            out.append(f"{self.name}_sum{_fmt_labels(key)} "
                       f"{_fmt_value(self._sum[key])}")
            out.append(f"{self.name}_count{_fmt_labels(key)} "
                       f"{self._n[key]}")
        return out

    def snapshot(self) -> dict:
        return {_fmt_labels(k) or "": {
                    "count": self._n[k], "sum": self._sum[k],
                    "buckets": dict(zip(
                        [_fmt_value(b) for b in self.buckets]
                        + ["+Inf"], self._counts[k]))}
                for k in sorted(self._counts)}


class MetricsRegistry:
    """Named metrics; registration is idempotent (same name + same
    kind returns the existing instance — the wiring helpers in every
    subsystem can therefore register at call sites)."""

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: Dict[str, _Metric] = {}

    def _register(self, cls, name: str, help: str, **kw) -> _Metric:
        with self._lock:
            got = self._metrics.get(name)
            if got is not None:
                if not isinstance(got, cls):
                    raise TypeError(
                        f"metric {name!r} already registered as "
                        f"{got.kind}, not {cls.kind}")
                return got
            m = cls(name, help, self, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = _DEF_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help, buckets=buckets)

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._metrics

    def get(self, name: str) -> Optional[_Metric]:
        with self._lock:
            return self._metrics.get(name)

    # --- export ----------------------------------------------------------

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (v0.0.4)."""
        lines = []
        with self._lock:
            for name in sorted(self._metrics):
                m = self._metrics[name]
                if m.help:
                    lines.append(f"# HELP {name} {m.help}")
                lines.append(f"# TYPE {name} {m.kind}")
                lines.extend(m.expose())
        return "\n".join(lines) + "\n"

    def snapshot(self, meta: Optional[dict] = None) -> dict:
        with self._lock:
            return {"schema": SNAPSHOT_SCHEMA,
                    "meta": dict(meta or {}),
                    "metrics": {name: {"kind": m.kind,
                                       "values": m.snapshot()}
                                for name, m in
                                sorted(self._metrics.items())}}

    def write(self, path: str, meta: Optional[dict] = None) -> str:
        """``.jsonl`` appends one snapshot line (timestamped); anything
        else writes/overwrites Prometheus text exposition format."""
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        if path.endswith(".jsonl"):
            snap = self.snapshot(meta=dict(meta or {},
                                           unix_time=time.time()))
            with open(path, "a") as f:
                f.write(json.dumps(snap) + "\n")
        else:
            with open(path, "w") as f:
                f.write(self.to_prometheus())
        return path

    def reset(self):
        with self._lock:
            self._metrics.clear()


# ---------------------------------------------------------------------------
# module-level default registry
# ---------------------------------------------------------------------------

_DEFAULT = MetricsRegistry()


def get_metrics() -> MetricsRegistry:
    return _DEFAULT


def set_metrics(reg: Optional[MetricsRegistry]) -> MetricsRegistry:
    """Install a fresh registry (None -> a new empty one); returns the
    previous.  Launchers swap one in so ``--metrics-out`` exports only
    this run's metrics."""
    global _DEFAULT
    prev = _DEFAULT
    _DEFAULT = reg if reg is not None else MetricsRegistry()
    return prev
