"""Context-manager span tracing, emitted as Chrome-trace-event JSON.

A ``Tracer`` records **spans** — named, categorised intervals with
stable ids — and **instants** (zero-duration markers).  The output is
the Chrome trace-event format (``{"traceEvents": [...]}``, "X"/"i"/"M"
phases), which Perfetto and ``chrome://tracing`` load directly; the
``python -m repro.launch.obs`` CLI summarises and cross-checks the same
file (docs/observability.md).

Determinism: span ids are sequence numbers assigned in emission order
(``s000000``, ``s000001``, …) and timestamps come from an injectable
``clock`` (seconds; ``time.perf_counter`` by default).  Under a
manually-advanced clock — the elastic runtime's ``VirtualClock`` — two
identical schedules produce byte-identical traces, which is what the
golden-schema tests pin.

The ledger cross-link: a span that timed a computation the energy
ledger also priced calls ``span.link_ledger(entry)``; the span's args
then carry the entry name, the measured wall fields and the predicted
joules, so the trace shows measured time AND predicted energy per span.

Module-level current tracer: deep layers (trainer, serve engine,
checkpoint worker) emit through ``get_tracer()`` so nothing needs a
tracer threaded through its signature; the default is a disabled tracer
whose spans are free no-ops.
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from typing import Callable, List, Optional

TRACE_SCHEMA = "chrome-trace-event"


class Span:
    """One open (or closed) interval; mutate args via ``annotate``."""

    __slots__ = ("name", "cat", "span_id", "tid", "ts_us", "dur_us",
                 "args", "_tracer")

    def __init__(self, tracer: Optional["Tracer"], name: str, cat: str,
                 span_id: str, tid: int, ts_us: float):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.span_id = span_id
        self.tid = tid
        self.ts_us = ts_us
        self.dur_us: Optional[float] = None
        self.args: dict = {}

    def annotate(self, **kw) -> "Span":
        self.args.update(kw)
        return self

    def link_ledger(self, entry) -> "Span":
        """Cross-link the ``LedgerEntry`` this span timed: the span
        carries the entry's name, measured wall fields and predicted
        joules, so the trace and ``BENCH_report.json`` join by name."""
        if entry is None:
            return self
        link = {"entry": entry.name, "kind": entry.kind}
        m = entry.measured or {}
        for k in ("wall_us_median", "total_s", "calls"):
            if k in m:
                link[k] = m[k]
        p = entry.predicted or {}
        for k in ("energy_j_per_iter", "energy_j_total"):
            if k in p:
                link[f"predicted_{k}"] = p[k]
        self.args["ledger"] = link
        return self

    def as_event(self) -> dict:
        ev = {"ph": "X", "name": self.name, "cat": self.cat or "misc",
              "pid": 0, "tid": self.tid,
              "ts": round(self.ts_us, 3),
              "dur": round(self.dur_us or 0.0, 3),
              "args": dict(self.args, span_id=self.span_id)}
        return ev


class _NullSpan(Span):
    """Shared no-op span handed out by a disabled tracer."""

    def __init__(self):
        super().__init__(None, "", "", "", 0, 0.0)

    def annotate(self, **kw):
        return self

    def link_ledger(self, entry):
        return self


_NULL_SPAN = _NullSpan()


class Tracer:
    """Collects spans/instants; writes Perfetto-loadable JSON.

    ``clock`` returns SECONDS (monotonic or virtual); event timestamps
    are microseconds relative to the tracer's construction instant.
    Thread-safe: the checkpoint writer thread and the training loop may
    emit concurrently.  Construct with ``enabled=False`` (or use the
    module default) for a zero-cost null tracer.
    """

    def __init__(self, *, clock: Callable[[], float] = time.perf_counter,
                 enabled: bool = True, meta: Optional[dict] = None):
        self.enabled = enabled
        self.clock = clock
        self.meta = dict(meta or {})
        self._t0 = clock() if enabled else 0.0
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self._open: List[Span] = []          # non-lexical begin/end spans
        self._seq = 0
        self._tids: dict = {}                # thread ident -> stable tid

    # --- internals -------------------------------------------------------

    def _now_us(self) -> float:
        return (self.clock() - self._t0) * 1e6

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            # stable small ints in order of first emission: the main
            # loop is tid 0, the first helper thread tid 1, ...
            tid = self._tids[ident] = len(self._tids)
        return tid

    def _next_id(self) -> str:
        sid = f"s{self._seq:06d}"
        self._seq += 1
        return sid

    # --- emission --------------------------------------------------------

    def begin(self, name: str, cat: str = "", **args) -> Span:
        """Open a non-lexical span (close with ``end``); span ids are
        assigned at begin time, so nesting order stays deterministic."""
        if not self.enabled:
            return _NULL_SPAN
        with self._lock:
            sp = Span(self, name, cat, self._next_id(), self._tid(),
                      self._now_us())
            sp.args.update(args)
            self._open.append(sp)
        return sp

    def end(self, span: Span) -> Span:
        if not self.enabled or span is _NULL_SPAN:
            return span
        with self._lock:
            span.dur_us = max(self._now_us() - span.ts_us, 0.0)
            if span in self._open:
                self._open.remove(span)
            self._events.append(span.as_event())
        return span

    @contextmanager
    def span(self, name: str, cat: str = "", **args):
        """``with tracer.span("train/step", cat="train", step=i) as sp``
        — the workhorse API; yields the span for ``annotate`` /
        ``link_ledger``."""
        sp = self.begin(name, cat, **args)
        try:
            yield sp
        finally:
            self.end(sp)

    def instant(self, name: str, cat: str = "", **args):
        """Zero-duration marker (watchdog trips, detections, …)."""
        if not self.enabled:
            return
        with self._lock:
            self._events.append({
                "ph": "i", "name": name, "cat": cat or "misc", "pid": 0,
                "tid": self._tid(), "ts": round(self._now_us(), 3),
                "s": "t", "args": dict(args, span_id=self._next_id())})

    # --- output ----------------------------------------------------------

    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def to_chrome(self) -> dict:
        """The Chrome/Perfetto trace document.  Still-open spans are
        closed at the current clock so a crash dump stays loadable."""
        with self._lock:
            evs = list(self._events)
            for sp in self._open:
                ev = sp.as_event()
                ev["dur"] = round(max(self._now_us() - sp.ts_us, 0.0), 3)
                ev["args"]["unclosed"] = True
                evs.append(ev)
            meta_evs = [{"ph": "M", "name": "process_name", "pid": 0,
                         "tid": 0, "args": {"name": "repro"}}]
            for ident, tid in sorted(self._tids.items(),
                                     key=lambda kv: kv[1]):
                meta_evs.append({"ph": "M", "name": "thread_name",
                                 "pid": 0, "tid": tid,
                                 "args": {"name": "main" if tid == 0
                                          else f"worker-{tid}"}})
        return {"traceEvents": meta_evs + evs,
                "displayTimeUnit": "ms",
                "otherData": dict(self.meta, schema=TRACE_SCHEMA)}

    def write(self, path: str) -> str:
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1)
        return path

    def summary(self) -> dict:
        """Per-category span counts and summed durations (seconds) —
        what the ``obs`` CLI prints and the recovery cross-check sums."""
        out: dict = {}
        for ev in self.events():
            if ev.get("ph") != "X":
                continue
            cat = ev.get("cat", "misc")
            rec = out.setdefault(cat, {"spans": 0, "total_s": 0.0})
            rec["spans"] += 1
            rec["total_s"] += ev.get("dur", 0.0) * 1e-6
        return out

    def __len__(self):
        with self._lock:
            return len(self._events)


# ---------------------------------------------------------------------------
# module-level current tracer
# ---------------------------------------------------------------------------

NULL_TRACER = Tracer(enabled=False)
_CURRENT: Tracer = NULL_TRACER


def get_tracer() -> Tracer:
    return _CURRENT


def set_tracer(tracer: Optional[Tracer]) -> Tracer:
    """Install ``tracer`` as the process-wide current tracer (None
    restores the disabled default); returns the previous one."""
    global _CURRENT
    prev = _CURRENT
    _CURRENT = tracer if tracer is not None else NULL_TRACER
    return prev


@contextmanager
def use_tracer(tracer: Tracer):
    prev = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)


# ---------------------------------------------------------------------------
# reading traces back (the CLI + tests)
# ---------------------------------------------------------------------------

def load_trace(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError(f"{path}: not a Chrome trace-event document "
                         "(no traceEvents key)")
    return doc


def span_events(doc: dict, cat: Optional[str] = None,
                name_prefix: str = "") -> List[dict]:
    """The "X" events of a loaded trace, optionally filtered."""
    out = []
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        if cat is not None and ev.get("cat") != cat:
            continue
        if name_prefix and not ev.get("name", "").startswith(name_prefix):
            continue
        out.append(ev)
    return out
