"""Observability: tracing, metrics export, and the energy-drift
watchdog (docs/observability.md).

Three pieces over the same runtime the energy ledger already prices:

  * ``Tracer``              — context-manager spans with stable ids,
    written as Chrome-trace-event JSON (Perfetto-loadable); spans
    cross-link the ``LedgerEntry`` they timed so the trace carries
    measured wall time AND predicted joules per span.
  * ``MetricsRegistry``     — counters/gauges/histograms exported as
    Prometheus text exposition format or JSONL snapshots.
  * ``EnergyDriftWatchdog`` — streams per-step measured/predicted
    ratios through windowed bands, records anomaly events to the
    ledger, and arms on-demand ``jax.profiler`` captures.

Every launcher takes ``--trace-out`` / ``--metrics-out``; ``python -m
repro.launch.obs`` renders/inspects the artifacts.  The module-level
``get_tracer()`` / ``get_metrics()`` defaults are free no-ops /
process-wide registries, so the deep wiring (trainer, pipeline,
elastic, serve, planner) costs nothing when observability is off.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram,
                               MetricsRegistry, SNAPSHOT_SCHEMA,
                               get_metrics, set_metrics)
from repro.obs.trace import (NULL_TRACER, Span, TRACE_SCHEMA, Tracer,
                             get_tracer, load_trace, set_tracer,
                             span_events, use_tracer)
from repro.obs.watchdog import EnergyDriftWatchdog, WatchdogEvent

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "SNAPSHOT_SCHEMA", "get_metrics", "set_metrics",
    "NULL_TRACER", "Span", "TRACE_SCHEMA", "Tracer", "get_tracer",
    "load_trace", "set_tracer", "span_events", "use_tracer",
    "EnergyDriftWatchdog", "WatchdogEvent",
]
