"""The energy-drift watchdog: in-flight measured/predicted banding.

The ledger proves (after the run) that measured cost tracks the
analytic energy account; the watchdog watches the SAME ratio while the
run is still going.  Each observed step contributes

    ratio = measured_step_seconds / predicted_step_seconds

(at fixed power the step's energy is proportional to its wall time, so
a wall-time ratio IS the measured/predicted energy ratio — see
docs/energy_model.md).  When no analytic prediction is available the
watchdog self-baselines: the median of the first ``min_samples`` steps
becomes the reference, and the ratio band becomes a drift band over the
run's own healthy steady state.

Two trip conditions:

  * **spike** — a single ratio ≥ ``spike_factor`` (a straggler step,
    a thermal event, an interfering tenant);
  * **drift** — the mean ratio over the trailing ``window`` leaves
    ``band`` (the energy model no longer predicts this run: wrong
    calibration, changed sharding, input-pipeline degradation).

A trip records an anomaly event to the energy ledger (kind
``anomaly``), marks the trace (instant event), bumps the
``obs_watchdog_trips_total`` counter — and, when a ``profile_dir`` is
configured, arms a one-shot ``jax.profiler`` capture: the caller wraps
its NEXT step in ``watchdog.capture(fn, *args)`` and the profiler
artifact (xplane + trace.json.gz) lands on disk for offline analysis.
After a trip the watchdog stays quiet for ``cooldown`` observations so
a sustained stall doesn't flood the ledger.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.obs.metrics import get_metrics
from repro.obs.trace import get_tracer


@dataclass
class WatchdogEvent:
    step: int
    kind: str                   # spike | drift
    ratio: float                # this observation's measured/predicted
    window_mean: float          # trailing-window mean ratio
    measured_s: float
    predicted_s: float

    def as_dict(self) -> dict:
        return {"step": self.step, "kind": self.kind,
                "ratio": self.ratio, "window_mean": self.window_mean,
                "measured_s": self.measured_s,
                "predicted_s": self.predicted_s}


@dataclass
class EnergyDriftWatchdog:
    """Stream per-step measured seconds; trip on spike or band drift."""

    band: tuple = (0.5, 2.0)        # windowed-mean drift band
    spike_factor: float = 3.0       # single-step trip threshold
    window: int = 8
    min_samples: int = 5            # self-baseline sample count
    cooldown: int = 20              # observations muted after a trip
    predicted_s: Optional[float] = None   # analytic step seconds; None
                                          # = self-baseline
    profile_dir: Optional[str] = None
    ledger: Optional[object] = None
    name: str = "watchdog"
    arch: str = ""
    impl: str = ""
    p: int = 0

    trips: List[WatchdogEvent] = field(default_factory=list)
    captures: List[str] = field(default_factory=list)
    _ratios: List[float] = field(default_factory=list, repr=False)
    _baseline: List[float] = field(default_factory=list, repr=False)
    _mute_until: int = field(default=0, repr=False)
    _obs: int = field(default=0, repr=False)
    _capture_armed: bool = field(default=False, repr=False)

    # --- observation -----------------------------------------------------

    def reference_s(self) -> Optional[float]:
        """The predicted step seconds ratios are taken against."""
        if self.predicted_s:
            return float(self.predicted_s)
        if len(self._baseline) >= self.min_samples:
            return float(np.median(self._baseline))
        return None

    def observe(self, step: int, measured_s: float,
                predicted_s: Optional[float] = None
                ) -> Optional[WatchdogEvent]:
        """Record one step; returns the trip event if this observation
        tripped the watchdog, else None."""
        self._obs += 1
        if predicted_s:
            self.predicted_s = float(predicted_s)
        ref = self.reference_s()
        if ref is None:
            # still collecting the self-baseline
            self._baseline.append(float(measured_s))
            return None
        ratio = float(measured_s) / ref
        self._ratios.append(ratio)
        tail = self._ratios[-self.window:]
        mean = float(np.mean(tail))
        get_metrics().gauge(
            "obs_energy_ratio",
            "trailing-window measured/predicted step ratio").set(
                mean, name=self.name)
        if self._obs < self._mute_until:
            return None
        kind = None
        if ratio >= self.spike_factor:
            kind = "spike"
        elif len(tail) >= self.window and \
                not (self.band[0] <= mean <= self.band[1]):
            kind = "drift"
        if kind is None:
            return None
        ev = WatchdogEvent(step=step, kind=kind, ratio=ratio,
                           window_mean=mean, measured_s=float(measured_s),
                           predicted_s=ref)
        self._trip(ev)
        return ev

    # --- trip actions ----------------------------------------------------

    def _trip(self, ev: WatchdogEvent):
        self.trips.append(ev)
        self._mute_until = self._obs + self.cooldown
        if self.profile_dir:
            self._capture_armed = True
        get_metrics().counter(
            "obs_watchdog_trips_total",
            "energy-drift watchdog anomaly trips").inc(kind=ev.kind)
        get_tracer().instant(
            f"watchdog/{ev.kind}", cat="watchdog", **ev.as_dict())
        if self.ledger is not None:
            from repro.telemetry import LedgerEntry
            self.ledger.record(LedgerEntry(
                name=f"{self.name}_step{ev.step}", suite="obs",
                kind="anomaly", arch=self.arch, impl=self.impl, p=self.p,
                measured={"step": ev.step, "dt_s": ev.measured_s,
                          "ratio": ev.ratio,
                          "window_mean": ev.window_mean},
                predicted={"dt_s": ev.predicted_s},
                extra={"event": f"watchdog_{ev.kind}",
                       "band": list(self.band),
                       "spike_factor": self.spike_factor,
                       "window": self.window,
                       "profile_armed": bool(self.profile_dir)}))

    # --- on-demand profiler capture --------------------------------------

    def capture_pending(self) -> bool:
        return self._capture_armed

    def capture(self, fn, *args, **kwargs):
        """Run ``fn(*args)`` under a one-shot ``jax.profiler`` trace
        when a trip armed a capture; otherwise just call it.  Capture
        failures never break the step — the artifact is best-effort."""
        if not self._capture_armed:
            return fn(*args, **kwargs)
        self._capture_armed = False
        import jax
        started = False
        try:
            jax.profiler.start_trace(self.profile_dir)
            started = True
        except Exception as exc:       # profiler unavailable/busy
            get_tracer().instant("watchdog/capture_failed",
                                 cat="watchdog", error=str(exc))
        try:
            return fn(*args, **kwargs)
        finally:
            if started:
                try:
                    jax.profiler.stop_trace()
                    self.captures.append(self.profile_dir)
                    get_tracer().instant("watchdog/capture",
                                         cat="watchdog",
                                         dir=self.profile_dir)
                except Exception as exc:
                    get_tracer().instant("watchdog/capture_failed",
                                         cat="watchdog", error=str(exc))

    def summary(self) -> dict:
        return {"observations": self._obs, "trips":
                [t.as_dict() for t in self.trips],
                "captures": list(self.captures),
                "reference_s": self.reference_s(),
                "band": list(self.band),
                "spike_factor": self.spike_factor}
