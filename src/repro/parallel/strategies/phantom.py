"""Phantom-parallel projection strategies (the paper's contribution) in
the ProjectionStrategy interface.

Table II accounting (per layer, per pass): the ghost collectives carry
k*batch floats — All-Gather forward, Reduce-Scatter backward — against
the tensor path's (n/p)*batch.  Per-rank forward flops: local diagonal
block (n_in/p)(n_out/p), compress k*n_in/p, decompress (p-1)*k*n_out/p
(2 flops per MAC), matching the paper's Eqn. 8 operating regime.

``lowrank_distill`` is the same computation/cost structure, but its
parameters come from a dense teacher via ``svd_phantom_init`` (truncated
SVD per off-diagonal block, shared-compressor constraint respected) —
the distill-then-finetune entry point.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import PhantomConfig, ProjectionSpec
from repro.core.phantom import (phantom_apply, phantom_decls,
                                phantom_dense_equivalent,
                                phantom_param_count)
from repro.parallel.strategies.base import (CommEvent, ProjectionStrategy,
                                            register)


@register("phantom")
class PhantomStrategy(ProjectionStrategy):
    """Feature-shard in, feature-shard out; k-wide ghost collectives."""

    in_layout = "shard"
    out_layout = "shard"

    def __init__(self, n_in, n_out, tp, *, dp=1, bias=True, fsdp=False,
                 spec=None):
        super().__init__(n_in, n_out, tp, dp=dp, bias=bias, fsdp=fsdp,
                         spec=spec)
        s = self.spec
        self.k = s.k
        self.pp = PhantomConfig(k=s.k, variant=s.variant,
                                include_self_term=s.include_self_term,
                                kernel_backend=s.kernel_backend)

    def decls(self):
        return phantom_decls(self.n_in, self.n_out, self.k, self.tp,
                             bias=self.bias, fsdp=self.fsdp, dp=self.dp)

    def apply(self, params, x, *, axes=None, compute_dtype=None):
        return phantom_apply(self.pp, params, x, axes,
                             compute_dtype=compute_dtype)

    def apply_shard(self, params, x_shard, axes, compute_dtype=None):
        return self.apply(params, x_shard, axes=axes,
                          compute_dtype=compute_dtype)

    def param_count(self):
        return phantom_param_count(self.n_in, self.n_out, self.k, self.tp,
                                   bias=self.bias)

    def flops(self, batch):
        p, k = self.tp, self.k
        local = (self.n_in / p) * (self.n_out / p)
        compress = k * (self.n_in / p)
        nsrc = (p - 1) + (1 if self.pp.include_self_term else 0)
        decompress = max(nsrc, 0) * k * (self.n_out / p)
        return 2.0 * (local + compress + decompress) * batch

    def comm_events(self, batch):
        m = self.k * batch
        if self.tp <= 1:
            return []
        return [CommEvent("all_gather", m, "fwd"),
                CommEvent("reduce_scatter", m, "bwd")]

    def dense_equivalent(self, params):
        W = phantom_dense_equivalent(
            params, include_self_term=self.pp.include_self_term)
        return W, params.get("b")


@register("lowrank_distill")
class LowrankDistillStrategy(PhantomStrategy):
    """Phantom factors initialized from a dense teacher matrix.

    Identical runtime/cost structure to ``phantom``; `init_from_dense`
    produces the decl-layout params via truncated SVD so a pretrained TP
    weight can be dropped into the phantom model class and finetuned.
    """

    def init_from_dense(self, W, b=None):
        """W [n_in, n_out] dense teacher -> global phantom params."""
        from repro.core.lowrank import svd_phantom_init
        params = svd_phantom_init(W, self.tp, self.k)
        if self.bias:
            params["b"] = (jnp.zeros((self.n_out,), jnp.float32)
                           if b is None else jnp.asarray(b, jnp.float32))
        return params

    def distill_error(self, W) -> float:
        """Relative Frobenius error of the rank-k phantom fit of W."""
        from repro.core.lowrank import block_lowrank_error
        return block_lowrank_error(W, self.tp, self.k)
