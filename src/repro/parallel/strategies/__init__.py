"""ProjectionStrategy API — swappable, cost-accounted sharded projections.

Usage:
    from repro.parallel.strategies import site_strategy
    st = site_strategy(cfg, "ffn_up", d, ff, axes.tp, dp=axes.dp,
                       bias=False, fsdp=cfg.fsdp)
    decls = st.decls()                 # ParamDecl tree
    y = st.apply(params, x, axes=axes) # sharded forward
    st.flops(batch), st.comm_events(batch)  # Table II accounting
"""
from repro.parallel.strategies.base import (CommEvent, ProjectionStrategy,
                                            available_strategies,
                                            get_strategy_cls, make_strategy,
                                            register, site_strategy)
from repro.parallel.strategies.phantom import (LowrankDistillStrategy,
                                               PhantomStrategy)
from repro.parallel.strategies.tensor import (TensorColStrategy,
                                              TensorRowStrategy)

__all__ = [
    "CommEvent", "ProjectionStrategy", "available_strategies",
    "get_strategy_cls", "make_strategy", "register", "site_strategy",
    "TensorColStrategy", "TensorRowStrategy", "PhantomStrategy",
    "LowrankDistillStrategy",
]
