"""ProjectionStrategy — one object per projection site that *computes* the
sharded projection AND *predicts* its cost.

The paper's central comparison (tensor-parallel vs phantom-parallel
projections, Table II) used to be hard-coded ``if ffn_impl == "phantom"``
branches at every call site, with the FLOP/bandwidth/energy model
re-derived by hand in ``core/energy.py``.  A strategy instance unifies the
two views: ``decls()``/``apply()`` drive the actual shard_map computation,
while ``flops()``/``comm_events()``/``param_count()`` are the *same
object's* per-operator cost predictions, so the Table II schedule falls
out of the executed operators instead of a parallel hand-maintained model
(the per-operator attribution PIE-P argues is required for trustworthy
parallel-inference energy prediction).

Layout contract
---------------
Activations inside ``shard_map`` are feature-sharded (``[..., n/p]``) or
full (``[..., n]``).  Each strategy declares what it consumes/produces:

  * ``in_layout``:  "full" (replicated features) | "shard"
  * ``out_layout``: "shard" | "partial" (needs a reduction by the caller)

``apply()`` is the native contract (what the fast paths compose);
``apply_shard()`` is the uniform feature-shard -> feature-shard wrapper
(gathers/reduces internally) that lets arbitrary strategies mix at
adjacent sites.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Type

from repro.configs.base import PHANTOM_KINDS, PROJECTION_SITES, ProjectionSpec


@dataclass(frozen=True)
class CommEvent:
    """One collective issued by a strategy, in paper Eqn. 26 units."""
    collective: str        # all_gather | reduce_scatter | all_reduce
    m_floats: float        # per-rank message size, floats
    phase: str = "fwd"     # fwd | bwd


class ProjectionStrategy:
    """Base class; concrete strategies register themselves by ``kind``."""

    kind: str = "?"
    in_layout: str = "full"
    out_layout: str = "shard"

    def __init__(self, n_in: int, n_out: int, tp: int, *, dp: int = 1,
                 bias: bool = True, fsdp: bool = False,
                 spec: Optional[ProjectionSpec] = None):
        self.n_in, self.n_out, self.tp, self.dp = n_in, n_out, tp, dp
        self.bias, self.fsdp = bias, fsdp
        self.spec = spec or ProjectionSpec(kind=self.kind)

    # --- compute side ----------------------------------------------------
    def decls(self) -> Dict:
        raise NotImplementedError

    def apply(self, params, x, *, axes=None, compute_dtype=None):
        """Native-layout forward (in_layout -> out_layout)."""
        raise NotImplementedError

    def apply_shard(self, params, x_shard, axes, compute_dtype=None):
        """Uniform feature-shard [..., n_in/p] -> [..., n_out/p]."""
        raise NotImplementedError

    # --- accounting side -------------------------------------------------
    def param_count(self) -> int:
        raise NotImplementedError

    def flops(self, batch: int) -> float:
        """Per-rank FORWARD flops for `batch` rows (2*MACs).  Training
        cost models multiply by 3 (fwd + bwd-input + bwd-weight)."""
        raise NotImplementedError

    def comm_events(self, batch: int) -> List[CommEvent]:
        """Collectives this strategy issues per fwd+bwd pass."""
        raise NotImplementedError

    def dense_equivalent(self, params):
        """GLOBAL (unsharded) params -> (W [n_in, n_out], b or None): the
        dense matrix this strategy computes.  Ground truth for tests."""
        raise NotImplementedError

    def __repr__(self):
        return (f"{type(self).__name__}({self.n_in}x{self.n_out}, "
                f"tp={self.tp}, kind={self.kind})")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[ProjectionStrategy]] = {}


def register(kind: str) -> Callable[[type], type]:
    def deco(cls):
        cls.kind = kind
        _REGISTRY[kind] = cls
        return cls
    return deco


def available_strategies() -> List[str]:
    return sorted(_REGISTRY)


def get_strategy_cls(kind: str) -> Type[ProjectionStrategy]:
    if kind not in _REGISTRY:
        raise KeyError(f"unknown projection strategy {kind!r}; "
                       f"registered: {available_strategies()}")
    return _REGISTRY[kind]


def make_strategy(spec: ProjectionSpec, n_in: int, n_out: int, tp: int, *,
                  dp: int = 1, bias: bool = True,
                  fsdp: bool = False) -> ProjectionStrategy:
    """Instantiate the strategy a ProjectionSpec selects for one site."""
    return get_strategy_cls(spec.kind)(n_in, n_out, tp, dp=dp, bias=bias,
                                       fsdp=fsdp, spec=spec)


def site_strategy(cfg, site: str, n_in: int, n_out: int, tp: int, *,
                  dp: int = 1, bias: bool = True, fsdp: bool = False,
                  allow_phantom: bool = True) -> ProjectionStrategy:
    """Resolve cfg's spec for `site` and instantiate it.

    ``allow_phantom=False`` forces the site's natural dense strategy —
    call sites use it to guard divisibility/mode constraints the phantom
    factorization needs (mirrors the old ``uses_phantom_proj`` guards).
    """
    spec = cfg.projection_spec(site)
    if spec.kind in PHANTOM_KINDS and (
            not allow_phantom or n_in % tp or n_out % tp):
        spec = ProjectionSpec(kind=PROJECTION_SITES[site])
    return make_strategy(spec, n_in, n_out, tp, dp=dp, bias=bias, fsdp=fsdp)
