"""Tensor-parallel (Megatron-style) projection strategies — the paper's
baseline, wrapped in the ProjectionStrategy interface.

Table II accounting (per layer, per pass):
  column path: forward All-Gather of the n_in/p activation shard, backward
  Reduce-Scatter (the gather's VJP) — message ~ n_in/p * batch floats.
  row path:    forward Reduce-Scatter of the partial n_out sums, backward
  All-Gather — message ~ n_out/p * batch floats.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core import tp as tpmod
from repro.parallel.strategies.base import (CommEvent, ProjectionStrategy,
                                            register)


@register("tensor_col")
class TensorColStrategy(ProjectionStrategy):
    """Column-parallel: W sharded on n_out; consumes full features."""

    in_layout = "full"
    out_layout = "shard"

    def decls(self):
        return tpmod.col_linear_decls(self.n_in, self.n_out, self.tp,
                                      bias=self.bias, fsdp=self.fsdp)

    def apply(self, params, x, *, axes=None, compute_dtype=None):
        return tpmod.col_linear_apply(params, x, compute_dtype)

    def apply_shard(self, params, x_shard, axes, compute_dtype=None):
        x_full = tpmod.gather_features(x_shard, axes)
        return tpmod.col_linear_apply(params, x_full, compute_dtype)

    def param_count(self):
        return self.n_in * self.n_out + (self.n_out if self.bias else 0)

    def flops(self, batch):
        return 2.0 * self.n_in * (self.n_out / self.tp) * batch

    def comm_events(self, batch):
        m = (self.n_in / self.tp) * batch
        return [CommEvent("all_gather", m, "fwd"),
                CommEvent("reduce_scatter", m, "bwd")]

    def dense_equivalent(self, params):
        return params["w"], params.get("b")


@register("tensor_row")
class TensorRowStrategy(ProjectionStrategy):
    """Row-parallel: W sharded on n_in; emits partial sums."""

    in_layout = "shard"
    out_layout = "partial"

    def decls(self):
        return tpmod.row_linear_decls(self.n_in, self.n_out, self.tp,
                                      bias=self.bias, fsdp=self.fsdp)

    def apply(self, params, x, *, axes=None, compute_dtype=None):
        """Partial sums over the sharded contraction dim.  The bias (if
        declared) must NOT be folded in here — it would be multiplied by
        p in the reduction; callers add it AFTER reducing, via
        ``add_bias``.  ``apply_shard`` does both internally."""
        return tpmod.row_linear_apply(params, x, compute_dtype)

    def add_bias(self, z_reduced, params, axes=None, sharded=False):
        """Add the replicated bias to the REDUCED output (full features,
        or the local feature shard when ``sharded``)."""
        if "b" not in params:
            return z_reduced
        b = params["b"]
        if sharded:
            j = lax.axis_index(axes.tp_name)
            nloc = self.n_out // self.tp
            b = lax.dynamic_slice_in_dim(b, j * nloc, nloc, 0)
        return z_reduced + b.astype(z_reduced.dtype)

    def apply_shard(self, params, x_shard, axes, compute_dtype=None):
        z = tpmod.row_linear_apply(params, x_shard, compute_dtype)
        z = tpmod.scatter_features(z, axes)
        return self.add_bias(z, params, axes, sharded=True)

    def param_count(self):
        return self.n_in * self.n_out + (self.n_out if self.bias else 0)

    def flops(self, batch):
        return 2.0 * (self.n_in / self.tp) * self.n_out * batch

    def comm_events(self, batch):
        m = (self.n_out / self.tp) * batch
        return [CommEvent("reduce_scatter", m, "fwd"),
                CommEvent("all_gather", m, "bwd")]

    def dense_equivalent(self, params):
        return params["w"], params.get("b")
