"""Spec-aware gradient reduction (DESIGN.md §6).

Inside shard_map, autodiff produces per-device gradient shards.  A param's
gradient must be psum'd over every mesh axis group that does NOT appear in
its PartitionSpec:

  * sharded over tp only              -> psum over dp      (classic DP)
  * FSDP ('dp' in spec)               -> already reduced by the
    all-gather-on-use VJP (reduce-scatter) — no dp psum
  * replicated params (norm scales in sp layout, replicated KV
    projections, BC/dt projections)   -> psum over dp AND tp
  * pipeline meshes: params NOT sharded over pp (embed/head/norms, and
    the mixed-strategy per-stage subtrees) are replicated over the pipe
    axis but only ONE stage computes a non-zero gradient for them, so
    the pipe psum restores the full gradient on every rank; stage-local
    layer stacks ('pp' in spec) keep their shard-local gradients.
"""
from __future__ import annotations

import jax
from jax import lax

from repro.parallel.axes import MeshAxes
from repro.parallel.params import ParamDecl, is_decl


def _spec_axes(spec):
    out = set()
    for e in spec:
        if e is None:
            continue
        if isinstance(e, tuple):
            out.update(e)
        else:
            out.add(e)
    return out


def reduce_grads(grads, decls, axes: MeshAxes):
    def red(g, d):
        ax = _spec_axes(d.spec)
        names = []
        if axes.pp > 1 and "pp" not in ax:
            names.append(axes.pp_name)
        if "dp" not in ax:
            names.extend(axes.dp_names)
        if "tp" not in ax:
            names.append(axes.tp_name)
        return lax.psum(g, tuple(names)) if names else g

    return jax.tree.map(red, grads, decls, is_leaf=is_decl)
