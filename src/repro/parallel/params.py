"""Parameter declaration trees.

A model is described as a pytree of ``ParamDecl`` (shape + logical sharding
spec + init recipe).  From one decl tree we derive, consistently:

  * ``abstract(decls)``      -> ShapeDtypeStruct tree (dry-run, no allocation)
  * ``specs(decls)``         -> logical PartitionSpec tree
  * ``materialize(decls)``   -> real arrays (smoke tests / real training)
  * ``stack(decls, L)``      -> per-layer decls stacked for lax.scan

Initialization is deterministic per path (fold_in of a crc32 of the path),
so re-creating the same model yields bit-identical parameters regardless of
declaration order — required for the elastic-restart tests.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field, replace
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ParamDecl:
    shape: tuple
    spec: P = P()
    init: str = "normal"       # normal | zeros | ones | embed
    scale: float | None = None  # normal stddev; default 1/sqrt(fan_in)
    dtype: Any = jnp.float32

    def fan_in_scale(self) -> float:
        if self.scale is not None:
            return self.scale
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        return fan_in ** -0.5


def is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def _map(tree, fn):
    return jax.tree.map(fn, tree, is_leaf=is_decl)


def abstract(decls):
    return _map(decls, lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype))


def specs(decls):
    return _map(decls, lambda d: d.spec)


def stack(decls, n: int):
    """Add a leading layer axis (for lax.scan over layers)."""
    return _map(decls, lambda d: replace(
        d, shape=(n,) + tuple(d.shape), spec=P(*((None,) + tuple(d.spec)))))


def materialize(decls, seed: int = 0, dtype_override=None):
    """Instantiate real parameter arrays (global shapes)."""
    root = jax.random.key(seed)
    paths_and_decls = jax.tree_util.tree_flatten_with_path(
        decls, is_leaf=is_decl)[0]
    treedef = jax.tree.structure(decls, is_leaf=is_decl)

    leaves = []
    for path, d in paths_and_decls:
        pathstr = "/".join(str(p) for p in path)
        key = jax.random.fold_in(root, zlib.crc32(pathstr.encode()))
        dt = dtype_override or d.dtype
        if d.init == "zeros":
            arr = jnp.zeros(d.shape, dt)
        elif d.init == "ones":
            arr = jnp.ones(d.shape, dt)
        elif d.init == "embed":
            arr = (jax.random.normal(key, d.shape, dt)
                   * jnp.asarray(0.02, dt))
        else:
            arr = (jax.random.normal(key, d.shape, dt)
                   * jnp.asarray(d.fan_in_scale(), dt))
        leaves.append(arr)
    return jax.tree.unflatten(treedef, leaves)


def param_count(decls) -> int:
    total = 0
    for d in jax.tree.leaves(decls, is_leaf=is_decl):
        n = 1
        for s in d.shape:
            n *= s
        total += n
    return total


def param_bytes(decls) -> int:
    total = 0
    for d in jax.tree.leaves(decls, is_leaf=is_decl):
        n = 1
        for s in d.shape:
            n *= s
        total += n * jnp.dtype(d.dtype).itemsize
    return total
