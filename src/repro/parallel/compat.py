"""Version-compat shims for the jax API surface this repo uses.

The repo targets current jax (``jax.shard_map`` with ``check_vma``,
``jax.sharding.AxisType``), but the container may ship jax 0.4.x where
``shard_map`` still lives in ``jax.experimental.shard_map`` and the
replication check is spelled ``check_rep``.  Route every shard_map call
through here so the rest of the codebase stays on the modern spelling.
"""
from __future__ import annotations

import os

import jax

# Async-collective + latency-hiding-scheduler recipe (SNIPPETS.md snippet
# 2): lets XLA start the k-wide ghost all-gather early and overlap it with
# the local diagonal GEMM instead of serializing gather -> decompress.
# These are scheduling hints only — the lowered HLO still contains the
# same collectives, so the PR-6 audit's pricing is unchanged.
COMM_OVERLAP_FLAGS = {
    "gpu": ("--xla_gpu_enable_async_collectives=true "
            "--xla_gpu_enable_latency_hiding_scheduler=true "
            "--xla_gpu_enable_highest_priority_async_stream=true"),
    "tpu": ("--xla_tpu_enable_async_collective_fusion=true "
            "--xla_tpu_enable_async_collective_fusion_fuse_all_gather"
            "=true "
            "--xla_tpu_overlap_compute_collective_tc=true "
            "--xla_enable_async_all_gather=true "
            "--xla_tpu_enable_latency_hiding_scheduler=true"),
    # CPU XLA has no async-collective scheduler and rejects the
    # accelerator-only flags, so overlap is a no-op there.
    "cpu": "",
}


def comm_overlap_flags(platform: str) -> str:
    """The XLA_FLAGS fragment enabling comm/compute overlap on
    ``platform`` ("tpu" | "gpu" | "cpu")."""
    try:
        return COMM_OVERLAP_FLAGS[platform]
    except KeyError:
        raise ValueError(f"unknown platform {platform!r}; known: "
                         f"{sorted(COMM_OVERLAP_FLAGS)}") from None


def enable_comm_overlap(platform: str) -> str:
    """Append the overlap recipe for ``platform`` to ``XLA_FLAGS``.

    Must run before jax initializes its backend (XLA_FLAGS is read at
    client creation); idempotent — flags already present are not
    re-appended.  Returns the flags applied ("" on cpu)."""
    flags = comm_overlap_flags(platform)
    if not flags:
        return ""
    current = os.environ.get("XLA_FLAGS", "")
    missing = [f for f in flags.split() if f not in current]
    if missing:
        os.environ["XLA_FLAGS"] = " ".join(
            ([current] if current else []) + missing)
    return " ".join(missing)

_NEW = hasattr(jax, "shard_map")
if not _NEW:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map


def tpu_compiler_params():
    """pltpu.CompilerParams, or its jax 0.4.x name TPUCompilerParams."""
    from jax.experimental.pallas import tpu as pltpu
    return getattr(pltpu, "CompilerParams", None) \
        or pltpu.TPUCompilerParams


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    if _NEW:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check_vma)
