"""Version-compat shims for the jax API surface this repo uses.

The repo targets current jax (``jax.shard_map`` with ``check_vma``,
``jax.sharding.AxisType``), but the container may ship jax 0.4.x where
``shard_map`` still lives in ``jax.experimental.shard_map`` and the
replication check is spelled ``check_rep``.  Route every shard_map call
through here so the rest of the codebase stays on the modern spelling.
"""
from __future__ import annotations

import jax

_NEW = hasattr(jax, "shard_map")
if not _NEW:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map


def tpu_compiler_params():
    """pltpu.CompilerParams, or its jax 0.4.x name TPUCompilerParams."""
    from jax.experimental.pallas import tpu as pltpu
    return getattr(pltpu, "CompilerParams", None) \
        or pltpu.TPUCompilerParams


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    if _NEW:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check_vma)
