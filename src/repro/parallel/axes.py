"""Logical parallel axes and their binding to a concrete mesh.

Logical axis names used in all ``ParamDecl`` specs and activation specs:

  * ``"dp"`` — data parallel.  Binds to ``('pod','data')`` on the multi-pod
    mesh and ``('data',)`` on the single-pod mesh.
  * ``"tp"`` — tensor/model parallel (also hosts EP and the phantom axis).
    Binds to ``'model'``.
  * ``"pp"`` — pipeline parallel (layer-to-stage partitioning).  Binds to
    ``'pipe'`` when the mesh provides one; meshes without a pipe axis are
    pp=1 and every ``"pp"`` spec entry resolves to replicated.

Everything inside ``shard_map`` uses these via a ``MeshAxes`` handle so the
same model code runs on any mesh that provides the logical axes.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class MeshAxes:
    tp: int                      # size of the model axis
    dp: int                      # total data-parallel ways (pod * data)
    dp_names: tuple              # ('pod','data') or ('data',)
    tp_name: str = "model"
    pp: int = 1                  # size of the pipeline axis
    pp_name: str = "pipe"

    @classmethod
    def from_mesh(cls, mesh: Mesh) -> "MeshAxes":
        names = mesh.axis_names
        dp_names = tuple(n for n in names if n in ("pod", "data"))
        dp = 1
        for n in dp_names:
            dp *= mesh.shape[n]
        pp = mesh.shape["pipe"] if "pipe" in names else 1
        return cls(tp=mesh.shape["model"], dp=dp, dp_names=dp_names, pp=pp)

    @property
    def all_names(self):
        return self.pp_names + self.dp_names + (self.tp_name,)

    @property
    def pp_names(self) -> tuple:
        """('pipe',) when the mesh has a pipeline axis, else ()."""
        return (self.pp_name,) if self.pp > 1 else ()


def resolve_spec(spec: P, axes: MeshAxes) -> P:
    """Map a logical PartitionSpec ('dp'/'tp' entries) to mesh axis names."""
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif entry == "dp":
            out.append(axes.dp_names if len(axes.dp_names) > 1
                       else axes.dp_names[0])
        elif entry == "tp":
            out.append(axes.tp_name)
        elif entry == "pp":
            # meshes without a pipe axis treat pp-sharded dims as replicated
            out.append(axes.pp_name if axes.pp > 1 else None)
        elif isinstance(entry, tuple):
            flat = []
            for e in entry:
                if e == "dp":
                    flat.extend(axes.dp_names)
                elif e == "tp":
                    flat.append(axes.tp_name)
                elif e == "pp":
                    if axes.pp > 1:
                        flat.append(axes.pp_name)
                else:
                    flat.append(e)
            out.append(tuple(flat) if flat else None)
        else:
            out.append(entry)
    return P(*out)


def named_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(spec, MeshAxes.from_mesh(mesh)))


def dp_axis_index(axes: MeshAxes):
    """Linear index of this device along the (flattened) dp axes."""
    idx = 0
    for n in axes.dp_names:
        idx = idx * jax.lax.axis_size(n) + jax.lax.axis_index(n)
    return idx
