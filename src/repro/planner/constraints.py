"""Resource feasibility for candidate plans.

Two tiers, by cost:

  * ``hbm_bytes_estimate`` — analytic napkin math (params + AdamW
    moments + grads + saved activations), cheap enough to filter the
    whole enumeration;
  * ``compiled_hbm_bytes`` — the ground truth for the survivors: lower
    the candidate's real probe step and read
    ``memory_analysis()`` through the shared cached
    ``telemetry.analyze_lowered`` entry point (the same cache the
    dry-run uses, so a module analyzed once is never re-lowered).

Throughput constraints price the candidate's step time with the
calibrated Eqn. 26 model — on a TPU target pass
``fits=tpu_collective_fits()`` through the calibration.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.planner.space import PlanCandidate

FLOAT_BYTES = 4.0
# TPU v5e HBM per chip; the CLI overrides for other targets.
DEFAULT_HBM_BYTES = 16 * 2 ** 30
# AdamW: params + m + v + grads, all fp32 in this repo's decls
_OPT_STATE_COPIES = 4.0


@dataclass
class Constraints:
    max_devices: int
    hbm_bytes_per_device: float = DEFAULT_HBM_BYTES
    min_throughput_rows_s: float = 0.0     # global rows/second floor

    def as_dict(self) -> dict:
        return {"max_devices": self.max_devices,
                "hbm_bytes_per_device": self.hbm_bytes_per_device,
                "min_throughput_rows_s": self.min_throughput_rows_s}


def hbm_bytes_estimate(plan: PlanCandidate) -> float:
    """Analytic per-device bytes for the training step.

    params/(tp·pp) · 4 copies (AdamW) + saved activations for the
    backward (one [rows_local, n/tp] tensor per stage-local layer plus
    the x/y batch, times the 1F1B in-flight bound min(mb, pp) for
    pipelined plans — stage 0 holds that many microbatches mid-
    wavefront).  For flat plans this is deliberately a slight
    over-estimate — the filter must not pass a plan the compiled check
    would reject.  Pipelined plans are priced at the IDEAL deployment
    bound; the compiled check lowers the SPMD *emulation*, whose
    unrolled wavefront retains all mb+pp-1 ticks of activations, so it
    can measure above this estimate — `launch/plan.py`'s recheck loop
    handles such late rejections by design."""
    from repro.parallel.strategies import make_strategy
    from repro.train.pipeline import PipelineSchedule
    st = make_strategy(plan.spec(), plan.width, plan.width, plan.tp)
    pp = max(plan.pp, 1)
    params_local = plan.depth * st.param_count() / plan.tp / pp
    state = params_local * _OPT_STATE_COPIES * FLOAT_BYTES
    rows_local = plan.batch / (plan.dp * plan.microbatches)
    feat_local = plan.width / plan.tp
    in_flight = 1
    if pp > 1:
        sched = PipelineSchedule(stages=pp, microbatches=plan.microbatches)
        in_flight = sched.max_in_flight(0)
    acts = (rows_local * feat_local * (plan.depth / pp + 2)
            * in_flight * FLOAT_BYTES)
    return state + acts


def compiled_hbm_bytes(plan: PlanCandidate, mesh) -> Optional[float]:
    """Per-device buffer bytes of the lowered probe step (argument +
    temp), via the shared analysis cache.  Returns None when the
    compiler reports no memory analysis (some backends).  Pipelined
    plans lower the 1F1B wavefront probe, so the mesh must carry the
    plan's pipe axis — and the number measured is the SPMD emulation's
    (all wavefront ticks resident), an upper bound on the ideal 1F1B
    deployment `hbm_bytes_estimate` prices."""
    import jax
    import jax.numpy as jnp

    from repro.parallel.params import abstract
    from repro.telemetry import analyze_lowered
    from repro.telemetry.probe import (make_ffn_pipeline_probe_step,
                                       make_ffn_probe_step)

    cfg = plan.model_config()
    make_probe = (make_ffn_pipeline_probe_step if plan.pp > 1
                  else make_ffn_probe_step)
    fn, decls = make_probe(cfg, mesh, plan.batch)
    x_sds = jax.ShapeDtypeStruct((plan.batch, plan.width), jnp.float32)
    lowered = fn.lower(abstract(decls), x_sds, x_sds)
    costs = analyze_lowered(lowered, default_group=plan.tp)
    mem = costs.memory or {}
    parts = [mem.get("argument_bytes"), mem.get("temp_bytes")]
    if all(v is None for v in parts):
        return None
    return float(sum(v or 0 for v in parts))


@dataclass
class Rejection:
    plan: PlanCandidate
    reason: str

    def as_dict(self) -> dict:
        return {"plan": self.plan.name, "reason": self.reason}


def filter_feasible(plans: List[PlanCandidate], constraints: Constraints
                    ) -> Tuple[List[PlanCandidate], List[Rejection]]:
    """Device-count and analytic-HBM filtering with recorded reasons.
    (Throughput needs a scored step time — ``planner.score`` applies
    ``min_throughput_rows_s`` after pricing.)"""
    kept: List[PlanCandidate] = []
    rejected: List[Rejection] = []
    for plan in plans:
        if plan.devices > constraints.max_devices:
            rejected.append(Rejection(
                plan, f"devices {plan.devices} > "
                      f"{constraints.max_devices} available"))
            continue
        est = hbm_bytes_estimate(plan)
        if est > constraints.hbm_bytes_per_device:
            rejected.append(Rejection(
                plan, f"HBM estimate {est/2**30:.2f} GiB > "
                      f"{constraints.hbm_bytes_per_device/2**30:.2f} "
                      f"GiB budget"))
            continue
        kept.append(plan)
    return kept, rejected
