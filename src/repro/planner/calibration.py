"""Calibrating the analytic energy model from the measured ledger.

The paper prices a configuration with E = ν·p·(A·α + B·β) (Eqns. 1–2)
where α/β are summed from the executing ``ProjectionStrategy`` objects
and β's collective times come from the Table III (c1, c2) fits.  The
measured-vs-predicted ledger (PR 2) records how far those analytic
accounts drift from what the compiler lowered and the machine executed —
this module closes the loop by FITTING per-strategy correction constants
from ``BENCH_ledger.jsonl`` so the planner scores candidate plans with a
model calibrated to *this* machine:

  * ``alpha_scale[kind]`` — measured/predicted flops, least-squares
    through the origin over that strategy's joined rows (the documented
    3×-GEMM undercount of the phantom backward lands here);
  * ``beta_scale[kind]``  — measured/predicted collective wire bytes
    (ring model both sides, so this pins near 1.0 unless a strategy
    issues unmodeled collectives);
  * ``nu_scale[kind]``    — iterations-to-target relative to the tensor
    baseline, from the Table I reproduction rows (``table1_*_iters``);
  * ``collective_fits``   — the (c1, c2) Eqn. 26 constants per
    collective, taken from the ``comm_model`` suite's measured fits.

Documented fallbacks (recorded in ``provenance``): with no ledger — or
no usable rows for a given constant — scales default to 1.0 and the
comm constants fall back to the paper's Table III Frontier fits
(``core.energy.PAPER_COLLECTIVE_FITS``), i.e. the uncalibrated paper
model.  ``lowrank_distill`` shares ``phantom``'s cost structure and
inherits its fitted scales when it has no rows of its own.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.energy import PAPER_COLLECTIVE_FITS

# ledger `impl` values -> strategy kind the constant calibrates
_IMPL_TO_KIND = {
    "tensor_col": "tensor_col",
    "tensor_row": "tensor_row",
    "dense": "tensor_col",
    "phantom": "phantom",
    "lowrank_distill": "lowrank_distill",
}

# strategy kinds that inherit another kind's fit when they have no rows
_KIND_FALLBACK = {"lowrank_distill": "phantom"}

PAPER_SOURCE = "paper defaults (Table III constants, scales = 1.0)"
LEDGER_SOURCE = "ledger-fit"


def least_squares_scale(pairs: Sequence[Tuple[float, float]]) -> float:
    """The s minimizing Σ (measured − s·predicted)² — the one-parameter
    least-squares fit of measured = s·predicted through the origin."""
    num = sum(m * p for p, m in pairs)
    den = sum(p * p for p, _ in pairs)
    return num / den if den else 1.0


@dataclass
class Calibration:
    """Fitted (or default) constants the planner prices plans with."""

    alpha_scale: Dict[str, float] = field(default_factory=dict)
    beta_scale: Dict[str, float] = field(default_factory=dict)
    nu_scale: Dict[str, float] = field(default_factory=dict)
    collective_fits: Dict[str, tuple] = field(
        default_factory=lambda: dict(PAPER_COLLECTIVE_FITS))
    provenance: Dict[str, dict] = field(default_factory=dict)
    source: str = PAPER_SOURCE

    def scales_for(self, kind: str) -> Tuple[float, float, float]:
        """(alpha_scale, beta_scale, nu_scale) for one strategy kind,
        resolving the documented lowrank→phantom inheritance."""
        base = _KIND_FALLBACK.get(kind)
        def get(table, default=1.0):
            if kind in table:
                return table[kind]
            if base is not None and base in table:
                return table[base]
            return default
        return (get(self.alpha_scale), get(self.beta_scale),
                get(self.nu_scale))

    def as_dict(self) -> dict:
        return {
            "alpha_scale": dict(self.alpha_scale),
            "beta_scale": dict(self.beta_scale),
            "nu_scale": dict(self.nu_scale),
            "collective_fits": {k: list(v)
                                for k, v in self.collective_fits.items()},
            "provenance": self.provenance,
            "source": self.source,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Calibration":
        """Rehydrate a serialized calibration (the ``calibration`` block
        of ``PLAN_report.json``) — how the serving router reuses the
        constants a planning pass already fitted."""
        return cls(
            alpha_scale=dict(d.get("alpha_scale") or {}),
            beta_scale=dict(d.get("beta_scale") or {}),
            nu_scale=dict(d.get("nu_scale") or {}),
            collective_fits={k: tuple(v) for k, v in
                             (d.get("collective_fits") or
                              PAPER_COLLECTIVE_FITS).items()},
            provenance=dict(d.get("provenance") or {}),
            source=d.get("source", PAPER_SOURCE))


def paper_default_calibration() -> Calibration:
    """The documented no-ledger fallback: the paper model verbatim."""
    return Calibration(provenance={"all": {"source": PAPER_SOURCE}})


def _load_rows(jsonl_path: Optional[str] = None,
               report: Optional[dict] = None) -> List[dict]:
    if report is not None:
        return list(report.get("entries", []))
    if jsonl_path and os.path.exists(jsonl_path):
        rows = []
        with open(jsonl_path) as f:
            for line in f:
                line = line.strip()
                if line:
                    rows.append(json.loads(line))
        return rows
    return []


def _fit_scales(rows: List[dict], key: str) -> Tuple[Dict[str, float],
                                                     Dict[str, dict]]:
    """Per-strategy least-squares scale over rows joining `key`."""
    by_kind: Dict[str, list] = {}
    used: Dict[str, list] = {}
    for r in rows:
        kind = _IMPL_TO_KIND.get(r.get("impl", ""))
        m = (r.get("measured") or {}).get(key)
        p = (r.get("predicted") or {}).get(key)
        if kind is None or not isinstance(m, (int, float)) \
                or not isinstance(p, (int, float)) or not p:
            continue
        by_kind.setdefault(kind, []).append((float(p), float(m)))
        used.setdefault(kind, []).append(r.get("name", "?"))
    scales, prov = {}, {}
    for kind, pairs in by_kind.items():
        scales[kind] = least_squares_scale(pairs)
        prov[kind] = {"source": LEDGER_SOURCE, "key": key,
                      "rows": used[kind], "n_rows": len(pairs),
                      "fitted": scales[kind]}
    return scales, prov


def _fit_nu(rows: List[dict]) -> Tuple[Dict[str, float], Dict[str, dict]]:
    """Iterations-to-fixed-loss relative to the tensor baseline, from
    rows carrying ``measured.iterations`` at a shared target loss (the
    Table I reproduction).  The phantom scale is the BEST (fewest-
    iteration) phantom row over the baseline — matching how Table I
    picks its k.  Only ``kind == "train"`` rows qualify: the planner's
    own pilot rows (``kind == "pilot"``) also carry iteration counts,
    and fitting those back in would double-apply ν on the very runs
    the iso-loss pass already prices directly."""
    rows = [r for r in rows if r.get("kind") == "train"]
    base = [r for r in rows
            if _IMPL_TO_KIND.get(r.get("impl", "")) == "tensor_col"
            and isinstance((r.get("measured") or {}).get("iterations"),
                           (int, float))]
    if not base:
        return {}, {}
    targets = {}
    for r in base:
        t = (r.get("extra") or {}).get("target_loss")
        targets.setdefault(t, r)
    scales: Dict[str, float] = {}
    prov: Dict[str, dict] = {}
    for r in rows:
        kind = _IMPL_TO_KIND.get(r.get("impl", ""))
        if kind in (None, "tensor_col"):
            continue
        it = (r.get("measured") or {}).get("iterations")
        t = (r.get("extra") or {}).get("target_loss")
        if not isinstance(it, (int, float)) or t not in targets:
            continue
        base_it = targets[t]["measured"]["iterations"]
        ratio = float(it) / max(float(base_it), 1.0)
        if kind not in scales or ratio < scales[kind]:
            scales[kind] = ratio
            prov[kind] = {"source": LEDGER_SOURCE, "key": "iterations",
                          "rows": [targets[t].get("name", "?"),
                                   r.get("name", "?")],
                          "baseline_iterations": base_it,
                          "iterations": it, "fitted": ratio}
    return scales, prov


def _fit_collectives(rows: List[dict]) -> Tuple[Dict[str, tuple],
                                                Dict[str, dict]]:
    """(c1, c2) per collective from the comm_model suite's measured
    fits (kind == "collective", impl = collective name)."""
    fits, prov = {}, {}
    for r in rows:
        if r.get("kind") != "collective":
            continue
        name = r.get("impl", "")
        m = r.get("measured") or {}
        c1, c2 = m.get("c1_us"), m.get("c2_us_per_float")
        if name in PAPER_COLLECTIVE_FITS and \
                isinstance(c1, (int, float)) and isinstance(c2, (int, float)):
            fits[name] = (float(c1), float(c2))
            prov[name] = {"source": LEDGER_SOURCE,
                          "rows": [r.get("name", "?")],
                          "c1_us": c1, "c2_us_per_float": c2}
    return fits, prov


def calibrate_from_rows(rows: List[dict]) -> Calibration:
    """Fit every constant the rows support; paper defaults elsewhere."""
    if not rows:
        return paper_default_calibration()
    alpha, prov_a = _fit_scales(rows, "flops_per_device")
    beta, prov_b = _fit_scales(rows, "collective_wire_bytes_per_device")
    nu, prov_n = _fit_nu(rows)
    coll, prov_c = _fit_collectives(rows)
    prov: Dict[str, dict] = {}
    prov.update({f"alpha_scale.{k}": v for k, v in prov_a.items()})
    prov.update({f"beta_scale.{k}": v for k, v in prov_b.items()})
    prov.update({f"nu_scale.{k}": v for k, v in prov_n.items()})
    prov.update({f"collective_fits.{k}": v for k, v in prov_c.items()})
    fits = dict(PAPER_COLLECTIVE_FITS)
    for k in fits:
        if k not in coll:
            prov[f"collective_fits.{k}"] = {"source": PAPER_SOURCE}
    fits.update(coll)
    fitted_any = bool(alpha or beta or nu or coll)
    return Calibration(
        alpha_scale=alpha, beta_scale=beta, nu_scale=nu,
        collective_fits=fits, provenance=prov,
        source=(LEDGER_SOURCE if fitted_any else PAPER_SOURCE))


def calibrate_from_ledger(jsonl_path: Optional[str] = None,
                          report: Optional[dict] = None) -> Calibration:
    """The planner's calibration entry point.

    Reads joined rows from a ``BENCH_ledger.jsonl`` stream (or an
    already-loaded ``BENCH_report.json`` dict) and fits what it can;
    with neither, returns the documented paper-defaults calibration."""
    rows = _load_rows(jsonl_path, report)
    return calibrate_from_rows(rows)


def load_calibration(plan_report_path: Optional[str] = None,
                     ledger_path: Optional[str] = None) -> Calibration:
    """The SERVING-side calibration entry point (docs/serving.md).

    Preference order: the constants a planning pass already fitted and
    serialized into ``PLAN_report.json`` > a fresh fit from
    ``BENCH_ledger.jsonl`` > the documented paper defaults.  Missing or
    unreadable files fall through rather than raise — serving must come
    up on a blank checkout."""
    if plan_report_path and os.path.exists(plan_report_path):
        try:
            with open(plan_report_path) as f:
                rec = json.load(f)
            block = rec.get("calibration")
            if block:
                return Calibration.from_dict(block)
        except (OSError, ValueError):
            pass
    if ledger_path and os.path.exists(ledger_path):
        return calibrate_from_ledger(jsonl_path=ledger_path)
    return paper_default_calibration()
