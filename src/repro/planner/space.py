"""The planner's search space: mesh shape × per-site strategy × phantom
(ghost) width × microbatch/scan settings.

A ``PlanCandidate`` is one fully-specified configuration the paper's
final claim quantifies over — notably it may use FEWER devices than are
available (``devices <= max devices``): the claim is exactly that a
phantom plan on a *smaller* mesh can match a tensor-parallel plan on the
full mesh at lower energy.  ``model_config()`` turns a candidate into
the ``ModelConfig`` the trainer/benchmarks consume, with the strategy
selection expressed through ``ModelConfig.projections`` (the
ProjectionStrategy API's config side — no legacy ``ffn_impl`` shims).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.configs.base import (PHANTOM_KINDS, PROJECTION_SITES,
                                ModelConfig, PipelineConfig, ProjectionMap,
                                ProjectionSpec)


@dataclass(frozen=True)
class PlanCandidate:
    """One point of the search space (paper-FFN subject by default)."""

    dp: int                        # data-parallel ways
    tp: int                        # model-parallel ways (the paper's p)
    strategy: str                  # projection kind at `site`
    width: int                     # model width n
    depth: int                     # layers L
    batch: int                     # global batch rows per step
    k: int = 0                     # ghost width (phantom family only)
    pp: int = 1                    # pipeline stages (pipe mesh axis)
    site: str = "ffn_layer"        # projection site the strategy binds to
    microbatches: int = 1
    scan_layers: bool = True
    variant: str = "fused"
    kernel_backend: str = "xla"    # xla | pallas | auto (docs/kernels.md)

    @property
    def devices(self) -> int:
        return self.dp * self.tp * self.pp

    @property
    def name(self) -> str:
        tag = f"{self.strategy}_n{self.width}_mesh{self.dp}x{self.tp}"
        if self.pp > 1:
            tag += f"x{self.pp}pp"
        if self.strategy in PHANTOM_KINDS:
            tag += f"_k{self.k}"
        if self.microbatches > 1:
            tag += f"_mb{self.microbatches}"
        if self.kernel_backend != "xla":
            tag += f"_{self.kernel_backend}"
        return tag

    def spec(self) -> ProjectionSpec:
        if self.strategy in PHANTOM_KINDS:
            return ProjectionSpec(kind=self.strategy, k=self.k,
                                  variant=self.variant,
                                  kernel_backend=self.kernel_backend)
        return ProjectionSpec(kind=self.strategy,
                              kernel_backend=self.kernel_backend)

    def model_config(self) -> ModelConfig:
        return ModelConfig(
            name=self.name, family="ffn", num_layers=self.depth,
            d_model=self.width, ffn_width=self.width, ffn_depth=self.depth,
            mlp="relu", microbatches=self.microbatches,
            scan_layers=self.scan_layers,
            pipeline=PipelineConfig(stages=self.pp),
            projections=ProjectionMap(**{self.site: self.spec()}))

    def with_width(self, width: int) -> "PlanCandidate":
        return replace(self, width=width)

    def as_dict(self) -> dict:
        return {
            "name": self.name, "dp": self.dp, "tp": self.tp,
            "pp": self.pp,
            "devices": self.devices, "strategy": self.strategy,
            "site": self.site, "width": self.width, "depth": self.depth,
            "batch": self.batch, "k": self.k,
            "microbatches": self.microbatches,
            "scan_layers": self.scan_layers,
            "projection_spec": {"kind": self.spec().kind,
                                "k": self.spec().k,
                                "variant": self.spec().variant},
        }


def mesh_shapes(max_devices: int,
                device_counts: Optional[Iterable[int]] = None
                ) -> List[Tuple[int, int]]:
    """All (dp, tp) factorizations of every candidate device count.

    Device counts default to the divisors of ``max_devices`` — the
    sub-meshes a torus slice actually offers — so an 8-device budget
    searches 1, 2, 4 and 8 chips."""
    if device_counts is None:
        device_counts = [d for d in range(1, max_devices + 1)
                         if max_devices % d == 0]
    shapes = []
    for d in device_counts:
        for tp in range(1, d + 1):
            if d % tp == 0:
                shapes.append((d // tp, tp))
    return shapes


def enumerate_plans(max_devices: int, *, width: int, depth: int,
                    batch: int,
                    strategies: Sequence[str] = ("tensor_col", "phantom"),
                    ks: Sequence[int] = (4, 8, 16),
                    microbatch_options: Sequence[int] = (1,),
                    pps: Sequence[int] = (1, 2),
                    site: str = "ffn_layer",
                    device_counts: Optional[Iterable[int]] = None,
                    allow_submesh_tensor: bool = False,
                    kernel_backends: Sequence[str] = ("xla",)
                    ) -> List[PlanCandidate]:
    """Enumerate the structurally-valid dp×tp×pp×strategy×k candidates.

    Validity here is *model-class* validity (divisibility, the phantom
    ghost-width regime k < n/p, layer stack dividing into pp stages);
    resource feasibility (HBM fit, minimum throughput) is
    `planner.constraints`' job so rejections can be reported with
    reasons.

    Tensor-family plans use the FULL device budget (dp×pp fill whatever
    the model axis doesn't): they are the baseline the paper compares
    against, and idling paid-for devices under the baseline would make
    every comparison trivially winnable.  Phantom-family plans may
    downsize — "fewer GPUs at the same loss" is the claim under test.
    ``allow_submesh_tensor=True`` opens the baseline family up too."""
    if site not in PROJECTION_SITES:
        raise KeyError(f"unknown projection site {site!r}")
    plans: List[PlanCandidate] = []
    seen_meshes = set()
    for dp, tp in mesh_shapes(max_devices, device_counts):
        for pp in pps:
            if pp < 1 or (dp * tp) % pp or pp > depth or depth % pp:
                continue
            # re-factor (dp, tp) so the three axes multiply to the same
            # device count: pp devices come out of the dp dimension
            # first (stage boundaries replace gradient replication,
            # not the model axis)
            if dp % pp == 0:
                dpp, tpp = dp // pp, tp
            elif tp % pp == 0 and tp // pp >= 1:
                dpp, tpp = dp, tp // pp
            else:
                continue
            key = (dpp, tpp, pp)
            if key in seen_meshes:
                continue
            seen_meshes.add(key)
            if width % max(tpp, 1) or batch % max(dpp, 1):
                continue
            for strat in strategies:
                phantom = strat in PHANTOM_KINDS
                if phantom and (tpp < 2 or width % tpp):
                    continue    # the phantom class needs >= 2 ranks
                if not phantom and not allow_submesh_tensor \
                        and dpp * tpp * pp != max_devices:
                    continue
                for mb in microbatch_options:
                    if batch % (dpp * mb):
                        continue
                    for k in (ks if phantom else (0,)):
                        # paper Eqn. 8 operating regime: ghosts narrower
                        # than the activation shard they replace
                        if phantom and k >= width // tpp:
                            continue
                        # kernel backend only changes the phantom fused
                        # inner op — non-phantom plans get one entry
                        for kb in (kernel_backends if phantom
                                   else kernel_backends[:1]):
                            plans.append(PlanCandidate(
                                dp=dpp, tp=tpp, strategy=strat,
                                width=width, depth=depth, batch=batch,
                                k=k, pp=pp, site=site, microbatches=mb,
                                kernel_backend=kb))
    return plans
