"""Scoring candidate plans with the calibrated energy model, and the
Pareto frontier over (predicted energy, predicted step time, quality).

The objective is exactly the paper's E = ν·p·(A·α + B·β), with three
calibration hooks from ``planner.calibration``:

  * α is scaled by the strategy's fitted ``alpha_scale`` (flops-model
    drift), β by ``beta_scale`` (wire-byte drift);
  * β's collective times are priced with the calibrated (c1, c2)
    Eqn. 26 constants — the comm_model suite's measured fits when a
    ledger exists, the paper's Table III otherwise;
  * ν is ``iterations · nu_scale[kind]`` — or the pilot-measured
    iterations-to-target when the iso-loss pass supplies one.

Microbatching is modeled faithfully: gradient accumulation leaves total
GEMM work unchanged but repeats each layer collective once per
microbatch at 1/mb the message size, so the c1·log2(p) latency term
multiplies by mb — the planner can therefore see when accumulation
stops being free.

Pipelined plans (pp > 1) price the IDEAL 1F1B deployment: each device
computes only its own L/pp layers (α and the layer-collective β divide
by pp), pays the stage-boundary point-to-point transfers (one
``collective_permute`` hop of the carried [rows_mb, n/tp] shard per
microbatch per direction — ``PipelineSchedule.p2p_events``), and idles
through the warmup/drain bubble — charged at static power B for the
bubble-stretched fraction (pp-1)/mb of the working step time, with the
step time itself stretched by (mb + pp - 1)/mb.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.energy import FRONTIER_A_W, FRONTIER_B_W, TPU_PEAK_FLOPS
from repro.planner.calibration import Calibration
from repro.planner.space import PlanCandidate


@dataclass
class ScoredPlan:
    plan: PlanCandidate
    alpha_s: float                 # calibrated compute seconds / iter
    beta_s: float                  # calibrated comm seconds / iter
    step_time_s: float
    energy_j_per_iter: float
    iterations: float              # ν to the target loss
    energy_j_total: float
    throughput_rows_s: float
    param_count: int               # model size (the capacity proxy)
    hbm_bytes_per_device: float = 0.0   # analytic napkin estimate
    predicted_loss: Optional[float] = None
    quality: Optional[float] = None   # lower is better (loss proxy)
    notes: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        d = {"plan": self.plan.as_dict(),
             "alpha_s": self.alpha_s, "beta_s": self.beta_s,
             "step_time_s": self.step_time_s,
             "energy_j_per_iter": self.energy_j_per_iter,
             "iterations": self.iterations,
             "energy_j_total": self.energy_j_total,
             "throughput_rows_s": self.throughput_rows_s,
             "param_count": self.param_count,
             "hbm_bytes_per_device": self.hbm_bytes_per_device}
        if self.predicted_loss is not None:
            d["predicted_loss"] = self.predicted_loss
        if self.quality is not None:
            d["quality"] = self.quality
        if self.notes:
            d["notes"] = self.notes
        return d


def score_plan(plan: PlanCandidate, calib: Calibration, *,
               iterations: float = 1.0,
               peak_flops: float = TPU_PEAK_FLOPS,
               A: float = FRONTIER_A_W, B: float = FRONTIER_B_W,
               training: bool = True,
               apply_nu_scale: bool = True) -> ScoredPlan:
    """Price one candidate with the calibrated model.

    ``apply_nu_scale=False`` when ``iterations`` is already a MEASURED
    iterations-to-target (the iso-loss pilots) — the calibration's
    fitted ν scale corrects *predicted* iteration counts and must not
    be applied on top of a measurement."""
    from repro.core.energy import (comm_time_us, costs_from_strategies,
                                   pipeline_p2p_time_us)
    from repro.parallel.strategies import make_strategy
    from repro.train.pipeline import PipelineSchedule

    st = make_strategy(plan.spec(), plan.width, plan.width, plan.tp,
                       dp=plan.dp)
    s_a, s_b, s_nu = calib.scales_for(plan.strategy)
    mb = plan.microbatches
    pp = max(plan.pp, 1)
    rows_per_pass = plan.batch / (plan.dp * mb)
    alpha, beta = costs_from_strategies(
        [st], plan.tp, plan.depth, rows_per_pass, peak_flops,
        fits=calib.collective_fits, training=training)
    # each pipeline stage computes only its own depth/pp layers
    alpha = alpha * mb * s_a / pp
    beta = beta * mb * s_b / pp
    if pp > 1:
        # stage-boundary p2p: the carried feature shard crosses each
        # boundary once per microbatch per direction
        sched = PipelineSchedule(stages=pp, microbatches=mb)
        m_boundary = rows_per_pass * plan.width / plan.tp
        beta += pipeline_p2p_time_us(
            sched, m_boundary, calib.collective_fits) * 1e-6 * s_b
    if training and plan.dp > 1:
        # data-parallel gradient synchronization: the step all-reduces
        # each layer's local (tp-sharded) parameter grads over the dp
        # group once per step — NOT per microbatch (accumulation syncs
        # after the last pass).  Without this term a pure-DP plan would
        # falsely price as communication-free.  Pipelined devices hold
        # (and sync) only their own stage's depth/pp layers.
        m_grads = st.param_count() / plan.tp
        us = comm_time_us("all_reduce", m_grads, plan.dp,
                          calib.collective_fits)
        beta += us * (plan.depth / pp) * 1e-6 * s_b
    work_s = alpha + beta
    # 1F1B warmup/drain bubble: the timeline stretches by (mb+pp-1)/mb;
    # devices idle through the stretch at static power B
    bubble_s = work_s * (pp - 1) / mb if pp > 1 else 0.0
    step_s = work_s + bubble_s
    e_iter = plan.devices * (A * alpha + B * (beta + bubble_s))
    nu = iterations * (s_nu if apply_nu_scale else 1.0)
    notes = {"alpha_scale": s_a, "beta_scale": s_b, "nu_scale": s_nu,
             "A_w": A, "B_w": B, "peak_flops": peak_flops}
    if pp > 1:
        notes["pp"] = pp
        notes["bubble_s"] = bubble_s
        notes["bubble_fraction"] = (pp - 1) / (mb + pp - 1)
    from repro.planner.constraints import hbm_bytes_estimate
    return ScoredPlan(
        plan=plan, alpha_s=alpha, beta_s=beta, step_time_s=step_s,
        energy_j_per_iter=e_iter, iterations=nu,
        energy_j_total=nu * e_iter,
        throughput_rows_s=(plan.batch / step_s) if step_s else 0.0,
        param_count=plan.depth * st.param_count(),
        hbm_bytes_per_device=hbm_bytes_estimate(plan),
        notes=notes)


def score_plans(plans: Sequence[PlanCandidate], calib: Calibration,
                **kw) -> List[ScoredPlan]:
    return [score_plan(p, calib, **kw) for p in plans]


def apply_throughput_floor(scored: Sequence[ScoredPlan],
                           min_rows_s: float):
    """Split scored plans on the throughput constraint."""
    if min_rows_s <= 0:
        return list(scored), []
    kept, rejected = [], []
    for s in scored:
        if s.throughput_rows_s >= min_rows_s:
            kept.append(s)
        else:
            rejected.append((s, f"throughput {s.throughput_rows_s:.1f} "
                                f"rows/s < {min_rows_s:.1f} floor"))
    return kept, rejected


def pareto_frontier(scored: Sequence[ScoredPlan],
                    keys: Sequence[str] = ("energy_j_total",
                                           "step_time_s",
                                           "hbm_bytes_per_device")
                    ) -> List[ScoredPlan]:
    """Non-dominated set, minimizing every key; sorted by the first.

    With the iso-loss pass normalizing every plan to the same predicted
    loss, the default frontier spans the three resources a deployment
    trades: energy, step time, and per-device memory.  Memory is what
    pipeline parallelism buys (each stage holds 1/pp of the stack and
    1F1B bounds in-flight activations at min(mb, pp)), so pp>1 plans
    appear here as the memory-lean points even when the latency-priced
    energy/step corner belongs to a small phantom mesh.  Restricting
    ``keys`` to (energy, step time) recovers the classic monotone 2-D
    curve — sorted by energy, step time non-increasing by construction
    of dominance."""
    def vec(s: ScoredPlan):
        return tuple(getattr(s, k) for k in keys)

    def dominates(a, b):
        return all(x <= y for x, y in zip(a, b)) and a != b

    front = []
    for s in scored:
        v = vec(s)
        if any(dominates(vec(o), v) for o in scored if o is not s):
            continue
        front.append(s)
    # drop exact duplicates in objective space (keep first)
    seen: Dict[tuple, bool] = {}
    uniq = []
    for s in sorted(front, key=vec):
        if vec(s) in seen:
            continue
        seen[vec(s)] = True
        uniq.append(s)
    return uniq
