"""The iso-loss frontier: pilot runs → loss-vs-phantom-width curves →
the paper-style matched-loss comparison.

The paper's final claim is that a *smaller phantom model on fewer GPUs*
reaches the same loss as a larger tensor-parallel model on more GPUs,
"offering the possibility for even greater energy savings".  That is a
statement about measured objects, all produced here:

  1. **Pilots** — small real training runs (``train.trainer.
     pilot_ffn_run`` on ``data/synthetic.TeacherDataset``), all at the
     SAME model width n (same teacher, same task): one for each
     tensor-family strategy, one per ghost width k for the phantom
     family.  Each runs a fixed step budget and records the first step
     the target loss was crossed (the measured ν) plus the final loss.
  2. **Loss curves** — a power law ``loss(k) = exp(a)·k^b`` fitted per
     phantom-family strategy over the ghost-width grid (log-log least
     squares).  k is the phantom model's capacity knob — the "phantom
     width" of the search space — so the curve says how small the
     phantom model can get before it stops reaching the target.
  3. **The comparison** — candidate plans priced with the calibrated
     model at their pilot-measured ν; plans whose pilot (or curve)
     reached the target carry ``predicted_loss == target`` — the
     matched-loss pool — and the verdict checks whether some phantom
     plan on a strictly smaller mesh undercuts every full-mesh tensor
     plan's energy.

Documented approximations: pilots run at one mesh (``pilot_tp``) while
plans span many — ν is strategy-intrinsic under this approximation (for
TP it is exact: the TP model class is p-independent; the phantom class
is not, and the report flags ν as pilot-mesh-measured).  A plan whose k
was never piloted gets its loss from the fitted curve and the ν of the
nearest piloted k, flagged ``nu_interpolated``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import PHANTOM_KINDS
from repro.planner.calibration import Calibration
from repro.planner.score import ScoredPlan, score_plan
from repro.planner.space import PlanCandidate


def _key(strategy: str, k: int) -> str:
    return f"{strategy}:k{k}"


@dataclass
class LossCurve:
    """Power-law fit loss(k) = exp(a) · k^b over a ghost-width grid."""
    strategy: str
    a: float
    b: float
    ks: List[int]
    losses: List[float]
    width: int
    pilot_tp: int

    def loss_at(self, k: float) -> float:
        return math.exp(self.a) * max(k, 1e-9) ** self.b

    def k_for(self, target_loss: float,
              max_extrapolation: float = 4.0) -> Optional[int]:
        """Smallest ghost width predicted to reach ``target_loss``;
        None when the curve is non-increasing in capacity (b >= 0 means
        more ghosts do not help on this grid) or the answer would
        extrapolate more than ``max_extrapolation``× past the grid."""
        if self.b >= 0 or target_loss <= 0:
            return None
        k = (target_loss / math.exp(self.a)) ** (1.0 / self.b)
        if not (min(self.ks) / max_extrapolation
                <= k <= max(self.ks) * max_extrapolation):
            return None
        return max(1, int(math.ceil(k)))

    def as_dict(self) -> dict:
        return {"strategy": self.strategy, "a": self.a, "b": self.b,
                "ks": self.ks, "losses": self.losses,
                "width": self.width, "pilot_tp": self.pilot_tp,
                "model": "loss(k) = exp(a) * k^b"}


def fit_loss_curve(strategy: str, ks: Sequence[int],
                   losses: Sequence[float], width: int,
                   pilot_tp: int) -> LossCurve:
    """Log-log least squares (closed form; the grids are tiny)."""
    xs = [math.log(k) for k in ks]
    ys = [math.log(max(l, 1e-12)) for l in losses]
    n = len(xs)
    mx, my = sum(xs) / n, sum(ys) / n
    den = sum((x - mx) ** 2 for x in xs)
    b = (sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / den
         if den else 0.0)
    a = my - b * mx
    return LossCurve(strategy=strategy, a=a, b=b, ks=list(ks),
                     losses=list(losses), width=width, pilot_tp=pilot_tp)


@dataclass
class IsoLossResult:
    """Everything the planner learned from the pilot phase."""
    target_loss: float
    width: int
    pilot_tp: int
    steps_budget: int
    curves: Dict[str, LossCurve] = field(default_factory=dict)
    pilots: List = field(default_factory=list)         # PilotResult
    nu: Dict[str, Optional[int]] = field(default_factory=dict)
    final_loss: Dict[str, float] = field(default_factory=dict)

    def lookup(self, strategy: str, k: int
               ) -> Tuple[Optional[int], Optional[float], bool]:
        """(nu, final_loss, piloted) for one (strategy, ghost width)."""
        key = _key(strategy, k)
        if key in self.nu:
            return self.nu[key], self.final_loss.get(key), True
        return None, None, False

    def as_dict(self) -> dict:
        return {
            "target_loss": self.target_loss, "width": self.width,
            "pilot_tp": self.pilot_tp, "steps_budget": self.steps_budget,
            "curves": {k: c.as_dict() for k, c in self.curves.items()},
            "pilots": [p.as_dict() for p in self.pilots],
            "nu": dict(self.nu),
            "final_loss": dict(self.final_loss),
        }


def run_pilots(strategies: Sequence[str], mesh, *, width: int, depth: int,
               batch: int, steps: int, target_loss: float,
               ks: Sequence[int] = (4, 8, 16), seed: int = 0,
               ledger=None) -> IsoLossResult:
    """The pilot phase: same width (same teacher/task) for every run;
    tensor-family strategies get one run, phantom-family one per k."""
    from repro.parallel.axes import MeshAxes
    from repro.train.trainer import pilot_ffn_run

    axes = MeshAxes.from_mesh(mesh)
    res = IsoLossResult(target_loss=target_loss, width=width,
                        pilot_tp=axes.tp, steps_budget=steps)
    for strat in strategies:
        phantom = strat in PHANTOM_KINDS
        k_grid = [k for k in ks if k < width // axes.tp] if phantom \
            else [0]
        grid_losses = []
        for k in k_grid:
            plan = PlanCandidate(dp=axes.dp, tp=axes.tp, strategy=strat,
                                 width=width, depth=depth, batch=batch,
                                 k=k)
            pilot = pilot_ffn_run(plan.model_config(), mesh, steps=steps,
                                  batch=batch, target_loss=target_loss,
                                  seed=seed, ledger=ledger)
            res.pilots.append(pilot)
            res.nu[_key(strat, k)] = pilot.iters_to_target
            res.final_loss[_key(strat, k)] = pilot.final_loss
            grid_losses.append(max(pilot.final_loss, 1e-12))
        if phantom and len(k_grid) >= 2:
            res.curves[strat] = fit_loss_curve(strat, k_grid, grid_losses,
                                               width, axes.tp)
    return res


def apply_iso_loss(plans: Sequence[PlanCandidate], iso: IsoLossResult,
                   calib: Calibration, **score_kw) -> List[ScoredPlan]:
    """Score each plan at its pilot-measured ν.  Plans whose pilot (or
    fitted curve) reached the target carry predicted_loss == target —
    the matched-loss pool ``matched_loss_comparison`` quantifies over;
    censored plans keep their observed final loss and are flagged."""
    scored = []
    for plan in plans:
        k = plan.k if plan.strategy in PHANTOM_KINDS else 0
        nu, final_loss, piloted = iso.lookup(plan.strategy, k)
        notes = {"iso_loss": True, "pilot_width": iso.width,
                 "pilot_tp": iso.pilot_tp}
        if piloted:
            reached = nu is not None
            loss = iso.target_loss if reached else final_loss
            nu_val = float(nu) if reached else float(iso.steps_budget)
        else:
            curve = iso.curves.get(plan.strategy)
            if curve is None:
                continue            # nothing measured for this strategy
            # nearest piloted k's ν, flagged; a censored neighbour
            # (never reached the target) cannot vouch for this k either
            near = min(curve.ks, key=lambda kk: abs(kk - k))
            nu_near, _, _ = iso.lookup(plan.strategy, near)
            curve_loss = curve.loss_at(k)
            if nu_near is None:
                reached = False
                nu_val = float(iso.steps_budget)
            else:
                reached = curve_loss <= iso.target_loss
                nu_val = float(nu_near)
            loss = iso.target_loss if reached else curve_loss
            notes["nu_interpolated_from_k"] = near
        if plan.width != iso.width:
            notes["width_mismatch_vs_pilot"] = plan.width
        notes["reached_target"] = bool(reached)
        notes["nu_censored"] = piloted and nu is None
        # ν is a measurement here — the calibration's nu_scale corrects
        # predicted iteration counts and must not double-apply
        s = score_plan(plan, calib, iterations=nu_val,
                       apply_nu_scale=False, **score_kw)
        s.predicted_loss = loss
        s.quality = loss
        s.notes.update(notes)
        scored.append(s)
    return scored


def matched_loss_comparison(scored: Sequence[ScoredPlan],
                            full_devices: int) -> dict:
    """The acceptance verdict: does some phantom plan on a strictly
    smaller mesh predict lower calibrated energy than EVERY
    tensor-parallel plan on the full mesh, at matched predicted loss?

    Quantifies over the matched pool — plans whose predicted loss IS
    the target (``notes.reached_target``, or every plan when scoring
    ran without pilots and all plans share the calibrated-ν target)."""
    matched = [s for s in scored
               if s.notes.get("reached_target", True)]
    tp_full = [s for s in matched
               if s.plan.strategy not in PHANTOM_KINDS
               and s.plan.devices == full_devices]
    ph_small = [s for s in matched
                if s.plan.strategy in PHANTOM_KINDS
                and s.plan.devices < full_devices]
    out = {"full_devices": full_devices,
           "matched_plans": len(matched),
           "tensor_full_mesh_plans": len(tp_full),
           "phantom_smaller_mesh_plans": len(ph_small),
           "phantom_dominates": False}
    if not tp_full or not ph_small:
        return out
    best_tp = min(tp_full, key=lambda s: s.energy_j_total)
    best_ph = min(ph_small, key=lambda s: s.energy_j_total)
    worst_tp = max(tp_full, key=lambda s: s.energy_j_total)
    out.update({
        "best_tensor_full": {"plan": best_tp.plan.name,
                             "energy_j": best_tp.energy_j_total,
                             "step_time_s": best_tp.step_time_s,
                             "iterations": best_tp.iterations,
                             "param_count": best_tp.param_count,
                             "devices": best_tp.plan.devices},
        "worst_tensor_full": {"plan": worst_tp.plan.name,
                              "energy_j": worst_tp.energy_j_total},
        "best_phantom_smaller": {"plan": best_ph.plan.name,
                                 "energy_j": best_ph.energy_j_total,
                                 "step_time_s": best_ph.step_time_s,
                                 "iterations": best_ph.iterations,
                                 "param_count": best_ph.param_count,
                                 "devices": best_ph.plan.devices},
        "energy_saving_vs_best_tensor":
            1.0 - best_ph.energy_j_total / best_tp.energy_j_total
            if best_tp.energy_j_total else 0.0,
        "model_size_ratio":
            best_ph.param_count / best_tp.param_count
            if best_tp.param_count else None,
        "phantom_dominates":
            best_ph.energy_j_total < best_tp.energy_j_total,
    })
    return out
