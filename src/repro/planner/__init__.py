"""The energy-aware configuration planner.

A decision-making layer on top of the measurement layer: calibrate the
analytic energy model from the ledger (``calibration``), enumerate mesh
× strategy × ghost-width candidates (``space``), filter for resource
feasibility (``constraints``), price everything with the calibrated
E = ν·p·(A·α + B·β) (``score``), normalize to a target loss with pilot
runs (``isoloss``) and report the Pareto frontier + winning plan
(``report``).  CLI: ``python -m repro.launch.plan``; docs:
``docs/planner.md``.
"""
from repro.planner.calibration import (Calibration, calibrate_from_ledger,
                                       calibrate_from_rows,
                                       least_squares_scale,
                                       load_calibration,
                                       paper_default_calibration)
from repro.planner.constraints import (Constraints, Rejection,
                                       compiled_hbm_bytes, filter_feasible,
                                       hbm_bytes_estimate)
from repro.planner.isoloss import (IsoLossResult, LossCurve, apply_iso_loss,
                                   fit_loss_curve, matched_loss_comparison,
                                   run_pilots)
from repro.planner.report import (PLAN_SCHEMA, build_report,
                                  load_plan_report, pick_winner,
                                  plan_summary_lines, record_frontier,
                                  write_plan_report)
from repro.planner.score import (ScoredPlan, apply_throughput_floor,
                                 pareto_frontier, score_plan, score_plans)
from repro.planner.space import PlanCandidate, enumerate_plans, mesh_shapes

__all__ = [
    "Calibration", "calibrate_from_ledger", "calibrate_from_rows",
    "least_squares_scale", "load_calibration",
    "paper_default_calibration",
    "Constraints", "Rejection", "compiled_hbm_bytes", "filter_feasible",
    "hbm_bytes_estimate",
    "IsoLossResult", "LossCurve", "apply_iso_loss", "fit_loss_curve",
    "matched_loss_comparison", "run_pilots",
    "PLAN_SCHEMA", "build_report", "load_plan_report", "pick_winner",
    "plan_summary_lines", "record_frontier", "write_plan_report",
    "ScoredPlan", "apply_throughput_floor", "pareto_frontier",
    "score_plan", "score_plans",
    "PlanCandidate", "enumerate_plans", "mesh_shapes",
]
