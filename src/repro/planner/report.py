"""``PLAN_report.json`` — the planner's single output artifact.

Schema ``plan-report/v1``: calibration (constants + provenance — fitted
from which ledger rows, or the documented paper-defaults fallback),
the enumerated/rejected/scored candidates, the Pareto frontier, the
iso-loss section (curves, pilots, the matched-loss comparison) and the
winning plan.  ``benchmarks/plan_smoke.py`` additionally streams the
frontier rows through the shared ``Ledger`` so they land in
``BENCH_report.json`` next to the measurements that calibrated them.
"""
from __future__ import annotations

import json
import time
from typing import List, Optional, Sequence

from repro.planner.calibration import Calibration
from repro.planner.constraints import Constraints, Rejection
from repro.planner.isoloss import IsoLossResult
from repro.planner.score import ScoredPlan

PLAN_SCHEMA = "plan-report/v1"


def pick_winner(frontier: Sequence[ScoredPlan]) -> Optional[ScoredPlan]:
    """Lowest calibrated total energy; ties break toward fewer devices,
    then faster steps."""
    if not frontier:
        return None
    return min(frontier, key=lambda s: (s.energy_j_total,
                                        s.plan.devices, s.step_time_s))


def build_report(*, calibration: Calibration, constraints: Constraints,
                 scored: Sequence[ScoredPlan],
                 frontier: Sequence[ScoredPlan],
                 rejected: Sequence[Rejection] = (),
                 throughput_rejected: Sequence[tuple] = (),
                 iso: Optional[IsoLossResult] = None,
                 comparison: Optional[dict] = None,
                 meta: Optional[dict] = None) -> dict:
    winner = pick_winner(frontier)
    return {
        "schema": PLAN_SCHEMA,
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "meta": dict(meta or {}),
        "calibration": calibration.as_dict(),
        "constraints": constraints.as_dict(),
        "counts": {
            "scored": len(scored),
            "frontier": len(frontier),
            "rejected": len(rejected) + len(throughput_rejected),
        },
        "rejected": [r.as_dict() for r in rejected]
                    + [{"plan": s.plan.name, "reason": why}
                       for s, why in throughput_rejected],
        "plans": [s.as_dict() for s in scored],
        "frontier": [s.as_dict() for s in frontier],
        "iso_loss": iso.as_dict() if iso is not None else None,
        "comparison": comparison,
        "winner": winner.as_dict() if winner is not None else None,
    }


def write_plan_report(report: dict, path: str) -> str:
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
    return path


def load_plan_report(path: str) -> dict:
    with open(path) as f:
        rec = json.load(f)
    if rec.get("schema") != PLAN_SCHEMA:
        raise ValueError(f"{path}: unknown plan schema "
                         f"{rec.get('schema')!r} (want {PLAN_SCHEMA})")
    return rec


def record_frontier(ledger, frontier: Sequence[ScoredPlan],
                    calibration: Calibration,
                    suite: str = "plan_smoke") -> List:
    """Stream the frontier through the shared Ledger, one entry per
    frontier plan, tagged with the producing suite."""
    from repro.telemetry import LedgerEntry
    out = []
    for s in frontier:
        out.append(ledger.record(LedgerEntry(
            name=f"plan_{s.plan.name}", suite=suite, kind="plan",
            arch=s.plan.name, impl=s.plan.strategy, p=s.plan.tp,
            predicted={
                "energy_j_total": s.energy_j_total,
                "energy_j_per_iter": s.energy_j_per_iter,
                "step_time_s": s.step_time_s,
                "iterations": s.iterations,
                "alpha_s": s.alpha_s, "beta_s": s.beta_s,
                "predicted_loss": s.predicted_loss,
            },
            extra={"devices": s.plan.devices, "dp": s.plan.dp,
                   "width": s.plan.width, "k": s.plan.k,
                   "calibration_source": calibration.source})))
    return out


def plan_summary_lines(report: dict) -> List[str]:
    """Human-readable frontier table (CLI output)."""
    lines = ["plan                                    devices  "
             "energy_J   step_s    loss",
             "-" * 72]
    for s in report.get("frontier", []):
        p = s["plan"]
        loss = s.get("predicted_loss")
        lines.append(f"{p['name']:<40}{p['devices']:>6}  "
                     f"{s['energy_j_total']:>9.3g}  {s['step_time_s']:>8.3g}"
                     f"  {loss if loss is None else format(loss, '.4f')}")
    comp = report.get("comparison") or {}
    if comp:
        lines.append("")
        lines.append(f"phantom-on-smaller-mesh dominates full-mesh TP: "
                     f"{comp.get('phantom_dominates')}")
        if comp.get("best_phantom_smaller"):
            bp, bt = comp["best_phantom_smaller"], comp["best_tensor_full"]
            lines.append(
                f"  best phantom: {bp['plan']} ({bp['devices']} dev, "
                f"{bp['energy_j']:.3g} J) vs best full-mesh TP: "
                f"{bt['plan']} ({bt['devices']} dev, "
                f"{bt['energy_j']:.3g} J)")
    w = report.get("winner")
    if w:
        lines.append(f"winner: {w['plan']['name']} "
                     f"({w['plan']['devices']} devices, "
                     f"{w['energy_j_total']:.3g} J to target)")
    return lines
