"""Static sharding & energy audit.

A rule engine that proves — without executing anything — that every
collective in the lowered HLO of each jitted entrypoint is priced by a
predicted ``CommEvent`` from the executing ``ProjectionStrategy`` /
pipeline / serving account, and vice versa; plus sharding-hygiene,
dtype-drift, recompilation-hazard and repo-idiom (AST) rules.  See
docs/analysis.md for the rule catalog and suppression syntax.

Entry point: ``python -m repro.launch.audit --all`` -> AUDIT_report.json
(schema ``audit-report/v1``).
"""
from repro.analysis.findings import (AUDIT_BASELINE_SCHEMA, ERROR, INFO,
                                     WARNING, Baseline, Finding,
                                     apply_baseline, load_baseline)
from repro.analysis.engine import (AUDIT_SCHEMA, AuditResult, audit_plans,
                                   run_audit)
from repro.analysis.rules import PROGRAM_RULES, rule_catalog, run_rules
from repro.analysis.units import (AuditUnit, PricedCollective,
                                  build_default_units, ffn_train_unit,
                                  pipeline_unit, plan_unit, serve_units)

__all__ = [
    "AUDIT_BASELINE_SCHEMA", "AUDIT_SCHEMA", "ERROR", "INFO", "WARNING",
    "AuditResult", "AuditUnit", "Baseline", "Finding", "PROGRAM_RULES",
    "PricedCollective", "apply_baseline", "audit_plans",
    "build_default_units", "ffn_train_unit", "load_baseline",
    "pipeline_unit", "plan_unit", "rule_catalog", "run_audit",
    "run_rules", "serve_units",
]
