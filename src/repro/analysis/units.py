"""Audit units: one lowered jitted entrypoint + its predicted account.

A unit is everything the program rules need about ONE entrypoint,
gathered WITHOUT executing it: the optimized HLO text and
``CompiledCosts`` (through the shared telemetry caches, so an audit
after a planning pass re-parses nothing), the closed jaxpr, the list of
``PricedCollective`` records the executing ``ProjectionStrategy`` /
pipeline / serving account predicts, the mesh-axis sizes, and the
config objects the entrypoint was built from (the recompilation-hazard
rule checks those are hashable and hash-stable).

Builders cover every shipped entrypoint family:

  * ``ffn_train_unit``  — the paper-FFN fwd+bwd probe step
  * ``pipeline_unit``   — the 1F1B pipelined probe step
  * ``serve_units``     — the serving engine's prefill + decode fns
  * ``plan_unit``       — one planner candidate (train or pipeline)
  * ``build_default_units`` — the ``audit --all`` set
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.telemetry.compiled import CompiledCosts, HLO_TO_PAPER

# below this per-rank message size (paper float units) a bucket mismatch
# is bookkeeping, not energy: loss scalars, masks, and the tiny gathers
# XLA freely relowers as all-reduces (serve_bench documents the latter)
SMALL_M_FLOATS = 4096.0


@dataclass(frozen=True)
class PricedCollective:
    """One predicted collective bucket: ``count`` occurrences of a
    ``kind`` collective moving ``m_floats`` per-rank floats each
    (CommEvent units) over a mesh axis of size ``group``."""
    kind: str          # paper kind: all_gather | all_reduce | ...
    m_floats: float
    group: int
    count: float = 1.0

    @property
    def total_m_floats(self) -> float:
        return self.m_floats * self.count


@dataclass
class AuditUnit:
    """One lowered entrypoint, ready for the program rules."""

    name: str                   # e.g. "ffn_train/paper-ffn-smoke/tp8"
    kind: str                   # ffn_train | pipeline | serve_* | plan
    hlo_text: str = ""
    costs: CompiledCosts = field(default_factory=CompiledCosts)
    jaxpr: Optional[object] = None          # ClosedJaxpr when captured
    predicted: List[PricedCollective] = field(default_factory=list)
    axes: Dict[str, int] = field(default_factory=dict)  # tp/dp/pp sizes
    compute_dtype: str = "float32"
    static_args: Dict[str, object] = field(default_factory=dict)
    # strict units pin the measured/predicted account (probe-grade, the
    # wire-ratio-1.00 paths); loose units (serving: bf16 wire vs float
    # units, latency-dominated small messages) downgrade bucket errors
    # one severity level
    strict: bool = True
    wire_rtol: float = 0.05
    small_m_floats: float = SMALL_M_FLOATS
    napkin_bytes: Optional[float] = None    # planner live-memory estimate
    meta: Dict[str, object] = field(default_factory=dict)

    def device_count(self) -> int:
        n = 1
        for v in self.axes.values():
            n *= max(int(v), 1)
        return n

    def measured_buckets(self) -> Dict[tuple, Dict[str, float]]:
        """Measured traffic bucketed by (paper kind, group size).
        Degenerate single-member groups (XLA lowers axis-size-1 psums
        as {{0},{1},..} collectives) move zero wire bytes and are
        dropped, mirroring ``predicted_buckets``."""
        out: Dict[tuple, Dict[str, float]] = {}
        for op, rec in self.costs.collectives.items():
            paper = HLO_TO_PAPER.get(op, op)
            for g, grec in rec.get("groups", {}).items():
                if int(g) <= 1:
                    continue
                key = (paper, int(g))
                b = out.setdefault(key, {"count": 0.0, "m_floats": 0.0})
                b["count"] += grec["count"]
                b["m_floats"] += grec["m_floats"]
        return out

    def predicted_buckets(self) -> Dict[tuple, Dict[str, float]]:
        """Predicted traffic in the same (kind, group) buckets —
        degenerate single-device groups carry no wire traffic and are
        dropped, matching what XLA lowers."""
        out: Dict[tuple, Dict[str, float]] = {}
        for pc in self.predicted:
            if pc.group <= 1 or pc.total_m_floats <= 0.0:
                continue
            key = (pc.kind, int(pc.group))
            b = out.setdefault(key, {"count": 0.0, "m_floats": 0.0})
            b["count"] += pc.count
            b["m_floats"] += pc.total_m_floats
        return out


def _lower_unit(fn, *args, default_group: int, with_jaxpr: bool = True):
    """Lower + compile (both cached) + parse one entrypoint; returns
    (hlo_text, CompiledCosts, jaxpr)."""
    import jax
    from repro.telemetry.compiled import analyze_lowered
    lowered = fn.lower(*args)
    costs, compiled = analyze_lowered(lowered, default_group=default_group,
                                      keep_compiled=True)
    jaxpr = None
    if with_jaxpr:
        try:
            jaxpr = jax.make_jaxpr(fn)(*args)
        except Exception:
            jaxpr = None        # jaxpr rules just skip this unit
    return compiled.as_text(), costs, jaxpr


def _loss_psum(devices: int) -> PricedCollective:
    # the probes' scalar loss psum over ALL mesh axes
    return PricedCollective("all_reduce", 1.0, devices, 1.0)


def ffn_train_unit(cfg, mesh, global_batch: int) -> AuditUnit:
    """The paper-FFN fwd+bwd probe step (``telemetry/probe.py``) —
    the entrypoint whose ledger wire ratio pins at 1.00."""
    import jax
    import jax.numpy as jnp
    from repro.core.ffn import ffn_strategy
    from repro.parallel.axes import MeshAxes
    from repro.parallel.params import abstract
    from repro.telemetry.probe import make_ffn_probe_step

    axes = MeshAxes.from_mesh(mesh)
    tp, dp = axes.tp, axes.dp
    fn, decls = make_ffn_probe_step(cfg, mesh, global_batch)
    x_sds = jax.ShapeDtypeStruct((global_batch, cfg.ffn_width),
                                 jnp.float32)
    hlo, costs, jaxpr = _lower_unit(fn, abstract(decls), x_sds, x_sds,
                                    default_group=tp)

    st = ffn_strategy(cfg, tp)
    L = cfg.num_layers
    # layer collectives see the PER-DP-SHARD rows (each data-parallel
    # replica runs the schedule on its own batch slice)
    rows_local = global_batch / max(dp, 1)
    predicted = [PricedCollective(ev.collective, ev.m_floats, tp, L)
                 for ev in st.comm_events(rows_local)]
    if dp > 1:
        # grad sync: one psum per param tensor (W and b per layer)
        m_grads = L * st.param_count() / max(tp, 1)
        predicted.append(PricedCollective(
            "all_reduce", m_grads / (2 * L), dp, 2.0 * L))
    predicted.append(_loss_psum(dp * tp))

    return AuditUnit(
        name=f"ffn_train/{cfg.name}/dp{dp}tp{tp}",
        kind="ffn_train", hlo_text=hlo, costs=costs, jaxpr=jaxpr,
        predicted=predicted, axes={"dp": dp, "tp": tp, "pp": 1},
        compute_dtype="float32",
        static_args={"cfg": cfg, "strategy_spec": cfg.projection_spec(
            "ffn_layer")},
        strict=True, wire_rtol=0.05,
        meta={"strategy": st.kind, "global_batch": global_batch},
    )


def kernel_unit(cfg, mesh, global_batch: int) -> AuditUnit:
    """The phantom FFN probe lowered with ``kernel_backend="pallas"`` —
    the fused custom_vjp entrypoint.  Predicted collectives come from
    ``telemetry.predict.fused_kernel_step_events`` (shared with the
    ledger), which equals the XLA path's account by construction: the
    kernel fuses GEMMs, never collectives, and this unit proves nothing
    went unpriced when the math moved inside ``pallas_call``."""
    import jax
    import jax.numpy as jnp
    from repro.core.ffn import ffn_strategy
    from repro.kernels.phantom_fused import (VMEM_BUDGET_BYTES,
                                             kernel_vmem_bytes)
    from repro.parallel.axes import MeshAxes
    from repro.parallel.params import abstract
    from repro.telemetry.predict import fused_kernel_step_events
    from repro.telemetry.probe import make_ffn_probe_step

    axes = MeshAxes.from_mesh(mesh)
    tp, dp = axes.tp, axes.dp
    fn, decls = make_ffn_probe_step(cfg, mesh, global_batch)
    x_sds = jax.ShapeDtypeStruct((global_batch, cfg.ffn_width),
                                 jnp.float32)
    hlo, costs, jaxpr = _lower_unit(fn, abstract(decls), x_sds, x_sds,
                                    default_group=tp)

    st = ffn_strategy(cfg, tp)
    L = cfg.num_layers
    rows_local = global_batch / max(dp, 1)
    predicted = [PricedCollective(ev.collective, ev.m_floats, tp, reps)
                 for ev, reps in
                 fused_kernel_step_events(cfg, tp, rows_local)]
    if dp > 1:
        m_grads = L * st.param_count() / max(tp, 1)
        predicted.append(PricedCollective(
            "all_reduce", m_grads / (2 * L), dp, 2.0 * L))
    predicted.append(_loss_psum(dp * tp))

    spec = cfg.projection_spec("ffn_layer")
    # default forward-kernel tiles, clamped the way the kernel clamps
    tiles = {dim: min(128, size) for dim, size in
             (("bm", int(rows_local)), ("bn", cfg.ffn_width // tp),
              ("bk", cfg.ffn_width // tp), ("bpk", tp * spec.k))}
    return AuditUnit(
        name=f"kernel/{cfg.name}/dp{dp}tp{tp}",
        kind="kernel", hlo_text=hlo, costs=costs, jaxpr=jaxpr,
        predicted=predicted, axes={"dp": dp, "tp": tp, "pp": 1},
        compute_dtype="float32",
        static_args={"cfg": cfg, "strategy_spec": spec},
        strict=True, wire_rtol=0.05,
        meta={"strategy": st.kind, "global_batch": global_batch,
              "kernel_backend": spec.kernel_backend,
              "kernel_tiles": tiles,
              "kernel_vmem_bytes": kernel_vmem_bytes(
                  tiles["bm"], tiles["bn"], tiles["bk"], tiles["bpk"],
                  "float32"),
              "kernel_vmem_budget": VMEM_BUDGET_BYTES},
    )


def pipeline_unit(cfg, mesh, global_batch: int) -> AuditUnit:
    """The 1F1B pipelined paper-FFN probe step — the entrypoint whose
    boundary_wire ratio pins at 1.0000."""
    import jax
    import jax.numpy as jnp
    from repro.parallel.axes import MeshAxes
    from repro.parallel.params import abstract
    from repro.telemetry.predict import pipeline_ffn_step_events
    from repro.telemetry.probe import make_ffn_pipeline_probe_step

    axes = MeshAxes.from_mesh(mesh)
    pp, tp, dp = axes.pp, axes.tp, axes.dp
    fn, decls = make_ffn_pipeline_probe_step(cfg, mesh, global_batch)
    x_sds = jax.ShapeDtypeStruct((global_batch, cfg.ffn_width),
                                 jnp.float32)
    hlo, costs, jaxpr = _lower_unit(fn, abstract(decls), x_sds, x_sds,
                                    default_group=tp)

    acct = pipeline_ffn_step_events(cfg, pp, tp, dp, global_batch,
                                    executed=True)
    predicted = [PricedCollective(ev.collective, ev.m_floats, g, n)
                 for ev, g, n in acct["events"]]
    predicted.append(_loss_psum(dp * tp * pp))

    return AuditUnit(
        name=f"pipeline/{cfg.name}/pp{pp}dp{dp}tp{tp}",
        kind="pipeline", hlo_text=hlo, costs=costs, jaxpr=jaxpr,
        predicted=predicted, axes={"dp": dp, "tp": tp, "pp": pp},
        compute_dtype="float32",
        static_args={"cfg": cfg, "pipeline": cfg.pipeline},
        strict=True, wire_rtol=0.05,
        meta={"strategy": acct["strategy"].kind,
              "microbatches": acct["schedule"].microbatches,
              "ticks": acct["schedule"].num_ticks,
              "global_batch": global_batch},
    )


def serve_units(sc, mesh=None) -> List[AuditUnit]:
    """The serving engine's own prefill and decode entrypoints for one
    ``ServeConfig`` — lowered exactly the way ``serve/router.run_config``
    lowers them for the measured ledger rows, priced by
    ``serve_step_events`` (the account ``serve_step_prediction`` sums).

    Serving units are LOOSE: the wire unit mismatch (bf16 messages count
    half a float) and XLA's freedom to relower tiny gathers as
    all-reduces put exact bucket matching out of reach — the energy-
    ratio CI band for this path is [0.5, 2.0], and the unit's tolerances
    mirror that."""
    import jax
    import numpy as np
    from repro.launch.mesh import make_local_mesh
    from repro.models.model import model_decls
    from repro.parallel.axes import MeshAxes
    from repro.parallel.params import abstract
    from repro.serve.engine import _add_modality_stubs, make_serve_fns
    from repro.configs.base import ShapeConfig
    from repro.telemetry.predict import serve_step_events

    cfg = sc.model_config()
    mesh = mesh or make_local_mesh(sc.dp, sc.tp)
    axes = MeshAxes.from_mesh(mesh)
    shape = ShapeConfig("serve", sc.max_len, sc.slots, "decode")
    prefill_fn, decode_fn, cache_sds, _ = make_serve_fns(cfg, mesh, shape)
    p_sds = abstract(model_decls(cfg, axes))

    S = sc.page_size            # one prefill bucket, the smallest
    batch = _add_modality_stubs(
        cfg, {"tokens": jax.ShapeDtypeStruct((sc.slots, S), np.int32)},
        sc.slots, S)
    tok_sds = jax.ShapeDtypeStruct((sc.slots, 1), np.int32)
    pos_sds = jax.ShapeDtypeStruct((sc.slots,), np.int32)

    units = []
    for phase, fn, args, rows in (
            ("prefill", prefill_fn, (p_sds, batch), sc.slots * S),
            ("decode", decode_fn, (p_sds, cache_sds, tok_sds, pos_sds),
             sc.slots)):
        hlo, costs, jaxpr = _lower_unit(fn, *args, default_group=sc.tp)
        events = serve_step_events(cfg, sc.tp, rows, phase,
                                   sequences=sc.slots, dp=sc.dp)
        predicted = [PricedCollective(ev.collective, ev.m_floats,
                                      sc.tp, n) for ev, n in events]
        units.append(AuditUnit(
            name=f"serve_{phase}/{sc.name}",
            kind=f"serve_{phase}", hlo_text=hlo, costs=costs,
            jaxpr=jaxpr, predicted=predicted,
            axes={"dp": sc.dp, "tp": sc.tp, "pp": 1},
            compute_dtype=cfg.dtype,
            static_args={"cfg": cfg, "serve_config": sc},
            strict=False, wire_rtol=0.75,
            small_m_floats=4.0 * SMALL_M_FLOATS,
            meta={"rows": rows, "phase": phase, "slots": sc.slots,
                  "prefill_len": S},
        ))
    return units


def plan_unit(plan, mesh=None) -> AuditUnit:
    """Audit one planner candidate: its probe entrypoint on a local mesh
    of the candidate's own (dp, tp, pp) shape.  Shares the telemetry
    caches with ``planner.constraints.compiled_hbm_bytes``, so auditing
    a frontier the planner already compiled re-lowers nothing."""
    from repro.launch.mesh import make_local_mesh
    from repro.planner.constraints import hbm_bytes_estimate

    cfg = plan.model_config()
    mesh = mesh or make_local_mesh(plan.dp, plan.tp, plan.pp)
    if plan.pp > 1:
        unit = pipeline_unit(cfg, mesh, plan.batch)
    else:
        unit = ffn_train_unit(cfg, mesh, plan.batch)
    unit.name = f"plan/{plan.name}"
    unit.kind = "plan"
    unit.napkin_bytes = float(hbm_bytes_estimate(plan))
    unit.meta["plan"] = plan.name
    return unit


def build_default_units(*, arch: str = "qwen2.5-14b") -> List[AuditUnit]:
    """The ``audit --all`` unit set: every shipped entrypoint family on
    the 8-device CPU host — tensor and phantom FFN train probes (pure-tp
    and dp×tp meshes), the 1F1B pipeline probe on a pp×dp×tp mesh, and
    a serving engine's prefill/decode pair (tensor and phantom).

    The train probes run at width 1024 (not the width-128 smoke size):
    the audited per-layer messages must clear the small-message noise
    floor, or every accounting error would demote to info."""
    from repro.configs.base import (dense_projection_map, get_config,
                                    phantom_projection_map)
    from repro.launch.mesh import make_local_mesh
    from repro.serve.router import ServeConfig

    units: List[AuditUnit] = []

    base = get_config("paper-ffn-4k", smoke=True).replace(
        d_model=1024, ffn_width=1024)
    dense = base.replace(name="audit-ffn-tensor",
                         projections=dense_projection_map())
    phantom = base.replace(
        name="audit-ffn-phantom",
        projections=phantom_projection_map(8, ffn_layer=True))
    units.append(ffn_train_unit(dense, make_local_mesh(1, 8), 64))
    units.append(ffn_train_unit(phantom, make_local_mesh(1, 8), 64))
    units.append(ffn_train_unit(phantom, make_local_mesh(2, 4), 64))

    pallas = base.replace(
        name="audit-ffn-pallas",
        projections=phantom_projection_map(8, ffn_layer=True,
                                           kernel_backend="pallas"))
    units.append(kernel_unit(pallas, make_local_mesh(1, 8), 64))

    pipe = phantom.replace(
        name="audit-ffn-pipe",
        pipeline=phantom.pipeline.__class__(stages=2), microbatches=4)
    units.append(pipeline_unit(pipe, make_local_mesh(2, 2, 2), 64))

    for impl in ("tensor", "phantom"):
        sc = ServeConfig(arch=arch, impl=impl, dp=1, tp=4, slots=4,
                         max_len=64, page_size=16)
        units.extend(serve_units(sc))
    return units
