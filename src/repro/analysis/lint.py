"""Repo-idiom AST lint: source rules over ``src/repro`` + ``benchmarks``.

Where the program rules audit what the compiler LOWERED, these audit
what the humans WROTE: parallelism must route through the pinned
``parallel/compat`` shim, nothing in-repo may call the deprecated
config shims its own deprecation tests pin, benchmark suites must
record to the shared ledger, and PRNGs must be explicitly seeded
(unseeded randomness breaks the measured-vs-predicted reproducibility
story).  Pure ``ast`` walk — no third-party linter is required at
runtime (ruff/mypy run as the separate CI lint job).
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Tuple

from repro.analysis.findings import ERROR, WARNING, Finding

# the deprecated config-shim surfaces (satellite: in-repo callers are
# migrated off; only the shim-pinning tests may touch them)
DEPRECATED_KEYWORDS = ("ffn_impl", "apply_ffn", "apply_attn_proj")
DEPRECATED_CALLS = ("pp_costs",)

# np.random entry points that are fine when (and only when) seeded
_SEEDED_FACTORIES = ("default_rng", "RandomState", "SeedSequence",
                     "Generator")

# files allowed to touch jax's shard_map: the compat shim itself
_RAW_SHARD_MAP_ALLOW = ("parallel/compat.py",)

SOURCE_RULES: Dict[str, Tuple[str, str, str]] = {
    # id -> (severity, rationale, short title)
    "raw-shard-map": (
        ERROR,
        "jax.shard_map moved across jax versions; everything must "
        "import it from repro.parallel.compat",
        "raw jax shard_map import"),
    "deprecated-shim": (
        ERROR,
        "ffn_impl / PhantomConfig.apply_* / pp_costs are deprecation "
        "shims kept for external callers; in-repo code uses "
        "ProjectionMap / phantom_costs",
        "deprecated shim call"),
    "ledger-missing": (
        WARNING,
        "a benchmark suite that never records to the shared Ledger "
        "produces numbers the report join can't see",
        "suite records nothing"),
    "unseeded-prng": (
        WARNING,
        "unseeded RNGs break run-to-run reproducibility of the "
        "measured-vs-predicted ledger",
        "unseeded PRNG"),
}


def _attr_chain(node: ast.AST) -> str:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _lint_tree(tree: ast.AST, rel: str) -> List[Finding]:
    out: List[Finding] = []

    def add(rule: str, line: int, msg: str, key: str):
        sev = SOURCE_RULES[rule][0]
        out.append(Finding(rule, sev, rel, f"{rel}:{line}: {msg}",
                           key=key, detail={"line": line}))

    allow_shard_map = rel.replace(os.sep, "/").endswith(
        _RAW_SHARD_MAP_ALLOW)
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and not allow_shard_map:
            mod = node.module or ""
            names = [a.name for a in node.names]
            if "shard_map" in mod or (mod.startswith("jax")
                                      and "shard_map" in names):
                add("raw-shard-map", node.lineno,
                    f"imports shard_map from {mod!r} instead of "
                    f"repro.parallel.compat", key="import")
        elif isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            leaf = chain.rsplit(".", 1)[-1]
            if not allow_shard_map and chain.startswith("jax") \
                    and leaf == "shard_map":
                add("raw-shard-map", node.lineno,
                    f"calls {chain} directly instead of "
                    f"repro.parallel.compat.shard_map", key="call")
            if leaf in DEPRECATED_CALLS:
                add("deprecated-shim", node.lineno,
                    f"calls deprecated {leaf}()", key=leaf)
            for kw in node.keywords:
                if kw.arg in DEPRECATED_KEYWORDS:
                    add("deprecated-shim", node.lineno,
                        f"passes deprecated keyword {kw.arg}= "
                        f"(use ModelConfig.projections)",
                        key=f"kw:{kw.arg}")
            if chain.startswith(("np.random.", "numpy.random.")):
                if leaf in _SEEDED_FACTORIES:
                    if not node.args and not node.keywords:
                        add("unseeded-prng", node.lineno,
                            f"{chain}() without a seed", key=leaf)
                elif leaf != "Generator":
                    add("unseeded-prng", node.lineno,
                        f"{chain}() uses numpy's global unseeded "
                        f"generator (use np.random.default_rng(seed))",
                        key=leaf)
    return out


def _is_bench_suite(rel: str) -> bool:
    norm = rel.replace(os.sep, "/")
    return norm.startswith("benchmarks/") and norm.endswith(".py") \
        and os.path.basename(norm) not in ("common.py", "run.py",
                                           "__init__.py")


def lint_file(path: str, rel: str) -> List[Finding]:
    with open(path) as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=rel)
    except SyntaxError as e:
        return [Finding("deprecated-shim", ERROR, rel,
                        f"{rel}: unparseable: {e}", key="syntax")]
    out = _lint_tree(tree, rel)
    if _is_bench_suite(rel) and not any(
            tok in src for tok in ("emit(", "get_ledger", "record_to",
                                   ".record(")):
        out.append(Finding(
            "ledger-missing", WARNING, rel,
            f"{rel}: benchmark suite never records to a ledger "
            f"(benchmarks.common.emit)", key="ledger"))
    return out


def lint_sources(root: str, subdirs=("src/repro", "benchmarks")
                 ) -> List[Finding]:
    """Walk the repo's own source (tests are out of scope — the shim-
    pinning tests must keep calling the shims)."""
    out: List[Finding] = []
    for sub in subdirs:
        base = os.path.join(root, sub)
        for dirpath, _dirnames, filenames in os.walk(base):
            for fname in sorted(filenames):
                if not fname.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fname)
                rel = os.path.relpath(path, root)
                out.extend(lint_file(path, rel))
    return out
