"""Program rules: checks over one lowered entrypoint (an ``AuditUnit``).

Every rule is a pure function ``rule(unit) -> List[Finding]`` registered
in ``PROGRAM_RULES``; ``run_rules`` applies them all.  Rules consume the
PARSED artifacts (``CompiledCosts.collectives`` buckets, the closed
jaxpr, the config objects) — never the raw entrypoint — so seeded-
violation fixtures can feed synthetic HLO through the real parser and
prove each rule fires (tests/test_audit_rules.py).
"""
from __future__ import annotations

import copy
from typing import Callable, Dict, List

from repro.analysis.findings import ERROR, INFO, WARNING, Finding
from repro.analysis.units import AuditUnit

# dtype-drift: bf16->f32 converts below this many elements are scalar
# bookkeeping (loss terms, norms), not a path-wide upcast
DTYPE_DRIFT_MIN_ELEMENTS = 65_536
# sharding-hygiene: lowered live memory may exceed the napkin estimate
# by fusion temporaries; past this factor something is replicated
MEMORY_BLOWUP_FACTOR = 8.0


def _demote(severity: str, strict: bool) -> str:
    """Loose units (serving) report one level below strict units."""
    if strict:
        return severity
    return {ERROR: WARNING, WARNING: INFO}.get(severity, INFO)


# ---------------------------------------------------------------------------
# R1: collective accounting
# ---------------------------------------------------------------------------

def rule_collective_accounting(unit: AuditUnit) -> List[Finding]:
    """Every lowered collective must match a predicted ``CommEvent``
    bucket by (kind, mesh-axis size) and per-rank message floats, and
    vice versa.  Unpriced measured traffic and predicted-but-never-
    lowered (phantom) traffic are errors; sub-``small_m_floats``
    mismatches are the latency-priced noise floor (scalar loss psums,
    the tiny gathers XLA relowers as all-reduces) and report as info."""
    out: List[Finding] = []
    measured = unit.measured_buckets()
    predicted = unit.predicted_buckets()
    for key in sorted(set(measured) | set(predicted)):
        kind, group = key
        skey = f"{kind}@g{group}"
        m = measured.get(key)
        p = predicted.get(key)
        if p is None:
            sev = INFO if m["m_floats"] < unit.small_m_floats \
                else _demote(ERROR, unit.strict)
            out.append(Finding(
                "collective-accounting", sev, unit.name,
                f"unpriced collective: lowered HLO issues {kind} over a "
                f"group of {group} ({m['count']:.0f} ops, "
                f"{m['m_floats']:.0f} floats/rank) but no CommEvent "
                f"prices it", key=skey,
                detail={"measured": m, "predicted": None}))
            continue
        if m is None:
            sev = INFO if p["m_floats"] < unit.small_m_floats \
                else _demote(ERROR, unit.strict)
            out.append(Finding(
                "collective-accounting", sev, unit.name,
                f"phantom prediction: the account prices {kind} over a "
                f"group of {group} ({p['m_floats']:.0f} floats/rank) "
                f"but the lowered HLO never issues it", key=skey,
                detail={"measured": None, "predicted": p}))
            continue
        hi = max(m["m_floats"], p["m_floats"])
        rel = abs(m["m_floats"] - p["m_floats"]) / hi if hi else 0.0
        if rel > unit.wire_rtol:
            sev = INFO if hi < unit.small_m_floats \
                else _demote(ERROR, unit.strict)
            out.append(Finding(
                "collective-accounting", sev, unit.name,
                f"mispriced collective: {kind} over a group of {group} "
                f"moves {m['m_floats']:.0f} floats/rank lowered vs "
                f"{p['m_floats']:.0f} predicted "
                f"(rel {rel:.2f} > rtol {unit.wire_rtol})",
                key=f"{skey}:bytes",
                detail={"measured": m, "predicted": p, "rel": rel}))
        elif m["count"] != p["count"]:
            out.append(Finding(
                "collective-accounting", INFO, unit.name,
                f"{kind} over a group of {group}: {m['count']:.0f} "
                f"lowered ops vs {p['count']:.0f} predicted events "
                f"(bytes agree — fusion/splitting only)",
                key=f"{skey}:count",
                detail={"measured": m, "predicted": p}))
    return out


# ---------------------------------------------------------------------------
# R2: sharding hygiene
# ---------------------------------------------------------------------------

def rule_sharding_hygiene(unit: AuditUnit) -> List[Finding]:
    """Collectives must run over mesh-axis-shaped groups (a group size
    that is no product of the unit's axes means a reshard the
    ``ProjectionSpec`` never implied), and the lowered live memory must
    stay within ``MEMORY_BLOWUP_FACTOR`` of the planner napkin estimate
    (past that something is accidentally replicated)."""
    out: List[Finding] = []
    sizes = [max(int(v), 1) for v in unit.axes.values()]
    legal = {1}
    for s in sizes:
        legal |= {g * s for g in list(legal)}
    for (kind, group), m in sorted(unit.measured_buckets().items()):
        if group not in legal:
            out.append(Finding(
                "sharding-hygiene", _demote(WARNING, unit.strict),
                unit.name,
                f"{kind} over a group of {group}, which is no product "
                f"of the mesh axes {unit.axes} — a reshard the "
                f"ProjectionSpec does not imply", key=f"group{group}",
                detail={"kind": kind, "group": group,
                        "axes": dict(unit.axes), "measured": m}))
    if unit.napkin_bytes:
        mem = unit.costs.memory or {}
        live = sum(float(mem.get(f) or 0.0)
                   for f in ("argument_bytes", "temp_bytes",
                             "output_bytes"))
        if live > MEMORY_BLOWUP_FACTOR * unit.napkin_bytes:
            out.append(Finding(
                "sharding-hygiene", _demote(WARNING, unit.strict),
                unit.name,
                f"live memory blowup: lowered buffers are "
                f"{live / 2**20:.1f} MiB vs the planner napkin estimate "
                f"{unit.napkin_bytes / 2**20:.1f} MiB "
                f"(> {MEMORY_BLOWUP_FACTOR:.0f}x — replication?)",
                key="memory-blowup",
                detail={"live_bytes": live,
                        "napkin_bytes": unit.napkin_bytes}))
    return out


# ---------------------------------------------------------------------------
# R3: dtype drift
# ---------------------------------------------------------------------------

def _walk_jaxpr(jaxpr):
    """Yield every eqn in a (closed) jaxpr, descending into sub-jaxprs
    (scan/while/cond/pjit bodies)."""
    core = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in core.eqns:
        yield eqn
        for v in eqn.params.values():
            sub = v if isinstance(v, (list, tuple)) else (v,)
            for s in sub:
                if hasattr(s, "eqns") or hasattr(s, "jaxpr"):
                    yield from _walk_jaxpr(s)


def rule_dtype_drift(unit: AuditUnit) -> List[Finding]:
    """In bf16 compute paths, a large bf16 -> f32 convert means some
    operator runs (and moves memory) at double width — drift the energy
    account never priced.  Scalar/small converts (losses, norm stats)
    are exempt below ``DTYPE_DRIFT_MIN_ELEMENTS``."""
    if unit.jaxpr is None or "bf" not in str(unit.compute_dtype):
        return []
    out: List[Finding] = []
    seen = set()
    for eqn in _walk_jaxpr(unit.jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        try:
            src = eqn.invars[0].aval
            dst = eqn.outvars[0].aval
        except Exception:
            continue
        if str(src.dtype) != "bfloat16" or str(dst.dtype) != "float32":
            continue
        n = 1
        for d in getattr(dst, "shape", ()):
            n *= int(d)
        if n < DTYPE_DRIFT_MIN_ELEMENTS:
            continue
        key = f"upcast{tuple(dst.shape)}"
        if key in seen:
            continue
        seen.add(key)
        out.append(Finding(
            "dtype-drift", WARNING, unit.name,
            f"f32 upcast inside a bf16 path: convert bf16 -> f32 of "
            f"shape {tuple(dst.shape)} ({n} elements)", key=key,
            detail={"shape": list(dst.shape), "elements": n}))
    return out


# ---------------------------------------------------------------------------
# R4: recompilation hazards
# ---------------------------------------------------------------------------

def rule_recompilation_hazard(unit: AuditUnit) -> List[Finding]:
    """The config objects an entrypoint is built from must be hashable
    AND hash-stable under copy (frozen dataclasses are; anything
    carrying a list/dict/array is not) — an unstable static arg makes
    every jit/telemetry cache keyed on it miss, recompiling the same
    program forever."""
    out: List[Finding] = []
    for name, obj in unit.static_args.items():
        try:
            h = hash(obj)
        except TypeError as e:
            out.append(Finding(
                "recompilation-hazard", ERROR, unit.name,
                f"unhashable static arg {name!r} "
                f"({type(obj).__name__}): {e}", key=name,
                detail={"type": type(obj).__name__}))
            continue
        try:
            clone = copy.deepcopy(obj)
        except Exception:
            continue
        if hash(clone) != h or clone != obj:
            out.append(Finding(
                "recompilation-hazard", ERROR, unit.name,
                f"hash-unstable static arg {name!r} "
                f"({type(obj).__name__}): an equal copy hashes "
                f"differently, so caches keyed on it always miss",
                key=name, detail={"type": type(obj).__name__}))
    return out


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def rule_kernel_vmem(unit: AuditUnit) -> List[Finding]:
    """Units carrying a Pallas kernel tile config (``meta.kernel_tiles``)
    must fit the per-core VMEM budget — the static form of the runtime
    ``KernelConfigError`` guard, so a planner/audit sweep flags an
    impossible tile plan before anything is launched."""
    tiles = (unit.meta or {}).get("kernel_tiles")
    if not tiles:
        return []
    from repro.kernels.phantom_fused import (VMEM_BUDGET_BYTES,
                                             kernel_vmem_bytes)
    budget = (unit.meta or {}).get("kernel_vmem_budget",
                                   VMEM_BUDGET_BYTES)
    need = kernel_vmem_bytes(tiles["bm"], tiles["bn"], tiles["bk"],
                             tiles.get("bpk", 0),
                             unit.compute_dtype or "float32")
    if need > budget:
        return [Finding(
            "kernel-vmem", _demote(ERROR, unit.strict), unit.name,
            f"fused-kernel tiles {tiles} need ~{need} B VMEM, over the "
            f"{budget} B per-core budget — the kernel would raise "
            f"KernelConfigError at run time; shrink the tiles or fall "
            f"back to kernel_backend='xla'", key="kernel-vmem",
            detail={"tiles": dict(tiles), "need_bytes": need,
                    "budget_bytes": budget})]
    return []


PROGRAM_RULES: Dict[str, Callable[[AuditUnit], List[Finding]]] = {
    "collective-accounting": rule_collective_accounting,
    "sharding-hygiene": rule_sharding_hygiene,
    "dtype-drift": rule_dtype_drift,
    "recompilation-hazard": rule_recompilation_hazard,
    "kernel-vmem": rule_kernel_vmem,
}


def run_rules(unit: AuditUnit) -> List[Finding]:
    out: List[Finding] = []
    for rule in PROGRAM_RULES.values():
        out.extend(rule(unit))
    return out


def rule_catalog() -> List[dict]:
    """Every rule (program + AST) with its severity and rationale —
    the docs/analysis.md table is generated from this."""
    from repro.analysis.lint import SOURCE_RULES
    cat = [
        {"id": "collective-accounting", "severity": ERROR,
         "kind": "program",
         "rationale": "every HLO collective must match a predicted "
                      "CommEvent by kind, mesh axis, and bytes — and "
                      "vice versa; unpriced traffic is unpriced energy"},
        {"id": "sharding-hygiene", "severity": WARNING,
         "kind": "program",
         "rationale": "collectives over non-mesh-axis groups are "
                      "resharding the ProjectionSpec never implied; "
                      "live memory far past the planner napkin estimate "
                      "is accidental replication"},
        {"id": "dtype-drift", "severity": WARNING, "kind": "program",
         "rationale": "large bf16->f32 converts inside bf16 paths run "
                      "operators at double width the energy account "
                      "never priced"},
        {"id": "recompilation-hazard", "severity": ERROR,
         "kind": "program",
         "rationale": "unhashable or hash-unstable entrypoint configs "
                      "defeat every compile cache"},
        {"id": "kernel-vmem", "severity": ERROR, "kind": "program",
         "rationale": "a Pallas tile working set over the per-core "
                      "VMEM budget cannot be scheduled on-chip; catch "
                      "the impossible tile plan statically"},
    ]
    cat += [{"id": rid, "severity": sev, "kind": "source",
             "rationale": why} for rid, (sev, why, _) in
            SOURCE_RULES.items()]
    return cat
