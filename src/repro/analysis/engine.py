"""Audit orchestration: run every rule, apply the baseline, emit the
``audit-report/v1`` record.

``run_audit`` is the library entrypoint ``repro.launch.audit`` wraps;
``audit_plans`` is the planner gate (``launch/plan.py`` drops frontier
candidates whose audit has active errors, same recheck-loop shape as
the compiled-HBM check).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.analysis.findings import (Baseline, Finding, apply_baseline,
                                     severity_counts)
from repro.analysis.rules import run_rules
from repro.analysis.units import AuditUnit

AUDIT_SCHEMA = "audit-report/v1"


@dataclass
class AuditResult:
    findings: List[Finding] = field(default_factory=list)   # active
    suppressed: List[Finding] = field(default_factory=list)
    stale_suppressions: List[str] = field(default_factory=list)
    units: List[AuditUnit] = field(default_factory=list)
    baseline_path: Optional[str] = None

    @property
    def counts(self) -> Dict[str, int]:
        return severity_counts(self.findings)

    @property
    def ok(self) -> bool:
        """True when nothing ERROR-severity is active (warnings and
        info report but don't gate)."""
        return self.counts["error"] == 0

    def as_dict(self) -> dict:
        return {
            "schema": AUDIT_SCHEMA,
            "ok": self.ok,
            "counts": self.counts,
            "units": [{
                "name": u.name, "kind": u.kind, "axes": dict(u.axes),
                "strict": u.strict, "compute_dtype": u.compute_dtype,
                "collectives": {
                    f"{kind}@g{g}": dict(b)
                    for (kind, g), b in sorted(
                        u.measured_buckets().items())},
                "predicted": {
                    f"{kind}@g{g}": dict(b)
                    for (kind, g), b in sorted(
                        u.predicted_buckets().items())},
                "meta": {k: v for k, v in u.meta.items()
                         if isinstance(v, (str, int, float, bool))},
            } for u in self.units],
            "findings": [f.as_dict() for f in self.findings],
            "suppressed": [f.as_dict() for f in self.suppressed],
            "stale_suppressions": list(self.stale_suppressions),
            "baseline": self.baseline_path,
        }

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.as_dict(), f, indent=1)
            f.write("\n")

    def summary_lines(self) -> List[str]:
        c = self.counts
        lines = [f"# audit: {len(self.units)} units, "
                 f"{c['error']} errors / {c['warning']} warnings / "
                 f"{c['info']} info "
                 f"({len(self.suppressed)} baseline-suppressed)"]
        for f in self.findings:
            lines.append(f"{f.severity.upper():8s} {f.rule:24s} "
                         f"{f.unit}: {f.message}")
        for fp in self.stale_suppressions:
            lines.append(f"STALE    baseline suppression matches "
                         f"nothing: {fp}")
        return lines


def run_audit(units: Sequence[AuditUnit], *,
              baseline: Optional[Baseline] = None,
              source_root: Optional[str] = None) -> AuditResult:
    """Program rules over ``units``, plus (when ``source_root`` is
    given) the AST lint over the repo source, ratcheted by the
    baseline."""
    from repro.analysis.lint import lint_sources
    findings: List[Finding] = []
    for unit in units:
        findings.extend(run_rules(unit))
    if source_root:
        findings.extend(lint_sources(source_root))
    baseline = baseline or Baseline()
    active, suppressed, stale = apply_baseline(findings, baseline)
    order = {"error": 0, "warning": 1, "info": 2}
    active.sort(key=lambda f: (order[f.severity], f.fingerprint))
    return AuditResult(findings=active, suppressed=suppressed,
                       stale_suppressions=stale, units=list(units),
                       baseline_path=baseline.path)


def audit_plans(plans: Sequence, *, mesh_cache: Optional[dict] = None,
                baseline: Optional[Baseline] = None) -> Dict[str, dict]:
    """Audit each planner candidate's lowered entrypoint; returns
    ``{plan.name: {"ok": bool, "errors": [messages]}}``.  Compiles go
    through the shared telemetry caches, so a frontier the
    compiled-HBM check already lowered re-compiles nothing.
    ``mesh_cache`` maps (dp, tp, pp) -> mesh for the same reason."""
    from repro.analysis.units import plan_unit
    from repro.launch.mesh import make_local_mesh
    mesh_cache = mesh_cache if mesh_cache is not None else {}
    out: Dict[str, dict] = {}
    for plan in plans:
        key = (plan.dp, plan.tp, plan.pp)
        if key not in mesh_cache:
            mesh_cache[key] = make_local_mesh(*key)
        try:
            unit = plan_unit(plan, mesh_cache[key])
        except Exception as e:     # unlowerable candidate = audit error
            out[plan.name] = {"ok": False,
                              "errors": [f"audit could not lower "
                                         f"{plan.name}: {e}"]}
            continue
        res = run_audit([unit], baseline=baseline)
        out[plan.name] = {
            "ok": res.ok,
            "errors": [f.message for f in res.findings
                       if f.severity == "error"],
            "counts": res.counts,
        }
    return out
