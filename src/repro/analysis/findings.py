"""Findings, severities, and the checked-in suppression baseline.

A ``Finding`` is one rule violation at one location (a lowered
entrypoint for program rules, a source file for AST rules).  Its
``fingerprint`` — ``rule:unit:key`` with no volatile numbers — is the
unit of suppression: the baseline file (``AUDIT_baseline.json``) lists
fingerprints with reasons, and ``apply_baseline`` splits an audit's
findings into active vs suppressed.  New violations therefore fail the
audit even when old accepted ones exist, the classic ratchet.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

ERROR = "error"
WARNING = "warning"
INFO = "info"
SEVERITIES = (ERROR, WARNING, INFO)

AUDIT_BASELINE_SCHEMA = "audit-baseline/v1"


@dataclass
class Finding:
    """One rule violation.

    ``key`` must be stable across runs (collective kind, symbol name,
    relative path — never byte counts or wall times); everything
    volatile belongs in ``detail``.
    """

    rule: str                   # rule id, e.g. "collective-accounting"
    severity: str               # error | warning | info
    unit: str                   # entrypoint name or repo-relative path
    message: str                # human-readable, one line
    key: str = ""               # stable suppression key within the unit
    detail: Dict[str, object] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}; "
                             f"want one of {SEVERITIES}")

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}:{self.unit}:{self.key}"

    def as_dict(self) -> Dict[str, object]:
        return {
            "rule": self.rule,
            "severity": self.severity,
            "unit": self.unit,
            "message": self.message,
            "fingerprint": self.fingerprint,
            "detail": dict(self.detail),
        }


@dataclass
class Baseline:
    """The checked-in suppression list."""

    suppressions: Dict[str, str] = field(default_factory=dict)
    path: Optional[str] = None

    def reason(self, fingerprint: str) -> Optional[str]:
        return self.suppressions.get(fingerprint)

    def as_dict(self) -> Dict[str, object]:
        return {
            "schema": AUDIT_BASELINE_SCHEMA,
            "suppressions": [
                {"fingerprint": fp, "reason": why}
                for fp, why in sorted(self.suppressions.items())
            ],
        }


def load_baseline(path: Optional[str]) -> Baseline:
    """Load ``AUDIT_baseline.json``; a missing file is an empty baseline
    (nothing suppressed), not an error."""
    if path is None:
        return Baseline()
    try:
        with open(path) as f:
            rec = json.load(f)
    except FileNotFoundError:
        return Baseline(path=path)
    if rec.get("schema") != AUDIT_BASELINE_SCHEMA:
        raise ValueError(f"{path}: unknown baseline schema "
                         f"{rec.get('schema')!r} "
                         f"(want {AUDIT_BASELINE_SCHEMA})")
    sup: Dict[str, str] = {}
    for entry in rec.get("suppressions", []):
        sup[str(entry["fingerprint"])] = str(entry.get("reason", ""))
    return Baseline(suppressions=sup, path=path)


def write_baseline(findings: Sequence[Finding], path: str,
                   reason: str = "accepted pre-existing finding") -> Baseline:
    """Snapshot the given findings as the new baseline (the deliberate
    ratchet reset — ``audit --update-baseline``)."""
    base = Baseline(
        suppressions={f.fingerprint: reason for f in findings}, path=path)
    with open(path, "w") as f:
        json.dump(base.as_dict(), f, indent=1)
        f.write("\n")
    return base


def apply_baseline(findings: Sequence[Finding], baseline: Baseline
                   ) -> Tuple[List[Finding], List[Finding], List[str]]:
    """Split findings into (active, suppressed); the third element lists
    baseline fingerprints that matched nothing — stale suppressions the
    report surfaces so the baseline shrinks as rules are fixed."""
    active: List[Finding] = []
    suppressed: List[Finding] = []
    seen = set()
    for f in findings:
        seen.add(f.fingerprint)
        if baseline.reason(f.fingerprint) is not None:
            suppressed.append(f)
        else:
            active.append(f)
    stale = [fp for fp in sorted(baseline.suppressions) if fp not in seen]
    return active, suppressed, stale


def severity_counts(findings: Sequence[Finding]) -> Dict[str, int]:
    counts = {s: 0 for s in SEVERITIES}
    for f in findings:
        counts[f.severity] += 1
    return counts
