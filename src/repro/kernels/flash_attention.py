"""Pallas TPU kernel: causal flash attention (forward).

The §Perf analysis (EXPERIMENTS.md cells A/C) shows the pure-XLA
blockwise attention pays HBM traffic for score blocks and online-softmax
accumulator rewrites — traffic a fused kernel keeps entirely in VMEM.
This kernel is that fix for TPU: grid over (batch*kv_head, q block), an
inner loop over kv blocks with the running (m, l, acc) carried in VMEM
scratch; only q/k/v reads and the final output write touch HBM.

GQA is handled by folding query heads of one kv group into the q block's
row dimension (rows = q_heads_per_group * block_q tokens).

TARGET is TPU (pl.pallas_call + BlockSpec); validated interpret=True
against kernels/ref.py on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.phantom_fused import KernelConfigError
from repro.parallel.compat import tpu_compiler_params

_CompilerParams = tpu_compiler_params()

NEG_INF = -1e30


def flash_attention_supported(s_q: int, s_kv: int, n_heads: int,
                              n_kv: int, *, block: int = 128) -> bool:
    """Static conditions under which this kernel can replace the XLA
    blockwise core: equal self-attention lengths that tile evenly, and
    GQA-divisible head counts.  ``models/attention.py`` consults this to
    fall back to XLA instead of tripping the shape check."""
    if s_q != s_kv or n_kv <= 0 or n_heads % n_kv:
        return False
    bq = min(block, s_q)
    return s_q % bq == 0


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_k: int,
            seq_len: int, causal: bool, scale: float):
    # q_ref: [block_q, Hg, hd]; k_ref/v_ref: [seq, hd]; o_ref like q_ref
    iq = pl.program_id(1)
    bq, hg, hd = q_ref.shape
    q = q_ref[...].astype(jnp.float32).reshape(bq * hg, hd)

    m0 = jnp.full((bq * hg,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq * hg,), jnp.float32)
    a0 = jnp.zeros((bq * hg, hd), jnp.float32)

    nk = seq_len // block_k

    def body(ik, carry):
        m, l, acc = carry
        ks = pl.load(k_ref, (pl.dslice(ik * block_k, block_k),
                             slice(None))).astype(jnp.float32)
        vs = pl.load(v_ref, (pl.dslice(ik * block_k, block_k),
                             slice(None))).astype(jnp.float32)
        s = jax.lax.dot_general(q, ks, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                  # [bq*hg, block_k]
        if causal:
            q_pos = (iq * block_q
                     + jax.lax.broadcasted_iota(jnp.int32,
                                                (bq, hg), 0)).reshape(-1)
            k_pos = ik * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = k_pos[None, :] <= q_pos[:, None]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        acc = acc * corr[:, None] + jax.lax.dot_general(
            p, vs, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        l = l * corr + jnp.sum(p, axis=-1)
        return m_new, l, acc

    if causal:
        # only kv blocks at or before this q block contribute
        nk_eff = jnp.minimum(nk, (iq + 1) * block_q // block_k
                             + (1 if block_q % block_k else 0))
    else:
        nk_eff = nk
    m, l, acc = jax.lax.fori_loop(0, nk_eff, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[...] = out.reshape(bq, hg, hd).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q [B, S, H, hd]; k, v [B, S, KV, hd] -> [B, S, H, hd].

    H % KV == 0 (GQA).  S % block == 0 (pad upstream).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if H % KV:
        raise KernelConfigError(f"q heads {H} not divisible by kv heads "
                                f"{KV} (GQA grouping)")
    Hg = H // KV
    bq = min(block_q, S)
    bk = min(block_k, S)
    if S % bq or S % bk:
        raise KernelConfigError(
            f"seq len {S} does not tile into blocks ({bq}, {bk}); pad "
            f"upstream or check flash_attention_supported() first")
    scale = hd ** -0.5

    # [B, S, KV, Hg, hd] -> grid (B*KV, S/bq)
    qg = q.reshape(B, S, KV, Hg, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(B * KV, S, Hg, hd)
    kg = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vg = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)

    out = pl.pallas_call(
        functools.partial(_kernel, block_q=bq, block_k=bk, seq_len=S,
                          causal=causal, scale=scale),
        grid=(B * KV, S // bq),
        in_specs=[
            pl.BlockSpec((None, bq, Hg, hd),
                         lambda b, i: (b, i, 0, 0)),          # q block
            pl.BlockSpec((None, S, hd), lambda b, i: (b, 0, 0)),  # k full
            pl.BlockSpec((None, S, hd), lambda b, i: (b, 0, 0)),  # v full
        ],
        out_specs=pl.BlockSpec((None, bq, Hg, hd),
                               lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, S, Hg, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(qg, kg, vg)

    return out.reshape(B, KV, S, Hg, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(B, S, H, hd)
