"""Pallas TPU kernels: fused phantom-layer forward and backward.

Forward:   z  = x @ L  +  g_cat @ D_cat
Backward:  [dx | dg] = dz @ [L ; D]^T          (one fused dgrad kernel)
           [dL ; dD] = [x | g]^T @ dz          (one fused wgrad kernel)

i.e. the per-rank phantom update (local diagonal block + concatenated
ghost decompression, DESIGN.md §2) as ONE kernel per pass so the small
decompress GEMM shares the output tile residency of the local GEMM
instead of issuing a second pass over HBM.  This is the op the paper
identifies as the performance cliff at large p (the "flip-flop"):
(p-1) skinny GEMMs die on GPU; on TPU we concatenate them and fuse with
the local update.

Tiling: the forward grid is (M/bm, N/bn, nk + npk) — one arbitrary-order
contraction axis that first walks the x@L blocks (nk steps of width bk),
then the ghost blocks (npk steps of width bpk), all into the same fp32
VMEM accumulator; the output tile is written once on the last step.  The
ghost operand is therefore tiled like any other contraction (never
resident at full p*k width), and every dimension is padded up to its
tile multiple with zeros (exact for a matmul) and sliced back, so
non-multiple-of-128 shapes are legal.  MXU-aligned tile defaults
(128x128x128).

TARGET is TPU (compiled via pl.pallas_call + BlockSpec); this container
is CPU-only so tests run interpret=True against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.parallel.compat import tpu_compiler_params

_CompilerParams = tpu_compiler_params()

# Per-core VMEM on current TPU generations (v4/v5e/v5p ~= 16 MiB); tile
# configs whose working set exceeds this cannot be scheduled on-chip.
VMEM_BUDGET_BYTES = 16 * 1024 * 1024


class KernelConfigError(ValueError):
    """A kernel shape/tile configuration that cannot run: mismatched
    operand shapes or a tile working set over the VMEM budget.  Callers
    that can should fall back to the XLA path (kernel_backend="xla")."""


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad2(a, rows: int, cols: int):
    """Zero-pad a 2-D operand up to the tile grid (zeros contribute
    exactly 0 to the accumulation; the caller slices the result back)."""
    r, c = a.shape
    if (r, c) == (rows, cols):
        return a
    return jnp.pad(a, ((0, rows - r), (0, cols - c)))


def kernel_vmem_bytes(bm: int, bn: int, bk: int, bpk: int, dtype,
                      acc_dtype=jnp.float32) -> int:
    """Worst-case VMEM residency of one fused-forward grid step: the four
    operand blocks double-buffered, plus the output tile and the fp32
    accumulator scratch.  Shared with ``analysis/rules.py`` so the audit
    can statically assert the bound for any planned tile config."""
    ib = jnp.dtype(dtype).itemsize
    operands = (bm * bk + bk * bn + bm * bpk + bpk * bn) * ib
    tile = bm * bn * (ib + jnp.dtype(acc_dtype).itemsize)
    return 2 * operands + tile


def check_kernel_fits(bm: int, bn: int, bk: int, bpk: int, dtype,
                      budget: int = VMEM_BUDGET_BYTES) -> int:
    need = kernel_vmem_bytes(bm, bn, bk, bpk, dtype)
    if need > budget:
        raise KernelConfigError(
            f"fused-kernel tiles bm={bm} bn={bn} bk={bk} bpk={bpk} "
            f"({jnp.dtype(dtype).name}) need ~{need} B VMEM, over the "
            f"{budget} B budget; shrink the tiles or fall back to "
            f"kernel_backend='xla'")
    return need


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_kernel(x_ref, l_ref, g_ref, d_ref, o_ref, acc_ref, *, nk: int,
                npk: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(kk < nk)
    def _local():
        acc_ref[...] += jnp.dot(x_ref[...], l_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(kk >= nk)
    def _ghost():
        acc_ref[...] += jnp.dot(g_ref[...], d_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(kk == nk + npk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "bpk", "interpret"))
def phantom_fused_matmul(x, L, g, D, *, bm: int = 128, bn: int = 128,
                         bk: int = 128, bpk: int = 128,
                         interpret: bool = False):
    """z = x @ L + g @ D.

    x [M, K]   local activation shard      (K = n_in / p)
    L [K, N]   local diagonal block        (N = n_out / p)
    g [M, PK]  gathered ghosts             (PK = p * k)
    D [PK, N]  concatenated decompressors
    -> z [M, N]

    Any shape is accepted (padded to the tile grid and sliced back); the
    ghost contraction is tiled over ``bpk`` so large p*k never exceeds
    the VMEM budget.
    """
    M, K = x.shape
    PK = g.shape[1]
    if L.shape[0] != K:
        raise KernelConfigError(
            f"L rows {L.shape[0]} != x contraction dim {K}")
    N = L.shape[1]
    if tuple(D.shape) != (PK, N):
        raise KernelConfigError(
            f"D shape {tuple(D.shape)} != ghost-width x n_out ({PK}, {N})")
    if g.shape[0] != M:
        raise KernelConfigError(f"g rows {g.shape[0]} != x rows {M}")

    bm_, bn_ = min(bm, M), min(bn, N)
    bk_, bpk_ = min(bk, K), min(bpk, PK)
    check_kernel_fits(bm_, bn_, bk_, bpk_, x.dtype)

    Mp, Np = _round_up(M, bm_), _round_up(N, bn_)
    Kp, PKp = _round_up(K, bk_), _round_up(PK, bpk_)
    x = _pad2(x, Mp, Kp)
    L = _pad2(L, Kp, Np)
    g = _pad2(g, Mp, PKp)
    D = _pad2(D, PKp, Np)
    nk, npk = Kp // bk_, PKp // bpk_

    out = pl.pallas_call(
        functools.partial(_fwd_kernel, nk=nk, npk=npk),
        grid=(Mp // bm_, Np // bn_, nk + npk),
        in_specs=[
            # steps < nk walk the local contraction; later steps pin to
            # the last local block (unread — @pl.when gates the math)
            pl.BlockSpec((bm_, bk_),
                         lambda i, j, kk: (i, jnp.minimum(kk, nk - 1))),
            pl.BlockSpec((bk_, bn_),
                         lambda i, j, kk: (jnp.minimum(kk, nk - 1), j)),
            # steps >= nk walk the ghost contraction bpk at a time
            pl.BlockSpec((bm_, bpk_),
                         lambda i, j, kk: (i, jnp.clip(kk - nk, 0,
                                                       npk - 1))),
            pl.BlockSpec((bpk_, bn_),
                         lambda i, j, kk: (jnp.clip(kk - nk, 0, npk - 1),
                                           j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, L, g, D)
    return out[:M, :N]


# ---------------------------------------------------------------------------
# backward (two generic tiled GEMMs with the forward's accumulator pattern)
# ---------------------------------------------------------------------------

def _acc_kernel(a_ref, b_ref, o_ref, acc_ref, *, nsteps: int, dims):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (dims, ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kk == nsteps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul_nt(a, b, *, bm: int = 128, bn: int = 128, bk: int = 128,
              interpret: bool = False):
    """c[M, J] = a[M, N] @ b[J, N]^T, fp32 accumulation (the dgrad shape:
    b rows are the stacked [L ; D] weight, c columns split into dx|dg)."""
    M, N = a.shape
    J, N2 = b.shape
    if N2 != N:
        raise KernelConfigError(f"b cols {N2} != a cols {N}")
    bm_, bn_, bk_ = min(bm, M), min(bn, J), min(bk, N)
    check_kernel_fits(bm_, bn_, bk_, 0, a.dtype)
    Mp, Jp, Np = _round_up(M, bm_), _round_up(J, bn_), _round_up(N, bk_)
    a = _pad2(a, Mp, Np)
    b = _pad2(b, Jp, Np)
    nsteps = Np // bk_

    out = pl.pallas_call(
        functools.partial(_acc_kernel, nsteps=nsteps, dims=((1,), (1,))),
        grid=(Mp // bm_, Jp // bn_, nsteps),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn_, bk_), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Jp), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
    return out[:M, :J]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul_tn(a, b, *, bm: int = 128, bn: int = 128, bk: int = 128,
              interpret: bool = False):
    """c[I, N] = a[M, I]^T @ b[M, N], fp32 accumulation (the wgrad shape:
    a columns are the stacked [x | g] activations, c rows split dL;dD)."""
    M, I = a.shape
    M2, N = b.shape
    if M2 != M:
        raise KernelConfigError(f"b rows {M2} != a rows {M}")
    bm_, bn_, bk_ = min(bm, I), min(bn, N), min(bk, M)
    check_kernel_fits(bm_, bn_, bk_, 0, a.dtype)
    Ip, Np, Mp = _round_up(I, bm_), _round_up(N, bn_), _round_up(M, bk_)
    a = _pad2(a, Mp, Ip)
    b = _pad2(b, Mp, Np)
    nsteps = Mp // bk_

    out = pl.pallas_call(
        functools.partial(_acc_kernel, nsteps=nsteps, dims=((0,), (0,))),
        grid=(Ip // bm_, Np // bn_, nsteps),
        in_specs=[
            pl.BlockSpec((bk_, bm_), lambda i, j, kk: (kk, i)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Ip, Np), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
    return out[:I, :N]


def phantom_fused_dgrad(dz, L, D, *, interpret: bool = False):
    """dx [M, K], dg [M, PK] = dz @ [L ; D]^T as ONE fused kernel call —
    the input and ghost gradients share the dz tile residency."""
    K = L.shape[0]
    W = jnp.concatenate([L, D], axis=0)          # [K + PK, N]
    din = matmul_nt(dz, W, interpret=interpret)  # [M, K + PK]
    return din[:, :K], din[:, K:]


def phantom_fused_wgrad(x, g, dz, *, interpret: bool = False):
    """dL [K, N], dD [PK, N] = [x | g]^T @ dz as ONE fused kernel call —
    both weight gradients share the dz tile residency."""
    K = x.shape[1]
    A = jnp.concatenate([x, g], axis=1)          # [M, K + PK]
    dW = matmul_tn(A, dz, interpret=interpret)   # [K + PK, N]
    return dW[:K], dW[K:]
