"""Pallas TPU kernel: fused phantom-layer update

    z = x @ L  +  g_cat @ D_cat

i.e. the per-rank phantom forward (local update + concatenated ghost
decompression, DESIGN.md §2) as ONE kernel so the small decompress GEMM
shares the output tile residency of the local GEMM instead of issuing a
second pass over HBM.  This is the op the paper identifies as the
performance cliff at large p (the "flip-flop"): (p-1) skinny GEMMs die on
GPU; on TPU we concatenate them and fuse with the local update.

Tiling: grid (M/bm, N/bn, K/bk) over the x@L contraction; the ghost GEMM
(contraction p*k, small) is computed once per output tile at k==0 into the
fp32 VMEM accumulator.  MXU-aligned tile defaults (128x128x128).

TARGET is TPU (compiled via pl.pallas_call + BlockSpec); this container is
CPU-only so tests run interpret=True against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.parallel.compat import tpu_compiler_params

_CompilerParams = tpu_compiler_params()


def _kernel(x_ref, l_ref, g_ref, d_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.dot(
            g_ref[...], d_ref[...],
            preferred_element_type=jnp.float32)

    acc_ref[...] += jnp.dot(x_ref[...], l_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def phantom_fused_matmul(x, L, g, D, *, bm: int = 128, bn: int = 128,
                         bk: int = 128, interpret: bool = False):
    """z = x @ L + g @ D.

    x [M, K]   local activation shard      (K = n_in / p)
    L [K, N]   local diagonal block        (N = n_out / p)
    g [M, PK]  gathered ghosts             (PK = p * k, MXU-aligned)
    D [PK, N]  concatenated decompressors
    -> z [M, N]
    """
    M, K = x.shape
    _, N = L.shape
    PK = g.shape[1]
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm_ == 0 and N % bn_ == 0 and K % bk_ == 0, (M, N, K)
    nk = K // bk_

    grid = (M // bm_, N // bn_, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),   # x
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),   # L
            pl.BlockSpec((bm_, PK), lambda i, j, k: (i, 0)),    # g
            pl.BlockSpec((PK, bn_), lambda i, j, k: (0, j)),    # D
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, L, g, D)
