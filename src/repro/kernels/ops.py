"""jit'd wrappers around the Pallas kernels (the public kernel API)."""
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.phantom_fused import phantom_fused_matmul  # noqa: F401
