"""The public kernel API: backend resolution plus the fused phantom op.

``phantom_fused_linear`` wraps the Pallas forward/backward kernels in a
``jax.custom_vjp`` so AD never differentiates through ``pallas_call``:
the forward is one fused (local + ghost-decompress) GEMM kernel, the
backward is one fused dgrad kernel (dx|dg) and one fused wgrad kernel
(dL;dD).  Collectives stay OUTSIDE the op — the caller all-gathers the
ghosts before and AD emits the priced ghost reduce-scatter after — so
the PR-6 static audit sees the identical collective account as the XLA
path.

``resolve_kernel_backend`` maps the ``ProjectionSpec.kernel_backend``
field ("xla" | "pallas" | "auto") to the executing backend: "auto"
picks Pallas only on a real TPU; on any other platform the kernels run
through the Pallas interpreter, which is correct but not fast, so
"auto" falls back to XLA there.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention import (flash_attention,  # noqa: F401
                                           flash_attention_supported)
from repro.kernels.phantom_fused import (KernelConfigError,  # noqa: F401
                                         kernel_vmem_bytes,
                                         phantom_fused_dgrad,
                                         phantom_fused_matmul,
                                         phantom_fused_wgrad)

KERNEL_BACKENDS = ("xla", "pallas", "auto")


def resolve_kernel_backend(backend: str) -> str:
    """'auto' -> 'pallas' on TPU, 'xla' elsewhere; validates the name."""
    if backend not in KERNEL_BACKENDS:
        raise ValueError(f"unknown kernel_backend {backend!r}; "
                         f"known: {KERNEL_BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend


def default_interpret() -> bool:
    """Pallas TPU kernels compile only on TPU; everywhere else run the
    interpreter (same numerics, no MXU — test/CI mode)."""
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _fused_linear(x, L, g, D, interpret):
    return phantom_fused_matmul(x, L, g, D, interpret=interpret)


def _fused_linear_fwd(x, L, g, D, interpret):
    z = phantom_fused_matmul(x, L, g, D, interpret=interpret)
    return z, (x, L, g, D)


def _fused_linear_bwd(interpret, res, dz):
    x, L, g, D = res
    dz = dz.astype(x.dtype)
    dx, dg = phantom_fused_dgrad(dz, L, D, interpret=interpret)
    dL, dD = phantom_fused_wgrad(x, g, dz, interpret=interpret)
    return (dx.astype(x.dtype), dL.astype(L.dtype),
            dg.astype(g.dtype), dD.astype(D.dtype))


_fused_linear.defvjp(_fused_linear_fwd, _fused_linear_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _flash_attn(q, k, v, causal, interpret):
    return flash_attention(q, k, v, causal=causal, interpret=interpret)


def _flash_attn_fwd(q, k, v, causal, interpret):
    out = flash_attention(q, k, v, causal=causal, interpret=interpret)
    return out, (q, k, v)


def _flash_attn_bwd(causal, interpret, res, do):
    # backward differentiates the dense reference (fp32 softmax) — the
    # forward stays fused; a fused flash backward is future work.  This
    # materializes the [B,S,KV,Hg,S] score tensor, so prefer the XLA
    # blockwise core for long-sequence TRAINING (docs/kernels.md).
    from repro.kernels.ref import flash_attention_ref
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: flash_attention_ref(q_, k_, v_, causal=causal),
        q, k, v)
    return vjp(do)


_flash_attn.defvjp(_flash_attn_fwd, _flash_attn_bwd)


def flash_attention_vjp(q, k, v, *, causal=True, interpret=None):
    """``flash_attention`` forward with a differentiable (reference)
    backward — what the attention core calls so ``jax.grad`` never
    reaches an AD-less ``pallas_call``."""
    if interpret is None:
        interpret = default_interpret()
    return _flash_attn(q, k, v, bool(causal), bool(interpret))


def phantom_fused_linear(x, L, g, D, *, interpret=None):
    """z = x @ L + g @ D with fused Pallas forward AND backward.

    x [..., K] local activation shard, L [K, N] diagonal block,
    g [..., PK] gathered ghosts, D [PK, N] concatenated decompressors
    -> z [..., N].  Arbitrary leading batch dims are flattened around
    the 2-D kernels.
    """
    if interpret is None:
        interpret = default_interpret()
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    g2 = g.reshape(-1, g.shape[-1])
    z = _fused_linear(x2, L, g2, D, bool(interpret))
    return z.reshape(*lead, L.shape[1])
