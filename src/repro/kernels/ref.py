"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
for the interpret-mode shape/dtype sweeps in tests/test_kernels.py)."""
from __future__ import annotations

import jax.numpy as jnp


def phantom_fused_ref(x, L, g, D):
    """z = x @ L + g @ D in fp32 accumulation."""
    z = (jnp.einsum("mk,kn->mn", x.astype(jnp.float32),
                    L.astype(jnp.float32))
         + jnp.einsum("mp,pn->mn", g.astype(jnp.float32),
                      D.astype(jnp.float32)))
    return z.astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """[B,S,H,hd] x [B,S,KV,hd] -> [B,S,H,hd]; GQA broadcast; fp32
    softmax."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    qg = q.reshape(B, S, KV, H // KV, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bckh->bqkgc", qg, k.astype(jnp.float32))
    s = s * hd ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
    p = p / jnp.sum(p, -1, keepdims=True)
    o = jnp.einsum("bqkgc,bckh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)
