"""repro: phantom parallelism (Seal et al., 2025) as a production-grade
multi-pod JAX training/inference framework."""
