"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b \
      --smoke --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b \
      --plan auto          # apply the planner's winning configuration

Full (non-smoke) configs target the production TPU mesh; on this CPU
container they are exercised through the dry-run
(``python -m repro.launch.dryrun``), so --smoke is the default here.
On a real multi-host TPU deployment this same entry point is launched
once per host after ``jax.distributed.initialize()`` (see README).

``--plan auto`` reads ``PLAN_report.json`` (running a quick calibrated
no-pilot planning pass over the --dp × --tp device budget if the report
doesn't exist yet) and applies the winning plan: its ``ProjectionSpec``
becomes the config's default projection for every site, and the mesh
becomes the winner's (dp, tp).  ``--plan <path>`` applies a specific
report.  See docs/planner.md.

``--elastic`` switches to the elastic fault-tolerant runtime
(docs/elastic.md): paper-FFN training on a simulated multi-host cluster
with async checkpointing, heartbeat failure detection, and energy-aware
re-planning of dp×tp×pp×k over the survivors.  ``--kill-at-step N
--kill-host hostK`` injects a deterministic device-loss event:

  PYTHONPATH=src python -m repro.launch.train --elastic \
      --devices 8 --hosts 4 --kill-at-step 25 --kill-host host3

The run must survive the loss, re-plan onto an audit-clean surviving
mesh, restore from the latest checkpoint and reach --target-loss; the
recovery energy account (replayed steps, checkpoint IO, restart) lands
in ``BENCH_report.json``.  Exit code reflects success.
"""
import argparse
import os
import sys


def _apply_plan(args, cfg):
    """Resolve --plan (auto | path) to a winner and apply it."""
    import repro.launch.plan as plan_cli
    from repro.configs.base import (PHANTOM_KINDS, ProjectionMap,
                                    ProjectionSpec)
    from repro.planner import load_plan_report

    path = plan_cli.DEFAULT_OUT if args.plan == "auto" else args.plan
    if os.path.exists(path):
        report = load_plan_report(path)
        print(f"[plan] loaded {path}")
    elif args.plan == "auto":
        pargs = plan_cli.build_parser().parse_args(
            ["--devices", str(args.dp * args.tp), "--no-pilots",
             "--out", path])
        report = plan_cli.plan(pargs)
        print(f"[plan] no report found — ran a no-pilot planning pass")
    else:
        raise FileNotFoundError(f"--plan {args.plan}: no such report")
    winner = report.get("winner")
    if not winner:
        raise ValueError(f"{path}: empty frontier, no winning plan")
    p = winner["plan"]
    budget = args.dp * args.tp * max(args.pp, 1)
    if p["devices"] > budget:
        # the XLA host device count was already pinned from --dp/--tp;
        # silently clamping the winner's mesh would train a different
        # configuration than the one we just announced
        raise ValueError(
            f"winning plan {p['name']} needs {p['devices']} devices but "
            f"--dp {args.dp} x --tp {args.tp} x --pp {args.pp} only "
            f"provisioned {budget}; re-run with --dp/--tp/--pp covering "
            f"the plan's mesh ({p['dp']}x{p['tp']}x{p.get('pp', 1)}pp)")
    spec = p.get("projection_spec", {})
    kind = spec.get("kind", p.get("strategy", "tensor"))
    if kind in PHANTOM_KINDS:
        default = ProjectionSpec(kind=kind, k=int(spec.get("k", 64)),
                                 variant=spec.get("variant", "fused"))
        applied = f"{kind} k={default.k}"
    else:
        # any tensor-family winner means "dense TP": the planner scored
        # one square FFN site, while an architecture mixes input-side
        # (column) and output-side (row) projections — the ``tensor``
        # pseudo-kind resolves each site to its natural dense sharding,
        # which is what the winner's strategy family prescribes
        default = ProjectionSpec(kind="tensor")
        applied = f"{kind} -> site-natural dense sharding"
    cfg = cfg.replace(projections=ProjectionMap(default=default))
    pp = int(p.get("pp", 1))
    print(f"[plan] applying winner {p['name']}: projections default="
          f"{applied}, mesh {p['dp']}x{p['tp']}"
          + (f"x{pp}pp" if pp > 1 else ""))
    return cfg, p["dp"], p["tp"], pp


def run_elastic_cli(args) -> int:
    """The --elastic entry point: paper-FFN elastic training with
    scripted fault injection; returns a process exit code (0 iff the
    run survived its faults and reached --target-loss)."""
    import tempfile

    from repro.obs import EnergyDriftWatchdog
    from repro.telemetry import Ledger
    from repro.train.elastic import ElasticConfig, run_elastic
    from repro.train.fault import FaultScript

    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    report_out = args.report_out or os.path.join(root, "BENCH_report.json")
    jsonl = os.path.join(os.path.dirname(report_out) or ".",
                         "BENCH_ledger.jsonl")

    kills = []
    steps = args.kill_at_step or []
    names = args.kill_host or []
    for i, s in enumerate(steps):
        # unnamed kills default to the highest-numbered hosts first
        host = (names[i] if i < len(names)
                else f"host{args.hosts - 1 - i}")
        kills.append((s, host))

    cfg = ElasticConfig(
        workdir=args.workdir or tempfile.mkdtemp(prefix="elastic_"),
        devices=args.devices, hosts=args.hosts, width=args.width,
        depth=args.depth, batch=args.batch, target_loss=args.target_loss,
        max_steps=args.steps, checkpoint_every=args.ckpt_every,
        slow_steps=tuple(args.slow_step or ()),
        slow_factor=args.slow_factor)
    ledger = Ledger(run="launch.train.elastic", jsonl_path=jsonl)
    profile_dir = args.profile_dir
    if profile_dir is None and cfg.slow_steps:
        profile_dir = os.path.join(cfg.workdir, "profile")
    watchdog = EnergyDriftWatchdog(
        ledger=ledger, profile_dir=profile_dir,
        name=f"elastic_ffn{cfg.width}", arch=f"ffn{cfg.width}")
    res = run_elastic(cfg, ledger=ledger, watchdog=watchdog,
                      fault_script=FaultScript(kills=tuple(kills)))
    ledger.write_report(report_out)
    acct = res.account
    print(f"[elastic] report -> {report_out}")
    print(f"[elastic] energy_j_total {acct['energy_j_total']:.3e} "
          f"(useful {acct['energy_j_useful']:.3e}, "
          f"replay {acct['energy_j_replay']:.3e}, "
          f"ckpt_io {acct['energy_j_ckpt_io']:.3e}, "
          f"restart {acct['energy_j_restart']:.3e}); "
          f"replay_overhead {acct['replay_overhead_ratio']:.3f}")
    wd = watchdog.summary()
    print(f"[obs] watchdog: {len(wd['trips'])} trip(s) over "
          f"{wd['observations']} observation(s)"
          + (f", profiler capture -> {wd['captures'][-1]}"
             if wd["captures"] else ""))
    if res.aborted:
        print("[elastic] FAILED: run aborted")
        return 2
    if not res.reached_target:
        print(f"[elastic] FAILED: final loss {res.final_loss:.4f} > "
              f"target {cfg.target_loss}")
        return 1
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--impl", default="phantom",
                    choices=["dense", "phantom"])
    ap.add_argument("--steps", type=int, default=None,
                    help="train steps (default 100; 300 with --elastic)")
    ap.add_argument("--batch", type=int, default=None,
                    help="global batch (default 8; 32 with --elastic)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--dp", type=int, default=2)
    ap.add_argument("--tp", type=int, default=4)
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline stages (adds a 'pipe' mesh axis and "
                         "runs the 1F1B schedule; layer count must "
                         "divide by it)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--plan", default=None,
                    help="'auto' or a PLAN_report.json path: apply the "
                         "energy planner's winning configuration "
                         "(projections + mesh)")
    # --- elastic fault-tolerant runtime (docs/elastic.md) ---
    ap.add_argument("--elastic", action="store_true",
                    help="run the elastic fault-tolerant paper-FFN "
                         "runtime with energy-aware re-planning")
    ap.add_argument("--devices", type=int, default=8,
                    help="[elastic] total device budget")
    ap.add_argument("--hosts", type=int, default=4,
                    help="[elastic] simulated hosts (devices%%hosts==0)")
    ap.add_argument("--kill-at-step", type=int, action="append",
                    default=None, metavar="N",
                    help="[elastic] inject a host loss at step N "
                         "(repeatable)")
    ap.add_argument("--kill-host", action="append", default=None,
                    metavar="HOST",
                    help="[elastic] which host dies at the matching "
                         "--kill-at-step (default hostH, last first)")
    ap.add_argument("--target-loss", type=float, default=0.12,
                    help="[elastic] stop when teacher loss reaches this")
    ap.add_argument("--width", type=int, default=64,
                    help="[elastic] paper-FFN width")
    ap.add_argument("--depth", type=int, default=2,
                    help="[elastic] paper-FFN depth")
    ap.add_argument("--ckpt-every", type=int, default=10,
                    help="[elastic] checkpoint cadence (steps)")
    ap.add_argument("--workdir", default=None,
                    help="[elastic] checkpoint/heartbeat dir "
                         "(default: a temp dir)")
    ap.add_argument("--report-out", default=None,
                    help="[elastic] write the energy ledger report here "
                         "(default: repo-root BENCH_report.json)")
    # --- observability (docs/observability.md) ---
    from repro.launch.obs import add_obs_args, obs_session
    add_obs_args(ap)
    ap.add_argument("--slow-step", type=int, action="append",
                    default=None, metavar="N",
                    help="[elastic] inject a watchdog-visible slow step "
                         "at step N (repeatable)")
    ap.add_argument("--slow-factor", type=float, default=6.0,
                    help="[elastic] slowdown factor for --slow-step")
    ap.add_argument("--profile-dir", default=None,
                    help="watchdog jax.profiler capture dir (default: "
                         "<workdir>/profile when --slow-step is given)")
    ap.add_argument("--kernel-backend", default=None,
                    choices=("xla", "pallas", "auto"),
                    help="executing kernel for the phantom fused "
                         "projection and the attention core (docs/"
                         "kernels.md); default: the config's per-site "
                         "specs (xla)")
    ap.add_argument("--overlap", default=None, choices=("tpu", "gpu"),
                    help="append the async-collective + latency-hiding-"
                         "scheduler XLA flag recipe for the given "
                         "platform (comm/compute overlap of the ghost "
                         "all-gather; no-op semantics on cpu)")
    args = ap.parse_args()
    if args.overlap:
        from repro.parallel.compat import enable_comm_overlap
        applied = enable_comm_overlap(args.overlap)
        print(f"[train] comm/compute overlap flags: {applied or '(set)'}")
    if args.steps is None:
        args.steps = 300 if args.elastic else 100
    if args.batch is None:
        args.batch = 32 if args.elastic else 8

    if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        ndev = (args.devices if args.elastic
                else args.dp * args.tp * max(args.pp, 1))
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={ndev} "
            + os.environ.get("XLA_FLAGS", ""))

    if args.elastic:
        with obs_session(args.trace_out, args.metrics_out,
                         meta={"run": "launch.train.elastic"}):
            rc = run_elastic_cli(args)
        sys.exit(rc)

    from repro.configs.base import ShapeConfig, get_config
    from repro.data.synthetic import LMDataset
    from repro.launch.mesh import make_local_mesh, make_production_mesh
    from repro.launch.specs import input_specs
    from repro.optim import make_optimizer
    from repro.optim.schedules import warmup_cosine
    from repro.parallel.axes import MeshAxes
    from repro.train.trainer import Trainer

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.plan:
        cfg, args.dp, args.tp, args.pp = _apply_plan(args, cfg)
    elif args.impl == "dense":
        from repro.configs.base import dense_projection_map
        cfg = cfg.replace(projections=dense_projection_map())
    if args.kernel_backend:
        from repro.configs.base import with_kernel_backend
        cfg = with_kernel_backend(cfg, args.kernel_backend)
    mesh = (make_local_mesh(args.dp, args.tp, args.pp) if args.smoke
            else make_production_mesh(pp=args.pp))
    axes = MeshAxes.from_mesh(mesh)
    if axes.pp > 1:
        print(f"[train] 1F1B pipeline: pp={axes.pp} stages x dp={axes.dp} "
              f"x tp={axes.tp}, {args.microbatches} microbatch(es)")
    _, bspec = input_specs(
        cfg, ShapeConfig("cli", args.seq, args.batch, "train"), axes)
    opt = make_optimizer(cfg.optimizer,
                         warmup_cosine(3e-4, 20, args.steps),
                         weight_decay=0.1)
    ds = LMDataset(cfg.vocab_size, args.batch, args.seq + 1)
    with obs_session(args.trace_out, args.metrics_out,
                     meta={"run": "launch.train", "arch": args.arch}):
        from repro.obs import EnergyDriftWatchdog
        watchdog = (EnergyDriftWatchdog(profile_dir=args.profile_dir,
                                        name=f"train_{cfg.name}",
                                        arch=cfg.name)
                    if args.profile_dir else None)
        trainer = Trainer(cfg, mesh, opt, ds, batch_spec=bspec,
                          microbatches=args.microbatches,
                          checkpoint_dir=args.ckpt_dir,
                          watchdog=watchdog)
        state = trainer.restore_or_init()
        trainer.run(state, args.steps)


if __name__ == "__main__":
    main()
