"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b \
      --smoke --steps 50

Full (non-smoke) configs target the production TPU mesh; on this CPU
container they are exercised through the dry-run
(``python -m repro.launch.dryrun``), so --smoke is the default here.
On a real multi-host TPU deployment this same entry point is launched
once per host after ``jax.distributed.initialize()`` (see README).
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--impl", default="phantom",
                    choices=["dense", "phantom"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--dp", type=int, default=2)
    ap.add_argument("--tp", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        ndev = args.dp * args.tp
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={ndev} "
            + os.environ.get("XLA_FLAGS", ""))

    import dataclasses

    from repro.configs.base import ShapeConfig, get_config
    from repro.data.synthetic import LMDataset
    from repro.launch.mesh import make_local_mesh, make_production_mesh
    from repro.launch.specs import input_specs
    from repro.optim import make_optimizer
    from repro.optim.schedules import warmup_cosine
    from repro.parallel.axes import MeshAxes
    from repro.train.trainer import Trainer

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.impl == "dense":
        from repro.configs.base import ProjectionMap
        cfg = cfg.replace(phantom=dataclasses.replace(
            cfg.phantom, apply_ffn=False, apply_attn_proj=False),
            projections=ProjectionMap())
    mesh = (make_local_mesh(args.dp, args.tp) if args.smoke
            else make_production_mesh())
    axes = MeshAxes.from_mesh(mesh)
    _, bspec = input_specs(
        cfg, ShapeConfig("cli", args.seq, args.batch, "train"), axes)
    opt = make_optimizer(cfg.optimizer,
                         warmup_cosine(3e-4, 20, args.steps),
                         weight_decay=0.1)
    ds = LMDataset(cfg.vocab_size, args.batch, args.seq + 1)
    trainer = Trainer(cfg, mesh, opt, ds, batch_spec=bspec,
                      microbatches=args.microbatches,
                      checkpoint_dir=args.ckpt_dir)
    state = trainer.restore_or_init()
    trainer.run(state, args.steps)


if __name__ == "__main__":
    main()
