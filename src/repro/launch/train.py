"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b \
      --smoke --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b \
      --plan auto          # apply the planner's winning configuration

Full (non-smoke) configs target the production TPU mesh; on this CPU
container they are exercised through the dry-run
(``python -m repro.launch.dryrun``), so --smoke is the default here.
On a real multi-host TPU deployment this same entry point is launched
once per host after ``jax.distributed.initialize()`` (see README).

``--plan auto`` reads ``PLAN_report.json`` (running a quick calibrated
no-pilot planning pass over the --dp × --tp device budget if the report
doesn't exist yet) and applies the winning plan: its ``ProjectionSpec``
becomes the config's default projection for every site, and the mesh
becomes the winner's (dp, tp).  ``--plan <path>`` applies a specific
report.  See docs/planner.md.
"""
import argparse
import os


def _apply_plan(args, cfg):
    """Resolve --plan (auto | path) to a winner and apply it."""
    import repro.launch.plan as plan_cli
    from repro.configs.base import (PHANTOM_KINDS, ProjectionMap,
                                    ProjectionSpec)
    from repro.planner import load_plan_report

    path = plan_cli.DEFAULT_OUT if args.plan == "auto" else args.plan
    if os.path.exists(path):
        report = load_plan_report(path)
        print(f"[plan] loaded {path}")
    elif args.plan == "auto":
        pargs = plan_cli.build_parser().parse_args(
            ["--devices", str(args.dp * args.tp), "--no-pilots",
             "--out", path])
        report = plan_cli.plan(pargs)
        print(f"[plan] no report found — ran a no-pilot planning pass")
    else:
        raise FileNotFoundError(f"--plan {args.plan}: no such report")
    winner = report.get("winner")
    if not winner:
        raise ValueError(f"{path}: empty frontier, no winning plan")
    p = winner["plan"]
    budget = args.dp * args.tp * max(args.pp, 1)
    if p["devices"] > budget:
        # the XLA host device count was already pinned from --dp/--tp;
        # silently clamping the winner's mesh would train a different
        # configuration than the one we just announced
        raise ValueError(
            f"winning plan {p['name']} needs {p['devices']} devices but "
            f"--dp {args.dp} x --tp {args.tp} x --pp {args.pp} only "
            f"provisioned {budget}; re-run with --dp/--tp/--pp covering "
            f"the plan's mesh ({p['dp']}x{p['tp']}x{p.get('pp', 1)}pp)")
    spec = p.get("projection_spec", {})
    kind = spec.get("kind", p.get("strategy", "tensor"))
    if kind in PHANTOM_KINDS:
        default = ProjectionSpec(kind=kind, k=int(spec.get("k", 64)),
                                 variant=spec.get("variant", "fused"))
        applied = f"{kind} k={default.k}"
    else:
        # any tensor-family winner means "dense TP": the planner scored
        # one square FFN site, while an architecture mixes input-side
        # (column) and output-side (row) projections — the ``tensor``
        # pseudo-kind resolves each site to its natural dense sharding,
        # which is what the winner's strategy family prescribes
        default = ProjectionSpec(kind="tensor")
        applied = f"{kind} -> site-natural dense sharding"
    cfg = cfg.replace(projections=ProjectionMap(default=default))
    pp = int(p.get("pp", 1))
    print(f"[plan] applying winner {p['name']}: projections default="
          f"{applied}, mesh {p['dp']}x{p['tp']}"
          + (f"x{pp}pp" if pp > 1 else ""))
    return cfg, p["dp"], p["tp"], pp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--impl", default="phantom",
                    choices=["dense", "phantom"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--dp", type=int, default=2)
    ap.add_argument("--tp", type=int, default=4)
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline stages (adds a 'pipe' mesh axis and "
                         "runs the 1F1B schedule; layer count must "
                         "divide by it)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--plan", default=None,
                    help="'auto' or a PLAN_report.json path: apply the "
                         "energy planner's winning configuration "
                         "(projections + mesh)")
    args = ap.parse_args()

    if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        ndev = args.dp * args.tp * max(args.pp, 1)
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={ndev} "
            + os.environ.get("XLA_FLAGS", ""))

    from repro.configs.base import ShapeConfig, get_config
    from repro.data.synthetic import LMDataset
    from repro.launch.mesh import make_local_mesh, make_production_mesh
    from repro.launch.specs import input_specs
    from repro.optim import make_optimizer
    from repro.optim.schedules import warmup_cosine
    from repro.parallel.axes import MeshAxes
    from repro.train.trainer import Trainer

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.plan:
        cfg, args.dp, args.tp, args.pp = _apply_plan(args, cfg)
    elif args.impl == "dense":
        from repro.configs.base import dense_projection_map
        cfg = cfg.replace(projections=dense_projection_map())
    mesh = (make_local_mesh(args.dp, args.tp, args.pp) if args.smoke
            else make_production_mesh(pp=args.pp))
    axes = MeshAxes.from_mesh(mesh)
    if axes.pp > 1:
        print(f"[train] 1F1B pipeline: pp={axes.pp} stages x dp={axes.dp} "
              f"x tp={axes.tp}, {args.microbatches} microbatch(es)")
    _, bspec = input_specs(
        cfg, ShapeConfig("cli", args.seq, args.batch, "train"), axes)
    opt = make_optimizer(cfg.optimizer,
                         warmup_cosine(3e-4, 20, args.steps),
                         weight_decay=0.1)
    ds = LMDataset(cfg.vocab_size, args.batch, args.seq + 1)
    trainer = Trainer(cfg, mesh, opt, ds, batch_spec=bspec,
                      microbatches=args.microbatches,
                      checkpoint_dir=args.ckpt_dir)
    state = trainer.restore_or_init()
    trainer.run(state, args.steps)


if __name__ == "__main__":
    main()
