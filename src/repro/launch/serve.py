"""Serving launcher: the energy-aware serving runtime over the mesh.

Fixed config, closed trace (the classic smoke run):

  PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b \
      --requests 8

Routed: price tensor/phantom x mesh x slots candidates in predicted
joules-per-token with the planner's calibrated constants, pick the
cheapest meeting the SLO, replay a synthetic trace through it and print
the measured TTFT/TPOT/e2e percentiles + the energy ledger join:

  PYTHONPATH=src python -m repro.launch.serve --route auto \
      --trace poisson --slo 200ms

``--ledger PATH`` streams the serve telemetry rows to a JSONL file (and
prints the joined ratios); ``--sample "t=0.8,k=40,p=0.95"`` switches
the whole trace from greedy to seeded sampling; ``--seed`` seeds both
the trace and the prompt token streams.  docs/serving.md documents the
runtime and the joules-per-token methodology.
"""
import argparse
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__)))))
DEFAULT_LEDGER_SRC = os.path.join(ROOT, "BENCH_ledger.jsonl")
DEFAULT_PLAN = os.path.join(ROOT, "PLAN_report.json")
DEFAULT_ROUTE_OUT = os.path.join(ROOT, "SERVE_route.json")
DEFAULT_REPORT = os.path.join(ROOT, "BENCH_report.json")


def parse_slo_ms(text):
    """'200ms' | '0.2s' | '200' (ms) -> float ms; None/'' -> 0."""
    if not text:
        return 0.0
    m = re.fullmatch(r"\s*([\d.]+)\s*(ms|s)?\s*", str(text))
    if not m:
        raise argparse.ArgumentTypeError(f"bad SLO {text!r} "
                                         "(want e.g. 200ms or 0.2s)")
    val = float(m.group(1))
    return val * 1e3 if m.group(2) == "s" else val


def parse_sampling(text):
    """'t=0.8,k=40,p=0.95' -> SamplingParams; ''/None -> greedy."""
    from repro.serve.sampling import SamplingParams
    if not text:
        return None
    kw = {}
    keys = {"t": "temperature", "temperature": "temperature",
            "k": "top_k", "top_k": "top_k",
            "p": "top_p", "top_p": "top_p", "seed": "seed"}
    for part in str(text).split(","):
        if not part.strip():
            continue
        k, _, v = part.partition("=")
        k = k.strip().lower()
        if k not in keys:
            raise argparse.ArgumentTypeError(
                f"bad --sample key {k!r} (known: t/k/p/seed)")
        field = keys[k]
        kw[field] = int(v) if field in ("top_k", "seed") else float(v)
    kw.setdefault("temperature", 0.8)
    return SamplingParams(**kw)


def build_parser():
    ap = argparse.ArgumentParser(
        prog="repro.launch.serve",
        description="continuous-batching serving with paged KV cache, "
                    "traffic/SLO harness and joules-per-token routing")
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--requests", type=int, default=None,
                    help="trace length (default 8; fleet mode defaults "
                         "to 100000 modeled / 64 executed)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--dp", type=int, default=2)
    ap.add_argument("--tp", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0,
                    help="trace + prompt RNG seed")
    ap.add_argument("--ledger", default="",
                    help="stream serve telemetry rows to this JSONL "
                         "path (standalone sessions record like run())")
    ap.add_argument("--trace", default="",
                    choices=["", "poisson", "bursty", "closed"],
                    help="synthetic workload; empty = legacy closed "
                         "batch of --requests equal prompts")
    ap.add_argument("--rate", type=float, default=None,
                    help="trace arrival rate in requests/s (default "
                         "4.0; fleet mode auto-sizes to the decode "
                         "pool's modeled capacity)")
    ap.add_argument("--slo", type=parse_slo_ms, default=0.0,
                    help="TTFT/TPOT SLO, e.g. 200ms")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request e2e deadline for goodput")
    ap.add_argument("--sample", default="",
                    help="sampling params, e.g. 't=0.8,k=40,p=0.95' "
                         "(default greedy)")
    ap.add_argument("--route", default="fixed",
                    choices=["fixed", "auto"],
                    help="auto: price candidates in predicted J/token "
                         "and serve the cheapest meeting --slo")
    ap.add_argument("--order", default="fcfs", choices=["fcfs", "edf"])
    ap.add_argument("--calibration", default=DEFAULT_PLAN,
                    help="PLAN_report.json with fitted constants "
                         "(falls back to BENCH_ledger.jsonl, then "
                         "paper defaults)")
    ap.add_argument("--route-out", default=DEFAULT_ROUTE_OUT,
                    help="persist the --route auto candidate J/token "
                         "table here as serve-route/v1 JSON "
                         "('' disables)")
    fleet = ap.add_argument_group("fleet (disaggregated serving)")
    fleet.add_argument("--fleet", action="store_true",
                       help="disaggregated prefill/decode fleet replay "
                            "with J/token autoscaling (modeled "
                            "discrete-event run by default)")
    fleet.add_argument("--executed", action="store_true",
                       help="fleet with real jitted engines (small "
                            "traces; proves token-exactness)")
    fleet.add_argument("--colocated", action="store_true",
                       help="run the single-engine baseline through "
                            "the fleet simulator instead")
    fleet.add_argument("--prefill-replicas", type=int, default=1,
                       help="initial prefill pool size")
    fleet.add_argument("--decode-replicas", type=int, default=1,
                       help="initial decode pool size")
    fleet.add_argument("--route-table", default=DEFAULT_ROUTE_OUT,
                       help="serve-route/v1 JSON the fleet planner "
                            "consumes when present (else it prices "
                            "candidates fresh)")
    fleet.add_argument("--report-out", default=DEFAULT_REPORT,
                       help="fleet mode: write the ledger report here")
    from repro.launch.obs import add_obs_args
    add_obs_args(ap)
    return ap


def _print_slo(report):
    for key in ("ttft_ms", "tpot_ms", "e2e_ms"):
        pc = report.get(key) or {}
        if pc:
            print(f"{key:8s} p50={pc['p50']:8.2f}  p95={pc['p95']:8.2f}  "
                  f"p99={pc['p99']:8.2f}  (ms)")
    print(f"requests={report.get('requests', 0)} "
          f"tokens={report.get('generated_tokens', 0)} "
          f"slo_met={report.get('slo_met_fraction', 0.0):.0%} "
          f"goodput_tokens={report.get('goodput_tokens', 0)}")


def main(argv=None):
    args = build_parser().parse_args(argv)

    if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.dp * args.tp} "
            + os.environ.get("XLA_FLAGS", ""))

    from repro.launch.obs import obs_session
    with obs_session(args.trace_out, args.metrics_out,
                     meta={"run": "launch.serve", "arch": args.arch}):
        return _main(args)


def _main(args):
    from repro.planner import load_calibration
    from repro.serve.router import (ServeConfig, candidate_configs, route,
                                    run_config)
    from repro.serve.traffic import make_trace, TraceItem
    from repro.telemetry import Ledger

    calib = load_calibration(plan_report_path=args.calibration,
                             ledger_path=DEFAULT_LEDGER_SRC)
    sampling = parse_sampling(args.sample)

    if args.fleet:
        return _fleet_main(args, calib, sampling)

    ledger = None
    if args.ledger:
        ledger = Ledger(run="launch.serve", jsonl_path=args.ledger)

    n_requests = args.requests if args.requests is not None else 8
    rate = args.rate if args.rate is not None else 4.0
    if args.trace:
        trace = make_trace(args.trace, n=n_requests,
                           rate_rps=rate,
                           prompt_len_range=(4, min(48, args.max_len - 1)),
                           new_tokens_range=(4, args.new_tokens),
                           deadline_ms=args.deadline_ms, seed=args.seed)
    else:
        # legacy closed batch: --requests equal 16-token prompts
        trace = [TraceItem(arrival_s=0.0, prompt_len=16,
                           max_new_tokens=args.new_tokens,
                           deadline_ms=args.deadline_ms, seed=args.seed)
                 for _ in range(n_requests)]

    if args.route == "auto":
        cands = candidate_configs(args.arch, args.dp * args.tp,
                                  slots_options=(args.slots,),
                                  max_len=args.max_len,
                                  page_size=args.page_size)
        winner, priced = route(cands, calib, trace, slo_ms=args.slo)
        print(f"# calibration: {calib.source}")
        print("# candidates (predicted, modeled accelerator):")
        for pc in priced:
            flag = "*" if pc is winner else " "
            print(f"# {flag} {pc.config.name:<44s} "
                  f"J/tok={pc.j_per_token:.3e} "
                  f"ttft={pc.ttft_s*1e3:.3f}ms tpot={pc.tpot_s*1e3:.3f}ms "
                  f"slo_ok={pc.meets_slo}")
        sc = winner.config
        print(f"# routed -> {sc.name} "
              f"(predicted {winner.j_per_token:.3e} J/token)")
        if args.route_out:
            from repro.serve.fleet import write_route_table
            from repro.serve.router import trace_stats
            write_route_table(
                args.route_out, args.arch, winner, priced,
                calibration=calib.source,
                stats=trace_stats(trace, args.page_size),
                slo_ms=args.slo)
            print(f"# route table ({len(priced)} candidates) -> "
                  f"{args.route_out}")
    else:
        impl = "phantom" if "phantom" in args.arch else "tensor"
        sc = ServeConfig(args.arch, impl, args.dp, args.tp, args.slots,
                         max_len=args.max_len, page_size=args.page_size)

    result = run_config(sc, trace, ledger=ledger, calib=calib,
                        seed=args.seed, slo_ms=args.slo,
                        sampling=sampling, order=args.order)
    print(f"# served {sc.name} on mesh {sc.dp}x{sc.tp}")
    _print_slo(result["slo"])
    ratio = result["energy_ratio"]
    print(f"joules/token (measured HLO account): "
          f"{result['j_per_token_measured']:.3e}")
    for kind in ("prefill", "decode"):
        if kind in ratio:
            print(f"energy measured/predicted [{kind}]: "
                  f"{ratio[kind]:.3f}")
    pages = result["pages"]
    print(f"pages: high_water={pages['high_water_pages']}"
          f"/{pages['total_pages']} allocs={pages['page_allocs']} "
          f"frees={pages['page_frees']} "
          f"fragmentation={pages['fragmentation']:.2f}")
    if ledger is not None:
        print(f"# wrote {len(ledger)} ledger rows to {args.ledger}")
    return 0


def _fleet_main(args, calib, sampling):
    """Disaggregated fleet replay (docs/serving.md, "Fleet")."""
    from repro.serve.fleet import (FleetConfig, FleetRouter,
                                   auto_rate_rps, baseline_config,
                                   load_route_table, plan_pools)
    from repro.serve.traffic import make_trace
    from repro.telemetry import Ledger

    n = args.requests if args.requests is not None else \
        (64 if args.executed else 100_000)
    kind = args.trace or "bursty"
    devices = args.dp * args.tp
    len_kw = dict(prompt_len_range=(4, min(48, args.max_len - 1)),
                  new_tokens_range=(4, args.new_tokens),
                  deadline_ms=args.deadline_ms, seed=args.seed)

    if args.colocated:
        pre_sc = dec_sc = baseline_config(
            args.arch, devices, slots=args.slots,
            max_len=args.max_len, page_size=args.page_size)
        print(f"# baseline (colocated single engine): {dec_sc.name}")
    else:
        # probe trace: the pool planner needs length statistics only
        probe = make_trace(kind, n=min(n, 2000), rate_rps=10.0,
                           **len_kw)
        table = None
        if args.route_table:
            try:
                table = load_route_table(args.route_table)
            except ValueError as exc:
                print(f"# ignoring route table: {exc}")
        pre_sc, dec_sc, notes = plan_pools(
            args.arch, devices, calib, probe, slo_ms=args.slo,
            slots=args.slots, max_len=args.max_len,
            page_size=args.page_size, route_table=table)
        print(f"# pool plan ({notes['source']}, "
              f"calibration: {calib.source}):")
        print(f"#   prefill -> {pre_sc.name} "
              f"({notes['prefill']['j_per_prompt']:.3e} J/prompt)")
        print(f"#   decode  -> {dec_sc.name} "
              f"({notes['decode']['j_per_token']:.3e} J/token)")

    rate = args.rate if args.rate is not None else \
        auto_rate_rps(dec_sc, calib, (4 + args.new_tokens) / 2,
                      replicas=args.decode_replicas)
    trace = make_trace(kind, n=n, rate_rps=rate, **len_kw)
    print(f"# trace: {kind} n={n} rate={rate:.2f} rps "
          f"slo={args.slo:.0f}ms "
          f"mode={'executed' if args.executed else 'modeled'}")

    ledger = Ledger(run="launch.serve.fleet",
                    jsonl_path=args.ledger or None,
                    meta={"arch": args.arch, "trace": kind,
                          "requests": n},
                    report_path=args.report_out or None)
    fc = FleetConfig(prefill=pre_sc, decode=dec_sc, slo_ms=args.slo,
                     executed=args.executed, colocated=args.colocated,
                     prefill_replicas=args.prefill_replicas,
                     decode_replicas=args.decode_replicas)
    router = FleetRouter(fc, calib=calib, ledger=ledger,
                         seed=args.seed)
    report = router.run(trace, sampling=sampling)
    ledger.close()

    _print_slo(report["slo"])
    pools = report["pools"]
    print(f"scale events: {report['scale_ups']} up / "
          f"{report['scale_downs']} down "
          f"(decode peak {pools['decode']['replicas_peak']} replicas)")
    for ev in report["scale_events"]:
        print(f"  t={ev['t_s']:8.2f}s {ev['pool']:7s} {ev['action']:4s} "
              f"-> {ev['replicas']} ({ev['reason']})")
    jt = report["j_per_token"]
    print(f"joules/token: prefill={jt['prefill']:.3e} "
          f"decode={jt['decode']:.3e} transfer={jt['transfer']:.3e}")
    print(f"joules/token [fleet]: {jt['fleet']:.3e}")
    xfer = report["transfer"]
    print(f"kv transfer: {xfer['measured']['migrations']:.0f} "
          f"migrations, "
          f"{xfer['measured']['transfer_wire_bytes']:.3e} bytes, "
          f"measured/predicted wire ratio = "
          f"{xfer['ratio_wire_bytes']:.4f}")
    if args.report_out:
        print(f"# wrote {len(ledger)} ledger rows -> {args.report_out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
