"""Serving launcher: continuous-batching engine over the mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b \
      --requests 8
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--dp", type=int, default=2)
    ap.add_argument("--tp", type=int, default=4)
    args = ap.parse_args()

    if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.dp * args.tp} "
            + os.environ.get("XLA_FLAGS", ""))

    import numpy as np

    from repro.configs.base import get_config
    from repro.launch.mesh import make_local_mesh
    from repro.models.model import model_decls
    from repro.parallel.axes import MeshAxes
    from repro.parallel.params import materialize
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config(args.arch, smoke=True)
    mesh = make_local_mesh(args.dp, args.tp)
    axes = MeshAxes.from_mesh(mesh)
    params = materialize(model_decls(cfg, axes), 0)
    eng = ServeEngine(cfg, mesh, params, slots=args.slots,
                      max_len=args.max_len)
    rng = np.random.RandomState(0)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab_size, 16,
                                       dtype=np.int64).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]
    eng.run(reqs)
    for i, r in enumerate(reqs):
        print(f"req{i}: {len(r.out_tokens)} tokens, done={r.done}")


if __name__ == "__main__":
    main()
