"""input_specs(): ShapeDtypeStruct stand-ins for every model input of every
(arch x shape) cell — weak-type-correct, shardable, zero allocation.  Used
by the dry-run, the trainer and the serve engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import cache_decls, n_vision_tokens
from repro.parallel.axes import MeshAxes


def _bspec(batch: int, axes: MeshAxes):
    """'dp' when the global batch divides the dp ways, else replicated
    (long_500k has batch 1)."""
    return "dp" if (axes.dp > 1 and batch % axes.dp == 0) else None


def input_specs(cfg: ModelConfig, shape: ShapeConfig, axes: MeshAxes):
    """Returns (sds_tree, spec_tree) for the step function's batch input.

    train:   tokens/labels [B, S] (+ frames / vision_embeds / positions)
    prefill: tokens [B, S] (+ modality extras)
    decode:  tokens [B, 1] (+ pos scalar; cache comes from cache_specs)
    """
    B, S = shape.global_batch, shape.seq_len
    bs = _bspec(B, axes)
    sds, spec = {}, {}

    if shape.kind in ("train", "prefill"):
        sds["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        spec["tokens"] = P(bs, None)
        if shape.kind == "train":
            sds["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
            spec["labels"] = P(bs, None)
        if cfg.family == "encdec":
            sds["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                 jnp.float32)
            spec["frames"] = P(bs, None, None)
        if cfg.frontend == "vision":
            nv = n_vision_tokens(cfg, S)
            sds["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, nv, cfg.d_model), jnp.float32)
            spec["vision_embeds"] = P(bs, None, None)
        if cfg.rope == "mrope":
            sds["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
            spec["positions"] = P(None, bs, None)
    else:  # decode
        sds["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        spec["tokens"] = P(bs, None)
    return sds, spec


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, axes: MeshAxes):
    """(sds, spec) for the decode KV/state cache of this cell."""
    return cache_decls(cfg, axes, shape.global_batch, shape.seq_len,
                       enc_len=shape.seq_len)
