"""Production meshes.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; tests/benches use small local meshes.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 — explicit axis types
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: make_mesh has no axis_types parameter
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(dp: int = 2, tp: int = 4):
    """Small mesh over host devices (tests/benches/examples)."""
    n = len(jax.devices())
    if dp * tp > n:
        dp = max(1, n // tp)
        if dp * tp > n:
            tp = n
            dp = 1
    return _make_mesh((dp, tp), ("data", "model"))
