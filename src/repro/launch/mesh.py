"""Production meshes.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; tests/benches use small local meshes.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 — explicit axis types
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: make_mesh has no axis_types parameter
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False, pp: int = 1):
    """dp×tp (×pod) production mesh, optionally with a leading pipeline
    axis.  Pipeline stages are the OUTERMOST axis: stage-boundary traffic
    is the lowest-volume communication, so it gets the slowest links.
    Stages come out of the leading (pod/data) dimension, which pp must
    divide — silently shrinking a 256-chip pod would idle paid-for
    devices."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if pp > 1:
        if shape[0] % pp:
            raise ValueError(
                f"pp={pp} does not divide the leading "
                f"{axes[0]}={shape[0]} axis of the production mesh")
        shape = (pp, shape[0] // pp) + shape[1:]
        axes = ("pipe",) + axes
    return _make_mesh(shape, axes)


def make_local_mesh(dp: int = 2, tp: int = 4, pp: int = 1):
    """Small mesh over host devices (tests/benches/examples).

    ``pp > 1`` adds a leading ``pipe`` axis (pipeline stages); meshes
    without one behave exactly as before (pp=1).  dp then tp shrink to
    fit the host (the historical contract); pp is a model property
    (stage count) and is never silently changed — too many stages for
    the host raises.
    """
    n = len(jax.devices())
    pp = max(pp, 1)
    if pp > n:
        raise ValueError(f"pp={pp} pipeline stages need at least pp "
                         f"devices; host has {n}")
    if dp * tp * pp > n:
        dp = max(1, n // (tp * pp))
        if dp * tp * pp > n:
            tp = max(1, n // pp)
            dp = 1
    if pp > 1:
        return _make_mesh((pp, dp, tp), ("pipe", "data", "model"))
    return _make_mesh((dp, tp), ("data", "model"))
