import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh) cell this lowers + compiles
the real step function — train_step (fwd+bwd+optimizer) for train shapes,
forward_prefill for prefill shapes, forward_decode (one token against a
seq_len KV cache) for decode shapes — against ShapeDtypeStruct stand-ins
on the production mesh, then records:

  * compiled.memory_analysis()   (per-device bytes: proves it fits)
  * compiled.cost_analysis()     (per-device FLOPs / HBM bytes)
  * collective wire bytes parsed from the optimized HLO
  * the three roofline terms (DESIGN.md §7)

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k
  python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all          # every cell, subprocesses
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time


def make_cfg(arch: str, impl: str, variant: str | None = None,
             extra: dict | None = None):
    from repro.configs.base import get_config
    extra = dict(extra or {})
    nested = {k.split(".", 1)[1]: extra.pop(k)
              for k in list(extra) if k.startswith("phantom.")}
    cfg = get_config(arch, **extra)
    if nested:
        from repro.configs.base import with_phantom_overrides
        cfg = with_phantom_overrides(cfg, **nested)
    if impl == "dense":
        from repro.configs.base import dense_projection_map
        cfg = cfg.replace(projections=dense_projection_map())
    elif variant:
        from repro.configs.base import with_phantom_overrides
        cfg = with_phantom_overrides(cfg, variant=variant)
    return cfg


def analysis_cfg(cfg, shape, groups: int):
    """Variant for exact cost accounting: every inner scan unrolled
    (XLA counts scan bodies once) and `groups` layer groups."""
    from repro.models.blocks import plan_period
    over = dict(microbatches=1, attn_kv_chunk=-1,
                loss_chunk=shape.seq_len, scan_layers=False)
    if cfg.family == "encdec":
        over["encoder_layers"] = groups
        over["num_layers"] = groups
    else:
        over["num_layers"] = plan_period(cfg) * groups
    if cfg.ssm is not None:
        over["ssm"] = dataclasses.replace(cfg.ssm,
                                          chunk=max(shape.seq_len, 16))
    return cfg.replace(**over)


def build_and_compile(arch: str, shape_name: str, multi_pod: bool,
                      impl: str, variant: str | None = None,
                      extra: dict | None = None, cfg=None):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import SHAPES, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import cache_specs, input_specs
    from repro.models.model import model_decls
    from repro.optim import make_optimizer
    from repro.parallel.axes import MeshAxes, resolve_spec
    from repro.parallel.params import abstract, specs

    if cfg is None:
        cfg = make_cfg(arch, impl, variant, extra)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = MeshAxes.from_mesh(mesh)

    t0 = time.time()
    if shape.kind == "train":
        from repro.train.trainer import make_train_step
        opt = make_optimizer(cfg.optimizer, 3e-4, weight_decay=0.1)
        step, decls, opt_decls = make_train_step(
            cfg, mesh, opt, batch_spec=input_specs(cfg, shape, axes)[1],
            microbatches=cfg.microbatches)
        params = abstract(decls)
        opt_state = abstract(opt_decls)
        batch_sds, _ = input_specs(cfg, shape, axes)
        import jax.numpy as jnp
        args = (params, opt_state,
                jax.ShapeDtypeStruct((), jnp.int32), batch_sds)
        lowered = step.lower(*args)
    else:
        from repro.serve.engine import make_serve_fns
        prefill_fn, decode_fn, cache_sds, _cspecs = make_serve_fns(
            cfg, mesh, shape)
        decls = model_decls(cfg, axes)
        params = abstract(decls)
        import jax.numpy as jnp
        if shape.kind == "prefill":
            batch_sds, _ = input_specs(cfg, shape, axes)
            lowered = prefill_fn.lower(params, batch_sds)
        else:
            B = shape.global_batch
            toks = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            pos = jax.ShapeDtypeStruct((B,), jnp.int32)
            lowered = decode_fn.lower(params, cache_sds, toks, pos)
    t_lower = time.time() - t0

    t1 = time.time()
    # compile through the shared telemetry cache: a module lowered to
    # identical HLO (cost-fix g=1/g=2 reruns, planner HBM-fit checks)
    # is compiled once per process; ``analyze`` parses it through the
    # matching analysis cache.  Only the compile is timed here — a ~0
    # compile_s means this process genuinely didn't recompile.
    from repro.telemetry import compile_lowered
    compiled = compile_lowered(lowered)
    t_compile = time.time() - t1
    return cfg, mesh, compiled, {"lower_s": t_lower, "compile_s": t_compile}


def analyze(cfg, mesh, compiled, timings, shape_name: str, impl: str):
    from repro.core.energy import roofline_terms
    from repro.models.model import count_params
    from repro.telemetry import analyze_compiled

    tp = mesh.shape["model"]
    costs = analyze_compiled(compiled, default_group=tp)
    flops = costs.flops
    hbm_bytes = costs.hbm_bytes
    wire, breakdown = costs.collective_wire_bytes, costs.collectives
    mem = costs.memory
    rt = roofline_terms(flops, hbm_bytes, wire)

    from repro.configs.base import SHAPES
    shape = SHAPES[shape_name]
    n_active = count_params(cfg, active_only=True, tp=tp)
    n_total = count_params(cfg, active_only=False, tp=tp)
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train"
                                   else 1)
    mf = 6.0 * n_active * tokens
    if shape.kind != "train":
        mf = 2.0 * n_active * tokens       # inference: fwd only
    n_dev = mesh.devices.size
    model_flops_per_dev = mf / n_dev

    return {
        "arch": cfg.name, "shape": shape_name, "impl": impl,
        "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "devices": int(n_dev),
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm_bytes,
        "collective_wire_bytes_per_device": wire,
        "collectives": breakdown,
        "memory": mem,
        "roofline": {
            "compute_s": rt.compute_s, "memory_s": rt.memory_s,
            "collective_s": rt.collective_s, "dominant": rt.dominant,
            "step_s": rt.step_s,
            "fraction": rt.fraction_of_roofline(),
        },
        "params_total": n_total, "params_active": n_active,
        "model_flops_per_device": model_flops_per_dev,
        "useful_flops_ratio": (model_flops_per_dev / flops) if flops else 0,
        "timings": timings,
    }


def _cell_costs(compiled, tp):
    from repro.telemetry import analyze_compiled
    c = analyze_compiled(compiled, default_group=tp)
    return (c.flops, c.hbm_bytes, c.collective_wire_bytes, c.collectives)


def parse_sets(pairs):
    """--set key=value (typed) -> cfg override dict."""
    out = {}
    for pair in pairs or []:
        k, v = pair.split("=", 1)
        if v in ("true", "True"):
            out[k] = True
        elif v in ("false", "False"):
            out[k] = False
        else:
            try:
                out[k] = int(v)
            except ValueError:
                out[k] = v
    return out


def cost_fix(arch, shape_name, impl, json_path, variant=None,
             overrides=None):
    """Scan-aware exact cost totals via g=1 / g=2 extrapolation (see
    experiments/cost_fix.py docstring); rewrites the cell JSON."""
    from repro.configs.base import SHAPES
    from repro.core.energy import roofline_terms
    from repro.models.blocks import plan_period
    from repro.models.model import count_params

    if os.path.exists(json_path):
        with open(json_path) as f:
            rec = json.load(f)
    else:
        rec = {"arch": arch, "shape": shape_name, "impl": impl,
               "mesh": {"data": 16, "model": 16}, "devices": 256,
               "memory": {}, "overrides": overrides or {}}
    cfg = make_cfg(arch, impl, variant, extra=overrides)
    shape = SHAPES[shape_name]
    base = {}
    for g in (1, 2):
        cfg_g = analysis_cfg(cfg, shape, g)
        _c, mesh, compiled, _t = build_and_compile(
            arch, shape_name, False, impl, cfg=cfg_g)
        base[g] = _cell_costs(compiled, mesh.shape["model"])
    if cfg.family == "encdec":
        n_groups = cfg.num_layers
    else:
        n_groups = cfg.num_layers // plan_period(cfg)
    f1, b1, w1, _ = base[1]
    f2, b2, w2, bd2 = base[2]
    flops = f1 + (f2 - f1) * (n_groups - 1)
    hbm = b1 + (b2 - b1) * (n_groups - 1)
    wire = w1 + (w2 - w1) * (n_groups - 1)
    # scale the per-op breakdown by the same wire ratio for reporting
    scale = wire / max(w2, 1e-9)
    breakdown = {k: {"count": v["count"],
                     "result_bytes": v["result_bytes"],
                     "wire_bytes": v["wire_bytes"] * scale}
                 for k, v in bd2.items()}

    rt = roofline_terms(flops, hbm, wire)
    tp = 16
    n_active = count_params(cfg, active_only=True, tp=tp)
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train"
                                   else 1)
    mf = (6.0 if shape.kind == "train" else 2.0) * n_active * tokens
    model_flops_per_dev = mf / 256
    rec.update({
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm,
        "collective_wire_bytes_per_device": wire,
        "collectives": breakdown,
        "roofline": {
            "compute_s": rt.compute_s, "memory_s": rt.memory_s,
            "collective_s": rt.collective_s, "dominant": rt.dominant,
            "step_s": rt.step_s, "fraction": rt.fraction_of_roofline(),
        },
        "model_flops_per_device": model_flops_per_dev,
        "useful_flops_ratio": (model_flops_per_dev / flops) if flops else 0,
        "cost_method": "scan-extrapolated",
    })
    with open(json_path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"fixed {json_path}: frac={rec['roofline']['fraction']:.3f} "
          f"dom={rec['roofline']['dominant']}")
    return rec


def run_cell(arch, shape, multi_pod, impl, variant=None, out_path=None,
             print_hlo_ops=False):
    cfg, mesh, compiled, timings = build_and_compile(
        arch, shape, multi_pod, impl, variant)
    rec = analyze(cfg, mesh, compiled, timings, shape, impl)
    print(compiled.memory_analysis())
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):     # older jax: one dict per device
        ca = ca[0] if ca else {}
    print({k: v for k, v in sorted(ca.items())
           if k in ("flops", "bytes accessed")})
    print(json.dumps(rec["roofline"], indent=None))
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"wrote {out_path}")
    return rec


SKIP = {
    # long_500k needs sub-quadratic attention: full-attention archs skip
    # (DESIGN.md §5); mamba2/jamba run it.
    ("granite-moe-3b-a800m", "long_500k"),
    ("olmoe-1b-7b", "long_500k"),
    ("seamless-m4t-large-v2", "long_500k"),
    ("chatglm3-6b", "long_500k"),
    ("qwen2.5-14b", "long_500k"),
    ("stablelm-3b", "long_500k"),
    ("phi3-mini-3.8b", "long_500k"),
    ("qwen2-vl-72b", "long_500k"),
}


def run_all(out_dir: str, impls=("dense", "phantom"), multi_pods=(False,),
            archs=None, shapes=None, timeout: int = 3600):
    from repro.configs.base import ARCH_IDS, SHAPES
    os.makedirs(out_dir, exist_ok=True)
    archs = archs or ARCH_IDS
    shapes = shapes or list(SHAPES)
    results = []
    for arch in archs:
        for shape in shapes:
            for impl in impls:
                for mp in multi_pods:
                    tag = f"{arch}_{shape}_{impl}_{'mp' if mp else 'sp'}"
                    out = os.path.join(out_dir, tag + ".json")
                    if (arch, shape) in SKIP:
                        with open(out, "w") as f:
                            json.dump({"arch": arch, "shape": shape,
                                       "impl": impl, "skipped":
                                       "full-attention arch at 500k"}, f)
                        print(f"SKIP {tag}")
                        continue
                    if os.path.exists(out):
                        print(f"CACHED {tag}")
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape,
                           "--impl", impl, "--out", out]
                    if mp:
                        cmd.append("--multi-pod")
                    print(f"RUN {tag}", flush=True)
                    env = dict(os.environ)
                    src = os.path.join(os.path.dirname(os.path.dirname(
                        os.path.dirname(os.path.dirname(
                            os.path.abspath(__file__))))), "src")
                    env["PYTHONPATH"] = (src + os.pathsep
                                         + env.get("PYTHONPATH", ""))
                    r = subprocess.run(cmd, capture_output=True, text=True,
                                       timeout=timeout, env=env)
                    if r.returncode != 0:
                        print(f"FAIL {tag}\n{r.stdout[-2000:]}"
                              f"\n{r.stderr[-2000:]}")
                    else:
                        print(r.stdout.strip().splitlines()[-1])
                    results.append((tag, r.returncode))
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--shape", default="train_4k",
                    choices=["train_4k", "prefill_32k", "decode_32k",
                             "long_500k"])
    ap.add_argument("--impl", default="phantom",
                    choices=["dense", "phantom"])
    ap.add_argument("--variant", default=None,
                    choices=[None, "faithful", "fused", "ring"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--cost-fix", default=None,
                    help="path to a cell JSON to rewrite with "
                         "scan-extrapolated exact costs")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (repeatable); used by "
                         "the §Perf hillclimb")
    args = ap.parse_args()

    overrides = parse_sets(getattr(args, "set"))
    if args.cost_fix:
        cost_fix(args.arch, args.shape, args.impl, args.cost_fix,
                 args.variant, overrides=overrides)
        return
    if args.all:
        run_all(args.out_dir)
        return
    run_cell(args.arch, args.shape, args.multi_pod, args.impl,
             args.variant, args.out)


if __name__ == "__main__":
    main()
