"""Static sharding & energy audit CLI.

  PYTHONPATH=src python -m repro.launch.audit --all

Lowers every shipped jitted entrypoint (paper-FFN train probe, 1F1B
pipeline probe, serving prefill/decode) WITHOUT executing anything,
runs the ``repro.analysis`` rule engine over the optimized HLO /
jaxpr, lints the repo source, and writes ``AUDIT_report.json``
(schema ``audit-report/v1``).  Exit status 1 when any ERROR-severity
finding survives the checked-in suppression baseline
(``AUDIT_baseline.json``) — warnings and info report but don't gate.
See docs/analysis.md for the rule catalog.
"""
import argparse
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__)))))
DEFAULT_BASELINE = os.path.join(ROOT, "AUDIT_baseline.json")
DEFAULT_OUT = os.path.join(ROOT, "AUDIT_report.json")


def build_parser():
    ap = argparse.ArgumentParser(
        prog="repro.launch.audit",
        description="prove every lowered collective is priced before "
                    "anything runs")
    ap.add_argument("--all", action="store_true",
                    help="audit every shipped entrypoint family plus "
                         "the source lint (the CI job)")
    ap.add_argument("--unit", default="",
                    help="only units whose name contains this substring")
    ap.add_argument("--arch", default="qwen2.5-14b",
                    help="architecture for the serving units")
    ap.add_argument("--source-only", action="store_true",
                    help="AST lint only — no lowering (fast)")
    ap.add_argument("--devices", type=int, default=8,
                    help="virtual host devices for the lowering meshes")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="suppression baseline (missing file = empty)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="accept all current findings into the baseline "
                         "(deliberate ratchet reset)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="report path (audit-report/v1)")
    return ap


def audit(args) -> int:
    from repro.analysis import load_baseline, run_audit
    from repro.analysis.findings import write_baseline

    units = []
    if not args.source_only:
        from repro.analysis.units import build_default_units
        units = build_default_units(arch=args.arch)
        if args.unit:
            units = [u for u in units if args.unit in u.name]
    baseline = load_baseline(args.baseline)
    result = run_audit(units, baseline=baseline, source_root=ROOT)

    if args.update_baseline:
        write_baseline(result.findings, args.baseline)
        print(f"# baseline: accepted {len(result.findings)} findings "
              f"into {args.baseline}")
        result = run_audit(units, baseline=load_baseline(args.baseline),
                           source_root=ROOT)

    result.write(args.out)
    print("\n".join(result.summary_lines()))
    print(f"# wrote {args.out}")
    return 0 if result.ok else 1


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if not (args.all or args.unit or args.source_only):
        build_parser().error("pick a scope: --all, --unit, or "
                             "--source-only")
    if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))
    return audit(args)


if __name__ == "__main__":
    sys.exit(main())
