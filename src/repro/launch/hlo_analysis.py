"""Parse collective-communication traffic out of compiled HLO text.

cost_analysis() has FLOPs and HBM bytes but NOT collective bytes, so we
walk the post-optimization HLO for all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops, take each op's
result shapes, its replica group size, and convert to per-device *wire*
bytes under a ring algorithm:

  all-gather       result*(g-1)/g        (device receives all but its own)
  reduce-scatter   result*(g-1)          (input = g x result, ring passes)
  all-reduce       2*result*(g-1)/g      (RS + AG phases)
  all-to-all       result*(g-1)/g
  collective-permute  result             (single hop)
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64"
                       r"|f64)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[0-9, ]+\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_PAIRS_RE = re.compile(r"source_target_pairs=\{([0-9,{} ]+)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).strip("{}").split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # replica_groups=[G,S]<=[N]: G groups of size S
        return int(m.group(2))
    m = _PAIRS_RE.search(line)
    if m:
        # collective-permute carries source_target_pairs, not
        # replica_groups; the devices a permute chains together trace
        # out the mesh axis it shifts (a ring or 1F1B hop over an axis
        # of size S connects S devices), so the group is the largest
        # connected component of the pair graph
        pairs = [(int(a), int(b))
                 for a, b in re.findall(r"\{(\d+),(\d+)\}", m.group(1))]
        if pairs:
            adj = defaultdict(set)
            for a, b in pairs:
                adj[a].add(b)
                adj[b].add(a)
            best, seen = 1, set()
            for start in adj:
                if start in seen:
                    continue
                comp, stack = 0, [start]
                seen.add(start)
                while stack:
                    comp += 1
                    for nb in adj[stack.pop()]:
                        if nb not in seen:
                            seen.add(nb)
                            stack.append(nb)
                best = max(best, comp)
            return best
    return default


def collective_bytes(hlo_text: str, default_group: int = 16):
    """Returns (per_device_wire_bytes_total, breakdown dict with per-op
    counts and bytes).  Each per-op record also carries ``m_floats``,
    the paper Eqn. 26 per-rank message total computed with each op's
    OWN replica-group size, and ``groups`` — a ``{group_size: {count,
    m_floats, wire_bytes}}`` map — so the static audit can match
    collectives by mesh axis, which the aggregate ``default_group``
    conversion can't express."""
    out = defaultdict(lambda: {"count": 0, "result_bytes": 0,
                               "wire_bytes": 0.0, "m_floats": 0.0,
                               "groups": {}})
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        result_shapes, op, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":       # started ops counted at -start
            continue
        rb = _shape_bytes(result_shapes)
        if rb == 0:
            # fallback: scan whole line (result may be a named tuple ref)
            rb = _shape_bytes(line.split("(", 1)[0])
        g = _group_size(line, default_group)
        g = max(g, 1)
        if op == "all-gather":
            wb = rb * (g - 1) / g
        elif op == "reduce-scatter":
            wb = rb * (g - 1)
        elif op == "all-reduce":
            wb = 2 * rb * (g - 1) / g
        elif op == "all-to-all":
            wb = rb * (g - 1) / g
        else:  # collective-permute
            wb = rb
        rec = out[op]
        rec["count"] += 1
        rec["result_bytes"] += rb
        rec["wire_bytes"] += wb
        # all-gather RESULT = m*g; everything else's result = m
        mf = rb / 4.0 / g if op == "all-gather" else rb / 4.0
        rec["m_floats"] += mf
        grec = rec["groups"].setdefault(
            g, {"count": 0, "m_floats": 0.0, "wire_bytes": 0.0})
        grec["count"] += 1
        grec["m_floats"] += mf
        grec["wire_bytes"] += wb
    total = sum(r["wire_bytes"] for r in out.values())
    return total, dict(out)


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))


# paper Eqn. 26 speaks in per-rank message sizes m (floats, 4 bytes);
# convert each HLO op's RESULT bytes back to that unit:
#   all-gather   result = m*g  ->  m = result/(4g)
#   others       result = m    ->  m = result/4
# (bf16 messages count as half a float — the unit is 4-byte floats, which
# is what the Table III fits and the energy model price.)
def collective_m_floats(breakdown: dict, group: int) -> float:
    """Total per-rank message floats across a ``collective_bytes``
    breakdown, in the paper's Eqn. 26 units.  Records carrying their own
    per-op ``m_floats`` (computed with each op's actual replica-group
    size) are preferred; ``group`` is the legacy aggregate fallback."""
    g = max(group, 1)
    total = 0.0
    for op, rec in breakdown.items():
        if "m_floats" in rec:
            total += rec["m_floats"]
            continue
        rb = rec["result_bytes"]
        total += rb / 4.0 / g if op == "all-gather" else rb / 4.0
    return total
