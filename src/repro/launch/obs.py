"""Observability artifact inspector (docs/observability.md).

  # per-category span summary of a --trace-out file (validates schema)
  PYTHONPATH=src python -m repro.launch.obs summary --trace trace.json

  # print a --metrics-out export (Prometheus text or JSONL snapshots)
  PYTHONPATH=src python -m repro.launch.obs metrics obs_metrics.prom

  # cross-check an elastic trace against the priced recovery account:
  # the recovery spans (replan/restore/compile) must sum to the
  # recovery-account/v1 seconds within --tol
  PYTHONPATH=src python -m repro.launch.obs verify-recovery \
      --trace trace.json --report BENCH_report.json

The trace files are Chrome-trace-event JSON: open them directly in
Perfetto (https://ui.perfetto.dev) or chrome://tracing.
"""
import argparse
import json
import sys
from contextlib import contextmanager

# the recovery account's measured restart seconds and the span names
# that time the same code blocks (train/elastic.py)
RECOVERY_SPANS = {"elastic/replan": "replan_s",
                  "elastic/restore": "restore_s",
                  "elastic/compile": "compile_s"}


def add_obs_args(ap: argparse.ArgumentParser):
    """The shared launcher flags (train/serve/plan all take them)."""
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Perfetto-loadable Chrome trace-event "
                         "JSON of this run")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="export metrics: Prometheus text, or one "
                         "snapshot line appended for .jsonl paths")
    return ap


@contextmanager
def obs_session(trace_out=None, metrics_out=None, meta=None):
    """Install a fresh Tracer / MetricsRegistry for one launcher run
    and write the requested artifacts on exit (crash included — a
    failing run still leaves its trace behind)."""
    from repro.obs import (MetricsRegistry, Tracer, get_metrics,
                           set_metrics, set_tracer)
    tracer = Tracer(meta=dict(meta or {})) if trace_out else None
    prev_t = set_tracer(tracer) if tracer is not None else None
    prev_m = set_metrics(MetricsRegistry()) if metrics_out else None
    try:
        yield tracer
    finally:
        if metrics_out:
            get_metrics().write(metrics_out, meta=dict(meta or {}))
            set_metrics(prev_m)
            print(f"[obs] metrics -> {metrics_out}")
        if tracer is not None:
            tracer.write(trace_out)
            set_tracer(prev_t)
            print(f"[obs] trace -> {trace_out}")


def cmd_summary(args) -> int:
    from repro.obs import load_trace, span_events
    doc = load_trace(args.trace)
    evs = doc.get("traceEvents", [])
    spans = span_events(doc)
    instants = [e for e in evs if e.get("ph") == "i"]
    print(f"# {args.trace}: {len(evs)} events "
          f"({len(spans)} spans, {len(instants)} instants)")
    by_cat = {}
    for ev in spans:
        rec = by_cat.setdefault(ev.get("cat", "misc"),
                                {"spans": 0, "total_s": 0.0, "names": {}})
        rec["spans"] += 1
        rec["total_s"] += ev.get("dur", 0.0) * 1e-6
        n = rec["names"]
        n[ev["name"]] = n.get(ev["name"], 0) + 1
    for cat in sorted(by_cat):
        rec = by_cat[cat]
        names = ", ".join(f"{k} x{v}" for k, v in
                          sorted(rec["names"].items()))
        print(f"{cat:<12} {rec['spans']:>6} spans "
              f"{rec['total_s']:>10.3f} s   {names}")
    linked = sum(1 for ev in spans
                 if (ev.get("args") or {}).get("ledger"))
    print(f"# ledger-linked spans: {linked}")
    print("# open in Perfetto: https://ui.perfetto.dev "
          "(Open trace file)")
    return 0


def cmd_metrics(args) -> int:
    path = args.path
    if path.endswith(".jsonl"):
        from repro.obs import SNAPSHOT_SCHEMA
        with open(path) as f:
            lines = [json.loads(ln) for ln in f if ln.strip()]
        if not lines:
            print(f"{path}: empty", file=sys.stderr)
            return 1
        for snap in lines:
            if snap.get("schema") != SNAPSHOT_SCHEMA:
                print(f"{path}: unknown snapshot schema "
                      f"{snap.get('schema')!r}", file=sys.stderr)
                return 1
        snap = lines[-1]
        print(f"# {path}: {len(lines)} snapshot(s); latest:")
        for name, m in snap["metrics"].items():
            vals = m["values"]
            if m["kind"] == "histogram":
                for lk, h in vals.items():
                    print(f"{name}{lk} count={h['count']} "
                          f"sum={h['sum']:.6g}")
            else:
                for lk, v in vals.items():
                    print(f"{name}{lk} {v:.6g}")
        return 0
    with open(path) as f:
        text = f.read()
    n_series = sum(1 for ln in text.splitlines()
                   if ln and not ln.startswith("#"))
    print(text, end="")
    print(f"# {path}: {n_series} series", file=sys.stderr)
    return 0


def cmd_verify_recovery(args) -> int:
    from repro.obs import load_trace, span_events
    doc = load_trace(args.trace)
    span_s = {}
    for ev in span_events(doc):
        if ev["name"] in RECOVERY_SPANS:
            span_s[ev["name"]] = (span_s.get(ev["name"], 0.0)
                                  + ev.get("dur", 0.0) * 1e-6)
    with open(args.report) as f:
        rep = json.load(f)
    accounts = [
        (e.get("extra") or {}).get("recovery")
        for e in rep.get("entries", [])
        if (e.get("extra") or {}).get("recovery", {}).get("schema")
        == "recovery-account/v1"]
    if not accounts:
        print(f"{args.report}: no recovery-account/v1 entry",
              file=sys.stderr)
        return 1
    acct = accounts[-1]
    acct_s = sum(float(acct.get(k, 0.0))
                 for k in RECOVERY_SPANS.values())
    trace_s = sum(span_s.values())
    print(f"recovery spans: "
          + ", ".join(f"{n}={span_s.get(n, 0.0):.3f}s"
                      for n in sorted(RECOVERY_SPANS)))
    print(f"trace recovery seconds {trace_s:.3f} vs account "
          f"{acct_s:.3f} (replan {acct.get('replan_s', 0):.3f} + "
          f"restore {acct.get('restore_s', 0):.3f} + "
          f"compile {acct.get('compile_s', 0):.3f})")
    if acct_s <= 0 and trace_s <= 0:
        print("no recovery occurred in either view: consistent")
        return 0
    denom = max(acct_s, 1e-9)
    rel = abs(trace_s - acct_s) / denom
    if rel > args.tol:
        print(f"FAIL: trace and account disagree by {rel:.1%} "
              f"(> {args.tol:.0%})", file=sys.stderr)
        return 1
    print(f"OK: within {rel:.1%} (tolerance {args.tol:.0%})")
    return 0


def build_parser():
    ap = argparse.ArgumentParser(
        prog="repro.launch.obs",
        description="inspect --trace-out / --metrics-out artifacts")
    sub = ap.add_subparsers(dest="cmd", required=True)

    s = sub.add_parser("summary",
                       help="per-category span summary of a trace")
    s.add_argument("--trace", required=True)
    s.set_defaults(fn=cmd_summary)

    m = sub.add_parser("metrics",
                       help="print a Prometheus/.jsonl metrics export")
    m.add_argument("path")
    m.set_defaults(fn=cmd_metrics)

    v = sub.add_parser("verify-recovery",
                       help="check elastic recovery spans against the "
                            "recovery-account/v1 seconds")
    v.add_argument("--trace", required=True)
    v.add_argument("--report", default="BENCH_report.json")
    v.add_argument("--tol", type=float, default=0.35,
                   help="relative tolerance (default 0.35: span and "
                        "account timers bracket slightly different "
                        "code)")
    v.set_defaults(fn=cmd_verify_recovery)
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
