"""Energy-aware configuration planner CLI.

  PYTHONPATH=src python -m repro.launch.plan --devices 8 --target-loss 0.2

Calibrates the analytic energy model from ``BENCH_ledger.jsonl`` (paper
defaults when absent), enumerates mesh × strategy × ghost-width
candidates up to ``--devices``, filters for HBM fit and throughput,
runs small pilot training runs to normalize every plan to the target
loss (``--no-pilots`` skips them and prices plans at the calibrated
ν scales instead), and writes ``PLAN_report.json`` with the Pareto
frontier, the matched-loss phantom-vs-TP comparison, and the winning
plan.  ``python -m repro.launch.train --plan auto`` applies the winner.
"""
import argparse
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__)))))
DEFAULT_LEDGER = os.path.join(ROOT, "BENCH_ledger.jsonl")
DEFAULT_OUT = os.path.join(ROOT, "PLAN_report.json")


def build_parser():
    ap = argparse.ArgumentParser(
        prog="repro.launch.plan",
        description="calibrated search over mesh x strategy x ghost "
                    "width with an iso-loss frontier")
    ap.add_argument("--devices", type=int, default=8,
                    help="device budget (the FULL mesh TP plans use)")
    ap.add_argument("--target-loss", type=float, default=0.2,
                    help="the fixed loss every plan is normalized to")
    ap.add_argument("--width", type=int, default=1024,
                    help="base FFN width n (iso-loss pilots may shrink "
                         "per-strategy widths)")
    ap.add_argument("--depth", type=int, default=2)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--ks", default="4,8,16",
                    help="comma-separated ghost widths to search")
    ap.add_argument("--strategies", default="tensor_col,phantom")
    ap.add_argument("--microbatches", default="1",
                    help="comma-separated gradient-accumulation options")
    ap.add_argument("--pps", default="1,2",
                    help="comma-separated pipeline-stage counts to "
                         "search (1 = no pipeline axis)")
    ap.add_argument("--hbm-gb", type=float, default=16.0,
                    help="per-device HBM budget (TPU v5e default)")
    ap.add_argument("--min-throughput", type=float, default=0.0,
                    help="global rows/s floor (0 = unconstrained)")
    ap.add_argument("--ledger", default=DEFAULT_LEDGER,
                    help="BENCH_ledger.jsonl to calibrate from")
    ap.add_argument("--no-pilots", action="store_true",
                    help="skip pilot runs; price plans at the "
                         "calibrated nu scales")
    ap.add_argument("--pilot-steps", type=int, default=300,
                    help="pilot iteration budget (also the censored nu)")
    ap.add_argument("--pilot-tp", type=int, default=4,
                    help="model-axis size the pilots train at")
    ap.add_argument("--compiled-hbm-check", action="store_true",
                    help="verify the frontier's HBM fit against the "
                         "lowered probe step (cached analysis)")
    ap.add_argument("--no-audit", dest="audit", action="store_false",
                    help="skip the static sharding/energy audit of the "
                         "frontier (on by default: a plan whose lowered "
                         "collectives don't match its priced account is "
                         "moved to rejected)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=DEFAULT_OUT)
    from repro.launch.obs import add_obs_args
    add_obs_args(ap)
    return ap


def _csv_ints(s):
    return tuple(int(x) for x in s.split(",") if x)


def plan(args, ledger=None, calib_rows=None) -> dict:
    """Run the full planning pass; returns the report dict (also
    written to ``args.out``).  ``ledger`` optionally receives
    pilot/frontier rows (the plan_smoke suite passes the shared
    benchmarks ledger); ``calib_rows`` calibrates from already-loaded
    ledger rows instead of the ``--ledger`` file (plan_smoke passes the
    in-process entries, since benchmarks.run truncates the JSONL stream
    at startup)."""
    from repro.launch.mesh import make_local_mesh
    from repro.planner import (Constraints, apply_iso_loss,
                               apply_throughput_floor, build_report,
                               calibrate_from_ledger, compiled_hbm_bytes,
                               enumerate_plans, filter_feasible,
                               matched_loss_comparison, pareto_frontier,
                               plan_summary_lines, record_frontier,
                               run_pilots, score_plans, write_plan_report)

    from repro.obs import get_tracer
    tracer = get_tracer()
    strategies = tuple(s for s in args.strategies.split(",") if s)
    ks = _csv_ints(args.ks)
    mbs = _csv_ints(args.microbatches)

    # 1. calibrate
    with tracer.span("plan/calibrate", cat="plan") as sp:
        if calib_rows is not None:
            from repro.planner import calibrate_from_rows
            calib = calibrate_from_rows(calib_rows)
            print(f"# calibration: {calib.source} "
                  f"(in-process ledger rows)")
        else:
            ledger_path = (args.ledger if os.path.exists(args.ledger)
                           else None)
            calib = calibrate_from_ledger(jsonl_path=ledger_path)
            print(f"# calibration: {calib.source}"
                  + (f" ({ledger_path})" if ledger_path else ""))
        sp.annotate(source=calib.source)

    # 2. enumerate + resource-filter
    constraints = Constraints(
        max_devices=args.devices,
        hbm_bytes_per_device=args.hbm_gb * 2 ** 30,
        min_throughput_rows_s=args.min_throughput)
    with tracer.span("plan/enumerate", cat="plan",
                     devices=args.devices) as sp:
        candidates = enumerate_plans(
            args.devices, width=args.width, depth=args.depth,
            batch=args.batch, strategies=strategies, ks=ks,
            microbatch_options=mbs, pps=_csv_ints(args.pps) or (1,))
        feasible, rejected = filter_feasible(candidates, constraints)
        sp.annotate(candidates=len(candidates), feasible=len(feasible))
    print(f"# {len(candidates)} candidates, {len(feasible)} feasible, "
          f"{len(rejected)} rejected")

    # 3. pilots -> iso-loss normalization
    iso = None
    if args.no_pilots:
        with tracer.span("plan/score", cat="plan"):
            scored = score_plans(feasible, calib,
                                 iterations=float(args.pilot_steps))
        for s in scored:
            s.predicted_loss = args.target_loss
            s.notes["iso_loss"] = False
    else:
        pilot_mesh = make_local_mesh(1, min(args.pilot_tp, args.devices))
        with tracer.span("plan/pilots", cat="plan",
                         strategies=list(strategies)):
            iso = run_pilots(strategies, pilot_mesh, width=args.width,
                             depth=args.depth, batch=args.batch,
                             steps=args.pilot_steps,
                             target_loss=args.target_loss, ks=ks,
                             seed=args.seed, ledger=ledger)
        for key, nu in sorted(iso.nu.items()):
            fl = iso.final_loss.get(key)
            print(f"# pilot {key}: nu={nu} final_loss="
                  f"{fl:.4f}" if fl is not None else f"# pilot {key}")
        for kind, curve in iso.curves.items():
            print(f"# pilot curve {kind}: loss(k) = "
                  f"exp({curve.a:.3f}) * k^{curve.b:.3f}")
        scored = apply_iso_loss(feasible, iso, calib)

    # 4. throughput floor + frontier + verdict (the verdict quantifies
    # over the SURVIVORS — a plan the floor rejected must not win it).
    # The frontier (and hence the winner) is drawn from the MATCHED
    # pool: a censored plan that never reached the target has a cheap
    # ν·e product but is not delivering the target loss — it must not
    # undercut plans that measurably did.
    scored_kept, thr_rejected = apply_throughput_floor(
        scored, args.min_throughput)

    def make_frontier(pool):
        m = [s for s in pool if s.notes.get("reached_target", True)]
        return pareto_frontier(m if m else pool)

    frontier = make_frontier(scored_kept)

    # ground-truth the frontier's HBM fit against the lowered probe
    # step (cached analysis); an over-budget plan is dropped and the
    # frontier recomputed so newly-exposed plans get checked too
    mesh_cache = {}
    if args.compiled_hbm_check:
        checked = set()
        while True:
            over = []
            for s in frontier:
                if id(s) in checked:
                    continue
                checked.add(id(s))
                key = (s.plan.dp, s.plan.tp, s.plan.pp)
                if key not in mesh_cache:
                    mesh_cache[key] = make_local_mesh(*key)
                got = compiled_hbm_bytes(s.plan, mesh_cache[key])
                s.notes["compiled_hbm_bytes"] = got
                if got is not None and \
                        got > constraints.hbm_bytes_per_device:
                    over.append(s)
            if not over:
                break
            for s in over:
                thr_rejected.append(
                    (s, f"compiled HBM {s.notes['compiled_hbm_bytes']/2**30:.2f} "
                        f"GiB > {args.hbm_gb:.2f} GiB budget"))
                scored_kept.remove(s)
            frontier = make_frontier(scored_kept)

    # static sharding & energy audit of the frontier: lower each
    # candidate's probe (through the shared telemetry caches — nothing
    # the HBM check compiled is re-lowered) and reject any plan whose
    # collectives don't reconcile with its priced CommEvent account.
    # Same recheck-loop shape as above: dropping a plan exposes new
    # frontier members, which must be audited too.
    audit_results = {}
    if getattr(args, "audit", True):
        from repro.analysis import audit_plans
        while True:
            todo = [s for s in frontier
                    if s.plan.name not in audit_results]
            if todo:
                audit_results.update(audit_plans(
                    [s.plan for s in todo], mesh_cache=mesh_cache))
            bad = [s for s in frontier
                   if not audit_results[s.plan.name]["ok"]]
            if not bad:
                break
            for s in bad:
                errs = audit_results[s.plan.name]["errors"]
                thr_rejected.append(
                    (s, f"static audit: {len(errs)} error(s), first: "
                        f"{errs[0] if errs else 'unlowerable'}"))
                scored_kept.remove(s)
            frontier = make_frontier(scored_kept)
        n_bad = sum(1 for r in audit_results.values() if not r["ok"])
        print(f"# audit: {len(audit_results)} frontier plans checked, "
              f"{n_bad} rejected")

    comparison = matched_loss_comparison(scored_kept, args.devices)
    if iso is not None and not comparison.get("matched_plans"):
        reachable = min(iso.final_loss.values(), default=float("nan"))
        print(f"# WARNING: no pilot reached --target-loss "
              f"{args.target_loss} within {args.pilot_steps} steps "
              f"(best final loss {reachable:.4f}); the matched-loss "
              f"comparison is empty — raise the target or "
              f"--pilot-steps", file=sys.stderr)

    report = build_report(
        calibration=calib, constraints=constraints, scored=scored_kept,
        frontier=frontier, rejected=rejected,
        throughput_rejected=thr_rejected, iso=iso, comparison=comparison,
        meta={"argv": vars(args), "target_loss": args.target_loss,
              "devices": args.devices})
    if audit_results:
        report["audit"] = audit_results
    if ledger is not None:
        record_frontier(ledger, frontier, calib)
    write_plan_report(report, args.out)
    print("\n".join(plan_summary_lines(report)))
    print(f"# wrote {args.out} ({len(frontier)} frontier plans)")
    return report


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))
    from repro.launch.obs import obs_session
    with obs_session(args.trace_out, args.metrics_out,
                     meta={"run": "launch.plan"}):
        report = plan(args)
    return 0 if report["frontier"] else 1


if __name__ == "__main__":
    sys.exit(main())
