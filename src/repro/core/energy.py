"""The paper's energy and communication models (§II-A, Appendix).

  e(n,p,L)   = A * alpha + B * beta           (Eqn. 1)
  E_lambda   = nu_lambda * e                  (Eqn. 2)
  comm_time(m,p) = c1*log2(p) + c2*m + c3     (Eqn. 26, microseconds)

with the Frontier-fitted Table III constants, plus TPU v5e analogues
derived from the roofline constants used everywhere else in this repo
(197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).
"""
from __future__ import annotations

import math
from dataclasses import dataclass


# --- hardware constants ----------------------------------------------------

# Frontier (paper §II-A): dynamic/static power per GCD.
FRONTIER_A_W = 560.0
FRONTIER_B_W = 90.0

# Paper Table III: comm_time(m, p) = c1*log2 p + c2*m [+ c3~0], microseconds,
# m in floats (4 bytes).  ``collective_permute`` is the point-to-point
# stage-boundary transfer of pipeline parallelism — a SINGLE hop, so its
# c1 is charged once instead of log2(p) times (``comm_time_us`` special-
# cases it); the paper has no p2p fit, so it is priced with the broadcast
# constants (the closest latency/byte-slope shape Table III offers).
P2P_COLLECTIVES = ("collective_permute", "p2p")

PAPER_COLLECTIVE_FITS = {
    "broadcast":      (35.5, 1.12e-3),
    "all_reduce":     (33.4, 2.56e-3),
    "all_gather":     (149.94, 2.07e-3),
    "reduce_scatter": (145.52, 2.40e-3),
    "collective_permute": (35.5, 1.12e-3),
}

# TPU v5e (roofline constants, DESIGN.md §2)
TPU_PEAK_FLOPS = 197e12          # bf16 / chip
TPU_HBM_BW = 819e9               # bytes/s
TPU_ICI_BW = 50e9                # bytes/s/link
TPU_ICI_LINKS = 2                # usable links per ring axis on a 2D torus
# v5e chip power envelope (for the TPU-projected energy model)
TPU_A_W = 200.0                  # busy
TPU_B_W = 60.0                   # idle/stalled-on-network


def tpu_collective_fits(hop_latency_us: float = 1.0) -> dict:
    """TPU v5e analogues of the paper's Table III (c1, c2) constants,
    derived from the ICI ring roofline rather than fitted: c2 is the wire
    time per float (4 bytes over the per-axis ICI links; doubled for
    all-reduce's RS+AG phases), c1 the per-log2(p)-hop latency.  Pass the
    result as ``fits=`` to ``comm_time_us`` to price the Eqn. 26 model on
    the TPU analogue instead of Frontier."""
    c2 = 4.0 / (TPU_ICI_BW * TPU_ICI_LINKS) * 1e6    # us per float
    return {
        "broadcast":      (hop_latency_us, c2),
        "all_gather":     (hop_latency_us, c2),
        "reduce_scatter": (hop_latency_us, c2),
        "all_reduce":     (hop_latency_us, 2.0 * c2),
        "collective_permute": (hop_latency_us, c2),
    }


def comm_time_us(collective: str, m_floats: float, p: int,
                 fits=None) -> float:
    """Paper Eqn. 26 with Table III constants (returns microseconds).

    Point-to-point transfers (``collective_permute`` — pipeline stage
    boundaries) are a single neighbor hop: c1 + c2*m, with no log2(p)
    latency term (``p`` only gates the degenerate single-rank case).
    """
    table = fits or PAPER_COLLECTIVE_FITS
    if collective in P2P_COLLECTIVES:
        if p <= 1:
            return 0.0
        c1, c2 = table["collective_permute"]
        return c1 + c2 * m_floats
    c1, c2 = table[collective]
    if p <= 1:
        return 0.0
    return c1 * math.log2(p) + c2 * m_floats


# --- per-iteration cost models (paper Eqns. 3-4, 24-25) -------------------
#
# These are now DERIVED from the ProjectionStrategy objects: a strategy's
# flops()/comm_events() are the per-operator account of the very operators
# the shard_map computation executes, so the Table II schedule (AG n/p-wide
# for TP, AG k-wide for phantom) is summed rather than re-derived by hand.
# tests/test_strategies.py pins the sums to the historical closed forms.

TRAIN_PASS_FACTOR = 3.0   # fwd + bwd-input + bwd-weight GEMMs


def costs_from_strategies(strategies, p: int, L: int, batch: int,
                          peak_flops: float, fits=None,
                          training: bool = True):
    """(alpha_sec, beta_sec) per iteration for L layers, each executing
    the given projection strategies once per pass.

    alpha: per-rank flops summed over strategies (x3 for training: the
    backward re-runs each GEMM twice — input grads + weight grads).
    beta:  paper Eqn. 26 comm time summed over each strategy's fwd+bwd
    collective events.
    """
    pass_factor = TRAIN_PASS_FACTOR if training else 1.0
    flops_rank = sum(st.flops(batch) for st in strategies) * pass_factor * L
    alpha = flops_rank / peak_flops
    us = 0.0
    for st in strategies:
        for ev in st.comm_events(batch):
            if not training and ev.phase == "bwd":
                continue
            us += comm_time_us(ev.collective, ev.m_floats, p, fits)
    beta = us * L * 1e-6
    return alpha, beta


def tp_costs(n: int, p: int, L: int, batch: int, peak_flops: float,
             fits=None):
    """(alpha_sec, beta_sec) per iteration for TP training of an n-wide,
    L-layer FFN: sums the ``tensor_col`` strategy's per-operator account
    (historically 6*n^2*batch/p flops + AG/RS of (n/p)*batch floats per
    layer)."""
    from repro.parallel.strategies import TensorColStrategy
    st = TensorColStrategy(n, n, p, bias=True)
    return costs_from_strategies([st], p, L, batch, peak_flops, fits)


def phantom_costs(n: int, p: int, L: int, k: int, batch: int,
                  peak_flops: float, fits=None):
    """(alpha_sec, beta_sec) per iteration for phantom-parallel training:
    sums the ``phantom`` strategy's account (historically 6*((n/p)^2 +
    k*n)*batch flops per rank + AG/RS of k*batch ghost floats per layer).
    """
    from repro.configs.base import ProjectionSpec
    from repro.parallel.strategies import make_strategy
    st = make_strategy(ProjectionSpec(kind="phantom", k=k), n, n, p,
                       bias=True)
    return costs_from_strategies([st], p, L, batch, peak_flops, fits)


def pp_costs(n: int, p: int, L: int, k: int, batch: int, peak_flops: float,
             fits=None):
    """DEPRECATED alias of ``phantom_costs``.  Historically "pp" meant
    *phantom*-parallel; since the pipeline-parallel (pp) mesh axis landed
    the name collides, so the phantom cost model is ``phantom_costs`` and
    this shim warns."""
    import warnings
    warnings.warn("pp_costs is deprecated (pp now means PIPELINE "
                  "parallelism); use phantom_costs", DeprecationWarning,
                  stacklevel=2)
    return phantom_costs(n, p, L, k, batch, peak_flops, fits)


def pipeline_p2p_time_us(schedule, m_floats: float, fits=None, *,
                         executed: bool = False) -> float:
    """Per-device microseconds of stage-boundary p2p traffic for one
    iteration of a ``PipelineSchedule`` — each event priced as a single
    ``collective_permute`` hop of ``m_floats`` (the carried activation /
    activation-grad shard)."""
    return sum(comm_time_us(ev.collective, ev.m_floats, schedule.stages,
                            fits)
               for ev in schedule.p2p_events(m_floats, executed=executed))


def energy_per_iteration(alpha_s: float, beta_s: float, p: int,
                         A: float = FRONTIER_A_W,
                         B: float = FRONTIER_B_W) -> float:
    """Paper Eqn. 1, summed over the p ranks (Joules/iteration)."""
    return p * (A * alpha_s + B * beta_s)


def energy_to_loss(alpha_s: float, beta_s: float, p: int, iterations: int,
                   A: float = FRONTIER_A_W, B: float = FRONTIER_B_W) -> float:
    """Paper Eqn. 2: E = nu * e."""
    return iterations * energy_per_iteration(alpha_s, beta_s, p, A, B)


@dataclass(frozen=True)
class RooflineTerms:
    """The three §Roofline terms, in seconds (per device)."""
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        # overlap model: memory traffic hides behind compute within fused
        # ops; collectives assumed exposed unless explicitly overlapped.
        return max(self.compute_s, self.memory_s) + self.collective_s

    def fraction_of_roofline(self) -> float:
        """useful-compute / achievable-step-time (1.0 = compute-bound and
        fully overlapped)."""
        if self.step_s == 0:
            return 0.0
        return self.compute_s / self.step_s


def roofline_terms(flops_per_device: float, hbm_bytes_per_device: float,
                   ici_bytes_per_device: float,
                   peak_flops: float = TPU_PEAK_FLOPS,
                   hbm_bw: float = TPU_HBM_BW,
                   ici_bw: float = TPU_ICI_BW * TPU_ICI_LINKS) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops_per_device / peak_flops,
        memory_s=hbm_bytes_per_device / hbm_bw,
        collective_s=ici_bytes_per_device / ici_bw,
    )
