"""Spectral initialization of phantom factors from a dense teacher matrix.

Beyond-paper utility: given a dense W [n_in, n_out] (e.g. a pretrained TP
weight), produce the best rank-k phantom factors per off-diagonal block via
truncated SVD, with the shared-compressor constraint handled by stacking
the row-block targets (C^(i) must serve every destination j).

Used by ``examples/distill_phantom.py`` and the approximation-quality tests.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def svd_phantom_init(W, p: int, k: int):
    """Factor W [n_in, n_out] into phantom params {L, C, D}.

    For row-block i, the compressor C^(i) [n_in/p, k] must serve all p-1
    destinations: choose it as the top-k left singular vectors of the
    concatenated off-diagonal row block W^(i, !=i) [n_in/p, (p-1)n_out/p],
    then D^(i,j) = C^(i)^T W^(i,j) (least squares given C).
    """
    W = np.asarray(W, np.float64)
    n_in, n_out = W.shape
    bi, bo = n_in // p, n_out // p
    L = np.zeros((p, bi, bo))
    C = np.zeros((n_in, k))
    D = np.zeros((p, k, n_out))
    for i in range(p):
        rows = slice(i * bi, (i + 1) * bi)
        L[i] = W[rows, i * bo:(i + 1) * bo]
        off = np.concatenate(
            [W[rows, j * bo:(j + 1) * bo] for j in range(p) if j != i],
            axis=1) if p > 1 else np.zeros((bi, 0))
        if off.shape[1]:
            u, s, _ = np.linalg.svd(off, full_matrices=False)
            basis = u[:, :k]                      # [bi, k]
        else:
            basis = np.eye(bi)[:, :k]
        C[rows, :basis.shape[1]] = basis
        for j in range(p):
            if j == i:
                continue
            D[i, :, j * bo:(j + 1) * bo] = basis.T @ W[rows, j * bo:(j + 1) * bo]
    return {"L": jnp.asarray(L, jnp.float32),
            "C": jnp.asarray(C, jnp.float32),
            "D": jnp.asarray(D, jnp.float32)}


def block_lowrank_error(W, p: int, k: int) -> float:
    """Relative Frobenius error of the best phantom approximation of W."""
    from repro.core.phantom import phantom_dense_equivalent
    params = svd_phantom_init(W, p, k)
    W_hat = phantom_dense_equivalent(params)
    W = jnp.asarray(W, jnp.float32)
    return float(jnp.linalg.norm(W - W_hat) / jnp.linalg.norm(W))
