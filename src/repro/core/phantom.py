"""Phantom parallelism — the paper's core contribution, as a composable
JAX module.

A phantom linear replaces a tensor-parallel ``n_in x n_out`` projection.
The weight matrix is viewed in ``p x p`` blocks (p = model-axis size):

  * diagonal blocks stay exact:      L^(j)      [n_in/p, n_out/p]
  * off-diagonal blocks are rank-k:  W^(i,j) ~= C^(i) D^(i,j)
       compressor   C^(i)  [n_in/p, k]   (shared across destinations j!)
       decompressor D^(i,j) [k, n_out/p]

Per-rank forward (paper Eqn. 11):
  g^(j)  = x^(j) C^(j)                      (compress: k ghost neurons)
  g_all  = AllGather_k(g)                   (k-wide collective, not n/p-wide)
  z^(j)  = x^(j) L^(j) + sum_{i != j} g^(i) D^(i,j)  (+ bias)

Backward (paper Eqns. 15-21) falls out of AD; the ghost-gradient
reduce-scatter of paper Algorithm 1 is the VJP of the all-gather (see
``core/autograd.py``).

Three execution variants (DESIGN.md §2):
  * ``faithful`` — (p-1) separate skinny decompress GEMMs + the custom_vjp
    AllGather, mirroring the paper's PyTorch implementation op-for-op.
  * ``fused``    — single concatenated decompress GEMM ``g_cat @ D_cat``:
    the TPU/MXU adaptation (one [B, p*k] x [p*k, n_out/p] matmul).  Removes
    the paper's small-GEMM "flip-flop" regime at large p by construction.
  * ``ring``     — ppermute ring; each hop overlaps a partial decompress
    GEMM with the next ghost transfer (collective-matmul style).

All apply functions run *inside* ``shard_map`` over the model axis and see
local parameter shards (see param layout in ``phantom_decls``).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import PhantomConfig
from repro.core.autograd import all_gather_ghosts
from repro.kernels.ops import phantom_fused_linear, resolve_kernel_backend
from repro.parallel.params import ParamDecl


# ---------------------------------------------------------------------------
# declarations
# ---------------------------------------------------------------------------

def phantom_decls(n_in: int, n_out: int, k: int, tp: int,
                  dtype=jnp.float32, bias: bool = True,
                  fsdp: bool = False, dp: int = 1) -> Dict[str, ParamDecl]:
    """Parameter layout for one phantom projection on a tp-way model axis.

    Global shapes (local views in brackets):
      L [tp, n_in/tp, n_out/tp]  sharded on dim0   ([1, n_in/tp, n_out/tp])
      C [n_in, k]                sharded on dim0   ([n_in/tp, k])
      D [tp, k, n_out]           sharded on dim2   ([tp, k, n_out/tp])
      b [n_out]                  sharded           ([n_out/tp])

    Note the phantom model class is mesh-dependent (paper Table I: PP model
    size varies with p).
    """
    assert n_in % tp == 0 and n_out % tp == 0, (n_in, n_out, tp)
    # FSDP applies to L only: C is tiny and D is already small per-device
    # after TP sharding (k << n/p); sharding k-sized dims over dp would
    # break divisibility (DESIGN.md §6).  The dp-sharded dim is whichever
    # local dim the dp ways divide (e.g. qwen2-vl down-proj: ff/tp=1848
    # doesn't divide 16, d/tp=512 does).
    l_spec = P("tp", None, None)
    if fsdp:
        if (n_in // tp) % max(dp, 1) == 0:
            l_spec = P("tp", "dp", None)
        elif (n_out // tp) % max(dp, 1) == 0:
            l_spec = P("tp", None, "dp")
    d = {
        "L": ParamDecl((tp, n_in // tp, n_out // tp), l_spec,
                       scale=(n_in // tp) ** -0.5, dtype=dtype),
        "C": ParamDecl((n_in, k), P("tp", None),
                       scale=(n_in // tp) ** -0.5, dtype=dtype),
        "D": ParamDecl((tp, k, n_out), P(None, None, "tp"),
                       scale=(max(tp - 1, 1) * k) ** -0.5, dtype=dtype),
    }
    if bias:
        d["b"] = ParamDecl((n_out,), P("tp"), init="zeros", dtype=dtype)
    return d


def phantom_param_count(n_in: int, n_out: int, k: int, tp: int,
                        bias: bool = True) -> int:
    """Paper §VI-B model-size accounting: n_in*n_out/p + n_in*k + p*k*n_out."""
    n = (n_in // tp) * (n_out // tp) * tp + n_in * k + tp * k * n_out
    return n + (n_out if bias else 0)


# ---------------------------------------------------------------------------
# apply (inside shard_map over the 'model' axis)
# ---------------------------------------------------------------------------

def _unshard_fsdp(p, axes, decls):
    """All-gather FSDP-sharded dims (VJP = reduce-scatter of grads)."""
    def fix(a, d):
        for dim, entry in enumerate(d.spec):
            if entry == "dp":
                return lax.all_gather(a, axes.dp_names, axis=dim, tiled=True)
        return a
    return jax.tree.map(fix, p, decls,
                        is_leaf=lambda x: isinstance(x, ParamDecl))


def phantom_apply(pp: PhantomConfig, params, x, axes, compute_dtype=None):
    """x: [..., n_in/p] local feature shard -> [..., n_out/p].

    Activations stay feature-sharded end-to-end — the paper's "no
    concatenation between layers" property.
    """
    tp_name = axes.tp_name
    p = axes.tp
    L = params["L"][0]                      # [n_in/p, n_out/p] local
    C = params["C"]                         # [n_in/p, k]
    D = params["D"]                         # [p, k, n_out/p]
    if compute_dtype is not None:
        x = x.astype(compute_dtype)
        L, C, D = (a.astype(compute_dtype) for a in (L, C, D))

    j = lax.axis_index(tp_name)

    # --- compress: k ghost neurons (paper: g = C y) ---
    g = jnp.einsum("...i,ik->...k", x, C)

    # fused variant may run as one Pallas kernel (local + decompress in a
    # single pass, custom_vjp backward); collectives stay out here so the
    # ghost all-gather / reduce-scatter account is backend-invariant.
    use_kernel = (p > 1 and pp.variant == "fused"
                  and resolve_kernel_backend(pp.kernel_backend) == "pallas")

    # --- local update --- (on the kernel path it fuses with decompress)
    if not use_kernel:
        z = jnp.einsum("...i,io->...o", x, L)

    if pp.variant == "ring" and p > 1:
        # ppermute ring: hop s brings the ghosts of rank (j - s) mod p; the
        # decompress GEMM for hop s-1 overlaps the transfer of hop s.
        perm = [(s, (s + 1) % p) for s in range(p)]
        g_rot = g
        for s in range(1, p):
            g_rot = lax.ppermute(g_rot, tp_name, perm)
            src = (j - s) % p
            Dsrc = jnp.take(D, src, axis=0)          # [k, n_out/p]
            z = z + jnp.einsum("...k,ko->...o", g_rot, Dsrc)
        if pp.include_self_term:
            Dself = jnp.take(D, j, axis=0)
            z = z + jnp.einsum("...k,ko->...o", g, Dself)
    elif pp.variant == "faithful" and p > 1:
        # paper-faithful: custom autograd AllGather (Algorithm 1) and p-1
        # separate skinny decompress GEMMs D^(i,j) g^(i).
        g_all = all_gather_ghosts(g, tp_name)        # [p, ..., k]
        for i in range(p):
            mask = (i != j) | jnp.asarray(pp.include_self_term)
            contrib = jnp.einsum("...k,ko->...o", g_all[i], D[i])
            z = z + jnp.where(mask, 1, 0).astype(z.dtype) * contrib
    elif p > 1:
        # fused (TPU adaptation): one concatenated GEMM over all sources.
        g_all = lax.all_gather(g, tp_name)           # [p, ..., k]
        gcat = jnp.moveaxis(g_all, 0, -2)            # [..., p, k]
        gcat = gcat.reshape(*gcat.shape[:-2], p * D.shape[1])
        Dcat = D.reshape(p * D.shape[1], D.shape[2])  # [p*k, n_out/p]
        if use_kernel:
            z = phantom_fused_linear(x, L, gcat, Dcat)
        else:
            z = z + jnp.einsum("...k,ko->...o", gcat, Dcat)
        if not pp.include_self_term:
            Dself = jnp.take(D, j, axis=0)
            z = z - jnp.einsum("...k,ko->...o", g, Dself)
    else:  # p == 1: purely local (self term is the only term)
        if pp.include_self_term:
            z = z + jnp.einsum("...k,ko->...o", g, jnp.take(D, j, axis=0))

    if "b" in params:
        z = z + params["b"].astype(z.dtype)
    return z


# ---------------------------------------------------------------------------
# dense equivalence (for tests and for spectral init)
# ---------------------------------------------------------------------------

def phantom_dense_equivalent(params, include_self_term: bool = False):
    """Assemble the dense [n_in, n_out] matrix this phantom layer computes.

    Used by tests: phantom_apply(x) must equal x @ W_dense + b for the
    *global* x.  params here are GLOBAL (unsharded) arrays.
    """
    L, C, D = params["L"], params["C"], params["D"]
    p, nin_p, nout_p = L.shape
    k = C.shape[1]
    n_in, n_out = p * nin_p, p * nout_p
    W = jnp.zeros((n_in, n_out), L.dtype)
    Csh = C.reshape(p, nin_p, k)
    Dsh = D.reshape(p, k, p, nout_p)     # [src, k, dst, n_out/p]
    for i in range(p):
        for j in range(p):
            if i == j:
                blk = L[j]
                if include_self_term:
                    blk = blk + Csh[i] @ Dsh[i, :, j, :]
            else:
                blk = Csh[i] @ Dsh[i, :, j, :]
            W = W.at[i * nin_p:(i + 1) * nin_p,
                     j * nout_p:(j + 1) * nout_p].set(blk)
    return W
