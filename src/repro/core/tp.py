"""Conventional tensor parallelism — the paper's baseline (Megatron-style
column/row sharded projections), implemented with explicit collectives
inside ``shard_map`` so its communication volume is exactly controlled and
comparable against phantom parallelism.

Collectives per TP FFN layer (paper Table II):
  forward:  All-Gather of the n/p activation shard  (message ~ n)
  backward: Reduce-Scatter of the activation grads  (VJP of the gather)

which reproduces beta_tau = L * O(p log p + n) — against phantom's
k-wide ghosts, beta_pi = L * O(p log p + k p).
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.parallel.params import ParamDecl


def col_linear_decls(n_in: int, n_out: int, tp: int, dtype=jnp.float32,
                     bias: bool = True, fsdp: bool = False) -> Dict[str, ParamDecl]:
    """Column-parallel: W [n_in, n_out] sharded on n_out."""
    d = {"w": ParamDecl((n_in, n_out), P("dp" if fsdp else None, "tp"),
                        dtype=dtype)}
    if bias:
        d["b"] = ParamDecl((n_out,), P("tp"), init="zeros", dtype=dtype)
    return d


def row_linear_decls(n_in: int, n_out: int, tp: int, dtype=jnp.float32,
                     bias: bool = True, fsdp: bool = False) -> Dict[str, ParamDecl]:
    """Row-parallel: W [n_in, n_out] sharded on n_in."""
    d = {"w": ParamDecl((n_in, n_out), P("tp", "dp" if fsdp else None),
                        dtype=dtype)}
    if bias:
        d["b"] = ParamDecl((n_out,), P(), init="zeros", dtype=dtype)
    return d


def col_linear_apply(params, x_full, compute_dtype=None):
    """x_full: [..., n_in] (replicated features) -> [..., n_out/p] shard."""
    w = params["w"]
    if compute_dtype is not None:
        x_full, w = x_full.astype(compute_dtype), w.astype(compute_dtype)
    z = jnp.einsum("...i,io->...o", x_full, w)
    if "b" in params:
        z = z + params["b"].astype(z.dtype)
    return z


def row_linear_apply(params, x_shard, compute_dtype=None):
    """x_shard: [..., n_in/p] -> PARTIAL [..., n_out]; caller psum/scatters."""
    w = params["w"]
    if compute_dtype is not None:
        x_shard, w = x_shard.astype(compute_dtype), w.astype(compute_dtype)
    z = jnp.einsum("...i,io->...o", x_shard, w)
    return z  # bias added after the reduction by the caller


def gather_features(x_shard, axes):
    """[..., n/p] feature shard -> [..., n] full (fwd AG, bwd RS)."""
    return lax.all_gather(x_shard, axes.tp_name, axis=-1, tiled=True)


def scatter_features(z_partial, axes):
    """partial [..., n] -> reduced [..., n/p] (fwd RS, bwd AG)."""
    return lax.psum_scatter(z_partial, axes.tp_name,
                            scatter_dimension=z_partial.ndim - 1, tiled=True)


def gather_seq(x, axes, axis=1):
    """sequence-parallel gather: [B, S/p, d] -> [B, S, d]."""
    return lax.all_gather(x, axes.tp_name, axis=axis, tiled=True)


def scatter_seq(z, axes, axis=1):
    """partial [B, S, d] -> reduced [B, S/p, d]."""
    return lax.psum_scatter(z, axes.tp_name, scatter_dimension=axis,
                            tiled=True)
