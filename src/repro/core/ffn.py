"""The paper's experimental subject: width-n, depth-L fully-connected
networks trained with MSE on the Gaussian-teacher dataset (§VI), in both
parallelization styles:

  * TP  — conventional tensor parallelism (baseline, paper Fig. 1a)
  * PP  — phantom parallelism (paper Fig. 1b/3/4)

Both run as a single ``shard_map`` over the whole mesh with explicit
collectives, so measured/lowered communication is exactly the paper's
Table II schedule:

  TP per layer:  All-Gather(n/p * batch) fwd, Reduce-Scatter bwd
  PP per layer:  All-Gather(k * batch)   fwd, Reduce-Scatter bwd

This module is used by the paper-reproduction benchmarks (Fig. 5/6/7,
Table I), the examples, and the equivalence tests.

Pipeline parallelism (``cfg.pipeline.stages > 1``): the layer stack is
cut into contiguous stages, each running its OWN per-stage
``ProjectionStrategy`` (tensor or phantom — ``PipelineConfig.
stage_specs``), and the train step executes the 1F1B wavefront of
``train/pipeline.py`` over the ``pipe`` mesh axis, ppermuting the
feature-sharded ``[B_mb, n/tp]`` activation across stage boundaries.  On
a pp=1 mesh the same config runs the stages sequentially — the
equivalence reference.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, PHANTOM_KINDS
from repro.parallel.axes import MeshAxes, resolve_spec
from repro.parallel.params import (abstract, is_decl, materialize, specs,
                                   stack)
from repro.parallel.compat import shard_map
from repro.parallel.strategies import make_strategy, site_strategy
from repro.train.pipeline import (PipelineSchedule, pipeline_run,
                                  split_microbatches)


# ---------------------------------------------------------------------------
# declarations (via the ProjectionStrategy API, site "ffn_layer")
# ---------------------------------------------------------------------------

def ffn_strategy(cfg: ModelConfig, tp: int):
    """The one square n x n projection strategy each paper-FFN layer uses."""
    n = cfg.ffn_width
    return site_strategy(cfg, "ffn_layer", n, n, tp, bias=True)


def ffn_stage_strategies(cfg: ModelConfig, tp: int):
    """One strategy per pipeline stage (len == pipeline.stages; a single
    entry for non-pipelined configs).  Per-stage phantom specs fall back
    to the dense site default under the same divisibility guard as
    ``site_strategy``."""
    S = cfg.pipeline.stages
    if S == 1:
        return [ffn_strategy(cfg, tp)]
    n = cfg.ffn_width
    out = []
    for s in range(S):
        spec = cfg.stage_projection_spec(s)
        if spec.kind in PHANTOM_KINDS and n % tp:
            spec = dataclasses.replace(spec, kind="tensor_col")
        out.append(make_strategy(spec, n, n, tp, bias=True))
    return out


def _stack_stages(layer_decls, L_loc: int, S: int):
    """[S, L_loc, ...] stage-stacked decls, stage axis sharded over pp."""
    st = stack(stack(layer_decls, L_loc), S)
    return jax.tree.map(
        lambda d: dataclasses.replace(
            d, spec=P(*(("pp",) + tuple(d.spec)[1:]))),
        st, is_leaf=is_decl)


def ffn_decls(cfg: ModelConfig, axes: MeshAxes):
    L, S = cfg.num_layers, cfg.pipeline.stages
    if S == 1:
        layer = ffn_strategy(cfg, axes.tp).decls()
        return {"layers": stack(layer, L)}
    if L % S:
        raise ValueError(f"{L} layers do not divide into {S} stages")
    sts = ffn_stage_strategies(cfg, axes.tp)
    L_loc = L // S
    if not cfg.pipeline.mixed:
        # homogeneous stages: ONE [S, L_loc, ...] stack, stage axis
        # sharded over the pipe mesh axis — each pipe rank holds exactly
        # its own stage's layers
        return {"stages": _stack_stages(sts[0].decls(), L_loc, S)}
    # mixed per-stage strategies have different param structures, so each
    # stage keeps its own subtree, replicated over the pipe axis (only
    # rank s computes with / gets gradients for stage s; the pipe-psum in
    # the step restores the full gradient everywhere)
    return {f"stage{s}": stack(sts[s].decls(), L_loc)
            for s in range(S)}


def ffn_model_params(cfg: ModelConfig, p: int) -> int:
    """Model size (paper Table I): TP size is p-independent; phantom
    shrinks.  Pipelined configs sum their per-stage strategies."""
    S = cfg.pipeline.stages
    if S == 1:
        return cfg.num_layers * ffn_strategy(cfg, p).param_count()
    L_loc = cfg.num_layers // S
    return sum(L_loc * st.param_count()
               for st in ffn_stage_strategies(cfg, p))


# ---------------------------------------------------------------------------
# forward (inside shard_map; x is the local [B_loc, n/p] feature shard)
# ---------------------------------------------------------------------------

def _act(name: str):
    return {"relu": jax.nn.relu, "gelu": jax.nn.gelu}.get(name, jax.nn.relu)


def ffn_apply(cfg: ModelConfig, axes: MeshAxes, params, x):
    if cfg.pipeline.stages > 1:
        raise ValueError("pipelined FFN configs run through "
                         "make_ffn_train_step / make_ffn_pipeline_probe; "
                         "ffn_apply is the single-stage path")
    act = _act(cfg.mlp)
    st = ffn_strategy(cfg, axes.tp)

    def body(carry, layer):
        z = st.apply_shard(layer, carry, axes)
        return act(z), None

    # scan_layers=False unrolls the layer loop (telemetry/dry-run cost
    # accounting: XLA's cost analysis counts a scan body once)
    unroll = 1 if cfg.scan_layers else max(cfg.num_layers, 1)
    x, _ = lax.scan(body, x, params["layers"], unroll=unroll)
    return x


def _apply_stage_stack(cfg, axes, st, stack_params, x):
    """Apply one stage's [L_loc, ...] layer stack to a feature shard."""
    act = _act(cfg.mlp)

    def body(carry, layer):
        return act(st.apply_shard(layer, carry, axes)), None

    L_loc = cfg.num_layers // cfg.pipeline.stages
    unroll = 1 if cfg.scan_layers else max(L_loc, 1)
    x, _ = lax.scan(body, x, stack_params, unroll=unroll)
    return x


def make_ffn_stage_fn(cfg: ModelConfig, axes: MeshAxes, params):
    """The per-rank ``stage_fn`` for ``pipeline_run`` (call INSIDE
    shard_map).  On a pp>1 mesh each rank applies its own stage — the
    local slice of the pipe-sharded stack, or a ``lax.switch`` over the
    per-stage subtrees when stages mix strategies.  On pp=1 all stages
    run sequentially (the equivalence reference)."""
    S = cfg.pipeline.stages
    sts = ffn_stage_strategies(cfg, axes.tp)
    mixed = cfg.pipeline.mixed

    if axes.pp == 1:
        def stage_fn(x):
            for s in range(S):
                sp = (params[f"stage{s}"] if mixed
                      else jax.tree.map(lambda a: a[s], params["stages"]))
                x = _apply_stage_stack(cfg, axes, sts[s], sp, x)
            return x, jnp.float32(0)
        return stage_fn

    if axes.pp != S:
        raise ValueError(f"mesh pipe axis {axes.pp} != pipeline stages {S}")
    if not mixed:
        local = jax.tree.map(lambda a: a[0], params["stages"])

        def stage_fn(x):
            return (_apply_stage_stack(cfg, axes, sts[0], local, x),
                    jnp.float32(0))
        return stage_fn

    s_idx = lax.axis_index(axes.pp_name)
    branches = [
        (lambda x, s=s: _apply_stage_stack(cfg, axes, sts[s],
                                           params[f"stage{s}"], x))
        for s in range(S)]

    def stage_fn(x):
        return lax.switch(s_idx, branches, x), jnp.float32(0)
    return stage_fn


# ---------------------------------------------------------------------------
# train step (whole step inside one shard_map)
# ---------------------------------------------------------------------------

def make_ffn_train_step(cfg: ModelConfig, mesh, optimizer,
                        global_batch: int):
    """Returns (step_fn, decls, opt_decls).

    step_fn(params, opt_state, step, x, y) -> (params, opt_state, loss)
    jit-compiled; params/opt sharded per decls; x,y sharded (dp, tp).

    Pipelined configs (``cfg.pipeline.stages > 1``) route to the 1F1B
    wavefront step; a pp>1 mesh with a single-stage config is an error.
    """
    axes = MeshAxes.from_mesh(mesh)
    if cfg.pipeline.stages > 1 or axes.pp > 1:
        return _make_ffn_pipeline_train_step(cfg, mesh, optimizer,
                                             global_batch)
    decls = ffn_decls(cfg, axes)
    opt_decls = optimizer.state_decls(decls)
    n = cfg.ffn_width

    def step_fn(params, opt_state, step, x, y):
        def loss_fn(p):
            out = ffn_apply(cfg, axes, p, x)
            # local share only — outputs are fully sharded (batch over dp,
            # features over tp) so the local sse IS this device's unique
            # contribution; cross-device sums happen via grad psums.
            return jnp.sum(jnp.square(out - y)) / (global_batch * n)

        sse_local, grads = jax.value_and_grad(loss_fn)(params)
        loss = lax.psum(sse_local, axes.all_names)
        grads = jax.tree.map(lambda g: lax.psum(g, axes.dp_names), grads)
        params, opt_state = optimizer.update(grads, opt_state, params, step)
        return params, opt_state, loss

    pspecs = jax.tree.map(lambda s: resolve_spec(s, axes), specs(decls))
    ospecs = jax.tree.map(lambda s: resolve_spec(s, axes), specs(opt_decls))
    bspec = resolve_spec(P("dp", "tp"), axes)

    sharded = shard_map(
        step_fn, mesh=mesh,
        in_specs=(pspecs, ospecs, P(), bspec, bspec),
        out_specs=(pspecs, ospecs, P()),
        check_vma=False,
    )
    return jax.jit(sharded, donate_argnums=(0, 1)), decls, opt_decls


def _make_ffn_pipeline_train_step(cfg: ModelConfig, mesh, optimizer,
                                  global_batch: int):
    """1F1B pipelined train step (same signature/contract as
    ``make_ffn_train_step``).

    Microbatching here is the PIPELINE's microbatching: the existing
    ``cfg.microbatches`` splitter feeds the wavefront (M microbatches
    over ``pp`` stages) instead of a sequential accumulation scan.  The
    loss masks to the last pipe rank — every other rank's parameters
    reach the objective only through the ppermute chain, whose transpose
    is the backward pipeline.
    """
    axes = MeshAxes.from_mesh(mesh)
    S = cfg.pipeline.stages
    if axes.pp > 1 and S != axes.pp:
        raise ValueError(f"mesh pipe axis {axes.pp} != pipeline "
                         f"stages {S}")
    decls = ffn_decls(cfg, axes)
    opt_decls = optimizer.state_decls(decls)
    n = cfg.ffn_width
    M = max(cfg.microbatches, 1)
    mixed = cfg.pipeline.mixed

    def step_fn(params, opt_state, step, x, y):
        x_mb = split_microbatches(x, M)
        y_mb = split_microbatches(y, M)

        def loss_fn(p):
            stage_fn = make_ffn_stage_fn(cfg, axes, p)
            y_hat, _aux = pipeline_run(stage_fn, x_mb, axes,
                                       unroll=not cfg.scan_layers)
            sse = jnp.sum(jnp.square(y_hat - y_mb))
            if axes.pp > 1:
                is_last = lax.axis_index(axes.pp_name) == axes.pp - 1
                sse = jnp.where(is_last, sse, jnp.float32(0))
            return sse / (global_batch * n)

        sse_local, grads = jax.value_and_grad(loss_fn)(params)
        loss = lax.psum(sse_local, axes.all_names)
        # homogeneous stage stacks are pipe-SHARDED (each rank owns its
        # stage's grads); mixed per-stage subtrees are pipe-replicated
        # and need the pipe psum to restore the full gradient everywhere
        red = axes.dp_names + (axes.pp_names if mixed else ())
        if red:
            grads = jax.tree.map(lambda g: lax.psum(g, red), grads)
        params, opt_state = optimizer.update(grads, opt_state, params, step)
        return params, opt_state, loss

    pspecs = jax.tree.map(lambda s: resolve_spec(s, axes), specs(decls))
    ospecs = jax.tree.map(lambda s: resolve_spec(s, axes), specs(opt_decls))
    bspec = resolve_spec(P("dp", "tp"), axes)

    sharded = shard_map(
        step_fn, mesh=mesh,
        in_specs=(pspecs, ospecs, P(), bspec, bspec),
        out_specs=(pspecs, ospecs, P()),
        check_vma=False,
    )
    return jax.jit(sharded, donate_argnums=(0, 1)), decls, opt_decls


def make_ffn_forward(cfg: ModelConfig, mesh):
    """jit'd forward pass for inference benchmarks."""
    axes = MeshAxes.from_mesh(mesh)
    decls = ffn_decls(cfg, axes)
    pspecs = jax.tree.map(lambda s: resolve_spec(s, axes), specs(decls))
    bspec = resolve_spec(P("dp", "tp"), axes)
    fwd = shard_map(
        partial(ffn_apply, cfg, axes), mesh=mesh,
        in_specs=(pspecs, bspec), out_specs=bspec, check_vma=False)
    return jax.jit(fwd), decls


def init_ffn(cfg: ModelConfig, mesh, optimizer, seed: int = 0):
    """Materialized params + optimizer state (for real training runs)."""
    axes = MeshAxes.from_mesh(mesh)
    decls = ffn_decls(cfg, axes)
    params = materialize(decls, seed)
    opt_state = optimizer.init(params)
    return params, opt_state


def abstract_ffn(cfg: ModelConfig, mesh, optimizer):
    """ShapeDtypeStruct stand-ins for the dry-run path."""
    axes = MeshAxes.from_mesh(mesh)
    decls = ffn_decls(cfg, axes)
    return abstract(decls), abstract(optimizer.state_decls(decls))
