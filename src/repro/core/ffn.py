"""The paper's experimental subject: width-n, depth-L fully-connected
networks trained with MSE on the Gaussian-teacher dataset (§VI), in both
parallelization styles:

  * TP  — conventional tensor parallelism (baseline, paper Fig. 1a)
  * PP  — phantom parallelism (paper Fig. 1b/3/4)

Both run as a single ``shard_map`` over the whole mesh with explicit
collectives, so measured/lowered communication is exactly the paper's
Table II schedule:

  TP per layer:  All-Gather(n/p * batch) fwd, Reduce-Scatter bwd
  PP per layer:  All-Gather(k * batch)   fwd, Reduce-Scatter bwd

This module is used by the paper-reproduction benchmarks (Fig. 5/6/7,
Table I), the examples, and the equivalence tests.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.parallel.axes import MeshAxes, resolve_spec
from repro.parallel.params import abstract, materialize, specs, stack
from repro.parallel.compat import shard_map
from repro.parallel.strategies import site_strategy


# ---------------------------------------------------------------------------
# declarations (via the ProjectionStrategy API, site "ffn_layer")
# ---------------------------------------------------------------------------

def ffn_strategy(cfg: ModelConfig, tp: int):
    """The one square n x n projection strategy each paper-FFN layer uses."""
    n = cfg.ffn_width
    return site_strategy(cfg, "ffn_layer", n, n, tp, bias=True)


def ffn_decls(cfg: ModelConfig, axes: MeshAxes):
    L = cfg.num_layers
    layer = ffn_strategy(cfg, axes.tp).decls()
    return {"layers": stack(layer, L)}


def ffn_model_params(cfg: ModelConfig, p: int) -> int:
    """Model size (paper Table I): TP size is p-independent; PP shrinks."""
    return cfg.num_layers * ffn_strategy(cfg, p).param_count()


# ---------------------------------------------------------------------------
# forward (inside shard_map; x is the local [B_loc, n/p] feature shard)
# ---------------------------------------------------------------------------

def _act(name: str):
    return {"relu": jax.nn.relu, "gelu": jax.nn.gelu}.get(name, jax.nn.relu)


def ffn_apply(cfg: ModelConfig, axes: MeshAxes, params, x):
    act = _act(cfg.mlp)
    st = ffn_strategy(cfg, axes.tp)

    def body(carry, layer):
        z = st.apply_shard(layer, carry, axes)
        return act(z), None

    # scan_layers=False unrolls the layer loop (telemetry/dry-run cost
    # accounting: XLA's cost analysis counts a scan body once)
    unroll = 1 if cfg.scan_layers else max(cfg.num_layers, 1)
    x, _ = lax.scan(body, x, params["layers"], unroll=unroll)
    return x


# ---------------------------------------------------------------------------
# train step (whole step inside one shard_map)
# ---------------------------------------------------------------------------

def make_ffn_train_step(cfg: ModelConfig, mesh, optimizer,
                        global_batch: int):
    """Returns (step_fn, decls, opt_decls).

    step_fn(params, opt_state, step, x, y) -> (params, opt_state, loss)
    jit-compiled; params/opt sharded per decls; x,y sharded (dp, tp).
    """
    axes = MeshAxes.from_mesh(mesh)
    decls = ffn_decls(cfg, axes)
    opt_decls = optimizer.state_decls(decls)
    n = cfg.ffn_width

    def step_fn(params, opt_state, step, x, y):
        def loss_fn(p):
            out = ffn_apply(cfg, axes, p, x)
            # local share only — outputs are fully sharded (batch over dp,
            # features over tp) so the local sse IS this device's unique
            # contribution; cross-device sums happen via grad psums.
            return jnp.sum(jnp.square(out - y)) / (global_batch * n)

        sse_local, grads = jax.value_and_grad(loss_fn)(params)
        loss = lax.psum(sse_local, axes.all_names)
        grads = jax.tree.map(lambda g: lax.psum(g, axes.dp_names), grads)
        params, opt_state = optimizer.update(grads, opt_state, params, step)
        return params, opt_state, loss

    pspecs = jax.tree.map(lambda s: resolve_spec(s, axes), specs(decls))
    ospecs = jax.tree.map(lambda s: resolve_spec(s, axes), specs(opt_decls))
    bspec = resolve_spec(P("dp", "tp"), axes)

    sharded = shard_map(
        step_fn, mesh=mesh,
        in_specs=(pspecs, ospecs, P(), bspec, bspec),
        out_specs=(pspecs, ospecs, P()),
        check_vma=False,
    )
    return jax.jit(sharded, donate_argnums=(0, 1)), decls, opt_decls


def make_ffn_forward(cfg: ModelConfig, mesh):
    """jit'd forward pass for inference benchmarks."""
    axes = MeshAxes.from_mesh(mesh)
    decls = ffn_decls(cfg, axes)
    pspecs = jax.tree.map(lambda s: resolve_spec(s, axes), specs(decls))
    bspec = resolve_spec(P("dp", "tp"), axes)
    fwd = shard_map(
        partial(ffn_apply, cfg, axes), mesh=mesh,
        in_specs=(pspecs, bspec), out_specs=bspec, check_vma=False)
    return jax.jit(fwd), decls


def init_ffn(cfg: ModelConfig, mesh, optimizer, seed: int = 0):
    """Materialized params + optimizer state (for real training runs)."""
    axes = MeshAxes.from_mesh(mesh)
    decls = ffn_decls(cfg, axes)
    params = materialize(decls, seed)
    opt_state = optimizer.init(params)
    return params, opt_state


def abstract_ffn(cfg: ModelConfig, mesh, optimizer):
    """ShapeDtypeStruct stand-ins for the dry-run path."""
    axes = MeshAxes.from_mesh(mesh)
    decls = ffn_decls(cfg, axes)
    return abstract(decls), abstract(optimizer.state_decls(decls))
