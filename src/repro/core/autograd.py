"""Custom-VJP collectives — the paper's Algorithm 1 ("Custom AllGather
Autograd Function") transcribed to JAX.

The paper extends ``torch.autograd.Function`` so that the forward pass
all-gathers the k-wide phantom (ghost) activations and the backward pass
reduce-scatters the ghost gradients back to their originating ranks.  In
JAX the VJP of ``lax.all_gather`` *is* ``lax.psum_scatter``, so the native
path gets this for free; we nevertheless provide the explicit custom_vjp
version (a) to mirror the paper's implementation, and (b) as the hook where
gradient compression can be spliced into the collective (see
``optim/compress.py``).

``tests/test_phantom.py::test_custom_allgather_matches_native`` checks the
two paths produce identical gradients.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def all_gather_ghosts(g, axis_name: str):
    """Paper Algorithm 1, FORWARD: gather k-wide ghost activations.

    g: local ghost activations ``[..., k]`` -> ``[p, ..., k]`` stacked by
    source rank.
    """
    return lax.all_gather(g, axis_name)


def _ag_fwd(g, axis_name):
    return lax.all_gather(g, axis_name), None


def _ag_bwd(axis_name, _res, grad_out):
    # Paper Algorithm 1, BACKWARD: Reduce-Scatter of the ghost gradients
    # (sum the (p-1) remote contributions for each source rank and deliver
    # them to it).
    grad_in = lax.psum_scatter(grad_out, axis_name, scatter_dimension=0,
                               tiled=False)
    return (grad_in,)


all_gather_ghosts.defvjp(_ag_fwd, _ag_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def psum_scatter_tiled(x, axis_name: str, scatter_dim: int):
    """Reduce-scatter with all-gather backward (transpose pair of above)."""
    return lax.psum_scatter(x, axis_name, scatter_dimension=scatter_dim,
                            tiled=True)


def _rs_fwd(x, axis_name, scatter_dim):
    return lax.psum_scatter(x, axis_name, scatter_dimension=scatter_dim,
                            tiled=True), None


def _rs_bwd(axis_name, scatter_dim, _res, grad_out):
    return (lax.all_gather(grad_out, axis_name, axis=scatter_dim,
                           tiled=True),)


psum_scatter_tiled.defvjp(_rs_fwd, _rs_bwd)
