from repro.core.phantom import (  # noqa: F401
    phantom_apply, phantom_decls, phantom_dense_equivalent,
    phantom_param_count,
)
from repro.core.autograd import all_gather_ghosts, psum_scatter_tiled  # noqa: F401
