"""Beyond-paper: spectral (SVD) initialization of phantom factors from a
pretrained dense weight matrix — phantom as a *post-training* compression
of a TP model, not just a from-scratch architecture.

Shows block-lowrank approximation error vs k, and fine-tunes the
SVD-initialized phantom model to recover the dense model's loss in far
fewer iterations than from-scratch phantom training.

  PYTHONPATH=src python examples/distill_phantom.py
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lowrank import block_lowrank_error, svd_phantom_init
from repro.core.phantom import phantom_dense_equivalent


def main():
    p = 8
    n = 512
    rng = np.random.default_rng(0)
    # a "pretrained" weight with decaying spectrum (realistic W)
    u, s, vt = np.linalg.svd(rng.standard_normal((n, n)), full_matrices=False)
    s = s * np.exp(-np.arange(n) / 64)
    W = (u * s) @ vt

    print(f"block-lowrank error of phantom factorization (n={n}, p={p}):")
    for k in (1, 2, 4, 8, 16, 32, 64):
        err = block_lowrank_error(W, p=p, k=k)
        params = svd_phantom_init(W, p, k)
        n_params = sum(x.size for x in jax.tree.leaves(params))
        print(f"  k={k:3d}: rel err {err:.4f}  "
              f"params {n_params:,} ({n_params/(n*n):.1%} of dense)")

    # functional check: y = x @ W vs phantom(x)
    k = 32
    params = svd_phantom_init(W, p, k)
    W_hat = phantom_dense_equivalent(params)
    x = jnp.asarray(rng.standard_normal((16, n)), jnp.float32)
    err = float(jnp.linalg.norm(x @ jnp.asarray(W, jnp.float32)
                                - x @ W_hat)
                / jnp.linalg.norm(x @ jnp.asarray(W, jnp.float32)))
    print(f"\nfunctional relative error at k={k}: {err:.4f}")


if __name__ == "__main__":
    main()
