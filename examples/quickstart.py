"""Quickstart: phantom parallelism vs tensor parallelism in one minute.

Trains the paper's FFN (§VI) both ways on the Gaussian-teacher dataset on
an 8-virtual-device CPU mesh and prints per-step time, model sizes, and
the communication volumes each pipeline lowers to.

  PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import time

import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, PhantomConfig,
                                dense_projection_map,
                                phantom_projection_map)
from repro.core.ffn import (abstract_ffn, ffn_model_params, init_ffn,
                            make_ffn_train_step)
from repro.data.synthetic import TeacherDataset
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import make_local_mesh
from repro.optim import AdamW


def main():
    mesh = make_local_mesh(1, 8)
    n, L, k, batch = 1024, 2, 8, 64
    ds = TeacherDataset(n, batch)
    print(f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}, "
          f"FFN n={n} L={L}, phantom k={k}\n")

    for impl in ("dense", "phantom"):
        projections = (phantom_projection_map(k, ffn_layer=True)
                       if impl == "phantom" else dense_projection_map())
        cfg = ModelConfig(name=impl, family="ffn", num_layers=L,
                          d_model=n, ffn_width=n, ffn_depth=L,
                          projections=projections, mlp="relu",
                          phantom=PhantomConfig(k=k))
        opt = AdamW(3e-3, weight_decay=0.0)
        step, decls, opt_decls = make_ffn_train_step(cfg, mesh, opt, batch)
        params, opt_state = init_ffn(cfg, mesh, opt)

        # what collectives does this pipeline actually lower to?
        a_p, a_o = abstract_ffn(cfg, mesh, opt)
        x_sds = jax.ShapeDtypeStruct((batch, n), jnp.float32)
        hlo = step.lower(a_p, a_o, jax.ShapeDtypeStruct((), jnp.int32),
                         x_sds, x_sds).compile().as_text()
        wire, _ = collective_bytes(hlo, default_group=8)

        losses = []
        t0 = time.time()
        for s in range(50):
            x, y = ds(s)
            params, opt_state, loss = step(params, opt_state,
                                           jnp.int32(s), x, y)
            losses.append(float(loss))
        dt = (time.time() - t0) / 50
        name = "tensor parallel (baseline)" if impl == "dense" \
            else "phantom parallel (paper) "
        print(f"{name}: params={ffn_model_params(cfg, 8):>9,}  "
              f"loss {losses[0]:.3f}->{losses[-1]:.3f}  "
              f"{dt*1e3:6.1f} ms/step  "
              f"collective wire bytes/step={int(wire):,}")


if __name__ == "__main__":
    main()
