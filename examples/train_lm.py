"""End-to-end driver: train a ~100M-param decoder LM (phantom MLPs) for a
few hundred steps with the production Trainer — data pipeline, grad clip,
cosine schedule, async checkpointing, straggler detection, restart.

  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--dense]
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import time

from repro.configs.base import (ModelConfig, PhantomConfig,
                                ShapeConfig, dense_projection_map,
                                phantom_projection_map)
from repro.data.synthetic import LMDataset
from repro.launch.mesh import make_local_mesh
from repro.launch.specs import input_specs
from repro.optim import AdamW
from repro.optim.schedules import warmup_cosine
from repro.parallel.axes import MeshAxes
from repro.train.fault import StragglerDetector
from repro.train.trainer import Trainer


def lm_100m(dense: bool = False) -> ModelConfig:
    return ModelConfig(
        name="lm-100m", family="dense", num_layers=8, d_model=512,
        num_heads=8, num_kv_heads=4, d_ff=2048, vocab_size=32_000,
        attn_shard="head", rope="full",
        phantom=PhantomConfig(k=8),
        projections=(dense_projection_map() if dense
                     else phantom_projection_map(8, ffn=True)),
        loss_chunk=256,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--dense", action="store_true",
                    help="TP baseline instead of phantom")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = lm_100m(args.dense)
    mesh = make_local_mesh(2, 4)
    axes = MeshAxes.from_mesh(mesh)
    from repro.models.model import count_params
    print(f"model: {cfg.name} ({count_params(cfg, tp=axes.tp)/1e6:.0f}M "
          f"params, phantom={'off' if args.dense else 'on'})")

    _, bspec = input_specs(cfg, ShapeConfig("ex", args.seq, args.batch,
                                            "train"), axes)
    opt = AdamW(warmup_cosine(3e-4, 20, args.steps), weight_decay=0.1)
    ds = LMDataset(cfg.vocab_size, args.batch, args.seq + 1)

    trainer = Trainer(cfg, mesh, opt, ds, batch_spec=bspec,
                      checkpoint_dir=args.ckpt_dir, checkpoint_every=50,
                      log_every=10)
    straggler = StragglerDetector()
    state = trainer.restore_or_init()

    t_last = [time.time()]
    orig_log = trainer.log_fn

    def log(msg):
        orig_log(msg)
        dt = time.time() - t_last[0]
        t_last[0] = time.time()
        straggler.record(state.step, dt)

    trainer.log_fn = log
    state = trainer.run(state, args.steps)
    print(f"done at step {state.step}; straggler flags: "
          f"{len(straggler.flagged)}")


if __name__ == "__main__":
    main()
