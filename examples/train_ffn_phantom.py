"""Paper reproduction driver (§VI-B): train TP and PP FFNs to the SAME
fixed loss, record iterations/model sizes, and evaluate the energy model
E = nu * p * (A*alpha + B*beta) at the paper's scale.

  PYTHONPATH=src python examples/train_ffn_phantom.py [--n 1024] [--k 8]
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse

import jax.numpy as jnp

from repro.configs.base import (ModelConfig, PhantomConfig,
                                dense_projection_map,
                                phantom_projection_map)
from repro.core.energy import (FRONTIER_A_W, FRONTIER_B_W, TPU_PEAK_FLOPS,
                               energy_to_loss, phantom_costs, tp_costs)
from repro.core.ffn import ffn_model_params, init_ffn, make_ffn_train_step
from repro.data.synthetic import TeacherDataset
from repro.launch.mesh import make_local_mesh
from repro.optim import AdamW


def train_to(cfg, mesh, ds, batch, target, max_iters):
    opt = AdamW(3e-3, weight_decay=0.0)
    step, decls, _ = make_ffn_train_step(cfg, mesh, opt, batch)
    params, opt_state = init_ffn(cfg, mesh, opt)
    for s in range(max_iters):
        x, y = ds(s)
        params, opt_state, loss = step(params, opt_state, jnp.int32(s),
                                       x, y)
        if float(loss) <= target:
            return s + 1, float(loss)
    return max_iters, float(loss)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--L", type=int, default=2)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--target", type=float, default=0.175)
    ap.add_argument("--max-iters", type=int, default=500)
    args = ap.parse_args()

    mesh = make_local_mesh(1, 8)
    p = 8
    ds = TeacherDataset(args.n, args.batch)

    base = dict(family="ffn", num_layers=args.L, d_model=args.n,
                ffn_width=args.n, ffn_depth=args.L, mlp="relu")
    tp_cfg = ModelConfig(name="tp", projections=dense_projection_map(),
                         phantom=PhantomConfig(k=args.k), **base)
    pp_cfg = ModelConfig(name="pp",
                         projections=phantom_projection_map(
                             args.k, ffn_layer=True),
                         phantom=PhantomConfig(k=args.k), **base)

    nu_tp, l_tp = train_to(tp_cfg, mesh, ds, args.batch, args.target,
                           args.max_iters)
    nu_pp, l_pp = train_to(pp_cfg, mesh, ds, args.batch, args.target,
                           args.max_iters)

    print(f"\n== fixed-loss comparison (target {args.target}) ==")
    print(f"TP: {ffn_model_params(tp_cfg, p):>9,} params, "
          f"{nu_tp} iters (final {l_tp:.4f})")
    print(f"PP: {ffn_model_params(pp_cfg, p):>9,} params, "
          f"{nu_pp} iters (final {l_pp:.4f})")

    a_t, b_t = tp_costs(args.n, p, args.L, args.batch, TPU_PEAK_FLOPS)
    a_p, b_p = phantom_costs(args.n, p, args.L, args.k, args.batch,
                        TPU_PEAK_FLOPS)
    E_tp = energy_to_loss(a_t, b_t, p, nu_tp, FRONTIER_A_W, FRONTIER_B_W)
    E_pp = energy_to_loss(a_p, b_p, p, nu_pp, FRONTIER_A_W, FRONTIER_B_W)
    print(f"\n== energy model (paper Eqn. 1/2, A={FRONTIER_A_W}W "
          f"B={FRONTIER_B_W}W) ==")
    print(f"E_TP = {E_tp:.2f} J   E_PP = {E_pp:.2f} J   "
          f"saving = {(1 - E_pp / E_tp) * 100:.0f}%")


if __name__ == "__main__":
    main()
