"""Serving demo: batched requests through the continuous-batching engine
(prefill + decode with a sequence-sharded KV cache and flash-decoding
LSE merges across the mesh).

  PYTHONPATH=src python examples/serve_lm.py [--requests 12]
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import time

import numpy as np

from repro.configs.base import get_config
from repro.launch.mesh import make_local_mesh
from repro.parallel.axes import MeshAxes
from repro.parallel.params import materialize
from repro.models.model import model_decls
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    mesh = make_local_mesh(2, 4)
    axes = MeshAxes.from_mesh(mesh)
    params = materialize(model_decls(cfg, axes), 0)

    eng = ServeEngine(cfg, mesh, params, slots=args.slots, max_len=128)
    rng = np.random.RandomState(0)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab_size, 16,
                                       dtype=np.int64).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]

    t0 = time.time()
    eng.run(reqs, max_steps=2000)
    dt = time.time() - t0
    total_tokens = sum(len(r.out_tokens) for r in reqs)
    print(f"{args.requests} requests x {args.new_tokens} tokens on "
          f"{args.slots} slots: {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s, continuous batching)")
    for i, r in enumerate(reqs[:3]):
        print(f"req{i}: {r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
