"""Regenerate the data tables inside EXPERIMENTS.md from
experiments/dryrun/*.json and experiments/perf/*.json.

Everything between the AUTOGEN markers is rewritten; prose outside them is
preserved.
"""
import glob
import json
import os
import re
import sys

sys.path.insert(0, os.path.dirname(__file__))
from make_report import dryrun_table, load, roofline_table  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")


def perf_rows():
    out = []
    for path in sorted(glob.glob(os.path.join(os.path.dirname(__file__),
                                              "perf", "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        rec["_tag"] = os.path.basename(path)[:-5]
        out.append(rec)
    return out


def perf_table(recs, prefix):
    lines = ["| step | compute_s | memory_s | collective_s | step_s | "
             "frac | Δstep vs prev |", "|---|---|---|---|---|---|---|"]
    prev = None
    for rec in recs:
        if not rec["_tag"].startswith(prefix):
            continue
        r = rec["roofline"]
        delta = ""
        if prev:
            delta = f"{(r['step_s']/prev - 1)*100:+.1f}%"
        prev = r["step_s"]
        lines.append(f"| {rec['_tag']} | {r['compute_s']:.4g} | "
                     f"{r['memory_s']:.4g} | {r['collective_s']:.4g} | "
                     f"{r['step_s']:.4g} | {r['fraction']:.3f} | {delta} |")
    return "\n".join(lines)


def replace_block(text, marker, content):
    pat = re.compile(rf"(<!-- AUTOGEN:{marker} -->).*?"
                     rf"(<!-- /AUTOGEN:{marker} -->)", re.S)
    return pat.sub(lambda m: m.group(1) + "\n" + content + "\n"
                   + m.group(2), text)


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    recs = load()
    text = replace_block(text, "dryrun_sp", dryrun_table(recs, "sp"))
    text = replace_block(text, "dryrun_mp", dryrun_table(recs, "mp"))
    text = replace_block(text, "roofline", roofline_table(recs))
    with open(path, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
