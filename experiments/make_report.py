"""Build the §Dry-run, §Roofline, §Energy-ledger, §Planner and §Elastic
markdown tables in EXPERIMENTS.md from experiments/dryrun/*.json and the
repo-root BENCH_report.json / PLAN_report.json (written by
``python -m benchmarks.run`` and ``python -m repro.launch.plan``)."""
import glob
import json
import os
import sys

DIR = os.path.join(os.path.dirname(__file__), "dryrun")
LEDGER_PATH = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_report.json")
PLAN_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "PLAN_report.json")


def load():
    recs = []
    for path in sorted(glob.glob(os.path.join(DIR, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def dryrun_table(recs, mesh_tag):
    lines = [
        "| arch | shape | impl | method | device bytes (arg/temp GiB) | "
        "GFLOPs/dev | HBM GB/dev | collective wire MB/dev "
        "(AG/AR/RS/A2A/CP counts) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("skipped"):
            if mesh_tag == "sp" and r.get("impl") != "phantom":
                lines.append(f"| {r['arch']} | {r['shape']} | "
                             f"{r.get('impl','-')} | - | SKIP: "
                             f"{r['skipped']} | - | - | - |")
            continue
        tag = "mp" if r["mesh"].get("pod") else "sp"
        if tag != mesh_tag:
            continue
        m = r["memory"]
        c = r["collectives"]
        method = ("exact" if r.get("cost_method") == "scan-extrapolated"
                  else "raw*")
        counts = "/".join(str(c.get(k, {}).get("count", 0)) for k in
                          ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all", "collective-permute"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['impl']} | {method} | "
            f"{fmt_bytes(m['argument_bytes'])}/{fmt_bytes(m['temp_bytes'])}"
            f" | {r['flops_per_device']/1e9:.1f} | "
            f"{r['hbm_bytes_per_device']/1e9:.1f} | "
            f"{r['collective_wire_bytes_per_device']/1e6:.1f} ({counts}) |")
    lines.append("")
    lines.append("`exact` = scan-extrapolated totals; `raw*` = "
                 "cost_analysis of the scanned compile (counts each scan "
                 "body once — compare only against other raw rows of the "
                 "same depth).  Memory columns are always from the real "
                 "full compile.")
    return "\n".join(lines)


def roofline_table(recs):
    lines = [
        "| arch | shape | impl | method | compute_s | memory_s | "
        "collective_s | dominant | step_s | frac | useful/HLO |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("skipped"):
            continue
        if r["mesh"].get("pod"):
            continue                      # roofline table is single-pod
        rf = r["roofline"]
        method = ("exact" if r.get("cost_method") == "scan-extrapolated"
                  else "raw*")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['impl']} | {method} | "
            f"{rf['compute_s']:.4g} | {rf['memory_s']:.4g} | "
            f"{rf['collective_s']:.4g} | {rf['dominant']} | "
            f"{rf['step_s']:.4g} | {rf['fraction']:.3f} | "
            f"{r['useful_flops_ratio']:.2f} |")
    lines.append("")
    lines.append("`exact` = scan-extrapolated totals (cost_fix); `raw*` = "
                 "full-compile cost_analysis, which counts each scan body "
                 "once — per-layer-scale numbers, comparable within a row "
                 "but NOT across depths (see §Roofline methodology note).")
    return "\n".join(lines)


def load_ledger(path=LEDGER_PATH):
    if not os.path.exists(path):
        return None
    with open(path) as f:
        rec = json.load(f)
    # literal (not imported from repro.telemetry: these scripts run
    # without PYTHONPATH=src) — keep in sync with telemetry/ledger.py
    if rec.get("schema") != "bench-ledger/v1":
        raise ValueError(f"{path}: unknown ledger schema "
                         f"{rec.get('schema')!r}")
    return rec


def _fmt_ratio(r):
    return f"{r:.3f}" if isinstance(r, (int, float)) else "-"


def ledger_table(report):
    """The measured-vs-predicted joins from BENCH_report.json: the rows
    that falsify (or confirm) the analytic energy model."""
    if report is None:
        return ("*(no BENCH_report.json — run `python -m benchmarks.run` "
                "to generate the energy ledger)*")
    lines = [
        "| entry | suite | impl | p | measured GFLOP/dev | "
        "flops M/P | wire KB/dev | wire M/P | wall us |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for e in report.get("entries", []):
        ratios = e.get("ratios") or {}
        if not ratios:
            continue
        m = e.get("measured") or {}
        fl = m.get("flops_per_device")
        wb = m.get("collective_wire_bytes_per_device")
        wall = m.get("wall_us_median")
        cells = [
            e["name"], e.get("suite", ""), e.get("impl", ""),
            str(e.get("p", "")),
            f"{fl/1e9:.3f}" if fl is not None else "-",
            _fmt_ratio(ratios.get("flops_per_device")),
            f"{wb/1e3:.1f}" if wb is not None else "-",
            _fmt_ratio(ratios.get("collective_wire_bytes_per_device")),
            f"{wall:.0f}" if wall is not None else "-",
        ]
        lines.append("| " + " | ".join(cells) + " |")
    lines.append("")
    suites = report.get("suites", {})
    status = "; ".join(f"{k}: {v['status']}" for k, v in sorted(
        suites.items())) or "no suite status recorded"
    lines.append(f"Suites — {status}.  M/P = measured/predicted; "
                 "measured = compiled-HLO account of the executed step, "
                 "predicted = ProjectionStrategy sums priced by the "
                 "paper's model (docs/energy_model.md).")
    return "\n".join(lines)


def elastic_table(report):
    """The elastic recovery accounts from BENCH_report.json: every run
    that survived a simulated host loss, with the replay/restart joules
    broken out of the total (docs/elastic.md)."""
    if report is None:
        return ("*(no BENCH_report.json — run `python -m benchmarks.run "
                "elastic_smoke` to generate the recovery account)*")
    rows = [e for e in report.get("entries", [])
            if e.get("kind") == "elastic"
            and (e.get("extra") or {}).get("recovery", {}).get("schema")
            == "recovery-account/v1"]
    if not rows:
        return ("*(no elastic rows in BENCH_report.json — run `python -m "
                "benchmarks.run elastic_smoke`)*")
    lines = [
        "| run | plans | restarts | replayed steps | total J | "
        "useful J | replay J | ckpt IO J | restart J | replay ratio | "
        "recovery ratio | final loss |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for e in rows:
        x = e["extra"]
        a = x["recovery"]
        m = e.get("measured") or {}
        loss = m.get("final_loss")
        loss_cell = (f"{loss:.4f}@{m.get('steps', '-')}"
                     if loss is not None else "-")
        lines.append(
            f"| {e['name']} | {' → '.join(x.get('plans', []))} | "
            f"{a['restarts']} | {a['replayed_steps']} | "
            f"{a['energy_j_total']:.3g} | {a['energy_j_useful']:.3g} | "
            f"{a['energy_j_replay']:.3g} | {a['energy_j_ckpt_io']:.3g} | "
            f"{a['energy_j_restart']:.3g} | "
            f"{a['replay_overhead_ratio']:.3f} | "
            f"{a['recovery_overhead_ratio']:.3f} | {loss_cell} |")
    lines.append("")
    lines.append("Replay ratio = replayed-step joules / all-step joules "
                 "(host-speed independent; the CI `elastic-smoke` job "
                 "bands it).  Recovery ratio additionally counts "
                 "checkpoint IO and restart (restore + re-plan + "
                 "recompile) energy.  See docs/elastic.md.")
    return "\n".join(lines)


def load_plan(path=PLAN_PATH):
    if not os.path.exists(path):
        return None
    with open(path) as f:
        rec = json.load(f)
    # literal (not imported from repro.planner: these scripts run
    # without PYTHONPATH=src) — keep in sync with planner/report.py
    if rec.get("schema") != "plan-report/v1":
        raise ValueError(f"{path}: unknown plan schema "
                         f"{rec.get('schema')!r}")
    return rec


def plan_table(report):
    """The planner's Pareto frontier + matched-loss verdict — the
    paper's final claim, decided by the calibrated model."""
    if report is None:
        return ("*(no PLAN_report.json — run `python -m "
                "repro.launch.plan` or `python -m benchmarks.run "
                "plan_smoke` to generate the configuration frontier)*")
    lines = [
        "| frontier plan | strategy | mesh (dp×tp) | width | k | "
        "ν | energy J | step s | pred. loss |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for s in report.get("frontier", []):
        p = s["plan"]
        loss = s.get("predicted_loss")
        lines.append(
            f"| {p['name']} | {p['strategy']} | "
            f"{p['dp']}×{p['tp']} ({p['devices']} dev) | {p['width']} | "
            f"{p.get('k', 0) or '-'} | {s['iterations']:.0f} | "
            f"{s['energy_j_total']:.3g} | {s['step_time_s']:.3g} | "
            f"{loss if loss is None else format(loss, '.4f')} |")
    lines.append("")
    cal = report.get("calibration", {})
    lines.append(f"Calibration: {cal.get('source', '?')} "
                 f"(α scales {cal.get('alpha_scale')}, "
                 f"β scales {cal.get('beta_scale')}).")
    comp = report.get("comparison") or {}
    if comp.get("best_phantom_smaller"):
        bp, bt = comp["best_phantom_smaller"], comp["best_tensor_full"]
        verdict = "DOMINATES" if comp.get("phantom_dominates") \
            else "does not dominate"
        lines.append(
            f"Matched-loss verdict: phantom on the smaller mesh "
            f"{verdict} — {bp['plan']} ({bp['devices']} devices, "
            f"{bp['energy_j']:.3g} J) vs best full-mesh tensor "
            f"{bt['plan']} ({bt['devices']} devices, "
            f"{bt['energy_j']:.3g} J), a "
            f"{comp.get('energy_saving_vs_best_tensor', 0)*100:.0f}% "
            f"calibrated energy saving (docs/planner.md).")
    return "\n".join(lines)


if __name__ == "__main__":
    recs = load()
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### single-pod (16x16)\n")
        print(dryrun_table(recs, "sp"))
        print("\n### multi-pod (2x16x16 = 512 chips)\n")
        print(dryrun_table(recs, "mp"))
    if which in ("all", "roofline"):
        print("\n### roofline\n")
        print(roofline_table(recs))
    if which in ("all", "ledger"):
        print("\n### energy ledger (measured vs predicted)\n")
        print(ledger_table(load_ledger()))
    if which in ("all", "plan"):
        print("\n### configuration planner (iso-loss frontier)\n")
        print(plan_table(load_plan()))
    if which in ("all", "elastic"):
        print("\n### elastic recovery (fault -> re-plan -> restore)\n")
        print(elastic_table(load_ledger()))
