"""Build the §Dry-run and §Roofline markdown tables in EXPERIMENTS.md
from experiments/dryrun/*.json."""
import glob
import json
import os
import sys

DIR = os.path.join(os.path.dirname(__file__), "dryrun")


def load():
    recs = []
    for path in sorted(glob.glob(os.path.join(DIR, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def dryrun_table(recs, mesh_tag):
    lines = [
        "| arch | shape | impl | method | device bytes (arg/temp GiB) | "
        "GFLOPs/dev | HBM GB/dev | collective wire MB/dev "
        "(AG/AR/RS/A2A/CP counts) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("skipped"):
            if mesh_tag == "sp" and r.get("impl") != "phantom":
                lines.append(f"| {r['arch']} | {r['shape']} | "
                             f"{r.get('impl','-')} | - | SKIP: "
                             f"{r['skipped']} | - | - | - |")
            continue
        tag = "mp" if r["mesh"].get("pod") else "sp"
        if tag != mesh_tag:
            continue
        m = r["memory"]
        c = r["collectives"]
        method = ("exact" if r.get("cost_method") == "scan-extrapolated"
                  else "raw*")
        counts = "/".join(str(c.get(k, {}).get("count", 0)) for k in
                          ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all", "collective-permute"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['impl']} | {method} | "
            f"{fmt_bytes(m['argument_bytes'])}/{fmt_bytes(m['temp_bytes'])}"
            f" | {r['flops_per_device']/1e9:.1f} | "
            f"{r['hbm_bytes_per_device']/1e9:.1f} | "
            f"{r['collective_wire_bytes_per_device']/1e6:.1f} ({counts}) |")
    lines.append("")
    lines.append("`exact` = scan-extrapolated totals; `raw*` = "
                 "cost_analysis of the scanned compile (counts each scan "
                 "body once — compare only against other raw rows of the "
                 "same depth).  Memory columns are always from the real "
                 "full compile.")
    return "\n".join(lines)


def roofline_table(recs):
    lines = [
        "| arch | shape | impl | method | compute_s | memory_s | "
        "collective_s | dominant | step_s | frac | useful/HLO |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("skipped"):
            continue
        if r["mesh"].get("pod"):
            continue                      # roofline table is single-pod
        rf = r["roofline"]
        method = ("exact" if r.get("cost_method") == "scan-extrapolated"
                  else "raw*")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['impl']} | {method} | "
            f"{rf['compute_s']:.4g} | {rf['memory_s']:.4g} | "
            f"{rf['collective_s']:.4g} | {rf['dominant']} | "
            f"{rf['step_s']:.4g} | {rf['fraction']:.3f} | "
            f"{r['useful_flops_ratio']:.2f} |")
    lines.append("")
    lines.append("`exact` = scan-extrapolated totals (cost_fix); `raw*` = "
                 "full-compile cost_analysis, which counts each scan body "
                 "once — per-layer-scale numbers, comparable within a row "
                 "but NOT across depths (see §Roofline methodology note).")
    return "\n".join(lines)


if __name__ == "__main__":
    recs = load()
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### single-pod (16x16)\n")
        print(dryrun_table(recs, "sp"))
        print("\n### multi-pod (2x16x16 = 512 chips)\n")
        print(dryrun_table(recs, "mp"))
    if which in ("all", "roofline"):
        print("\n### roofline\n")
        print(roofline_table(recs))
