"""Scan-aware cost correction for the dry-run roofline numbers.

XLA's ``cost_analysis()`` counts a while/scan BODY once, not per trip —
so the layer-scan, grad-accumulation scan, kv-chunk scan, loss-chunk scan
and SSD chunk scan all undercount FLOPs/bytes/collectives.  This pass
recomputes exact per-device totals per cell by:

  * building analysis variants with every inner scan unrolled
    (microbatches=1, attn_kv_chunk=-1, loss_chunk=S, ssd chunk=S) and the
    layer stack at g=1 and g=2 groups,
  * extrapolating linearly in g (costs are affine in the group count:
    intercept = embed/loss/head, slope = per-group cost),

then rewrites flops/bytes/wire + roofline terms in the cell's JSON
(memory_analysis of the REAL full compile is kept — buffers are reused
across scan iterations, so the full compile is the fits proof).

Run AFTER the main sweep:  PYTHONPATH=src python experiments/cost_fix.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses
import glob
import json
import subprocess


def fix_one(path: str, timeout: int = 1800) -> bool:
    with open(path) as f:
        rec = json.load(f)
    if rec.get("skipped") or rec.get("cost_method") == "scan-extrapolated":
        return False
    if rec.get("mesh", {}).get("pod"):
        return False            # roofline table is single-pod only
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
        + os.pathsep + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", rec["arch"], "--shape", rec["shape"],
           "--impl", rec["impl"], "--cost-fix", path]
    r = subprocess.run(cmd, capture_output=True, text=True,
                       timeout=timeout, env=env)
    if r.returncode != 0:
        print(f"FAIL {path}\n{r.stdout[-1500:]}\n{r.stderr[-1500:]}")
        return False
    print(r.stdout.strip().splitlines()[-1])
    return True


def main():
    paths = sorted(glob.glob(os.path.join(os.path.dirname(__file__),
                                          "dryrun", "*_sp.json")))
    for p in paths:
        try:
            fix_one(p)
        except Exception as e:
            print(f"ERROR {p}: {e}")
    print("COST FIX DONE")


if __name__ == "__main__":
    main()
