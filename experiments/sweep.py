import sys
sys.path.insert(0, "/root/repo/src")
from repro.launch.dryrun import run_all
run_all("/root/repo/experiments/dryrun", impls=("dense", "phantom"),
        multi_pods=(False,), timeout=2400)
run_all("/root/repo/experiments/dryrun", impls=("phantom",),
        multi_pods=(True,), timeout=2400)
print("SWEEP DONE")
