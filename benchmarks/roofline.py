"""§Roofline reader: aggregates experiments/dryrun/*.json into the
per-(arch x shape x impl) roofline table (used to build EXPERIMENTS.md)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def rows(dirname="experiments/dryrun"):
    out = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        out.append(rec)
    return out


def run(dirname="experiments/dryrun"):
    recs = rows(dirname)
    if not recs:
        emit("roofline_no_dryrun_data", 0.0,
             "run: python -m repro.launch.dryrun --all")
        return
    for rec in recs:
        if rec.get("skipped"):
            emit(f"roofline_{rec['arch']}_{rec['shape']}_"
                 f"{rec.get('impl','-')}", 0.0, f"SKIP:{rec['skipped']}")
            continue
        r = rec["roofline"]
        emit(f"roofline_{rec['arch']}_{rec['shape']}_{rec['impl']}"
             f"_{'mp' if rec['mesh'].get('pod') else 'sp'}",
             r["step_s"] * 1e6,
             f"dom={r['dominant']};frac={r['fraction']:.3f};"
             f"comp={r['compute_s']:.4g}s;mem={r['memory_s']:.4g}s;"
             f"coll={r['collective_s']:.4g}s;"
             f"useful={rec['useful_flops_ratio']:.2f}")


if __name__ == "__main__":
    run()
