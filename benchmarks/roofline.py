"""§Roofline reader: aggregates experiments/dryrun/*.json into the
per-(arch x shape x impl) roofline table (used to build EXPERIMENTS.md)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def rows(dirname="experiments/dryrun"):
    out = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        out.append(rec)
    return out


def run(dirname="experiments/dryrun"):
    recs = rows(dirname)
    if not recs:
        emit("roofline_no_dryrun_data", 0.0,
             "run: python -m repro.launch.dryrun --all")
        return
    for rec in recs:
        if rec.get("skipped"):
            emit(f"roofline_{rec['arch']}_{rec['shape']}_"
                 f"{rec.get('impl','-')}", 0.0, f"SKIP:{rec['skipped']}",
                 kind="skip", arch=rec.get("arch", ""),
                 impl=rec.get("impl", ""),
                 extra={"skipped": rec["skipped"]})
            continue
        r = rec["roofline"]
        shape_kind = ("train" if "train" in rec["shape"] else
                      "prefill" if "prefill" in rec["shape"] else "decode")
        emit(f"roofline_{rec['arch']}_{rec['shape']}_{rec['impl']}"
             f"_{'mp' if rec['mesh'].get('pod') else 'sp'}",
             r["step_s"] * 1e6,
             f"dom={r['dominant']};frac={r['fraction']:.3f};"
             f"comp={r['compute_s']:.4g}s;mem={r['memory_s']:.4g}s;"
             f"coll={r['collective_s']:.4g}s;"
             f"useful={rec['useful_flops_ratio']:.2f}",
             kind=shape_kind, arch=rec["arch"], impl=rec["impl"],
             p=rec["mesh"].get("model", 0),
             measured={
                 "flops_per_device": rec["flops_per_device"],
                 "hbm_bytes_per_device": rec["hbm_bytes_per_device"],
                 "collective_wire_bytes_per_device":
                     rec["collective_wire_bytes_per_device"]},
             predicted={
                 "flops_per_device": rec["model_flops_per_device"],
                 "model": "6*N_active*tokens (train) / 2 (infer)"},
             extra={"shape": rec["shape"], "roofline": r,
                    "useful_flops_ratio": rec["useful_flops_ratio"],
                    "cost_method": rec.get("cost_method", "raw")})


if __name__ == "__main__":
    run()
