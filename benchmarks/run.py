"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines AND records every row into
the measured-vs-predicted energy ledger; after the suites finish it
writes ``BENCH_report.json`` (aggregate) and ``BENCH_ledger.jsonl``
(per-entry stream) at the repo root.  Exits non-zero if any suite fails.

Usage: ``python -m benchmarks.run [suite ...]`` (no args = all suites);
``python -m benchmarks.run --list`` prints the suites and exits.
"""
import os

# benches need a small local mesh (NOT the dry-run's 512)
if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import sys
import time
import traceback

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
REPORT_PATH = os.path.join(ROOT, "BENCH_report.json")
JSONL_PATH = os.path.join(ROOT, "BENCH_ledger.jsonl")

# suite name -> one-line description, in run order (kept static so
# ``--list`` answers without importing jax or any suite module)
SUITES = {
    "comm_model": "paper Table III: fit the c1/c2 collective comm model "
                  "on this host's mesh",
    "train_smoke": "metered TP-vs-phantom FFN step "
                   "(measured/predicted ledger join)",
    "kernel_bench": "fused Pallas vs XLA phantom FFN step "
                    "(kernel ledger join, wire ratio pinned both ways)",
    "pipeline_smoke": "metered 1F1B pipelined FFN step on the pp=2 mesh "
                      "(stage-boundary wire-byte join)",
    "plan_smoke": "energy-aware planner end-to-end: calibrate, search, "
                  "iso-loss frontier -> PLAN_report.json",
    "serve_bench": "serving runtime: fixed trace through tensor + "
                   "phantom configs, SLO + joules-per-token ledger rows",
    "fleet": "disaggregated prefill/decode fleet vs colocated baseline "
             "on one bursty trace (KV wire band + J/token)",
    "elastic_smoke": "kill a simulated host mid-run: detect, re-plan "
                     "onto survivors, restore, price the recovery",
    "fig5_comm": "paper Fig. 5a: TP vs PP communication per epoch",
    "fig5_exec": "paper Fig. 5b/c: TP vs PP execution time per epoch",
    "fig6_large": "paper Fig. 6: large-n projection + memory footprints",
    "table1_energy": "paper Table I / Fig. 7: fixed-loss energy "
                     "comparison",
    "roofline": "§Roofline reader over experiments/dryrun/*.json",
}


def list_suites() -> int:
    for name, desc in SUITES.items():
        print(f"{name:<14} {desc}")
    return 0


def main(argv=None) -> int:
    names = list(sys.argv[1:] if argv is None else argv)
    if "--list" in names or "-l" in names:
        return list_suites()
    from benchmarks import (comm_model, common, elastic_smoke, fig5_comm,
                            fig5_exec, fig6_large, fleet_bench,
                            kernel_bench, pipeline_smoke, plan_smoke,
                            roofline, serve_bench, table1_energy,
                            train_smoke)
    suites = {
        "comm_model": comm_model.run,
        "train_smoke": train_smoke.run,
        "kernel_bench": kernel_bench.run,
        "pipeline_smoke": pipeline_smoke.run,
        "plan_smoke": plan_smoke.run,
        "serve_bench": serve_bench.run,
        "fleet": fleet_bench.run,
        "elastic_smoke": elastic_smoke.run,
        "fig5_comm": fig5_comm.run,
        "fig5_exec": fig5_exec.run,
        "fig6_large": fig6_large.run,
        "table1_energy": table1_energy.run,
        "roofline": roofline.run,
    }
    assert set(suites) == set(SUITES), "SUITES descriptions out of sync"
    unknown = [n for n in names if n not in suites]
    if unknown:
        print(f"unknown suite(s) {unknown}; known: {sorted(suites)}",
              file=sys.stderr)
        return 2

    import jax
    from repro.telemetry import Ledger
    ledger = Ledger(run="benchmarks.run", jsonl_path=JSONL_PATH,
                    meta={"argv": names or ["all"],
                          "devices": len(jax.devices()),
                          "backend": jax.default_backend(),
                          "jax": jax.__version__})
    common.set_ledger(ledger)

    failed = []
    for name, fn in suites.items():
        if names and name not in names:
            continue
        common.set_suite(name)
        print(f"# === {name} ===", flush=True)
        # perf_counter at µs resolution: the analytic suites (fig6_large,
        # roofline) finish in well under the 0.1 s that time.time()
        # rounding could resolve, and used to report 0.0 seconds
        t0 = time.perf_counter()
        try:
            fn()
            ledger.suite_ok(name, round(time.perf_counter() - t0, 6))
        except Exception as exc:
            traceback.print_exc()
            ledger.suite_failed(name, f"{type(exc).__name__}: {exc}",
                                round(time.perf_counter() - t0, 6))
            failed.append(name)
            print(f"{name}_FAILED,0.0,{type(exc).__name__}")
        print(f"# {name} took {time.perf_counter()-t0:.3f}s", flush=True)

    ledger.write_report(REPORT_PATH)
    print(f"# wrote {REPORT_PATH} ({len(ledger)} entries, "
          f"{len(ledger.joined())} measured-vs-predicted joins) "
          f"and {JSONL_PATH}", flush=True)
    if failed:
        print(f"# FAILED suites: {', '.join(failed)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
