"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines.

  comm_model     paper Table III (collective comm-model fit)
  fig5_comm      paper Fig. 5a  (TP vs PP communication / epoch)
  fig5_exec      paper Fig. 5b/c (TP vs PP execution time / epoch)
  fig6_large     paper Fig. 6   (large-n projection + memory footprints)
  table1_energy  paper Table I / Fig. 7 (fixed-loss energy comparison)
  roofline       §Roofline reader over experiments/dryrun/*.json
"""
import os

# benches need a small local mesh (NOT the dry-run's 512)
if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import sys
import time
import traceback


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    from benchmarks import (comm_model, fig5_comm, fig5_exec, fig6_large,
                            roofline, table1_energy)
    suites = {
        "comm_model": comm_model.run,
        "fig5_comm": fig5_comm.run,
        "fig5_exec": fig5_exec.run,
        "fig6_large": fig6_large.run,
        "table1_energy": table1_energy.run,
        "roofline": roofline.run,
    }
    for name, fn in suites.items():
        if only and name != only:
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception:
            traceback.print_exc()
            print(f"{name}_FAILED,0.0,")
        print(f"# {name} took {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
