"""Shared benchmark utilities.  Import AFTER benchmarks.run has set the
device-count flag (or standalone: sets 8 itself)."""
from __future__ import annotations

import os

if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import time

import jax
import numpy as np


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (blocks on ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
