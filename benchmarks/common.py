"""Shared benchmark utilities.  Import AFTER benchmarks.run has set the
device-count flag (or standalone: sets 8 itself).

Every ``emit()`` both prints the legacy ``name,us,derived`` CSV line and
records a ``LedgerEntry`` into the process-wide ledger, so all suites
report through the telemetry subsystem (docs/benchmarks.md);
``benchmarks/run.py`` writes the aggregate ``BENCH_report.json``.
"""
from __future__ import annotations

import os

if "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

from repro.telemetry import Ledger, LedgerEntry
from repro.telemetry import measure as _measure

_LEDGER = None
_SUITE = "adhoc"


def get_ledger() -> Ledger:
    global _LEDGER
    if _LEDGER is None:
        _LEDGER = Ledger(run="benchmarks")
    return _LEDGER


def set_ledger(ledger: Ledger):
    global _LEDGER
    _LEDGER = ledger


def set_suite(name: str):
    """Tag subsequent emit() entries with the running suite's name."""
    global _SUITE
    _SUITE = name


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (blocks on ready)."""
    return _measure(fn, *args, warmup=warmup, iters=iters)


def emit(name: str, us: float, derived: str = "", *, kind: str = "bench",
         arch: str = "", impl: str = "", p: int = 0, measured=None,
         predicted=None, extra=None) -> LedgerEntry:
    """Print the legacy CSV line AND record a ledger entry.

    Callers with a real measured/predicted pair pass both dicts (the
    ledger computes the ratio columns); bare calls still land in the
    report as CSV-equivalent rows.
    """
    print(f"{name},{us:.1f},{derived}")
    ex = dict(extra or {})
    if derived:
        ex["derived"] = derived
    m = dict(measured or {})
    # for bare legacy emits the CSV us column is a wall measurement; rows
    # that pass an explicit measured dict (or are analytic — the us then
    # prints a model value) must not have it stamped in
    if us and measured is None and kind not in ("analytic", "derived",
                                                "skip"):
        m.setdefault("wall_us_median", us)
    return get_ledger().record(LedgerEntry(
        name=name, suite=_SUITE, kind=kind, arch=arch, impl=impl, p=p,
        measured=m or None, predicted=dict(predicted) if predicted
        else None, extra=ex))
