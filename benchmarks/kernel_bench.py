"""Fused Pallas kernel vs XLA phantom FFN step — the kernel ledger join.

Runs the identical phantom FFN probe step (telemetry/probe.py) twice —
``kernel_backend="xla"`` (composed GEMM chain) and ``"pallas"`` (the
fused custom_vjp op from ``kernels/ops.py``) — and records both as
ledger rows with measured/predicted flops and wire ratios.  The wire
ratio must pin at 1.00 for BOTH backends: the kernel fuses GEMMs, never
collectives, so any drift means an unpriced collective snuck inside the
fused entrypoint (the same invariant ``analysis.units.kernel_unit``
audits statically).

On this CPU container the pallas row runs through the Pallas interpreter
(a correctness mode lowered as per-tile loops), so its wall time and
HLO-counted flops are NOT the TPU roofline — the interpreter's grid loop
body is counted once by XLA cost analysis, which is why the pallas row's
flops ratio band in ``ci/bench_baseline.json`` sits below the XLA row's.
On TPU the same entrypoint compiles to the MXU kernel.
"""
from __future__ import annotations

from benchmarks.common import emit


def run(steps: int = 5):
    from repro.configs.base import (ModelConfig, PhantomConfig,
                                    phantom_projection_map)
    from repro.launch.mesh import make_local_mesh
    from repro.parallel.axes import MeshAxes
    from repro.telemetry import measure_ffn_step

    mesh = make_local_mesh(1, 8)
    p = MeshAxes.from_mesh(mesh).tp
    n, L, batch, k = 512, 2, 32, 8
    for backend in ("xla", "pallas"):
        cfg = ModelConfig(
            name=f"ffn{n}-phantom-{backend}", family="ffn",
            num_layers=L, d_model=n, ffn_width=n, ffn_depth=L,
            mlp="relu", phantom=PhantomConfig(k=k),
            projections=phantom_projection_map(
                k, ffn_layer=True, kernel_backend=backend))
        measured, predicted = measure_ffn_step(cfg, mesh, batch,
                                               steps=steps)
        rf = (measured["flops_per_device"]
              / predicted["flops_per_device"])
        rw = (measured["collective_wire_bytes_per_device"]
              / predicted["collective_wire_bytes_per_device"])
        emit(f"kernel_bench_{backend}",
             measured.get("wall_us_median", 0.0),
             f"n={n};L={L};k={k};flops_ratio={rf:.3f};"
             f"wire_ratio={rw:.4f}",
             kind="kernel", arch=cfg.name, impl=f"phantom_{backend}",
             p=p, measured=measured, predicted=predicted,
             extra={"n": n, "L": L, "k": k, "batch": batch,
                    "steps": steps, "kernel_backend": backend})


if __name__ == "__main__":
    run()
