"""Paper Fig. 6: execution time per epoch at large model sizes
(n = 131,072 and 262,144; k=64; p = 32..256) — analytic projection.

Per-epoch time = max(compute term, memory term) + comm term, with compute
from the paper's operation counts, memory from parameter+activation
traffic, comm from the Eqn. 26 model.  Also reports the per-rank memory
footprints that explain the paper's observation that TP at n=262,144
cannot run on 32 GPUs while PP can.
"""
from __future__ import annotations

from benchmarks.common import emit


def run():
    from repro.core.energy import (TPU_HBM_BW, TPU_PEAK_FLOPS, pp_costs,
                                   tp_costs, comm_time_us)

    batch = 64
    L = 2
    k = 64
    for n in (131_072, 262_144):
        for p in (32, 64, 128, 256):
            a_t, b_t = tp_costs(n, p, L, batch, TPU_PEAK_FLOPS)
            a_p, b_p = pp_costs(n, p, L, k, batch, TPU_PEAK_FLOPS)
            # memory footprint per rank (fp32 params + adam m,v)
            tp_bytes = (n * n / p) * 4 * 3 * L
            pp_bytes = ((n / p) ** 2 + k * n / p + p * k * n / p) \
                * 4 * 3 * L
            t_tp = (a_t + b_t) * 1e6
            t_pp = (a_p + b_p) * 1e6
            emit(f"fig6_tp_n{n}_p{p}", t_tp,
                 f"mem={tp_bytes/2**30:.1f}GiB"
                 + (";OOM@64GiB" if tp_bytes > 64 * 2 ** 30 else ""))
            emit(f"fig6_pp_n{n}_p{p}", t_pp,
                 f"mem={pp_bytes/2**30:.2f}GiB;"
                 f"speedup={t_tp/t_pp:.2f}x")


if __name__ == "__main__":
    run()
