"""Paper Fig. 6: execution time per epoch at large model sizes
(n = 131,072 and 262,144; k=64; p = 32..256) — analytic projection.

Per-epoch time = max(compute term, memory term) + comm term, with compute
from the paper's operation counts, memory from parameter+activation
traffic, comm from the Eqn. 26 model.  Also reports the per-rank memory
footprints that explain the paper's observation that TP at n=262,144
cannot run on 32 GPUs while PP can.  Predicted-only ledger rows (nothing
at this scale runs in the container — that is the point of the model).
"""
from __future__ import annotations

from benchmarks.common import emit


def run():
    from repro.core.energy import TPU_PEAK_FLOPS, phantom_costs, tp_costs

    batch = 64
    L = 2
    k = 64
    for n in (131_072, 262_144):
        for p in (32, 64, 128, 256):
            a_t, b_t = tp_costs(n, p, L, batch, TPU_PEAK_FLOPS)
            a_p, b_p = phantom_costs(n, p, L, k, batch, TPU_PEAK_FLOPS)
            # memory footprint per rank (fp32 params + adam m,v)
            tp_bytes = (n * n / p) * 4 * 3 * L
            pp_bytes = ((n / p) ** 2 + k * n / p + p * k * n / p) \
                * 4 * 3 * L
            t_tp = (a_t + b_t) * 1e6
            t_pp = (a_p + b_p) * 1e6
            emit(f"fig6_tp_n{n}_p{p}", t_tp,
                 f"mem={tp_bytes/2**30:.1f}GiB"
                 + (";OOM@64GiB" if tp_bytes > 64 * 2 ** 30 else ""),
                 kind="analytic", impl="tensor_col", p=p,
                 predicted={"alpha_s": a_t, "beta_s": b_t,
                            "step_us": t_tp, "mem_bytes": tp_bytes},
                 extra={"n": n, "L": L, "batch": batch,
                        "oom_64gib": tp_bytes > 64 * 2 ** 30})
            emit(f"fig6_pp_n{n}_p{p}", t_pp,
                 f"mem={pp_bytes/2**30:.2f}GiB;"
                 f"speedup={t_tp/t_pp:.2f}x",
                 kind="analytic", impl="phantom", p=p,
                 predicted={"alpha_s": a_p, "beta_s": b_p,
                            "step_us": t_pp, "mem_bytes": pp_bytes},
                 extra={"n": n, "L": L, "k": k,
                        "speedup_vs_tp": t_tp / t_pp})


if __name__ == "__main__":
    run()
