"""Paper Table III reproduction: fit the unified communication model
comm_time(m, p) = c1*log2(p) + c2*m (+c3) to measured collectives.

The paper fits on Frontier/RCCL up to 256 GPUs; this container measures
the same collectives over 8 virtual CPU devices — the NUMBERS differ, the
METHODOLOGY (and the fit quality check) is the reproduction.  The paper's
Frontier constants and the TPU-projected constants (ICI ring model) are
printed alongside for the energy model to consume.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit


def _fit(ms, ps, ts):
    """least squares for t = c1 log2 p + c2 m + c3."""
    A = np.stack([np.log2(ps), ms, np.ones_like(ms)], axis=1)
    coef, *_ = np.linalg.lstsq(A, ts, rcond=None)
    pred = A @ coef
    rmse = float(np.sqrt(np.mean((np.log2(np.maximum(pred, 1e-9))
                                  - np.log2(np.maximum(ts, 1e-9))) ** 2)))
    return coef, rmse


def run():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_local_mesh
    from repro.core.energy import PAPER_COLLECTIVE_FITS
    from repro.parallel.compat import shard_map

    mesh = make_local_mesh(1, 8)

    def collective(kind):
        def ag(x):
            return jax.lax.all_gather(x, "model")

        def ar(x):
            return jax.lax.psum(x, "model")

        def rs(x):
            return jax.lax.psum_scatter(x, "model", scatter_dimension=0,
                                        tiled=True)
        f = {"all_gather": ag, "all_reduce": ar, "reduce_scatter": rs}[kind]
        return jax.jit(shard_map(f, mesh=mesh, in_specs=P("model"),
                                     out_specs=(P(None) if kind ==
                                                "all_gather" else
                                                P("model")
                                                if kind == "reduce_scatter"
                                                else P("model")),
                                     check_vma=False))

    print("# paper Table III methodology: fit c1*log2(p)+c2*m+c3 "
          "(measured, 8 virtual CPU devices)")
    results = {}
    for kind in ("all_gather", "all_reduce", "reduce_scatter"):
        fn = collective(kind)
        ms, ts = [], []
        for logm in range(10, 19, 2):
            m = 2 ** logm
            x = jnp.ones((8 * max(m // 8, 1),), jnp.float32)
            us = timeit(fn, x)
            ms.append(m)
            ts.append(us)
            emit(f"comm_{kind}_m{m}", us, f"floats={m}")
        coef, rmse = _fit(np.array(ms, float),
                          np.full(len(ms), 8.0), np.array(ts))
        results[kind] = coef
        paper = PAPER_COLLECTIVE_FITS.get(kind)
        emit(f"comm_fit_{kind}", 0.0,
             f"c1={coef[0]:.3g};c2={coef[1]:.3g};c3={coef[2]:.3g};"
             f"rmse_log2={rmse:.2f}",
             kind="collective", impl=kind, p=8,
             measured={"c1_us": float(coef[0]), "c2_us_per_float":
                       float(coef[1]), "c3_us": float(coef[2]),
                       "rmse_log2": rmse},
             predicted=({"c1_us": paper[0], "c2_us_per_float": paper[1],
                         "source": "paper Table III (Frontier)"}
                        if paper else None))
    print("# paper Frontier fits (Table III) for the energy model:")
    for kind, (c1, c2) in PAPER_COLLECTIVE_FITS.items():
        emit(f"comm_paper_{kind}", 0.0, f"c1={c1};c2={c2}",
             kind="analytic", impl=kind,
             predicted={"c1_us": c1, "c2_us_per_float": c2})

    predict_table2(measured_fits={
        kind: (coef[0], coef[1]) for kind, coef in results.items()})


def predict_table2(measured_fits=None, p: int = 8, batch: int = 1024):
    """Paper Table II predictions, summed from ProjectionStrategy
    ``comm_events()`` instead of re-derived by hand: per layer, TP issues
    an AG of (n/p)*batch floats, phantom an AG of k*batch — whatever the
    instantiated strategies say they issue is what gets priced."""
    from repro.configs.base import ProjectionSpec, get_config
    from repro.core.energy import PAPER_COLLECTIVE_FITS, comm_time_us
    from repro.parallel.strategies import make_strategy

    print("# paper Table II comm schedule, summed from strategy "
          "comm_events() (per layer, per iteration)")
    for arch in ("paper-ffn-4k", "paper-ffn-16k", "paper-ffn-64k"):
        cfg = get_config(arch)
        n, k = cfg.ffn_width, cfg.phantom.k
        tp_st = make_strategy(ProjectionSpec(kind="tensor_col"), n, n, p,
                              bias=True)
        pp_st = make_strategy(ProjectionSpec(kind="phantom", k=k), n, n, p,
                              bias=True)
        for label, st in (("tp", tp_st), ("pp", pp_st)):
            events = st.comm_events(batch)
            floats = sum(ev.m_floats for ev in events)
            us_paper = sum(comm_time_us(ev.collective, ev.m_floats, p,
                                        PAPER_COLLECTIVE_FITS)
                           for ev in events)
            extra = f"m_floats={floats:.0f};us_paper_fit={us_paper:.1f}"
            predicted = {"collective_m_floats": floats,
                         "comm_us": us_paper}
            measured = None
            if measured_fits:
                us_meas = sum(comm_time_us(ev.collective, ev.m_floats, p,
                                           measured_fits)
                              for ev in events)
                extra += f";us_measured_fit={us_meas:.1f}"
                measured = {"comm_us_local_fit": us_meas}
            emit(f"table2_{label}_{arch}", us_paper, extra,
                 kind="analytic", arch=arch, impl=st.kind, p=p,
                 measured=measured, predicted=predicted,
                 extra={"batch": batch})


if __name__ == "__main__":
    run()
