"""Metered TP-vs-phantom FFN train step — the ledger's core join.

For each implementation this compiles the FFN probe step (layers
unrolled, input grads kept: telemetry/probe.py documents why both matter
for exact accounting), reads MEASURED per-device flops / HBM bytes /
collective wire bytes from the lowered HLO, runs a few metered
executions for wall time, and joins against the PREDICTED account summed
from the same ``ProjectionStrategy`` objects.  The flops/wire ratio
columns in ``BENCH_report.json`` come from here; tests/test_telemetry.py
pins them within tolerance.
"""
from __future__ import annotations

from benchmarks.common import emit



def _projections(impl: str, k: int):
    """Explicit per-site strategy selection for the paper-FFN subject
    (the deprecated ffn_impl= shim is off-limits in-repo)."""
    from repro.configs.base import (dense_projection_map,
                                    phantom_projection_map)
    if impl == "phantom":
        return phantom_projection_map(k, ffn_layer=True)
    return dense_projection_map()

def run(steps: int = 5):
    from repro.configs.base import ModelConfig, PhantomConfig
    from repro.launch.mesh import make_local_mesh
    from repro.parallel.axes import MeshAxes
    from repro.telemetry import measure_ffn_step

    mesh = make_local_mesh(1, 8)
    p = MeshAxes.from_mesh(mesh).tp
    n, L, batch, k = 512, 2, 32, 8
    for impl, strat in (("dense", "tensor_col"), ("phantom", "phantom")):
        cfg = ModelConfig(name=f"ffn{n}-{impl}", family="ffn",
                          num_layers=L, d_model=n, ffn_width=n,
                          ffn_depth=L, mlp="relu",
                          phantom=PhantomConfig(k=k),
                          projections=_projections(impl, k))
        measured, predicted = measure_ffn_step(cfg, mesh, batch,
                                               steps=steps)
        rf = (measured["flops_per_device"]
              / predicted["flops_per_device"])
        rw = (measured["collective_wire_bytes_per_device"]
              / predicted["collective_wire_bytes_per_device"])
        emit(f"train_smoke_{strat}", measured.get("wall_us_median", 0.0),
             f"n={n};L={L};k={k};flops_ratio={rf:.3f};"
             f"wire_ratio={rw:.4f}",
             kind="train", arch=cfg.name, impl=strat, p=p,
             measured=measured, predicted=predicted,
             extra={"n": n, "L": L, "k": k, "batch": batch,
                    "steps": steps})


if __name__ == "__main__":
    run()
