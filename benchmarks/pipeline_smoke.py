"""Pipeline smoke: metered 1F1B FFN steps on the pp=2 × dp=2 × tp=2 mesh.

For tensor and phantom per-stage strategies this compiles the pipelined
FFN probe (wavefront ticks AND layers unrolled, input grads kept — the
same exactness arguments as the flat probe), reads the MEASURED
per-device flops / collective wire bytes / stage-boundary
collective-permute wire bytes from the lowered HLO, runs a few metered
executions, and joins against the PREDICTED executed-SPMD account from
``telemetry.pipeline_ffn_step_prediction`` — the same
``PipelineSchedule.p2p_events`` pricing the planner uses, at the
executed tick count.

The suite (and the CI ``pipeline-smoke`` job, re-checking from
``BENCH_report.json``) asserts the measured/predicted STAGE-BOUNDARY
wire-byte ratio lands in [0.9, 1.1]: the p2p energy term prices exactly
the ppermutes the compiler emitted.
"""
from __future__ import annotations

from benchmarks.common import emit

BOUNDARY_BAND = (0.9, 1.1)



def _projections(impl: str, k: int):
    """Explicit per-site strategy selection for the paper-FFN subject
    (the deprecated ffn_impl= shim is off-limits in-repo)."""
    from repro.configs.base import (dense_projection_map,
                                    phantom_projection_map)
    if impl == "phantom":
        return phantom_projection_map(k, ffn_layer=True)
    return dense_projection_map()

def run(steps: int = 3):
    from repro.configs.base import (ModelConfig, PhantomConfig,
                                    PipelineConfig)
    from repro.launch.mesh import make_local_mesh
    from repro.parallel.axes import MeshAxes
    from repro.telemetry import measure_ffn_pipeline_step

    mesh = make_local_mesh(2, 2, 2)          # (pipe=2, data=2, model=2)
    axes = MeshAxes.from_mesh(mesh)
    if axes.pp != 2:
        raise RuntimeError(f"needs an 8-device host for the pp=2 mesh, "
                           f"got pp={axes.pp}")
    n, L, batch, k, M = 256, 4, 32, 8, 4
    out_of_band = []
    for impl, strat in (("dense", "tensor_col"), ("phantom", "phantom")):
        cfg = ModelConfig(name=f"pipe{n}-{impl}", family="ffn",
                          num_layers=L, d_model=n, ffn_width=n,
                          ffn_depth=L, mlp="relu",
                          phantom=PhantomConfig(k=k),
                          projections=_projections(impl, k),
                          pipeline=PipelineConfig(stages=axes.pp),
                          microbatches=M)
        measured, predicted = measure_ffn_pipeline_step(cfg, mesh, batch,
                                                        steps=steps)
        rf = (measured["flops_per_device"]
              / predicted["flops_per_device"])
        rw = (measured["collective_wire_bytes_per_device"]
              / predicted["collective_wire_bytes_per_device"])
        rb = (measured["boundary_wire_bytes_per_device"]
              / predicted["boundary_wire_bytes_per_device"])
        emit(f"pipeline_smoke_{strat}",
             measured.get("wall_us_median", 0.0),
             f"n={n};L={L};k={k};pp={axes.pp};mb={M};"
             f"flops_ratio={rf:.3f};wire_ratio={rw:.4f};"
             f"boundary_wire_ratio={rb:.4f}",
             kind="train", arch=cfg.name, impl=strat, p=axes.tp,
             measured=measured, predicted=predicted,
             extra={"n": n, "L": L, "k": k, "batch": batch,
                    "pp": axes.pp, "dp": axes.dp, "microbatches": M,
                    "ticks": predicted["ticks"],
                    "bubble_fraction": predicted["bubble_fraction"],
                    "boundary_wire_ratio": rb, "steps": steps})
        if not (BOUNDARY_BAND[0] <= rb <= BOUNDARY_BAND[1]):
            out_of_band.append((strat, rb))
    if out_of_band:
        raise RuntimeError(
            f"stage-boundary wire ratio outside {BOUNDARY_BAND}: "
            f"{out_of_band}")


if __name__ == "__main__":
    run()
