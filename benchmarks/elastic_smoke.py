"""Elastic smoke: kill a simulated host mid-run, recover, price it.

The paper's elastic story end-to-end: paper-FFN training starts on the
baseline tensor plan pinned to the FULL 8-device budget; at step 25 a
scripted fault kills one of the 4 simulated hosts (2 devices).  The
heartbeat monitor detects the loss after the (virtual-clock) timeout,
the planner re-solves dp×tp×pp×k over the 6 survivors — picking the
paper-sanctioned downsize onto a phantom plan, SVD-distilling the
tensor checkpoint into the phantom factor class — the re-planned mesh
passes the static collective audit, training resumes from the latest
checkpoint, and the run must still reach the target loss.

The recovery energy account (``telemetry.recovery_account``) prices the
whole episode: useful steps, replayed steps, checkpoint IO and restart
(restore + re-plan + recompile) overhead.  The suite (and the CI
``elastic-smoke`` job, re-checking from ``BENCH_report.json``) asserts
the REPLAY overhead ratio — replayed-step joules over all-step joules,
the one quantity independent of this host's wall-clock speed — lands in
``REPLAY_BAND``: a kill at step 25 with checkpoint cadence 10 and a
~2-3-step detection lag must replay a handful of steps, not zero (no
actual recovery) and not a third of the run (checkpoint/detection
regression).
"""
from __future__ import annotations

import tempfile

from benchmarks.common import emit, get_ledger

REPLAY_BAND = (0.02, 0.30)
KILL_STEP = 25
KILL_HOST = "host3"


def run():
    from repro.train.elastic import ElasticConfig, run_elastic
    from repro.train.fault import FaultScript

    cfg = ElasticConfig(
        workdir=tempfile.mkdtemp(prefix="elastic_smoke_"),
        devices=8, hosts=4, width=64, depth=2, batch=32,
        target_loss=0.12, max_steps=300, checkpoint_every=10,
        initial_strategy="tensor_col", heartbeat_timeout_s=2.5)
    res = run_elastic(
        cfg, ledger=get_ledger(),
        fault_script=FaultScript(kills=((KILL_STEP, KILL_HOST),)))
    acct = res.account

    if res.aborted:
        raise RuntimeError("elastic run aborted instead of recovering")
    if not res.reached_target:
        raise RuntimeError(
            f"target loss {cfg.target_loss} missed: final "
            f"{res.final_loss:.4f} at step {res.final_step}")
    if len(res.recoveries) != 1:
        raise RuntimeError(
            f"expected exactly 1 recovery, got {len(res.recoveries)}")
    rec = res.recoveries[0]
    if rec["devices_after"] >= rec["devices_before"]:
        raise RuntimeError(
            f"re-plan did not downsize: {rec['devices_before']} -> "
            f"{rec['devices_after']} devices")
    if not rec["audit_ok"]:
        raise RuntimeError("re-planned mesh did not pass the static audit")
    ratio = acct["replay_overhead_ratio"]
    if not (REPLAY_BAND[0] <= ratio <= REPLAY_BAND[1]):
        raise RuntimeError(
            f"replay overhead ratio {ratio:.4f} outside {REPLAY_BAND}")

    emit("elastic_smoke_recovery",
         acct["wall_s"] * 1e6,
         f"plans={'>'.join(res.plan_names)};kill={KILL_STEP};"
         f"restored={rec['restored_step']};"
         f"replayed={rec['replayed_steps']};"
         f"devices={rec['devices_before']}->{rec['devices_after']};"
         f"distilled={rec['distilled']};"
         f"replay_ratio={ratio:.4f};"
         f"final_loss={res.final_loss:.4f}@{res.final_step}",
         kind="elastic", arch=f"ffn{cfg.width}x{cfg.depth}",
         impl=res.plan_names[-1], p=0,
         measured={"final_loss": res.final_loss,
                   "steps": res.final_step, "wall_s": acct["wall_s"],
                   "replayed_steps": acct["replayed_steps"]},
         predicted={"energy_j_total": acct["energy_j_total"],
                    "energy_j_useful": acct["energy_j_useful"],
                    "energy_j_replay": acct["energy_j_replay"],
                    "energy_j_ckpt_io": acct["energy_j_ckpt_io"],
                    "energy_j_restart": acct["energy_j_restart"]},
         extra={"replay_band": list(REPLAY_BAND),
                "replay_overhead_ratio": ratio,
                "recovery_overhead_ratio":
                    acct["recovery_overhead_ratio"],
                "kill_step": KILL_STEP, "kill_host": KILL_HOST,
                "recovery": rec, "target_loss": cfg.target_loss})


if __name__ == "__main__":
    run()
