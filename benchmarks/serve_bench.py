"""Serving benchmark: replay one fixed trace through a tensor and a
phantom serve config on the 8-way mesh, stream SLO + energy rows into
the shared ledger, and exercise the router's decision.

Per config this produces joined ``serve_prefill_*`` / ``serve_decode_*``
ledger rows (measured = wall stats + compiled-HLO account priced by the
energy model; predicted = the calibrated per-step serve prediction) —
the measured/predicted ``energy_j_per_iter`` ratio is the serving
analogue of train_smoke's flops/wire ratios, and the serve-smoke CI job
fails if it leaves [0.5, 2.0].  A ``serve_bench_route`` row records
which config the router picked for the trace and why (predicted
joules-per-token table).

Raises (failing the suite) if the SLO report comes back empty, if a
request never finished, or if any energy ratio leaves the band.
"""
from __future__ import annotations

from benchmarks.common import emit, get_ledger

RATIO_BAND = (0.5, 2.0)
ARCH = "chatglm3-6b"


def run(devices: int = 8):
    from repro.planner import calibrate_from_rows, load_calibration
    from repro.planner.calibration import LEDGER_SOURCE
    from repro.serve.router import (ServeConfig, route, run_config,
                                    trace_stats)
    from repro.serve.traffic import make_trace

    ledger = get_ledger()
    # calibrate from whatever rows earlier suites left in this process'
    # ledger (comm_model when run together — the CI serve-smoke job
    # does) — same pattern as plan_smoke; standalone runs fall back to
    # the constants the last planning pass serialized.  The energy-ratio
    # band below assumes HOST-fitted collective constants: under the
    # paper's Frontier Table III the per-collective c1 spread is wide
    # enough that XLA's lowering choices (tiny gathers as all-reduces)
    # shift the latency-dominated smoke ratios out of band.
    calib = calibrate_from_rows([e.as_dict() for e in ledger.entries])
    if calib.source != LEDGER_SOURCE:
        import os
        plan_path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "PLAN_report.json")
        calib = load_calibration(plan_report_path=plan_path)
    print(f"# serve_bench calibration: {calib.source}")

    trace = make_trace("poisson", n=10, rate_rps=50.0,
                       prompt_len_range=(4, 40),
                       new_tokens_range=(3, 10), seed=0)
    slo_ms = 200.0

    configs = [
        ServeConfig(ARCH, "tensor", dp=2, tp=4, slots=4, max_len=64),
        # the paper's claim on the serving path: phantom on HALF the mesh
        ServeConfig(ARCH, "phantom", dp=1, tp=4, slots=4, max_len=64),
    ]

    winner, priced = route(configs, calib, trace, slo_ms=slo_ms)
    stats = trace_stats(trace)
    emit("serve_bench_route", 0.0,
         f"winner={winner.config.name};"
         f"j_per_token={winner.j_per_token:.3e};"
         f"calibration={calib.source}",
         kind="analytic", arch=ARCH, impl=winner.config.impl,
         p=winner.config.tp,
         predicted={"j_per_token": winner.j_per_token,
                    "ttft_s": winner.ttft_s, "tpot_s": winner.tpot_s},
         extra={"table": [pc.as_dict() for pc in priced],
                "trace": stats, "slo_ms": slo_ms})

    bad = []
    for sc in configs:
        res = run_config(sc, trace, ledger=ledger, calib=calib,
                         seed=0, slo_ms=slo_ms)
        slo = res["slo"]
        if not slo.get("requests"):
            raise RuntimeError(f"{sc.name}: EMPTY SLO report {slo}")
        if slo["requests"] != len(trace):
            raise RuntimeError(
                f"{sc.name}: {slo['requests']}/{len(trace)} requests "
                f"finished")
        ttft = slo["ttft_ms"].get("p95", 0.0)
        tpot = (slo.get("tpot_ms") or {}).get("p50", 0.0)
        ratios = res["energy_ratio"]
        emit(f"serve_bench_{sc.impl}",
             slo["ttft_ms"].get("p50", 0.0) * 1e3,
             f"cfg={sc.name};tokens={slo['generated_tokens']};"
             f"ttft_p95_ms={ttft:.2f};tpot_p50_ms={tpot:.2f};"
             f"ratio_dec={ratios.get('decode', 0):.3f}",
             kind="analytic", arch=ARCH, impl=sc.impl, p=sc.tp,
             measured={"j_per_token": res["j_per_token_measured"],
                       "decode_steps": res["decode_steps"],
                       "prefill_steps": res["prefill_steps"]},
             extra={"slo": slo, "pages": res["pages"],
                    "energy_ratio": ratios})
        for kind, r in ratios.items():
            if not (RATIO_BAND[0] <= r <= RATIO_BAND[1]):
                bad.append(f"{sc.name} {kind}: {r:.3f}")
    if bad:
        raise RuntimeError(
            "serve energy measured/predicted ratio outside "
            f"{list(RATIO_BAND)}: {bad}")


if __name__ == "__main__":
    run()
