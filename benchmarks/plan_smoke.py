"""Planner smoke: a full calibrated planning pass on the 8-way mesh.

Runs the energy-aware configuration planner end-to-end — calibration
from whatever ``BENCH_ledger.jsonl`` rows earlier suites produced in
this process' ledger (paper defaults otherwise), enumeration, pilot
training runs, iso-loss scoring, Pareto frontier — and writes
``PLAN_report.json`` at the repo root.  The frontier rows and every
pilot run stream through the shared benchmarks ``Ledger`` so they land
in ``BENCH_report.json`` next to the measurements that calibrated them.

Raises (failing the suite, and the CI plan-smoke job) if the frontier
comes back empty or the matched-loss comparison finds no phantom plan
on a smaller mesh undercutting the full-mesh tensor baseline.
"""
from __future__ import annotations

import os

from benchmarks.common import emit, get_ledger

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PLAN_PATH = os.path.join(ROOT, "PLAN_report.json")


def run(devices: int = 8):
    import repro.launch.plan as plan_cli

    args = plan_cli.build_parser().parse_args([
        "--devices", str(devices), "--target-loss", "0.25",
        "--width", "512", "--batch", "64", "--ks", "4,8,16",
        "--pilot-steps", "120", "--pilot-tp", "4", "--out", PLAN_PATH,
    ])
    # calibrate from THIS run's in-process rows (comm_model/train_smoke
    # when run together) — benchmarks.run truncates the JSONL stream at
    # startup, so reading the file back here would see only our own
    # partial write
    ledger = get_ledger()
    rows = [e.as_dict() for e in ledger.entries]
    report = plan_cli.plan(args, ledger=ledger, calib_rows=rows)

    frontier = report["frontier"]
    if not frontier:
        raise RuntimeError("planner produced an EMPTY Pareto frontier")
    if not any(e["plan"].get("pp", 1) > 1 for e in frontier):
        raise RuntimeError(
            "no pipeline-parallel (pp>1) candidate on the Pareto "
            f"frontier: {[e['plan']['name'] for e in frontier]}")
    comp = report.get("comparison") or {}
    best = report["winner"]
    emit("plan_smoke_frontier", 0.0,
         f"plans={len(frontier)};winner={best['plan']['name']};"
         f"winner_devices={best['plan']['devices']};"
         f"calibration={report['calibration']['source']}",
         kind="analytic", impl=best["plan"]["strategy"],
         p=best["plan"]["tp"],
         predicted={"energy_j_total": best["energy_j_total"],
                    "step_time_s": best["step_time_s"]},
         extra={"frontier_size": len(frontier),
                "calibration_source": report["calibration"]["source"]})
    emit("plan_smoke_verdict", 0.0,
         f"phantom_dominates={comp.get('phantom_dominates')};"
         f"saving={comp.get('energy_saving_vs_best_tensor', 0)*100:.0f}%",
         kind="analytic",
         extra={"comparison": {k: v for k, v in comp.items()
                               if not isinstance(v, dict)}})
    if not comp.get("phantom_dominates"):
        raise RuntimeError(
            "no phantom plan on a smaller mesh undercut the full-mesh "
            f"tensor baseline at matched loss: {comp}")


if __name__ == "__main__":
    run()
