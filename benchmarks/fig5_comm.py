"""Paper Fig. 5a: per-epoch communication cost, TP vs PP.

Two views, both recorded as ledger entries: (1) the paper's sizes
(n=65,536, L=6, k=64) through the fitted Eqn. 26 model with Table III
Frontier constants — the analytic reproduction (predicted-only rows);
(2) collective wire bytes parsed from the actually-lowered HLO of both
pipelines on the local mesh, joined against the strategy-predicted wire
bytes — proof the implementation emits the Table II schedule (AG/RS of
n/p*batch for TP vs k*batch for PP) with a measured/predicted ratio.
"""
from __future__ import annotations

from benchmarks.common import emit



def _projections(impl: str, k: int):
    """Explicit per-site strategy selection for the paper-FFN subject
    (the deprecated ffn_impl= shim is off-limits in-repo)."""
    from repro.configs.base import (dense_projection_map,
                                    phantom_projection_map)
    if impl == "phantom":
        return phantom_projection_map(k, ffn_layer=True)
    return dense_projection_map()

def run():
    from repro.configs.base import ModelConfig, PhantomConfig
    from repro.core.energy import comm_time_us
    from repro.launch.mesh import make_local_mesh
    from repro.parallel.axes import MeshAxes
    from repro.telemetry import measure_ffn_step

    # --- analytic at paper scale (Fig 5a: n=65536, L=6, k=64) ----------
    n, L, k, batch = 65_536, 6, 64, 64
    for p in (32, 64, 128):
        tp_us = L * (comm_time_us("all_gather", (n / p) * batch, p)
                     + comm_time_us("reduce_scatter", (n / p) * batch, p))
        pp_us = L * (comm_time_us("all_gather", k * batch, p)
                     + comm_time_us("reduce_scatter", k * batch, p))
        emit(f"fig5a_comm_tp_p{p}", tp_us, f"n={n};L={L}",
             kind="analytic", impl="tensor_col", p=p,
             predicted={"comm_us": tp_us},
             extra={"n": n, "L": L, "batch": batch})
        emit(f"fig5a_comm_pp_p{p}", pp_us,
             f"k={k};ratio={pp_us/tp_us:.4f}",
             kind="analytic", impl="phantom", p=p,
             predicted={"comm_us": pp_us},
             extra={"n": n, "L": L, "k": k, "pp_over_tp": pp_us / tp_us})

    # --- measured wire bytes from lowered HLO vs strategy prediction ----
    mesh = make_local_mesh(1, 8)
    p8 = MeshAxes.from_mesh(mesh).tp
    n_s, L_s, k_s, batch_s = 1024, 2, 8, 32
    for impl, strat in (("dense", "tensor_col"), ("phantom", "phantom")):
        cfg = ModelConfig(name=f"fig5a-{impl}", family="ffn",
                          num_layers=L_s, d_model=n_s, ffn_width=n_s,
                          ffn_depth=L_s, mlp="relu",
                          phantom=PhantomConfig(k=k_s),
                          projections=_projections(impl, k_s))
        measured, predicted = measure_ffn_step(cfg, mesh, batch_s)
        wire = measured["collective_wire_bytes_per_device"]
        ratio = wire / predicted["collective_wire_bytes_per_device"]
        per_op = ";".join(
            f"{op}={int(rec['wire_bytes'])}B"
            for op, rec in sorted(measured["collectives"].items()))
        emit(f"fig5a_hlo_wire_{impl}", 0.0,
             f"total={int(wire)}B;ratio={ratio:.4f};{per_op}",
             kind="train", arch=cfg.name, impl=strat, p=p8,
             measured=measured, predicted=predicted,
             extra={"n": n_s, "L": L_s, "k": k_s, "batch": batch_s})


if __name__ == "__main__":
    run()
