"""Paper Fig. 5a: per-epoch communication cost, TP vs PP.

Two views: (1) the paper's sizes (n=65,536, L=6, k=64) through the fitted
Eqn. 26 model with Table III Frontier constants — the analytic
reproduction; (2) collective wire bytes parsed from actually-lowered HLO
of both pipelines on the local mesh — proof the implementation emits the
Table II schedule (AG/RS of n/p*batch for TP vs k*batch for PP).
"""
from __future__ import annotations

from benchmarks.common import emit


def run():
    import jax
    import jax.numpy as jnp
    from repro.configs.base import ModelConfig, PhantomConfig
    from repro.core.energy import comm_time_us
    from repro.core.ffn import make_ffn_train_step, abstract_ffn
    from repro.launch.hlo_analysis import collective_bytes
    from repro.launch.mesh import make_local_mesh
    from repro.optim import SGD

    # --- analytic at paper scale (Fig 5a: n=65536, L=6, k=64) ----------
    n, L, k, batch = 65_536, 6, 64, 64
    for p in (32, 64, 128):
        tp_us = L * (comm_time_us("all_gather", (n / p) * batch, p)
                     + comm_time_us("reduce_scatter", (n / p) * batch, p))
        pp_us = L * (comm_time_us("all_gather", k * batch, p)
                     + comm_time_us("reduce_scatter", k * batch, p))
        emit(f"fig5a_comm_tp_p{p}", tp_us, f"n={n};L={L}")
        emit(f"fig5a_comm_pp_p{p}", pp_us,
             f"k={k};ratio={pp_us/tp_us:.4f}")

    # --- measured wire bytes from lowered HLO ---------------------------
    mesh = make_local_mesh(1, 8)
    n_s, L_s, k_s, batch_s = 1024, 2, 8, 32
    for impl in ("dense", "phantom"):
        cfg = ModelConfig(name="b", family="ffn", num_layers=L_s,
                          d_model=n_s, ffn_width=n_s, ffn_depth=L_s,
                          ffn_impl=impl, mlp="relu",
                          phantom=PhantomConfig(k=k_s))
        opt = SGD(0.1)
        step, decls, opt_decls = make_ffn_train_step(cfg, mesh, opt,
                                                     batch_s)
        params, opt_state = abstract_ffn(cfg, mesh, opt)
        x = jax.ShapeDtypeStruct((batch_s, n_s), jnp.float32)
        compiled = step.lower(params, opt_state,
                              jax.ShapeDtypeStruct((), jnp.int32),
                              x, x).compile()
        wire, breakdown = collective_bytes(compiled.as_text(),
                                           default_group=8)
        per_op = ";".join(f"{k_}={int(v['wire_bytes'])}B"
                          for k_, v in sorted(breakdown.items()))
        emit(f"fig5a_hlo_wire_{impl}", 0.0,
             f"total={int(wire)}B;{per_op}")


if __name__ == "__main__":
    run()
