"""Fleet benchmark: replay ONE seeded bursty trace twice — through the
disaggregated 1-prefill / up-to-2-decode phantom fleet, and through the
colocated single-engine tensor baseline (``baseline_config``: the
conventional fixed tensor-parallel deployment) — on the virtual clock.

Both replays stream rows into the shared ledger: the fleet run joins a
``fleet_transfer_*`` row whose measured KV-page wire bytes must match
the a-priori prediction (``transfer_wire_bytes`` ratio in [0.9, 1.1] —
the serving analogue of pipeline_smoke's stage-boundary band), plus
``fleet_summary_*`` / ``baseline_summary_*`` rows carrying end-to-end
joules-per-token.  The suite fails if the wire ratio leaves the band,
if the autoscaler never scales, or if disaggregation does not at least
match the baseline's fleet J/token on the bursty trace (the PR's
headline claim: elastic replicas + idle static power accounting beat
fixed provisioning).
"""
from __future__ import annotations

from benchmarks.common import emit, get_ledger

WIRE_BAND = (0.9, 1.1)
ARCH = "chatglm3-6b"
N_REQUESTS = 20_000
SLO_MS = 200.0


def run(devices: int = 8):
    from repro.planner import calibrate_from_rows, load_calibration
    from repro.planner.calibration import LEDGER_SOURCE
    from repro.serve.fleet import (AutoscalePolicy, FleetConfig,
                                   FleetRouter, auto_rate_rps,
                                   baseline_config)
    from repro.serve.router import ServeConfig, trace_stats
    from repro.serve.traffic import make_trace

    ledger = get_ledger()
    # same calibration fallback chain as serve_bench: rows left by
    # earlier suites in this process (comm_model when run together),
    # else the constants the last planning pass serialized
    calib = calibrate_from_rows([e.as_dict() for e in ledger.entries])
    if calib.source != LEDGER_SOURCE:
        import os
        plan_path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "PLAN_report.json")
        calib = load_calibration(plan_report_path=plan_path)
    print(f"# fleet_bench calibration: {calib.source}")

    # the fleet shape under test: phantom pools on the SMALL tp=2 mesh
    # (the per-token winner under ledger-fit calibration), one prefill
    # replica, decode elastic up to two replicas (replicas ARE the dp
    # axis — pool configs stay dp=1)
    pre_sc = ServeConfig(ARCH, "phantom", dp=1, tp=2, slots=4,
                         max_len=64)
    dec_sc = ServeConfig(ARCH, "phantom", dp=1, tp=2, slots=4,
                         max_len=64)
    base_sc = baseline_config(ARCH, devices)

    # size the arrival rate against ONE decode replica so the bursts
    # (8x base rate) overload the minimum fleet and force scale-ups;
    # 0.9 nominal utilization keeps the fleet's static-power idle bill
    # small enough that disaggregation wins on joules as well as SLO
    probe = make_trace("bursty", n=2000, rate_rps=10.0, seed=0)
    mean_new = trace_stats(probe)["mean_new_tokens"]
    rate = auto_rate_rps(dec_sc, calib, mean_new, replicas=1,
                         utilization=0.9)
    trace = make_trace("bursty", n=N_REQUESTS, rate_rps=rate, seed=0)
    print(f"# fleet_bench trace: bursty n={len(trace)} "
          f"rate={rate:.1f}rps mean_new={mean_new:.1f}")

    fleet_fc = FleetConfig(
        prefill=pre_sc, decode=dec_sc, slo_ms=SLO_MS,
        prefill_replicas=1, decode_replicas=1,
        prefill_policy=AutoscalePolicy(min_replicas=1, max_replicas=1),
        decode_policy=AutoscalePolicy(min_replicas=1, max_replicas=2))
    fleet = FleetRouter(fleet_fc, calib=calib,
                        ledger=ledger).run(trace)

    base_fc = FleetConfig(
        prefill=base_sc, decode=base_sc, slo_ms=SLO_MS,
        colocated=True, decode_replicas=1)
    base = FleetRouter(base_fc, calib=calib, ledger=ledger).run(trace)

    for tag, rep in (("fleet", fleet), ("baseline", base)):
        req = rep["requests"]
        if req["finished"] != req["trace"] - req["rejected"]:
            raise RuntimeError(
                f"{tag}: {req['finished']} finished of "
                f"{req['trace']} admitted ({req['rejected']} rejected)")

    ratio = fleet["transfer"]["ratio_wire_bytes"]
    fleet_j = fleet["j_per_token"]["fleet"]
    base_j = base["j_per_token"]["fleet"]
    emit("fleet_bench_compare", fleet_j * 1e6,
         f"fleet_j_per_token={fleet_j:.4f};"
         f"baseline_j_per_token={base_j:.4f};"
         f"wire_ratio={ratio:.4f};"
         f"scale_ups={fleet['scale_ups']};"
         f"scale_downs={fleet['scale_downs']};"
         f"calibration={calib.source}",
         kind="analytic", arch=ARCH,
         impl=f"{pre_sc.impl}-fleet-vs-{base_sc.impl}",
         p=dec_sc.tp,
         predicted={"j_per_token_fleet": fleet_j,
                    "j_per_token_baseline": base_j},
         extra={"fleet_slo_met": fleet["slo"]["slo_met_fraction"],
                "baseline_slo_met": base["slo"]["slo_met_fraction"],
                "wire_ratio": ratio,
                "decode_replicas_peak":
                    fleet["pools"]["decode"]["replicas_peak"]})

    if not (WIRE_BAND[0] <= ratio <= WIRE_BAND[1]):
        raise RuntimeError(
            f"KV transfer measured/predicted wire ratio {ratio:.4f} "
            f"outside {list(WIRE_BAND)}")
    if not (fleet["scale_ups"] >= 1 and fleet["scale_downs"] >= 1):
        raise RuntimeError(
            f"autoscaler never exercised: ups={fleet['scale_ups']} "
            f"downs={fleet['scale_downs']}")
    if fleet_j > base_j:
        raise RuntimeError(
            f"fleet J/token {fleet_j:.4f} worse than single-engine "
            f"baseline {base_j:.4f} on the bursty trace")
    print(f"# fleet {fleet_j:.4f} J/tok <= baseline {base_j:.4f} "
          f"J/tok; wire ratio {ratio:.4f}")


if __name__ == "__main__":
    run()
