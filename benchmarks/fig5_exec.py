"""Paper Fig. 5b/c: total execution time per epoch, TP vs PP, measured.

Paper shape: two-layer FFNs (n=4096 / 16384) over increasing GPU counts;
here: reduced widths on the 8-virtual-device CPU mesh (same code path the
dry-run proves at 512 devices).  PP should beat TP per epoch and the gap
should grow with n — the paper's qualitative claim.  Each row lands in
the ledger with its measured wall time plus the strategy-predicted
per-step account (flops/comm; wall time is not ratioed — CPU wall
against TPU-roofline seconds would be meaningless).
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, timeit



def _projections(impl: str, k: int):
    """Explicit per-site strategy selection for the paper-FFN subject
    (the deprecated ffn_impl= shim is off-limits in-repo)."""
    from repro.configs.base import (dense_projection_map,
                                    phantom_projection_map)
    if impl == "phantom":
        return phantom_projection_map(k, ffn_layer=True)
    return dense_projection_map()

def run():
    from repro.configs.base import ModelConfig, PhantomConfig
    from repro.core.ffn import init_ffn, make_ffn_train_step
    from repro.data.synthetic import TeacherDataset
    from repro.launch.mesh import make_local_mesh
    from repro.optim import SGD
    from repro.parallel.axes import MeshAxes
    from repro.telemetry import ffn_step_prediction

    mesh = make_local_mesh(1, 8)
    p = MeshAxes.from_mesh(mesh).tp
    batch = 32
    for n, k in ((1024, 3), (2048, 4), (4096, 4)):
        times = {}
        for impl, strat in (("dense", "tensor_col"),
                            ("phantom", "phantom")):
            cfg = ModelConfig(name=f"fig5bc-{impl}", family="ffn",
                              num_layers=2, d_model=n, ffn_width=n,
                              ffn_depth=2, mlp="relu",
                              phantom=PhantomConfig(k=k),
                              projections=_projections(impl, k))
            opt = SGD(0.05)
            step, decls, _ = make_ffn_train_step(cfg, mesh, opt, batch)
            params, opt_state = init_ffn(cfg, mesh, opt)
            ds = TeacherDataset(n, batch)
            x, y = ds(0)

            def once(p_, o, xx, yy):
                return step(p_, o, jnp.int32(0), xx, yy)

            us = timeit(once, params, opt_state, x, y, warmup=2, iters=5)
            times[impl] = us
            emit(f"fig5bc_{impl}_n{n}", us, f"k={k};p={p}",
                 kind="train", arch=cfg.name, impl=strat, p=p,
                 measured={"wall_us_median": us},
                 predicted=ffn_step_prediction(cfg, p, batch),
                 extra={"n": n, "k": k, "batch": batch})
        emit(f"fig5bc_speedup_n{n}", 0.0,
             f"pp_over_tp={times['dense']/times['phantom']:.2f}x",
             kind="derived", p=p,
             extra={"pp_over_tp": times["dense"] / times["phantom"],
                    "n": n})


if __name__ == "__main__":
    run()
