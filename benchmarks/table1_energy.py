"""Paper Table I / Fig. 7: energy to train TP vs PP FFNs to the SAME
fixed loss.

Real mini-reproduction on the local mesh: both models train on the
paper's Gaussian-teacher dataset until loss <= target; we record
iteration counts and model sizes (the paper's key observation: the PP
model is smaller AND needs fewer iterations), then compute energy with
the paper's model E = nu * p * (A*alpha + B*beta) using Frontier's
A=560W / B=90W and the Table III comm fits at the paper's scale.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit



def _projections(impl: str, k: int):
    """Explicit per-site strategy selection for the paper-FFN subject
    (the deprecated ffn_impl= shim is off-limits in-repo)."""
    from repro.configs.base import (dense_projection_map,
                                    phantom_projection_map)
    if impl == "phantom":
        return phantom_projection_map(k, ffn_layer=True)
    return dense_projection_map()

def run():
    from repro.configs.base import ModelConfig, PhantomConfig
    from repro.core.energy import (FRONTIER_A_W, FRONTIER_B_W,
                                   TPU_PEAK_FLOPS, energy_to_loss,
                                   phantom_costs, tp_costs)
    from repro.core.ffn import (ffn_model_params, init_ffn,
                                make_ffn_train_step)
    from repro.data.synthetic import TeacherDataset
    from repro.launch.mesh import make_local_mesh
    from repro.optim import AdamW

    # n=1024 is the smallest width where the paper's Table-I regime
    # reproduces on CPU (PP reaches the fixed loss in FEWER iterations
    # than TP; below ~n=512 the phantom class is too constrained and the
    # ordering flips — noted in EXPERIMENTS.md).
    mesh = make_local_mesh(1, 8)
    n, L, batch = 1024, 2, 64
    target = 0.175
    max_iters = 500
    ds = TeacherDataset(n, batch)

    def train_to_target(cfg):
        opt = AdamW(3e-3, weight_decay=0.0)
        step, decls, _ = make_ffn_train_step(cfg, mesh, opt, batch)
        params, opt_state = init_ffn(cfg, mesh, opt)
        for s in range(max_iters):
            x, y = ds(s)
            params, opt_state, loss = step(params, opt_state,
                                           jnp.int32(s), x, y)
            if float(loss) <= target:
                return s + 1
        return max_iters

    rows = []
    tp_cfg = ModelConfig(name="tp", family="ffn", num_layers=L,
                         d_model=n, ffn_width=n, ffn_depth=L, mlp="relu",
                         phantom=PhantomConfig(k=4),
                         projections=_projections("dense", 4))
    nu_tp = train_to_target(tp_cfg)
    for k in (4, 8, 16):
        pp_cfg = tp_cfg.replace(phantom=PhantomConfig(k=k),
                                projections=_projections("phantom", k))
        nu_pp = train_to_target(pp_cfg)
        rows.append((k, nu_pp, ffn_model_params(pp_cfg, 8)))

    size_tp = ffn_model_params(tp_cfg, 8)
    emit("table1_tp_iters", 0.0,
         f"iters={nu_tp};params={size_tp};loss<={target}",
         kind="train", arch=tp_cfg.name, impl="tensor_col", p=8,
         measured={"iterations": nu_tp, "param_count": size_tp},
         extra={"n": n, "L": L, "target_loss": target})
    for k, nu_pp, size_pp in rows:
        emit(f"table1_pp_k{k}_iters", 0.0,
             f"iters={nu_pp};params={size_pp};"
             f"size_ratio={size_pp/size_tp:.3f}",
             kind="train", arch=f"pp-k{k}", impl="phantom", p=8,
             measured={"iterations": nu_pp, "param_count": size_pp},
             extra={"n": n, "L": L, "k": k, "target_loss": target,
                    "size_ratio_vs_tp": size_pp / size_tp})

    # paper-scale energy model (n=16384, L=2, Table I geometry)
    n_p, L_p, batch_p = 16_384, 2, 64
    for p, k in [(8, 16), (16, 6), (32, 4), (64, 2), (128, 2), (256, 4)]:
        a_t, b_t = tp_costs(n_p, p, L_p, batch_p, TPU_PEAK_FLOPS)
        a_p, b_p = phantom_costs(n_p, p, L_p, k, batch_p, TPU_PEAK_FLOPS)
        # iterations scale with the measured small-scale ratio (PP trains
        # in fewer iterations because the model is smaller — paper
        # Table I; reproduced by the measured runs above)
        nu_ratio = min(rows[0][1] / max(nu_tp, 1), 1.0)
        E_tp = energy_to_loss(a_t, b_t, p, 453, FRONTIER_A_W,
                              FRONTIER_B_W)
        E_pp = energy_to_loss(a_p, b_p, p, int(453 * nu_ratio),
                              FRONTIER_A_W, FRONTIER_B_W)
        emit(f"table1_energy_p{p}", 0.0,
             f"E_tp={E_tp:.0f}J;E_pp={E_pp:.0f}J;"
             f"saving={(1-E_pp/E_tp)*100:.0f}%",
             kind="analytic", p=p,
             predicted={"energy_j_tp": E_tp, "energy_j_pp": E_pp,
                        "saving_fraction": 1 - E_pp / E_tp,
                        "alpha_s_tp": a_t, "beta_s_tp": b_t,
                        "alpha_s_pp": a_p, "beta_s_pp": b_p},
             extra={"n": n_p, "L": L_p, "k": k,
                    "iters_ratio_measured": nu_ratio})


if __name__ == "__main__":
    run()
