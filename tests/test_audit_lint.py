"""Repo-idiom AST lint fixtures + deprecation-shim warning pins.

The lint half plants each violation in a temp source tree and asserts
``lint_sources`` reports it (and that the REAL repo tree is clean —
that's the migration satellite's acceptance).  The deprecation half
pins that the legacy shims still warn for external callers while the
shipped configs stay silent.
"""
import warnings

import pytest

from repro.analysis.lint import lint_file, lint_sources


def _write(root, rel, text):
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(text)
    return p


def _by_rule(findings):
    out = {}
    for f in findings:
        out.setdefault(f.rule, []).append(f)
    return out


def test_each_lint_rule_fires(tmp_path):
    _write(tmp_path, "src/repro/bad_shard.py",
           "from jax.experimental.shard_map import shard_map\n")
    _write(tmp_path, "src/repro/bad_shim.py",
           "cfg = make(ffn_impl='phantom')\n"
           "c = pp_costs(1, 2)\n")
    _write(tmp_path, "src/repro/bad_rng.py",
           "import numpy as np\n"
           "x = np.random.rand(4)\n"
           "g = np.random.default_rng()\n")
    _write(tmp_path, "benchmarks/bad_bench.py",
           "def run(out_dir, smoke=True):\n    return []\n")
    found = _by_rule(lint_sources(str(tmp_path)))
    assert len(found["raw-shard-map"]) == 1
    assert {f.key for f in found["deprecated-shim"]} == {"kw:ffn_impl",
                                                         "pp_costs"}
    assert {f.key for f in found["unseeded-prng"]} == {"rand",
                                                       "default_rng"}
    assert len(found["ledger-missing"]) == 1
    # every finding names file:line and carries a stable fingerprint
    for fs in found.values():
        for f in fs:
            assert f.unit in f.message and ":" in f.message
            assert f.fingerprint.startswith(f.rule + ":")


def test_lint_allows_compat_shim_and_seeded_rng(tmp_path):
    _write(tmp_path, "src/repro/parallel/compat.py",
           "from jax.experimental.shard_map import shard_map\n")
    _write(tmp_path, "src/repro/good_rng.py",
           "import numpy as np\n"
           "g = np.random.default_rng(0)\n")
    _write(tmp_path, "benchmarks/good_bench.py",
           "from benchmarks.common import emit\n"
           "def run(out_dir, smoke=True):\n    emit({})\n    return []\n")
    _write(tmp_path, "benchmarks/common.py",   # helper, not a suite
           "def run(out_dir):\n    return []\n")
    assert lint_sources(str(tmp_path)) == []


def test_unparseable_file_is_an_error(tmp_path):
    p = _write(tmp_path, "src/repro/broken.py", "def f(:\n")
    fs = lint_file(str(p), "src/repro/broken.py")
    assert len(fs) == 1 and fs[0].severity == "error"
    assert fs[0].key == "syntax"


def test_repo_tree_is_lint_clean():
    """The migration satellite's acceptance: no in-repo caller touches
    the deprecated shims, raw shard_map, or unseeded RNGs."""
    import os
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    errors = [f for f in lint_sources(root) if f.severity == "error"]
    assert errors == [], "\n".join(f.message for f in errors)


# ---------------------------------------------------------------------------
# deprecation pins: the shims must keep warning for external callers
# ---------------------------------------------------------------------------

def test_legacy_projection_shim_warns_once_per_resolution():
    from repro.configs.base import (PhantomConfig, ProjectionMap,
                                    get_config)
    legacy = get_config("paper-ffn-4k", smoke=True).replace(
        ffn_impl="phantom", phantom=PhantomConfig(k=4),
        projections=ProjectionMap())
    with pytest.warns(DeprecationWarning, match="ffn_impl|apply_"):
        spec = legacy.projection_spec("ffn_layer")
    assert spec.kind == "phantom"


def test_pp_costs_shim_warns():
    from repro.core.energy import pp_costs
    with pytest.warns(DeprecationWarning):
        pp_costs(64, 4, 2, 4, 8, 1e12)


def test_shipped_configs_emit_no_deprecation_warnings():
    """Every registered arch resolves every projection site through its
    explicit ProjectionMap — the legacy shim path must stay cold."""
    from repro.configs.base import (PROJECTION_SITES, _MODULES,
                                    get_config)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        for arch in sorted(_MODULES):
            for smoke in (False, True):
                cfg = get_config(arch, smoke=smoke)
                for site in PROJECTION_SITES:
                    cfg.projection_spec(site)
