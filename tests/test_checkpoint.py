"""Checkpointing: bitwise roundtrip, truly-async lifecycle (non-blocking
save, flush-on-exit, torn-write atomicity, the latest-is-always-complete
invariant), corrupt fallback, stale-timeline truncation, and ELASTIC
restore onto a different mesh."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, get_config
from repro.launch.specs import input_specs
from repro.optim import make_optimizer
from repro.parallel.axes import MeshAxes
from repro.parallel.params import materialize
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import make_train_step
from helpers import make_batch


def _setup(mesh, tmp, arch="stablelm-3b"):
    cfg = get_config(arch, smoke=True)
    axes = MeshAxes.from_mesh(mesh)
    _, spec = input_specs(cfg, ShapeConfig("s", 64, 8, "train"), axes)
    opt = make_optimizer("adamw", 1e-3)
    step_fn, decls, opt_decls = make_train_step(cfg, mesh, opt,
                                                batch_spec=spec)
    params = materialize(decls, 0)
    return cfg, opt, step_fn, decls, opt_decls, params


def _tiny_tree(scale=1.0):
    return {"layers": {"w": np.full((2, 4, 4), scale, np.float32),
                       "b": np.zeros((2, 4), np.float32)}}


# ---------------------------------------------------------------------------
# async lifecycle (host-tree only: no mesh needed)
# ---------------------------------------------------------------------------

def test_save_async_nonblocking(tmp_path, monkeypatch):
    """save_async returns while the write is still in flight; flush
    joins it and the checkpoint is then complete."""
    mgr = CheckpointManager(str(tmp_path), keep=2)
    gate = threading.Event()
    orig = mgr._write

    def slow_write(step, host, meta):
        gate.wait(timeout=10.0)
        orig(step, host, meta)

    monkeypatch.setattr(mgr, "_write", slow_write)
    t0 = time.perf_counter()
    mgr.save_async(1, _tiny_tree(), {})
    assert time.perf_counter() - t0 < 1.0      # did not wait on the gate
    assert mgr.available_steps() == []         # write still gated
    gate.set()
    mgr.flush()
    assert mgr.available_steps() == [1]
    assert mgr.latest_step() == 1


def test_flush_raises_worker_error(tmp_path, monkeypatch):
    """Write failures surface at flush(), not silently in the worker."""
    mgr = CheckpointManager(str(tmp_path))

    def boom(step, host, meta):
        raise IOError("disk on fire")

    monkeypatch.setattr(mgr, "_write", boom)
    mgr.save_async(1, _tiny_tree(), {})
    with pytest.raises(IOError, match="disk on fire"):
        mgr.flush()
    # errors are consumed: a later healthy flush is clean
    mgr.flush()


def test_torn_write_leaves_latest_complete(tmp_path):
    """A crash mid-save leaves a .tmp orphan and an untouched `latest`;
    the next manager sweeps the orphan."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tiny_tree(), {})
    # simulate a torn step-2 write: partial dir, no COMMITTED marker
    torn = os.path.join(str(tmp_path), "step_0000000002.tmp")
    os.makedirs(torn)
    with open(os.path.join(torn, "leaf_00000.npy"), "wb") as f:
        f.write(b"partial")
    assert mgr.latest_step() == 1              # invariant holds
    mgr2 = CheckpointManager(str(tmp_path))    # fresh process
    assert not os.path.exists(torn)            # orphan swept
    assert mgr2.latest_step() == 1
    assert mgr2.available_steps() == [1]


def test_latest_pointer_repair(tmp_path):
    """A `latest` pointer naming a missing checkpoint (e.g. GC'd by an
    older buggy manager) is repaired to the newest complete one."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tiny_tree(), {})
    with open(os.path.join(str(tmp_path), "latest"), "w") as f:
        f.write("99")
    mgr2 = CheckpointManager(str(tmp_path))
    assert mgr2.latest_step() == 1


def test_invalidate_after_truncates_stale_timeline(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=10)
    for s in (1, 2, 3):
        mgr.save(s, _tiny_tree(float(s)), {})
    mgr.invalidate_after(1)
    assert mgr.available_steps() == [1]
    assert mgr.latest_step() == 1
    _, flat = mgr.load_host(1)
    np.testing.assert_array_equal(flat["params/layers/w"],
                                  np.full((2, 4, 4), 1.0, np.float32))


def test_meta_roundtrip(tmp_path):
    """The caller's meta block (the elastic runtime stores the executing
    plan) survives the roundtrip."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _tiny_tree(), {}, meta={"plan": {"name": "t", "tp": 2}})
    assert mgr.meta(5) == {"plan": {"name": "t", "tp": 2}}
    index, _ = mgr.load_host(5)
    assert index["meta"]["plan"]["tp"] == 2


def test_gc_respects_keep_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tiny_tree(), {})
    assert mgr.available_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_context_manager_flushes(tmp_path):
    with CheckpointManager(str(tmp_path)) as mgr:
        mgr.save_async(1, _tiny_tree(), {})
    assert mgr.available_steps() == [1]


def test_io_stats_accumulate(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.io_stats() == {"io_seconds": 0.0, "io_bytes": 0, "saves": 0}
    mgr.save(1, _tiny_tree(), {})
    st = mgr.io_stats()
    assert st["saves"] == 1
    assert st["io_bytes"] >= _tiny_tree()["layers"]["w"].nbytes
    assert st["io_seconds"] > 0


# ---------------------------------------------------------------------------
# sharded roundtrips (mesh-placed state)
# ---------------------------------------------------------------------------

def test_roundtrip_bitwise(mesh24, tmp_path):
    cfg, opt, step_fn, decls, opt_decls, params = _setup(mesh24, tmp_path)
    opt_state = opt.init(params)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(7, params, opt_state)
    state = mgr.restore(7, decls, opt_decls, mesh24)
    assert state.step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(opt_state),
                    jax.tree.leaves(state.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_other_mesh(mesh24, mesh14, tmp_path):
    """save on (data=2, model=4), restore on (data=1, model=4) — the
    elastic rescale a pod loss forces.  dp changes, tp stays (the phantom
    model class is tp-dependent, DESIGN.md §4): global arrays reshard to
    the new mesh and training continues with identical math."""
    cfg, opt, step24, decls, opt_decls, params = _setup(mesh24, tmp_path)
    opt_state = opt.init(params)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, params, opt_state)

    from repro.launch.specs import input_specs as isp
    axes14 = MeshAxes.from_mesh(mesh14)
    _, spec14 = isp(cfg, ShapeConfig("s", 64, 8, "train"), axes14)
    step14, decls14, opt_decls14 = make_train_step(
        cfg, mesh14, opt, batch_spec=spec14)
    state = mgr.restore(3, decls14, opt_decls14, mesh14)

    batch = make_batch(cfg, 8, 64)
    p24, o24, m24 = step24(params, opt_state, jnp.int32(3), batch)
    p14, o14, m14 = step14(state.params, state.opt_state, jnp.int32(3),
                           batch)
    # same math on both meshes (global batch fixed; per-device batch 2x)
    np.testing.assert_allclose(float(m24["loss"]), float(m14["loss"]),
                               rtol=1e-5)


def test_corrupt_checkpoint_fallback(mesh24, tmp_path):
    cfg, opt, step_fn, decls, opt_decls, params = _setup(mesh24, tmp_path)
    opt_state = opt.init(params)
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, params, opt_state)
    mgr.save(2, params, opt_state)
    # corrupt the newer one (simulates post-commit disk damage)
    step2 = os.path.join(str(tmp_path), "step_0000000002")
    for f in os.listdir(step2):
        if f.startswith("leaf_00000"):
            with open(os.path.join(step2, f), "wb") as fh:
                fh.write(b"garbage")
            break
    state = mgr.restore_latest(decls, opt_decls, mesh24)
    assert state is not None and state.step == 1


def test_resume_equals_uninterrupted(mesh24, tmp_path):
    """train 4 steps straight == train 2, checkpoint, restore, train 2."""
    cfg, opt, step_fn, decls, opt_decls, params = _setup(mesh24, tmp_path)
    opt_state = opt.init(params)

    pA, oA = params, opt_state
    for s in range(4):
        pA, oA, mA = step_fn(pA, oA, jnp.int32(s), make_batch(cfg, 8, 64,
                                                              seed=s))

    pB, oB = materialize(decls, 0), opt.init(materialize(decls, 0))
    for s in range(2):
        pB, oB, _ = step_fn(pB, oB, jnp.int32(s), make_batch(cfg, 8, 64,
                                                             seed=s))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, pB, oB)
    st = mgr.restore(2, decls, opt_decls, mesh24)
    pB, oB = st.params, st.opt_state
    for s in range(2, 4):
        pB, oB, mB = step_fn(pB, oB, jnp.int32(s), make_batch(cfg, 8, 64,
                                                              seed=s))
    np.testing.assert_allclose(float(mA["loss"]), float(mB["loss"]),
                               rtol=1e-6)
