"""Checkpointing: bitwise roundtrip, async atomicity, corrupt fallback,
ELASTIC restore onto a different mesh."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, get_config
from repro.launch.specs import input_specs
from repro.optim import make_optimizer
from repro.parallel.axes import MeshAxes
from repro.parallel.params import materialize
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import make_train_step
from helpers import make_batch


def _setup(mesh, tmp, arch="stablelm-3b"):
    cfg = get_config(arch, smoke=True)
    axes = MeshAxes.from_mesh(mesh)
    _, spec = input_specs(cfg, ShapeConfig("s", 64, 8, "train"), axes)
    opt = make_optimizer("adamw", 1e-3)
    step_fn, decls, opt_decls = make_train_step(cfg, mesh, opt,
                                                batch_spec=spec)
    params = materialize(decls, 0)
    return cfg, opt, step_fn, decls, opt_decls, params


def test_roundtrip_bitwise(mesh24, tmp_path):
    cfg, opt, step_fn, decls, opt_decls, params = _setup(mesh24, tmp_path)
    opt_state = opt.init(params)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(7, params, opt_state)
    state = mgr.restore(7, decls, opt_decls, mesh24)
    assert state.step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(opt_state),
                    jax.tree.leaves(state.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_other_mesh(mesh24, mesh14, tmp_path):
    """save on (data=2, model=4), restore on (data=1, model=4) — the
    elastic rescale a pod loss forces.  dp changes, tp stays (the phantom
    model class is tp-dependent, DESIGN.md §4): global arrays reshard to
    the new mesh and training continues with identical math."""
    cfg, opt, step24, decls, opt_decls, params = _setup(mesh24, tmp_path)
    opt_state = opt.init(params)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, params, opt_state)

    from repro.launch.specs import input_specs as isp
    axes14 = MeshAxes.from_mesh(mesh14)
    _, spec14 = isp(cfg, ShapeConfig("s", 64, 8, "train"), axes14)
    step14, decls14, opt_decls14 = make_train_step(
        cfg, mesh14, opt, batch_spec=spec14)
    state = mgr.restore(3, decls14, opt_decls14, mesh14)

    batch = make_batch(cfg, 8, 64)
    p24, o24, m24 = step24(params, opt_state, jnp.int32(3), batch)
    p14, o14, m14 = step14(state.params, state.opt_state, jnp.int32(3),
                           batch)
    # same math on both meshes (global batch fixed; per-device batch 2x)
    np.testing.assert_allclose(float(m24["loss"]), float(m14["loss"]),
                               rtol=1e-5)


def test_corrupt_checkpoint_fallback(mesh24, tmp_path):
    cfg, opt, step_fn, decls, opt_decls, params = _setup(mesh24, tmp_path)
    opt_state = opt.init(params)
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, params, opt_state)
    mgr.save(2, params, opt_state)
    # corrupt the newer one (simulates a crash mid-write)
    step2 = os.path.join(str(tmp_path), "step_0000000002")
    for f in os.listdir(step2):
        if f.startswith("leaf_00000"):
            with open(os.path.join(step2, f), "wb") as fh:
                fh.write(b"garbage")
            break
    state = mgr.restore_latest(decls, opt_decls, mesh24)
    assert state is not None and state.step == 1


def test_gc_keeps_latest(mesh24, tmp_path):
    cfg, opt, step_fn, decls, opt_decls, params = _setup(mesh24, tmp_path)
    opt_state = opt.init(params)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, params, opt_state)
    assert mgr.available_steps() == [3, 4]


def test_resume_equals_uninterrupted(mesh24, tmp_path):
    """train 4 steps straight == train 2, checkpoint, restore, train 2."""
    cfg, opt, step_fn, decls, opt_decls, params = _setup(mesh24, tmp_path)
    opt_state = opt.init(params)

    pA, oA = params, opt_state
    for s in range(4):
        pA, oA, mA = step_fn(pA, oA, jnp.int32(s), make_batch(cfg, 8, 64,
                                                              seed=s))

    pB, oB = materialize(decls, 0), opt.init(materialize(decls, 0))
    for s in range(2):
        pB, oB, _ = step_fn(pB, oB, jnp.int32(s), make_batch(cfg, 8, 64,
                                                             seed=s))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, pB, oB)
    st = mgr.restore(2, decls, opt_decls, mesh24)
    pB, oB = st.params, st.opt_state
    for s in range(2, 4):
        pB, oB, mB = step_fn(pB, oB, jnp.int32(s), make_batch(cfg, 8, 64,
                                                              seed=s))
    np.testing.assert_allclose(float(mA["loss"]), float(mB["loss"]),
                               rtol=1e-6)
