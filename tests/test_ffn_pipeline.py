"""End-to-end paper-FFN pipelines: TP exactness vs single-device dense,
PP trains to a fixed loss, variants produce identical trajectories, and
the energy-model inequalities hold at the paper's operating points."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, PhantomConfig
from repro.core.ffn import (ffn_model_params, init_ffn, make_ffn_forward,
                            make_ffn_train_step)
from repro.data.synthetic import TeacherDataset, gaussian_teacher
from repro.optim import SGD
from repro.parallel.compat import shard_map


def _cfg(impl, n=64, L=2, k=4, variant="fused"):
    return ModelConfig(name=f"t-{impl}-{variant}", family="ffn",
                       num_layers=L, d_model=n,
                       ffn_width=n, ffn_depth=L, ffn_impl=impl, mlp="relu",
                       phantom=PhantomConfig(k=k, variant=variant))


def _build_step(cfg, mesh, batch):
    """Session-cache maker: one compile per (cfg, mesh, batch) — the
    trains-to-loss and identical-trajectory tests share the SGD(0.3)
    step instead of re-jitting it per case."""
    opt = SGD(0.3)
    step_fn, decls, _ = make_ffn_train_step(cfg, mesh, opt, batch)
    return step_fn, decls, opt


def test_tp_matches_single_device_dense(mesh24):
    """The TP pipeline is an exact reparametrization: forward must equal
    the unsharded dense stack bit-for-bit (up to fp32 reduction order)."""
    cfg = _cfg("dense")
    fwd, decls = make_ffn_forward(cfg, mesh24)
    from repro.parallel.params import materialize
    params = materialize(decls, 1)
    x = jax.random.normal(jax.random.key(0), (8, cfg.ffn_width))
    out = fwd(params, x)
    ref = x
    for l in range(cfg.num_layers):
        ref = jax.nn.relu(ref @ params["layers"]["w"][l]
                          + params["layers"]["b"][l])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("impl,variant", [("dense", "fused"),
                                          ("phantom", "fused"),
                                          ("phantom", "faithful"),
                                          ("phantom", "ring")])
def test_pipeline_trains_to_loss(mesh24, compiled_step_cache, impl,
                                 variant):
    cfg = _cfg(impl, variant=variant)
    step_fn, decls, opt = compiled_step_cache.build(_build_step, cfg,
                                                    mesh24, 16)
    params, opt_state = init_ffn(cfg, mesh24, opt)
    ds = TeacherDataset(cfg.ffn_width, 16)
    first = last = None
    for s in range(60):
        x, y = ds(s)
        params, opt_state, loss = step_fn(params, opt_state, jnp.int32(s),
                                          x, y)
        if first is None:
            first = float(loss)
        last = float(loss)
    assert last < 0.7 * first, (impl, variant, first, last)


def test_variants_identical_training(mesh24, compiled_step_cache):
    """faithful / fused / ring are the SAME model: identical losses.
    (Steps come from the session cache — the fused/faithful/ring compiles
    are shared with test_pipeline_trains_to_loss.)"""
    traces = {}
    for variant in ("faithful", "fused", "ring"):
        cfg = _cfg("phantom", variant=variant)
        step_fn, decls, opt = compiled_step_cache.build(_build_step, cfg,
                                                        mesh24, 16)
        params, opt_state = init_ffn(cfg, mesh24, opt)
        ds = TeacherDataset(cfg.ffn_width, 16)
        losses = []
        for s in range(10):
            x, y = ds(s)
            params, opt_state, loss = step_fn(params, opt_state,
                                              jnp.int32(s), x, y)
            losses.append(float(loss))
        traces[variant] = losses
    np.testing.assert_allclose(traces["faithful"], traces["fused"],
                               rtol=1e-4)
    np.testing.assert_allclose(traces["faithful"], traces["ring"],
                               rtol=1e-4)


def test_pp_model_smaller_and_energy_lower():
    """Paper Table I structure: phantom model smaller; per-iteration
    energy lower at the paper's operating points."""
    from repro.core.energy import (energy_per_iteration, phantom_costs,
                                   tp_costs, TPU_PEAK_FLOPS)
    n, L, batch = 16_384, 2, 64
    for p, k in [(8, 16), (16, 6), (32, 4), (64, 2), (128, 2), (256, 4)]:
        pp_params = ffn_model_params(_cfg("phantom", n=n, L=L, k=k), p)
        tp_params = ffn_model_params(_cfg("dense", n=n, L=L), p)
        assert pp_params < tp_params
        a_t, b_t = tp_costs(n, p, L, batch, TPU_PEAK_FLOPS)
        a_p, b_p = phantom_costs(n, p, L, k, batch, TPU_PEAK_FLOPS)
        assert a_p < a_t and b_p < b_t
        assert (energy_per_iteration(a_p, b_p, p)
                < energy_per_iteration(a_t, b_t, p))


def test_compressed_dp_training_converges(mesh24):
    """Beyond-paper: phantom-style gradient compression on the dp axis
    still trains the paper's FFN (error feedback)."""
    from repro.optim.compress import compressed_dp_psum, init_compress_state
    from repro.parallel.axes import MeshAxes, resolve_spec
    from repro.parallel.params import materialize, specs
    from repro.core.ffn import ffn_decls, ffn_apply
    from jax.sharding import PartitionSpec as P

    cfg = _cfg("phantom")
    axes = MeshAxes.from_mesh(mesh24)
    decls = ffn_decls(cfg, axes)
    params = materialize(decls, 0)
    q_state, err_state = init_compress_state(params, rank=2)

    pspecs = jax.tree.map(lambda s: resolve_spec(s, axes), specs(decls))
    qspecs = jax.tree.map(lambda qq: P(*((None,) * qq.ndim)), q_state)
    especs = jax.tree.map(lambda ee: P(*((None,) * ee.ndim)), err_state)
    bspec = resolve_spec(P("dp", "tp"), axes)

    def step(p, q, e, x, y):
        def loss_fn(pp):
            out = ffn_apply(cfg, axes, pp, x)
            return jnp.sum((out - y) ** 2) / (16 * cfg.ffn_width)
        l, g = jax.value_and_grad(loss_fn)(p)
        # NOTE: q/err for tp-sharded params are per-shard (fine: the
        # compression operates shard-locally, reducing over dp only)
        g, q, e = compressed_dp_psum(g, q, e, axes, rank=2)
        p = jax.tree.map(lambda w, gw: w - 0.3 * gw, p, g)
        return p, q, e, jax.lax.psum(l, axes.all_names)

    fn = jax.jit(shard_map(
        step, mesh=mesh24,
        in_specs=(pspecs, qspecs, especs, bspec, bspec),
        out_specs=(pspecs, qspecs, especs, P()), check_vma=False))

    ds = TeacherDataset(cfg.ffn_width, 16)
    first = last = None
    for s in range(60):
        x, y = ds(s)
        params, q_state, err_state, loss = fn(params, q_state, err_state,
                                              x, y)
        if first is None:
            first = float(loss)
        last = float(loss)
    assert last < 0.8 * first, (first, last)
