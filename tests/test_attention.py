"""Attention correctness across sharding modes: head / ring / decode-LSE
must all equal the dense flash reference built from the same (global)
weights."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, PhantomConfig
from repro.models import attention as A
from repro.models.rope import apply_rope, rope_for
from repro.parallel.axes import MeshAxes
from repro.parallel.params import materialize
from repro.kernels.ref import flash_attention_ref
from helpers import allclose, rand, resolved_param_specs, smap


def _cfg(H, kv, d, mode="head", rope="none", layout_phantom=False,
         qkv_bias=False):
    return ModelConfig(
        name="t", family="dense", num_layers=1, d_model=d, num_heads=H,
        num_kv_heads=kv, d_ff=d, vocab_size=128, attn_shard=mode,
        rope=rope, qkv_bias=qkv_bias, dtype="float32",
        phantom=PhantomConfig(k=2, apply_ffn=False,
                              apply_attn_proj=layout_phantom))


def _ref_attention(cfg, params_global, x, positions, causal=True):
    """Dense reference from GLOBAL weights."""
    H, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim()
    B, S, d = x.shape
    q = (x @ params_global["wq"]["w"]).reshape(B, S, H, hd)
    k = (x @ params_global["wk"]["w"]).reshape(B, S, kv, hd)
    v = (x @ params_global["wv"]["w"]).reshape(B, S, kv, hd)
    if "b" in params_global["wq"]:
        q = q + params_global["wq"]["b"].reshape(H, hd)
        k = k + params_global["wk"]["b"].reshape(kv, hd)
        v = v + params_global["wv"]["b"].reshape(kv, hd)
    if cfg.rope != "none":
        q = rope_for(cfg, q, positions)
        k = rope_for(cfg, k, positions)
    o = flash_attention_ref(q, k, v, causal=causal)
    return o.reshape(B, S, H * hd) @ params_global["wo"]["w"]


def _run_mode(mesh, cfg, params, x, positions, layout="rep"):
    axes = MeshAxes.from_mesh(mesh)
    decls = A.attn_decls(cfg, axes)
    pspecs = resolved_param_specs(decls, mesh)
    xspec = {"rep": P("data", None, None),
             "sp": P("data", "model", None),
             "fp": P("data", None, "model")}[layout]

    def f(p, xx, pp):
        out, _ = A.attention(cfg, layout, p, xx, pp, axes, decls,
                             kind="train", causal=True)
        if layout == "rep":
            out = jax.lax.psum(out, "model") * 0 + out  # already psum'd
        return out

    fn = smap(f, mesh, (pspecs, xspec, P("data", None)), xspec)
    return fn(params, x, positions)


@pytest.mark.parametrize("H,kv", [(8, 8), (8, 4), (8, 2), (8, 1)])
def test_head_mode_matches_reference(mesh24, H, kv):
    d, B, S = 32, 4, 16
    cfg = _cfg(H, kv, d)
    axes = MeshAxes.from_mesh(mesh24)
    decls = A.attn_decls(cfg, axes)
    params = materialize(decls, 7)
    x = rand(0, (B, S, d), scale=0.5)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out = _run_mode(mesh24, cfg, params, x, pos, layout="rep")
    ref = _ref_attention(cfg, params, x, pos)
    allclose(out, ref, rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("rope,frac", [("full", 1.0), ("partial", 0.5),
                                       ("partial", 0.25)])
def test_head_mode_with_rope(mesh24, rope, frac):
    d, B, S, H, kv = 32, 2, 16, 4, 2
    cfg = _cfg(H, kv, d, rope=rope)
    cfg = cfg.replace(rope_fraction=frac)
    axes = MeshAxes.from_mesh(mesh24)
    decls = A.attn_decls(cfg, axes)
    params = materialize(decls, 8)
    x = rand(1, (B, S, d), scale=0.5)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out = _run_mode(mesh24, cfg, params, x, pos, layout="rep")
    ref = _ref_attention(cfg, params, x, pos)
    allclose(out, ref, rtol=2e-3, atol=2e-4)


def test_ring_mode_matches_reference(mesh24):
    """ring (sequence-sharded) attention == dense reference; H=6 doesn't
    divide tp=4 — exactly the case ring exists for."""
    d, B, S, H, kv = 24, 2, 16, 6, 2
    cfg = _cfg(H, kv, d, mode="ring")
    axes = MeshAxes.from_mesh(mesh24)
    decls = A.attn_decls(cfg, axes)
    params = materialize(decls, 9)
    x = rand(2, (B, S, d), scale=0.5)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out = _run_mode(mesh24, cfg, params, x, pos, layout="sp")
    ref = _ref_attention(cfg, params, x, pos)
    # out is seq-sharded [B, S/p, d] stitched back by shard_map
    allclose(out, ref, rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("mode,H,kv", [("head", 8, 4), ("head", 8, 2),
                                       ("ring", 6, 2)])
def test_decode_matches_prefill_reference(mesh24, mode, H, kv):
    """prefill S tokens -> decode token S: logits must equal the dense
    reference attention over the full S+1 sequence at the last position."""
    d, B, S = (24 if mode == "ring" else 32), 4, 16
    cfg = _cfg(H, kv, d, mode=mode, rope="full")
    axes = MeshAxes.from_mesh(mesh24)
    decls = A.attn_decls(cfg, axes)
    params = materialize(decls, 11)
    x_all = rand(3, (B, S + 1, d), scale=0.5)
    pos_all = jnp.broadcast_to(jnp.arange(S + 1), (B, S + 1))

    # sharded: prefill then one decode step
    def prefill_f(p, xx, pp):
        out, kvc = A.attention(cfg, "rep", p, xx, pp, axes, decls,
                               kind="prefill", causal=True, return_kv=True)
        return out, kvc

    cspec = {"k": P("data", "model", None, None),
             "v": P("data", "model", None, None)}
    fn_pre = smap(prefill_f, mesh24,
                  (resolved_param_specs(decls, mesh24),
                   P("data", None, None), P("data", None)),
                  (P("data", None, None), cspec))
    _, cache = fn_pre(params, x_all[:, :S], pos_all[:, :S])
    # pad cache seq dim to make room for the decoded token (as the serve
    # engine does before decoding)
    cache = jax.tree.map(
        lambda c: jnp.pad(c, ((0, 0), (0, S), (0, 0), (0, 0))), cache)

    def decode_f(p, xx, c, pos):
        out, newc = A.attention(cfg, "rep", p, xx, None, axes, decls,
                                kind="decode", cache=c, pos=pos)
        return out

    fn_dec = smap(decode_f, mesh24,
                  (resolved_param_specs(decls, mesh24),
                   P("data", None, None), cspec, P("data")),
                  P("data", None, None))
    out_dec = fn_dec(params, x_all[:, S:S + 1],
                     cache, jnp.full((B,), S, jnp.int32))

    ref = _ref_attention(cfg, params, x_all, pos_all)[:, S:S + 1]
    allclose(out_dec, ref, rtol=3e-3, atol=3e-4)


def test_mrope_sections_cover_headdim():
    from repro.models.rope import mrope_sections
    for hd in (32, 64, 128):
        assert sum(mrope_sections(hd)) == hd


def test_rope_preserves_norm():
    x = rand(4, (2, 8, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    y = apply_rope(x, pos)
    allclose(jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1),
             rtol=1e-4)


def test_ring_gather_kv_variant_matches(mesh24):
    """§Perf cell C variant: gather-KV ring == ppermute ring == reference."""
    d, B, S, H, kv = 24, 2, 16, 6, 2
    cfg = _cfg(H, kv, d, mode="ring")
    cfg2 = cfg.replace(attn_ring_gather_kv=True)
    axes = MeshAxes.from_mesh(mesh24)
    decls = A.attn_decls(cfg, axes)
    params = materialize(decls, 21)
    x = rand(7, (B, S, d), scale=0.5)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out1 = _run_mode(mesh24, cfg, params, x, pos, layout="sp")
    out2 = _run_mode(mesh24, cfg2, params, x, pos, layout="sp")
    allclose(out1, out2, rtol=1e-4, atol=1e-5)
    ref = _ref_attention(cfg, params, x, pos)
    allclose(out2, ref, rtol=2e-3, atol=2e-4)


# ---------------------------------------------------------------------------
# kernel_backend="pallas": the flash-attention core (kernels/ops.py) must
# wire into the attention module and match the XLA core exactly — values
# AND gradients (the custom_vjp backward runs the dense reference)
# ---------------------------------------------------------------------------

def test_flash_core_wiring_matches_xla(mesh24, monkeypatch):
    from repro.configs.base import (ProjectionMap, ProjectionSpec,
                                    with_kernel_backend)
    d, B, S, H, kv = 32, 4, 16, 8, 8
    base = _cfg(H, kv, d).replace(
        projections=ProjectionMap(default=ProjectionSpec()))
    cfg_p = with_kernel_backend(base, "pallas")
    axes = MeshAxes.from_mesh(mesh24)
    decls = A.attn_decls(cfg_p, axes)
    params = materialize(decls, 11)
    x = rand(20, (B, S, d), scale=0.5)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))

    # prove the pallas backend actually routes through the flash core
    # (otherwise this parity test is vacuous)
    calls = []
    real = A.flash_attention_vjp

    def spy(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(A, "flash_attention_vjp", spy)
    out_p = _run_mode(mesh24, cfg_p, params, x, pos, layout="rep")
    assert calls, "pallas backend did not reach the flash core"
    out_x = _run_mode(mesh24, base, params, x, pos, layout="rep")
    allclose(out_p, out_x, rtol=2e-3, atol=2e-4)
    allclose(out_p, _ref_attention(cfg_p, params, x, pos),
             rtol=2e-3, atol=2e-4)

    # gradient parity through the custom_vjp core
    def make_grad_fn(cfg):
        pspecs = resolved_param_specs(decls, mesh24)
        xspec = P("data", None, None)

        def f(p, xx, pp):
            def loss_fn(xx_):
                out, _ = A.attention(cfg, "rep", p, xx_, pp, axes,
                                     decls, kind="train", causal=True)
                return jnp.sum(out * out)

            loss, g = jax.value_and_grad(loss_fn)(xx)
            return jax.lax.psum(loss, ("data",)), g

        return smap(f, mesh24, (pspecs, xspec, P("data", None)),
                    (P(), xspec))

    lp, gp = make_grad_fn(cfg_p)(params, x, pos)
    lx, gx = make_grad_fn(base)(params, x, pos)
    allclose(lp, lx, rtol=1e-4, atol=1e-5)
    allclose(gp, gx, rtol=1e-3, atol=1e-4, msg="dL/dx flash vs xla")


def test_flash_not_used_when_unsupported(mesh24, monkeypatch):
    """Shapes the flash kernel cannot take (decode's s_q != s_kv, ragged
    GQA groups, seq not a block multiple) must fall back to the XLA core
    even under kernel_backend="pallas" — correctness never depends on
    the kernel's shape envelope."""
    from repro.configs.base import (ProjectionMap, ProjectionSpec,
                                    with_kernel_backend)
    from repro.kernels.ops import flash_attention_supported
    assert not flash_attention_supported(1, 16, 2, 2)     # decode shape
    assert not flash_attention_supported(16, 16, 3, 2)    # ragged groups
    assert not flash_attention_supported(160, 160, 2, 2)  # 160 % 128
    assert flash_attention_supported(16, 16, 2, 2)

    d, B, S, H, kv = 32, 4, 160, 8, 8   # S=160: not a 128-block multiple
    base = _cfg(H, kv, d).replace(
        projections=ProjectionMap(default=ProjectionSpec()))
    cfg_p = with_kernel_backend(base, "pallas")
    calls = []
    monkeypatch.setattr(A, "flash_attention_vjp",
                        lambda *a, **kw: calls.append(1))
    axes = MeshAxes.from_mesh(mesh24)
    decls = A.attn_decls(cfg_p, axes)
    params = materialize(decls, 12)
    x = rand(21, (B, S, d), scale=0.5)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out = _run_mode(mesh24, cfg_p, params, x, pos, layout="rep")
    assert not calls, "unsupported shape still routed to the flash core"
    allclose(out, _ref_attention(cfg_p, params, x, pos),
             rtol=2e-3, atol=2e-4)
