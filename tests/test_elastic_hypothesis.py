"""Recovery-equivalence property suite (the elastic runtime's pin).

Two invariants, at the mechanism level (``make_ffn_train_step`` +
``CheckpointManager`` + ``convert_ffn_params``), over drawn (strategy,
mesh pair, kill step, ghost width) configurations:

1. **Recovery equivalence** — kill → restore-on-a-DIFFERENT-mesh →
   finish must reproduce the uninterrupted run's loss trajectory within
   float-reassociation tolerance.  Valid whenever the model class is
   mesh-independent: the dense family on any (dp, tp, pp); the phantom
   family at fixed (k, tp) across dp/pp changes (DESIGN.md §4 — the
   class is (k, tp)-dependent).  Mixed per-stage strategies restore on
   the SAME mesh (their per-stage subtrees don't convert across
   classes).

2. **Cross-mesh roundtrip exactness** — a GLOBAL host tree converted
   A→B→A between same-class plan layouts (flat [L, ...] vs pipelined
   [S, L/S, ...]; e.g. save on 1×8, restore on 2×2×2) is BITWISE
   identical, optimizer moments included.

The deterministic seeded draws below always run (hypothesis is not
installed in every container); when hypothesis IS available the same
oracles run again under ``@given`` with a wider draw space.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.planner.space import PlanCandidate
from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import (_nest, convert_ffn_params,
                                 place_host_tree)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                          # deterministic draws only
    HAVE_HYPOTHESIS = False

WIDTH, DEPTH, BATCH = 32, 2, 16

# same-class mesh pairs: (strategy, (dp,tp,pp) save side, (dp,tp,pp)
# restore side).  Tensor is mesh-independent (incl. the flat 1x8 ->
# staged 2x2x2 relayout); phantom keeps (k, tp) and moves dp.
MESH_PAIRS = (
    ("tensor_col", (1, 8, 1), (2, 2, 2)),
    ("tensor_col", (2, 4, 1), (4, 2, 1)),
    ("tensor_col", (4, 2, 1), (1, 2, 1)),
    ("phantom", (1, 2, 1), (2, 2, 1)),
    ("phantom", (2, 2, 1), (4, 2, 1)),
    ("phantom", (4, 2, 1), (1, 2, 1)),
)
KS = (2, 4)


def _mesh(shape, _cache={}):
    from repro.launch.mesh import make_local_mesh
    if shape not in _cache:
        _cache[shape] = make_local_mesh(*shape)
    return _cache[shape]


def _plan(strategy, shape, k=0):
    dp, tp, pp = shape
    return PlanCandidate(dp=dp, tp=tp, strategy=strategy, width=WIDTH,
                         depth=DEPTH, batch=BATCH, k=k, pp=pp)


def _make_step(cfg, mesh, batch):
    from repro.core.ffn import make_ffn_train_step
    from repro.optim import AdamW
    opt = AdamW(3e-3, weight_decay=0.0)
    step_fn, decls, opt_decls = make_ffn_train_step(cfg, mesh, opt, batch)
    return step_fn, decls, opt_decls, opt


def _run(step_fn, params, opt_state, ds, start, stop):
    losses = []
    for s in range(start, stop):
        x, y = ds(s)
        params, opt_state, loss = step_fn(params, opt_state,
                                          jnp.int32(s), x, y)
        losses.append(float(loss))
    return params, opt_state, losses


def assert_recovery_equivalence(cache, tmpdir, strategy, shape_a,
                                shape_b, k, kill, total, seed):
    """Oracle 1: uninterrupted on mesh A == kill at ``kill``, checkpoint
    restore converted onto mesh B, finish — same final loss."""
    from repro.core.ffn import init_ffn
    from repro.data.synthetic import TeacherDataset

    plan_a, plan_b = _plan(strategy, shape_a, k), _plan(strategy, shape_b, k)
    cfg_a, cfg_b = plan_a.model_config(), plan_b.model_config()
    mesh_a, mesh_b = _mesh(shape_a), _mesh(shape_b)
    fa, decls_a, odecls_a, opt_a = cache.build(_make_step, cfg_a, mesh_a,
                                               BATCH)
    fb, decls_b, odecls_b, opt_b = cache.build(_make_step, cfg_b, mesh_b,
                                               BATCH)
    ds = TeacherDataset(WIDTH, BATCH, seed=seed)

    # reference: uninterrupted on mesh A
    p0, o0 = init_ffn(cfg_a, mesh_a, opt_a, seed=seed)
    _, _, ref = _run(fa, p0, o0, ds, 0, total)

    # faulted: run to the kill, checkpoint, convert, finish on mesh B
    p, o = init_ffn(cfg_a, mesh_a, opt_a, seed=seed)
    p, o, pre = _run(fa, p, o, ds, 0, kill)
    np.testing.assert_allclose(pre, ref[:kill], rtol=1e-6)
    mgr = CheckpointManager(str(tmpdir))
    mgr.save(kill, p, o, meta={"plan": plan_a.as_dict()})
    index, flat = mgr.load_host(kill)
    nested = _nest(flat)
    params_h, opt_h, distilled = convert_ffn_params(
        plan_a, plan_b, nested["params"], nested["opt"])
    assert not distilled                     # same class: exact path
    assert opt_h is not None                 # moments survive exactly
    pb = place_host_tree(params_h, decls_b, mesh_b)
    ob = place_host_tree(opt_h, odecls_b, mesh_b)
    _, _, post = _run(fb, pb, ob, ds, kill, total)
    np.testing.assert_allclose(post, ref[kill:], rtol=2e-4, atol=1e-6)


def assert_roundtrip_exact(strategy, shape_a, shape_b, k, seed):
    """Oracle 2: A->B->A layout conversion is bitwise, moments included."""
    from repro.core.ffn import init_ffn

    plan_a, plan_b = _plan(strategy, shape_a, k), _plan(strategy, shape_b, k)
    cfg_a = plan_a.model_config()
    mesh_a = _mesh(shape_a)
    from repro.optim import AdamW
    opt = AdamW(3e-3, weight_decay=0.0)
    p, o = init_ffn(cfg_a, mesh_a, opt, seed=seed)
    import jax
    host_p = jax.tree.map(lambda a: np.asarray(a), p)
    host_o = jax.tree.map(lambda a: np.asarray(a), o)

    ab_p, ab_o, d1 = convert_ffn_params(plan_a, plan_b, host_p, host_o)
    back_p, back_o, d2 = convert_ffn_params(plan_b, plan_a, ab_p, ab_o)
    assert not d1 and not d2
    for x, y in zip(jax.tree.leaves(host_p), jax.tree.leaves(back_p)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree.leaves(host_o), jax.tree.leaves(back_o)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# deterministic seeded draws — always run
# ---------------------------------------------------------------------------

_SEEDED = [(s, a, b, (KS[i % len(KS)] if s == "phantom" else 0),
            2 + i % 3, 6 + i % 3, i)
           for i, (s, a, b) in enumerate(MESH_PAIRS)]
_IDS = [f"{s}-{'x'.join(map(str, a))}->{'x'.join(map(str, b))}-k{k}"
        for s, a, b, k, _, _, _ in _SEEDED]


@pytest.mark.parametrize("case", _SEEDED, ids=_IDS)
def test_recovery_equivalence_seeded(compiled_step_cache, tmp_path, case):
    strategy, shape_a, shape_b, k, kill, total, seed = case
    assert_recovery_equivalence(compiled_step_cache, tmp_path, strategy,
                                shape_a, shape_b, k, kill, total, seed)


@pytest.mark.parametrize("case", _SEEDED, ids=_IDS)
def test_roundtrip_exact_seeded(case):
    strategy, shape_a, shape_b, k, _, _, seed = case
    assert_roundtrip_exact(strategy, shape_a, shape_b, k, seed)


def test_mixed_restores_same_mesh(compiled_step_cache, tmp_path):
    """Mixed per-stage strategies: kill + restore on the SAME mesh is
    exact (no conversion; per-stage subtrees place back verbatim)."""
    from helpers import pipeline_cfg
    from repro.data.synthetic import TeacherDataset
    from repro.parallel.params import materialize

    cfg = pipeline_cfg("mixed", k=2, M=2, stages=2, n=WIDTH)
    mesh = _mesh((2, 2, 2))
    fn, decls, opt_decls, opt = compiled_step_cache.build(
        _make_step, cfg, mesh, BATCH)
    ds = TeacherDataset(WIDTH, BATCH, seed=3)

    p0 = place_host_tree(materialize(decls, 3), decls, mesh)
    o0 = opt.init(p0)
    _, _, ref = _run(fn, p0, o0, ds, 0, 6)

    p = place_host_tree(materialize(decls, 3), decls, mesh)
    o = opt.init(p)
    p, o, _ = _run(fn, p, o, ds, 0, 3)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, p, o)
    _, flat = mgr.load_host(3)
    nested = _nest(flat)
    pb = place_host_tree(nested["params"], decls, mesh)
    ob = place_host_tree(nested["opt"], opt_decls, mesh)
    _, _, post = _run(fn, pb, ob, ds, 3, 6)
    np.testing.assert_allclose(post, ref[3:], rtol=1e-6)


def test_class_change_requires_distill():
    """Tensor -> phantom conversion flags ``distilled`` and drops the
    moments; width/depth changes are rejected outright."""
    from repro.core.phantom import phantom_dense_equivalent

    rng = np.random.default_rng(0)
    host = {"layers": {
        "w": rng.standard_normal((DEPTH, WIDTH, WIDTH)).astype(np.float32),
        "b": rng.standard_normal((DEPTH, WIDTH)).astype(np.float32)}}
    t_plan = _plan("tensor_col", (2, 4, 1))
    p_plan = _plan("phantom", (1, 2, 1), k=4)
    conv, opt_h, distilled = convert_ffn_params(t_plan, p_plan, host,
                                                {"m": host, "v": host})
    assert distilled and opt_h is None
    # the distilled factors reproduce each layer's dense DIAGONAL blocks
    # exactly (truncated SVD only approximates the off-diagonal coupling)
    lyr = {k: np.asarray(v[0]) for k, v in conv["layers"].items()
           if k in ("L", "C", "D")}
    W_hat = np.asarray(phantom_dense_equivalent(lyr))
    W = host["layers"]["w"][0]
    blk = WIDTH // p_plan.tp
    for i in range(p_plan.tp):
        sl = slice(i * blk, (i + 1) * blk)
        np.testing.assert_allclose(W_hat[sl, sl], W[sl, sl], rtol=1e-5,
                                   atol=1e-5)

    with pytest.raises(ValueError, match="width"):
        convert_ffn_params(t_plan, t_plan.with_width(64), host)


# ---------------------------------------------------------------------------
# hypothesis-driven draws — same oracles, wider space
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @given(pair=st.sampled_from(MESH_PAIRS), k=st.sampled_from(KS),
           kill=st.integers(2, 5), seed=st.integers(0, 1000))
    @settings(max_examples=6, deadline=None)
    def test_recovery_equivalence_property(compiled_step_cache,
                                           tmp_path_factory, pair, k,
                                           kill, seed):
        strategy, shape_a, shape_b = pair
        if strategy != "phantom":
            k = 0                  # dead knob for tensor: dedupe compiles
        tmp = tmp_path_factory.mktemp(f"rec{seed}")
        assert_recovery_equivalence(compiled_step_cache, tmp, strategy,
                                    shape_a, shape_b, k, kill, kill + 3,
                                    seed)

    @given(pair=st.sampled_from(MESH_PAIRS), k=st.sampled_from(KS),
           seed=st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_roundtrip_exact_property(pair, k, seed):
        strategy, shape_a, shape_b = pair
        if strategy != "phantom":
            k = 0
        assert_roundtrip_exact(strategy, shape_a, shape_b, k, seed)
