"""The serving runtime layers (docs/serving.md): paged KV-cache
invariants under churn, length-bucketed scheduling, per-request
sampling, traffic traces / SLO tracking, router pricing — and the
engine-level guarantee that mixed-length request streams through the
bucketing scheduler produce IDENTICAL tokens to single-request greedy
decoding (per-slot isolation)."""
import numpy as np
import pytest

from repro.serve.kv_cache import CacheOverflow, PagedKVCache
from repro.serve.sampling import Sampler, SamplingParams
from repro.serve.scheduler import Scheduler, bucket_of
from repro.serve.traffic import SLOTracker, make_trace


# ---------------------------------------------------------------------------
# paged KV cache (host-only)
# ---------------------------------------------------------------------------

def test_paged_cache_alloc_advance_free():
    pc = PagedKVCache(slots=4, max_len=64, page_size=16)
    rec = pc.alloc(0, 16)
    assert rec.pages == 1 and pc.allocated_pages == 1
    # decode writes cross a page boundary -> one new page
    assert pc.advance(0, 15) == 0
    assert pc.advance(0, 16) == 1
    assert pc.advance(0, 17) == 0
    pc.check()
    assert pc.free(0) == 2
    assert pc.allocated_pages == 0
    pc.check()


def test_paged_cache_admission_and_overflow():
    pc = PagedKVCache(slots=2, max_len=64, page_size=16)
    assert pc.can_admit(40, 10)
    assert not pc.can_admit(60, 10)          # 60+10 > 64
    assert not pc.can_admit(30, 10, padded_len=60)
    with pytest.raises(CacheOverflow):
        pc.alloc(0, 100)
    pc.alloc(0, 64)
    with pytest.raises(CacheOverflow):
        pc.advance(0, 64)                    # past the last frame
    with pytest.raises(RuntimeError):
        pc.alloc(0, 16)                      # double-alloc


def test_paged_cache_churn_invariants():
    """Random alloc/advance/free churn holds every invariant at every
    step and returns to an empty pool."""
    rng = np.random.RandomState(0)
    pc = PagedKVCache(slots=8, max_len=128, page_size=16)
    live = {}
    for _ in range(500):
        op = rng.rand()
        free_slots = [s for s in range(8) if s not in live]
        if op < 0.4 and free_slots:
            s = int(rng.choice(free_slots))
            n = int(rng.randint(1, 100))
            if pc.pages_for(n) <= pc.frames_per_slot:
                pc.alloc(s, n)
                live[s] = n
        elif op < 0.8 and live:
            s = int(rng.choice(list(live)))
            pos = min(live[s] + int(rng.randint(0, 8)), 127)
            pc.advance(s, pos)
            live[s] = max(live[s], pos + 1)
        elif live:
            s = int(rng.choice(list(live)))
            pc.free(s)
            del live[s]
        pc.check()
        assert 0.0 <= pc.occupancy() <= 1.0
        assert 0.0 <= pc.fragmentation() < 1.0 or not live
    for s in list(live):
        pc.free(s)
    pc.check()
    assert pc.allocated_pages == 0
    st = pc.stats()
    assert st["page_allocs"] == st["page_frees"]
    assert st["requests_admitted"] == st["requests_freed"]
    assert st["high_water_pages"] <= pc.total_pages


def test_paged_cache_free_list_stays_address_ordered():
    """Freed frames re-enter the pool in ADDRESS order regardless of
    free order, so external fragmentation is a residency property —
    it returns to exactly 0.0 whenever the pool empties, instead of
    ratcheting up across bursts (the append-order failure mode this
    replaces)."""
    pc = PagedKVCache(slots=4, max_len=64, page_size=16)
    for s in range(4):
        pc.alloc(s, 64)                  # drain the whole pool
    assert pc.free_pages == 0
    # free out of address order: slots 2, 0, 3, 1
    for s in (2, 0, 3, 1):
        pc.free(s)
        pc.check()                       # verifies ascending free list
    assert pc._free == list(range(pc.total_pages))
    assert pc.external_fragmentation() == 0.0


def test_paged_cache_bursty_churn_external_fragmentation():
    """Bursty alloc/free churn (whole cohorts admitted, random subsets
    freed) — the invariant check holds at every step and the external
    fragmentation metric lands back at exactly 0.0 at every point the
    pool returns to empty."""
    rng = np.random.RandomState(42)
    pc = PagedKVCache(slots=8, max_len=128, page_size=16)
    empties = 0
    for _burst in range(60):
        live = []
        # burst: admit a cohort of random-length requests
        for s in range(int(rng.randint(2, 9))):
            n = int(rng.randint(1, 129))
            if pc.pages_for(n) <= pc.frames_per_slot:
                pc.alloc(s, n)
                live.append(s)
        pc.check()
        assert 0.0 <= pc.external_fragmentation() <= 1.0
        # drain in shuffled order, some decode growth along the way
        rng.shuffle(live)
        for s in live:
            pos = min(pc._table[s].live_tokens
                      + int(rng.randint(0, 16)), 127)
            pc.advance(s, pos)
            pc.free(s)
            pc.check()
        assert pc.allocated_pages == 0
        assert pc.external_fragmentation() == 0.0, \
            "external fragmentation must vanish with occupancy"
        empties += 1
    assert empties == 60
    st = pc.stats()
    assert st["free_pages"] == st["total_pages"]
    assert st["external_fragmentation"] == 0.0


# ---------------------------------------------------------------------------
# scheduler (host-only; uses engine Request lazily to avoid jax import
# ordering issues — conftest sets the device flag first anyway)
# ---------------------------------------------------------------------------

def _req(n, **kw):
    from repro.serve.engine import Request
    return Request(prompt=np.arange(n, dtype=np.int32), **kw)


def test_bucket_of():
    assert [bucket_of(x, 16) for x in (1, 15, 16, 17, 32, 33)] == \
        [16, 16, 16, 32, 32, 48]


def test_scheduler_groups_share_bucket():
    sch = Scheduler(bucket=16)
    reqs = [_req(n) for n in (12, 16, 23, 8, 40)]
    sch.add(reqs)
    S, group = sch.next_group(free_slots=4)
    assert S == 16
    assert [len(r.prompt) for r in group] == [12, 16, 8]
    S2, group2 = sch.next_group(free_slots=4)
    assert S2 == 32 and [len(r.prompt) for r in group2] == [23]
    S3, group3 = sch.next_group(free_slots=4)
    assert S3 == 48 and len(group3) == 1
    assert len(sch) == 0


def test_scheduler_edf_order():
    sch = Scheduler(bucket=16, order="edf")
    late = _req(10, deadline_ms=5000.0)
    soon = _req(11, deadline_ms=100.0)
    none = _req(12)                       # no deadline sorts last
    sch.add([none, late, soon])
    _, group = sch.next_group(free_slots=3)
    assert [len(r.prompt) for r in group] == [11, 10, 12]


def test_scheduler_rejects_oversize():
    pages = PagedKVCache(slots=2, max_len=64, page_size=16)
    sch = Scheduler(bucket=16, pages=pages)
    ok = _req(30, max_new_tokens=8)
    bad = _req(60, max_new_tokens=16)     # 64 padded + 16 > 64
    rejected = sch.add([ok, bad])
    assert rejected == [bad] and bad.done and "rejected" in bad.error
    assert len(sch) == 1


def test_scheduler_exact_length_mode():
    # recurrent families: an unpaddable prompt is REJECTED at admission
    # (not a session crash), multiple-of-bucket prompts group exactly
    sch = Scheduler(bucket=16, mixed_lengths=False)
    bad = _req(12)
    assert sch.add([bad]) == [bad]
    assert bad.done and "rejected" in bad.error
    assert len(sch) == 0
    sch.add([_req(32), _req(16)])
    S, group = sch.next_group(free_slots=4)
    assert S == 32 and len(group) == 1    # exact-length groups only


def test_scheduler_interleave_policy():
    sch = Scheduler(bucket=16, min_free_for_prefill=3)
    sch.add([_req(16) for _ in range(4)])
    assert not sch.should_refill(free_slots=2, active_slots=2)
    assert sch.should_refill(free_slots=3, active_slots=1)
    # a fully idle engine always refills (no deadlock)
    assert sch.should_refill(free_slots=1, active_slots=0)


# ---------------------------------------------------------------------------
# sampling (host-only)
# ---------------------------------------------------------------------------

def test_sampling_greedy_and_vocab_slice():
    logits = np.arange(12, dtype=np.float32)     # padded vocab 12
    s = Sampler(SamplingParams(), vocab_size=10)
    assert s(logits) == 9                        # argmax inside vocab


def test_sampling_seeded_deterministic():
    logits = np.random.RandomState(0).randn(64).astype(np.float32)
    a = Sampler(SamplingParams(temperature=0.7, seed=3), 64)
    b = Sampler(SamplingParams(temperature=0.7, seed=3), 64)
    assert [a(logits) for _ in range(20)] == [b(logits) for _ in range(20)]


def test_sampling_topk_topp_support():
    logits = np.arange(10, dtype=np.float32)
    s = Sampler(SamplingParams(temperature=1.0, top_k=3, seed=0), 10)
    draws = {s(logits) for _ in range(300)}
    assert draws <= {7, 8, 9}
    sp = Sampler(SamplingParams(temperature=1.0, top_p=0.5, seed=0), 10)
    draws_p = {sp(logits) for _ in range(300)}
    assert 9 in draws_p and draws_p <= {8, 9}


# ---------------------------------------------------------------------------
# traffic + SLO (host-only)
# ---------------------------------------------------------------------------

def test_trace_reproducible_and_kinds():
    a = make_trace("poisson", n=16, rate_rps=8.0, seed=5)
    b = make_trace("poisson", n=16, rate_rps=8.0, seed=5)
    assert a == b
    assert all(x.arrival_s <= y.arrival_s for x, y in zip(a, a[1:]))
    burst = make_trace("bursty", n=16, rate_rps=8.0, seed=5)
    assert burst != a
    closed = make_trace("closed", n=4, seed=0)
    assert all(t.arrival_s == 0.0 for t in closed)
    with pytest.raises(ValueError):
        make_trace("warp", n=4)


def test_slo_tracker_report():
    from repro.serve.engine import Request
    tr = SLOTracker(slo_ttft_ms=100.0)
    for i, (ttft_s, n) in enumerate([(0.05, 4), (0.2, 3)]):
        r = Request(prompt=np.arange(4, dtype=np.int32), req_id=i)
        r.arrival_s, r.t_first_s = 0.0, ttft_s
        r.t_done_s = ttft_s + 0.01 * (n - 1)
        r.out_tokens = list(range(n))
        tr.observe(r)
    rep = tr.report()
    assert rep["requests"] == 2 and rep["generated_tokens"] == 7
    assert rep["ttft_ms"]["p50"] == pytest.approx(125.0)
    assert rep["tpot_ms"]["p50"] == pytest.approx(10.0)
    assert rep["slo_met_fraction"] == pytest.approx(0.5)
    assert rep["goodput_tokens"] == 4


# ---------------------------------------------------------------------------
# engine: per-slot isolation + termination + page churn (mesh)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serve_setup(request, mesh24):
    from repro.configs.base import get_config
    from repro.models.model import model_decls
    from repro.parallel.axes import MeshAxes
    from repro.parallel.params import materialize

    cfg = get_config("chatglm3-6b", smoke=True)
    mesh = mesh24
    params = materialize(model_decls(cfg, MeshAxes.from_mesh(mesh)), 1)
    return cfg, mesh, params


def test_engine_mixed_lengths_match_single_request(serve_setup):
    """Mixed-length streams through the bucketing scheduler produce
    identical tokens to single-request greedy decoding."""
    from repro.serve.engine import Request, ServeEngine

    cfg, mesh, params = serve_setup
    rng = np.random.RandomState(0)
    lens = [12, 16, 23, 8, 32, 17]
    prompts = [rng.randint(0, cfg.vocab_size, s).astype(np.int32)
               for s in lens]

    eng = ServeEngine(cfg, mesh, params, slots=4, max_len=64)
    reqs = [Request(prompt=p.copy(), max_new_tokens=5) for p in prompts]
    eng.run(reqs, max_steps=200)
    assert all(r.done for r in reqs)

    solo_eng = ServeEngine(cfg, mesh, params, slots=4, max_len=64)
    for p, r in zip(prompts, reqs):
        solo = Request(prompt=p.copy(), max_new_tokens=5)
        solo_eng.run([solo], max_steps=100)
        assert solo.out_tokens == r.out_tokens, \
            f"len {len(p)}: {solo.out_tokens} != {r.out_tokens}"

    # paged-cache invariants after churn: everything freed
    for e in (eng, solo_eng):
        e.pages.check()
        assert e.pages.allocated_pages == 0
        st = e.pages.stats()
        assert st["page_allocs"] == st["page_frees"] > 0


def test_engine_rejects_undivisible_page_size(serve_setup):
    """Bucket-padded prefill lengths must divide the model axis — the
    invariant the old `S % 16 == 0` assert enforced, now checked at
    engine construction."""
    from repro.serve.engine import ServeEngine

    cfg, mesh, params = serve_setup        # tp = 4
    with pytest.raises(ValueError, match="model-axis"):
        ServeEngine(cfg, mesh, params, slots=2, max_len=64, page_size=6)


def test_engine_eos_and_one_token_at_prefill(serve_setup):
    """The prefill-produced first token is checked against eos_id, and
    max_new_tokens=1 requests finish WITHOUT burning a decode step."""
    from repro.serve.engine import Request, ServeEngine

    cfg, mesh, params = serve_setup
    rng = np.random.RandomState(3)
    prompt = rng.randint(0, cfg.vocab_size, 16).astype(np.int32)

    eng = ServeEngine(cfg, mesh, params, slots=2, max_len=64)
    probe = Request(prompt=prompt.copy(), max_new_tokens=1)
    eng.run([probe], max_steps=10)
    assert probe.done and len(probe.out_tokens) == 1
    assert eng.decode_meter.calls == 0       # no decode step burned
    first = probe.out_tokens[0]

    r_eos = Request(prompt=prompt.copy(), max_new_tokens=8, eos_id=first)
    eng.run([r_eos], max_steps=10)
    assert r_eos.done and r_eos.out_tokens == [first]
    assert eng.decode_meter.calls == 0       # eos seen at prefill


def test_engine_trace_replay_slo(serve_setup):
    """An open-loop trace replay finishes every request and produces a
    populated SLO report with page stats."""
    from repro.serve.engine import ServeEngine
    from repro.serve.scheduler import bucket_of
    from repro.serve.traffic import (SLOTracker, make_trace, replay,
                                     trace_requests)

    cfg, mesh, params = serve_setup
    trace = make_trace("poisson", n=6, rate_rps=100.0,
                       prompt_len_range=(4, 30),
                       new_tokens_range=(2, 5), seed=1)
    reqs = trace_requests(trace, cfg.vocab_size, seed=1)
    eng = ServeEngine(cfg, mesh, params, slots=4, max_len=64)
    eng.warmup(bucket_of(t.prompt_len, 16) for t in trace)
    tracker = replay(eng, reqs, tracker=SLOTracker(slo_ttft_ms=1e6))
    rep = tracker.report()
    assert rep["requests"] == 6
    assert rep["generated_tokens"] == sum(t.max_new_tokens for t in trace)
    assert rep["ttft_ms"] and rep["e2e_ms"]
    assert rep["slo_met_fraction"] == 1.0    # SLO set absurdly high
    assert eng.pages.allocated_pages == 0


def test_engine_sampled_serving_reproducible(serve_setup):
    """Per-request seeded sampling is schedule-independent: the same
    request seed yields the same tokens whether served alone or with
    batch-mates."""
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.sampling import SamplingParams

    cfg, mesh, params = serve_setup
    rng = np.random.RandomState(7)
    prompt = rng.randint(0, cfg.vocab_size, 16).astype(np.int32)
    sp = SamplingParams(temperature=0.9, top_k=50, seed=11)

    eng = ServeEngine(cfg, mesh, params, slots=4, max_len=64)
    target = Request(prompt=prompt.copy(), max_new_tokens=6, sampling=sp)
    others = [Request(prompt=rng.randint(0, cfg.vocab_size,
                                         16).astype(np.int32),
                      max_new_tokens=6) for _ in range(3)]
    eng.run([target] + others, max_steps=100)

    solo = Request(prompt=prompt.copy(), max_new_tokens=6, sampling=sp)
    eng2 = ServeEngine(cfg, mesh, params, slots=4, max_len=64)
    eng2.run([solo], max_steps=100)
    assert solo.out_tokens == target.out_tokens


# ---------------------------------------------------------------------------
# router pricing (host-only)
# ---------------------------------------------------------------------------

def test_router_pricing_and_route():
    from repro.planner import paper_default_calibration
    from repro.serve.router import candidate_configs, route

    calib = paper_default_calibration()
    trace = make_trace("poisson", n=8, rate_rps=4.0, seed=0)
    cands = candidate_configs("chatglm3-6b", 8, slots_options=(4,))
    assert any(c.impl == "phantom" for c in cands)
    assert any(c.impl == "tensor" for c in cands)
    assert all(c.tp >= 2 for c in cands)
    # tensor candidates use the full device budget; phantom may downsize
    assert all(c.devices == 8 for c in cands if c.impl == "tensor")
    assert any(c.devices < 8 for c in cands if c.impl == "phantom")

    winner, priced = route(cands, calib, trace, slo_ms=1e6)
    assert winner.meets_slo
    assert winner.j_per_token == min(pc.j_per_token for pc in priced)
    assert all(pc.j_per_token > 0 for pc in priced)
    # an impossible SLO falls back to the lowest-latency candidate
    w2, p2 = route(cands, calib, trace, slo_ms=1e-9)
    assert not w2.meets_slo
    assert w2.ttft_s == min(pc.ttft_s for pc in p2)


def test_serve_calibration_loading(tmp_path):
    """planner.load_calibration: PLAN_report.json constants win, then
    a ledger fit, then paper defaults."""
    import json

    from repro.planner import Calibration, load_calibration
    from repro.planner.calibration import PAPER_SOURCE

    calib = Calibration(alpha_scale={"phantom": 1.25},
                        source="ledger-fit")
    plan = tmp_path / "PLAN_report.json"
    plan.write_text(json.dumps({"schema": "plan-report/v1",
                                "calibration": calib.as_dict()}))
    got = load_calibration(plan_report_path=str(plan))
    assert got.alpha_scale == {"phantom": 1.25}
    assert got.scales_for("phantom")[0] == 1.25
    # lowrank inherits phantom's fit through from_dict round-trip
    assert got.scales_for("lowrank_distill")[0] == 1.25

    got2 = load_calibration(plan_report_path=str(tmp_path / "nope.json"))
    assert got2.source == PAPER_SOURCE
