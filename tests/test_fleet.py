"""Disaggregated serving fleet (serve/fleet): autoscaler decision
logic, pool planning + route-table round trip, the modeled DES replay
(determinism, KV-transfer wire band, scale events, idle static power),
the colocated single-engine baseline, and executed-mode token parity
against a plain ServeEngine replay of the same trace."""
import json

import pytest

from repro.planner.calibration import Calibration
from repro.serve.fleet import (AutoscalePolicy, Autoscaler, FleetConfig,
                               FleetRouter, PoolStats, auto_rate_rps,
                               baseline_config, load_route_table,
                               plan_pools, write_route_table)
from repro.serve.router import ServeConfig, route, trace_stats
from repro.serve.traffic import make_trace

ARCH = "chatglm3-6b"


def _sc(impl="phantom", tp=2, slots=4):
    return ServeConfig(ARCH, impl, dp=1, tp=tp, slots=slots, max_len=64)


def _fleet_fc(**kw):
    kw.setdefault("prefill", _sc())
    kw.setdefault("decode", _sc())
    kw.setdefault("slo_ms", 200.0)
    kw.setdefault("prefill_policy",
                  AutoscalePolicy(min_replicas=1, max_replicas=1))
    kw.setdefault("decode_policy",
                  AutoscalePolicy(min_replicas=1, max_replicas=2))
    return FleetConfig(**kw)


def _overload_trace(n=4000, seed=0):
    calib = Calibration()
    probe = make_trace("bursty", n=500, rate_rps=10.0, seed=seed)
    mean_new = trace_stats(probe)["mean_new_tokens"]
    rate = auto_rate_rps(_sc(), calib, mean_new, replicas=1,
                         utilization=0.9)
    return make_trace("bursty", n=n, rate_rps=rate, seed=seed), calib


# ---------------------------------------------------------------------------
# autoscaler decision logic (pure, no simulation)
# ---------------------------------------------------------------------------

class TestAutoscaler:
    POL = AutoscalePolicy(min_replicas=1, max_replicas=3, cooldown_s=1.0,
                          idle_ticks=2, scale_down_util=0.35)

    def _busy(self, depth=40, n=1):
        return PoolStats(queue_depth=depth, n_active=n, n_warming=0,
                         service_s_per_item=0.05, busy_fraction=1.0)

    def _idle(self, n=2):
        return PoolStats(queue_depth=0, n_active=n, n_warming=0,
                         service_s_per_item=0.05, busy_fraction=0.0)

    def test_scales_up_on_deep_queue(self):
        sc = Autoscaler(self.POL, pool="decode", slo_ms=200.0)
        # 40 items * 50ms / 1 replica = 2s wait >> 0.7 * 200ms budget
        assert sc.evaluate(0.0, self._busy()) == "up"
        assert sc.events[-1].action == "up"
        assert sc.events[-1].replicas == 2

    def test_cooldown_blocks_consecutive_decisions(self):
        sc = Autoscaler(self.POL, pool="decode", slo_ms=200.0)
        assert sc.evaluate(0.0, self._busy()) == "up"
        assert sc.evaluate(0.5, self._busy(n=2)) is None
        assert sc.evaluate(1.5, self._busy(n=2)) == "up"

    def test_up_clamped_at_max(self):
        sc = Autoscaler(self.POL, pool="decode", slo_ms=200.0)
        assert sc.evaluate(0.0, self._busy(n=3)) is None

    def test_warming_counts_as_capacity(self):
        """A replica already ordered suppresses the next scale-up (no
        thundering herd while one is warming)."""
        sc = Autoscaler(self.POL, pool="decode", slo_ms=200.0)
        st = PoolStats(queue_depth=4, n_active=1, n_warming=1,
                       service_s_per_item=0.05, busy_fraction=1.0)
        # 4 * 50ms / 2 = 100ms < 140ms budget
        assert sc.evaluate(0.0, st) is None

    def test_scales_down_after_idle_ticks(self):
        sc = Autoscaler(self.POL, pool="decode", slo_ms=200.0)
        assert sc.evaluate(0.0, self._idle()) is None
        assert sc.evaluate(2.0, self._idle()) == "down"
        assert sc.events[-1].replicas == 1

    def test_down_clamped_at_min(self):
        sc = Autoscaler(self.POL, pool="decode", slo_ms=200.0)
        for t in range(10):
            assert sc.evaluate(float(2 * t), self._idle(n=1)) is None

    def test_busy_tick_resets_idle_streak(self):
        sc = Autoscaler(self.POL, pool="decode", slo_ms=200.0)
        assert sc.evaluate(0.0, self._idle()) is None
        st = PoolStats(queue_depth=0, n_active=2, n_warming=0,
                       service_s_per_item=0.05, busy_fraction=0.9)
        assert sc.evaluate(2.0, st) is None      # streak broken
        assert sc.evaluate(4.0, self._idle()) is None  # streak = 1 again

    def test_no_slo_uses_default_wait_budget(self):
        sc = Autoscaler(self.POL, pool="prefill", slo_ms=0.0)
        # est wait 2s > default 0.5s budget
        assert sc.evaluate(0.0, self._busy()) == "up"


# ---------------------------------------------------------------------------
# pool planning + route table
# ---------------------------------------------------------------------------

class TestPlanPools:
    def test_plans_dp1_pools(self):
        trace = make_trace("poisson", n=64, seed=0)
        pre, dec, notes = plan_pools(ARCH, 8, Calibration(), trace,
                                     slo_ms=200.0)
        assert pre.dp == 1 and dec.dp == 1
        assert notes["source"] == "priced"
        assert notes["candidates"] > 0
        assert notes["decode"]["j_per_token"] > 0

    def test_route_table_round_trip(self, tmp_path):
        trace = make_trace("poisson", n=64, seed=0)
        calib = Calibration()
        stats = trace_stats(trace)
        configs = [_sc("tensor"), _sc("phantom")]
        winner, priced = route(configs, calib, trace, slo_ms=200.0)
        path = str(tmp_path / "route.json")
        block = write_route_table(path, ARCH, winner, priced,
                                  calibration=calib.source,
                                  stats=stats, slo_ms=200.0)
        assert block["schema"] == "serve-route/v1"
        loaded = load_route_table(path)
        assert loaded == json.load(open(path))
        pre, dec, notes = plan_pools(ARCH, 8, calib, trace,
                                     slo_ms=200.0, route_table=loaded)
        assert notes["source"] == "route-table"
        assert notes["candidates"] == len(priced)
        assert pre.dp == 1 and dec.dp == 1

    def test_missing_route_table_is_none(self, tmp_path):
        assert load_route_table(str(tmp_path / "nope.json")) is None
        assert load_route_table("") is None

    def test_wrong_schema_fails_loudly(self, tmp_path):
        path = tmp_path / "bad.json"
        path.write_text('{"schema": "other/v9"}')
        with pytest.raises(ValueError, match="serve-route/v1"):
            load_route_table(str(path))

    def test_mismatched_arch_falls_back_to_pricing(self):
        trace = make_trace("poisson", n=64, seed=0)
        table = {"schema": "serve-route/v1", "arch": "other-model",
                 "candidates": [{"config": {}}]}
        _, _, notes = plan_pools(ARCH, 8, Calibration(), trace,
                                 route_table=table)
        assert notes["source"] == "priced"

    def test_baseline_config_is_full_node_tensor(self):
        sc = baseline_config(ARCH, 8)
        assert sc.impl == "tensor" and sc.dp == 1
        assert sc.tp in (8, 4, 2) and sc.devices == sc.tp

    def test_auto_rate_scales_with_replicas(self):
        calib = Calibration()
        r1 = auto_rate_rps(_sc(), calib, 14.0, replicas=1)
        r2 = auto_rate_rps(_sc(), calib, 14.0, replicas=2)
        assert r1 > 0
        assert r2 == pytest.approx(2 * r1)


# ---------------------------------------------------------------------------
# modeled DES replay
# ---------------------------------------------------------------------------

class TestModeledFleet:
    @pytest.fixture(scope="class")
    def run(self):
        trace, calib = _overload_trace()
        router = FleetRouter(_fleet_fc(), calib=calib)
        return router, router.run(trace), trace

    def test_completes_all_admitted(self, run):
        _, rep, trace = run
        req = rep["requests"]
        assert rep["mode"] == "modeled"
        assert req["trace"] == len(trace)
        assert req["finished"] == req["trace"] - req["rejected"]
        assert rep["slo"]["generated_tokens"] > 0

    def test_scales_up_and_down(self, run):
        _, rep, _ = run
        assert rep["scale_ups"] >= 1
        assert rep["scale_downs"] >= 1
        assert rep["pools"]["decode"]["replicas_peak"] >= 2
        for ev in rep["scale_events"]:
            assert ev["pool"] in ("prefill", "decode")
            assert ev["action"] in ("up", "down")

    def test_transfer_wire_band(self, run):
        _, rep, _ = run
        x = rep["transfer"]
        assert x["measured"]["migrations"] > 0
        assert 0.9 <= x["ratio_wire_bytes"] <= 1.1
        assert x["ratio_migrations"] == pytest.approx(1.0)

    def test_idle_static_power_billed(self, run):
        """Every powered device-second not spent stepping is billed at
        B watts — what makes over-provisioning visible in J/token."""
        _, rep, _ = run
        for phase in ("prefill", "decode"):
            p = rep["pools"][phase]
            assert p["device_s"] > 0
            assert p["idle_j"] >= 0
            assert p["j_per_token"] > 0
        j = rep["j_per_token"]
        assert j["fleet"] == pytest.approx(
            j["prefill"] + j["decode"] + j["transfer"])

    def test_deterministic_replay(self):
        trace, calib = _overload_trace(n=1500)
        a = FleetRouter(_fleet_fc(), calib=calib).run(trace)
        b = FleetRouter(_fleet_fc(), calib=calib).run(trace)
        assert json.dumps(a, sort_keys=True) == \
            json.dumps(b, sort_keys=True)

    def test_oversize_requests_rejected(self):
        trace = make_trace("poisson", n=32, prompt_len_range=(60, 80),
                           new_tokens_range=(8, 16), seed=1)
        calib = Calibration()
        rep = FleetRouter(_fleet_fc(), calib=calib).run(trace)
        # padded prompt + new tokens can't fit max_len=64
        assert rep["requests"]["rejected"] > 0


# ---------------------------------------------------------------------------
# colocated single-engine baseline
# ---------------------------------------------------------------------------

class TestColocatedBaseline:
    @pytest.fixture(scope="class")
    def run(self):
        trace, calib = _overload_trace(n=1500)
        fc = FleetConfig(prefill=baseline_config(ARCH, 8),
                         decode=baseline_config(ARCH, 8),
                         slo_ms=200.0, colocated=True,
                         decode_replicas=1)
        return FleetRouter(fc, calib=calib).run(trace)

    def test_transfer_is_free(self, run):
        """Colocated hand-offs are slot splices, not wire events: they
        are counted but carry zero bytes and zero joules."""
        x = run["transfer"]
        assert x["measured"]["migrations"] > 0
        assert x["measured"]["transfer_wire_bytes"] == 0
        assert x["measured"]["energy_j"] == 0.0
        assert run["j_per_token"]["transfer"] == 0.0

    def test_never_scales(self, run):
        assert run["scale_events"] == []
        assert run["pools"]["decode"]["replicas_peak"] == 1

    def test_prefill_runs_on_decode_replicas(self, run):
        pre = run["pools"]["prefill"]
        assert pre["replicas_final"] == 0      # counters only
        assert pre["steps"] > 0                # ...but work was billed
        assert pre["device_s"] == 0.0          # no devices of its own

    def test_executed_colocated_unsupported(self):
        fc = FleetConfig(prefill=_sc(), decode=_sc(), executed=True,
                         colocated=True)
        with pytest.raises(NotImplementedError):
            FleetRouter(fc, calib=Calibration())


# ---------------------------------------------------------------------------
# executed mode: real engines, token parity with a plain ServeEngine
# ---------------------------------------------------------------------------

def test_executed_fleet_matches_single_engine_tokens():
    """The fleet's prefill -> migrate -> adopt -> decode path must emit
    EXACTLY the tokens a plain ServeEngine replay of the same trace
    produces (greedy, same params seed): migration moves KV pages, it
    must not change a single logit."""
    from repro.launch.mesh import make_local_mesh
    from repro.models.model import model_decls
    from repro.parallel.axes import MeshAxes
    from repro.parallel.params import materialize
    from repro.serve.engine import ServeEngine
    from repro.serve.traffic import replay, trace_requests

    sc = ServeConfig(ARCH, "tensor", dp=1, tp=2, slots=4, max_len=64)
    trace = make_trace("poisson", n=8, rate_rps=50.0,
                       prompt_len_range=(4, 24),
                       new_tokens_range=(3, 8), seed=0)
    calib = Calibration()

    fc = FleetConfig(prefill=sc, decode=sc, slo_ms=200.0, executed=True,
                     prefill_replicas=1, decode_replicas=1,
                     prefill_policy=AutoscalePolicy(min_replicas=1,
                                                    max_replicas=1),
                     decode_policy=AutoscalePolicy(min_replicas=1,
                                                   max_replicas=1))
    router = FleetRouter(fc, calib=calib, seed=0)
    rep = router.run(trace)
    assert rep["mode"] == "executed"
    assert rep["requests"]["finished"] == len(trace)
    assert 0.9 <= rep["transfer"]["ratio_wire_bytes"] <= 1.1

    # reference: the SAME trace through one plain ServeEngine with the
    # same params seed — greedy decode must match stream-for-stream
    cfg = sc.model_config()
    mesh = make_local_mesh(sc.dp, sc.tp)
    params = materialize(
        model_decls(cfg, MeshAxes.from_mesh(mesh)), 0)
    eng = ServeEngine(cfg, mesh, params, slots=sc.slots,
                      max_len=sc.max_len, page_size=sc.page_size)
    ref_reqs = trace_requests(trace, cfg.vocab_size, seed=0)
    replay(eng, ref_reqs)

    fleet_toks = {r.req_id: list(r.out_tokens)
                  for r in router.finished}
    ref_toks = {r.req_id: list(r.out_tokens) for r in ref_reqs}
    assert fleet_toks == ref_toks
