"""Mamba2 SSD: chunked algorithm vs naive recurrence, decode vs chunked,
chunk-size invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import _ssd_chunked, _ssd_decode_step
from helpers import allclose, rand


def _naive_ssd(x, dt, A, Bm, Cm):
    """Direct per-step recurrence: s_t = exp(dt A) s + dt B (x) x."""
    B_, S, H, hd = x.shape
    N = Bm.shape[-1]
    s = jnp.zeros((B_, H, hd, N))
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A[None, :])                 # [B,H]
        dBx = jnp.einsum("bh,bm,bhp->bhpm", dt[:, t], Bm[:, t], x[:, t])
        s = s * dA[:, :, None, None] + dBx
        ys.append(jnp.einsum("bm,bhpm->bhp", Cm[:, t], s))
    return jnp.stack(ys, 1), s


def _inputs(seed, B=2, S=32, H=4, hd=8, N=16):
    x = rand(seed, (B, S, H, hd), scale=0.5)
    dt = jax.nn.softplus(rand(seed + 1, (B, S, H)))
    A = -jnp.exp(rand(seed + 2, (H,), scale=0.3))
    Bm = rand(seed + 3, (B, S, N), scale=0.5)
    Cm = rand(seed + 4, (B, S, N), scale=0.5)
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_chunked_matches_naive(chunk):
    x, dt, A, Bm, Cm = _inputs(0)
    y_ref, s_ref = _naive_ssd(x, dt, A, Bm, Cm)
    y, s = _ssd_chunked(x, dt, A, Bm, Cm, chunk)
    allclose(y, y_ref, rtol=2e-3, atol=2e-4, msg=f"chunk={chunk}")
    allclose(s, s_ref, rtol=2e-3, atol=2e-4)


def test_chunk_size_invariance():
    x, dt, A, Bm, Cm = _inputs(5)
    y1, s1 = _ssd_chunked(x, dt, A, Bm, Cm, 4)
    y2, s2 = _ssd_chunked(x, dt, A, Bm, Cm, 16)
    allclose(y1, y2, rtol=1e-4)
    allclose(s1, s2, rtol=1e-4)


def test_decode_continues_chunked():
    """state from chunked prefill + decode step == chunked over S+1."""
    x, dt, A, Bm, Cm = _inputs(9, S=33)
    y_all, s_all = _ssd_chunked(x[:, :32], dt[:, :32], A, Bm[:, :32],
                                Cm[:, :32], 8)
    y_dec, s_dec = _ssd_decode_step(s_all, x[:, 32], dt[:, 32], A,
                                    Bm[:, 32], Cm[:, 32])
    y_ref, s_ref = _naive_ssd(x, dt, A, Bm, Cm)
    allclose(y_dec, y_ref[:, 32], rtol=3e-3, atol=3e-4)
    allclose(s_dec, s_ref, rtol=3e-3, atol=3e-4)


def test_initial_state_threading():
    x, dt, A, Bm, Cm = _inputs(13, S=32)
    _, s_half = _ssd_chunked(x[:, :16], dt[:, :16], A, Bm[:, :16],
                             Cm[:, :16], 8)
    y2, s_full = _ssd_chunked(x[:, 16:], dt[:, 16:], A, Bm[:, 16:],
                              Cm[:, 16:], 8, initial_state=s_half)
    y_ref, s_ref = _naive_ssd(x, dt, A, Bm, Cm)
    allclose(y2, y_ref[:, 16:], rtol=3e-3, atol=3e-4)
    allclose(s_full, s_ref, rtol=3e-3, atol=3e-4)
