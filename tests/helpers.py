"""Shared test helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel.axes import MeshAxes, resolve_spec
from repro.parallel.params import specs
from repro.parallel.compat import shard_map


def smap(fn, mesh, in_specs, out_specs):
    return jax.jit(shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False))


def resolved_param_specs(decls, mesh):
    axes = MeshAxes.from_mesh(mesh)
    return jax.tree.map(lambda s: resolve_spec(s, axes), specs(decls))


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return jax.random.normal(jax.random.key(key), shape, dtype) * scale


def allclose(a, b, rtol=2e-4, atol=2e-4, msg=""):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=rtol, atol=atol, err_msg=msg)


def make_batch(cfg, B, S, seed=0):
    """LM batch + modality stubs for any arch family."""
    from repro.data.synthetic import LMDataset
    from repro.models.model import n_vision_tokens
    ds = LMDataset(cfg.vocab_size, B, S + 1, seed=seed)
    batch = dict(ds(0))
    rng = np.random.RandomState(seed)
    if cfg.family == "encdec":
        batch["frames"] = rng.randn(B, S, cfg.d_model).astype(np.float32)
    if cfg.frontend == "vision":
        nv = n_vision_tokens(cfg, S)
        batch["vision_embeds"] = rng.randn(B, nv, cfg.d_model).astype(
            np.float32)
    if cfg.rope == "mrope":
        pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S)).copy()
        batch["positions"] = np.stack([pos, pos, pos])
    return batch


# ---------------------------------------------------------------------------
# pipeline parallelism (shared by test_pipeline.py and the hypothesis suite)
# ---------------------------------------------------------------------------

def pipeline_cfg(kind: str, k: int, M: int, stages: int, n: int = 32,
                 layers=None):
    """A paper-FFN config cut into ``stages`` pipeline stages: homogeneous
    tensor/phantom, or mixed (alternating per-stage specs)."""
    from repro.configs.base import (ModelConfig, PhantomConfig,
                                    PipelineConfig, ProjectionSpec)
    if kind == "mixed":
        pipe = PipelineConfig(stages=stages, stage_specs=tuple(
            ProjectionSpec(kind="phantom", k=k) if s % 2
            else ProjectionSpec(kind="tensor") for s in range(stages)))
    else:
        pipe = PipelineConfig(stages=stages)
    L = layers or stages
    return ModelConfig(
        name=f"pipe-{kind}-k{k}-m{M}-s{stages}-n{n}-L{L}", family="ffn",
        num_layers=L, d_model=n, ffn_width=n, ffn_depth=L,
        ffn_impl="phantom" if kind == "phantom" else "dense", mlp="relu",
        phantom=PhantomConfig(k=k), pipeline=pipe, microbatches=M)


def assert_pipeline_equivalence(cache, mesh_pp, mesh_ref, kind, k, M,
                                stages, seed, batch=8):
    """Loss AND grads (params + input) of the 1F1B wavefront on
    ``mesh_pp`` must match the sequential reference on ``mesh_ref``
    within float-reassociation tolerance."""
    from repro.parallel.params import materialize
    from repro.telemetry.probe import make_ffn_pipeline_probe_step

    cfg = pipeline_cfg(kind, k, M, stages)
    fn_pp, decls = cache.build(make_ffn_pipeline_probe_step, cfg,
                               mesh_pp, batch)
    fn_ref, decls_ref = cache.build(make_ffn_pipeline_probe_step, cfg,
                                    mesh_ref, batch)
    assert jax.tree.structure(decls) == jax.tree.structure(decls_ref)

    params = materialize(decls, seed % 7)
    kx, ky = jax.random.split(jax.random.key(seed))
    x = jax.random.normal(kx, (batch, cfg.ffn_width), jnp.float32)
    y = jax.random.normal(ky, (batch, cfg.ffn_width), jnp.float32)

    loss_pp, (gp_pp, gx_pp) = fn_pp(params, x, y)
    loss_ref, (gp_ref, gx_ref) = fn_ref(params, x, y)
    np.testing.assert_allclose(float(loss_pp), float(loss_ref), rtol=2e-4)
    for a, b in zip(jax.tree.leaves(gp_pp), jax.tree.leaves(gp_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(gx_pp), np.asarray(gx_ref),
                               rtol=5e-4, atol=1e-6)
