"""Shared test helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel.axes import MeshAxes, resolve_spec
from repro.parallel.params import specs
from repro.parallel.compat import shard_map


def smap(fn, mesh, in_specs, out_specs):
    return jax.jit(shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False))


def resolved_param_specs(decls, mesh):
    axes = MeshAxes.from_mesh(mesh)
    return jax.tree.map(lambda s: resolve_spec(s, axes), specs(decls))


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return jax.random.normal(jax.random.key(key), shape, dtype) * scale


def allclose(a, b, rtol=2e-4, atol=2e-4, msg=""):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=rtol, atol=atol, err_msg=msg)


def make_batch(cfg, B, S, seed=0):
    """LM batch + modality stubs for any arch family."""
    from repro.data.synthetic import LMDataset
    from repro.models.model import n_vision_tokens
    ds = LMDataset(cfg.vocab_size, B, S + 1, seed=seed)
    batch = dict(ds(0))
    rng = np.random.RandomState(seed)
    if cfg.family == "encdec":
        batch["frames"] = rng.randn(B, S, cfg.d_model).astype(np.float32)
    if cfg.frontend == "vision":
        nv = n_vision_tokens(cfg, S)
        batch["vision_embeds"] = rng.randn(B, nv, cfg.d_model).astype(
            np.float32)
    if cfg.rope == "mrope":
        pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S)).copy()
        batch["positions"] = np.stack([pos, pos, pos])
    return batch
